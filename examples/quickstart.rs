//! Quickstart: generate accelerator designs hitting a target runtime.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use diffaxe::dse;
use diffaxe::models::DiffAxE;
use diffaxe::workload::Gemm;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    anyhow::ensure!(
        DiffAxE::artifacts_present(dir),
        "artifacts/ missing — run `make artifacts` first"
    );
    println!("loading + compiling AOT artifacts (one-time cost)...");
    let engine = DiffAxE::load(dir)?;
    println!(
        "ready: scale={} T={} diffusion-batch={}",
        engine.stats.scale, engine.stats.t_steps, engine.stats.gen_batch
    );

    // BERT-base QKV projection at sequence length 128
    let g = Gemm::new(128, 768, 2304);
    let st = engine.stats.stats_for(&g);
    let (lo, hi) = st.runtime_range();
    let target = (lo.ln() * 0.5 + hi.ln() * 0.5).exp(); // mid-range target
    println!("\nworkload {g}: asking for designs with runtime ~{target:.0} cycles");

    let p = st.norm_runtime(target);
    let conds: Vec<(f32, [f32; 3])> = (0..16).map(|_| (p, g.norm_vec())).collect();
    let t = std::time::Instant::now();
    let designs = engine.sample_runtime(7, &conds)?;
    let dt = t.elapsed().as_secs_f64();

    println!("generated {} designs in {:.0} ms ({:.1} ms each):\n", designs.len(),
             dt * 1e3, dt * 1e3 / designs.len() as f64);
    println!("{:<52} {:>12} {:>9} {:>8}", "design", "cycles", "err", "power");
    let mut errs = Vec::new();
    for hw in &designs {
        let (s, e) = dse::evaluate(hw, &g);
        let err = (s.cycles as f64 - target) / target;
        errs.push(err.abs());
        println!(
            "{:<52} {:>12} {:>8.1}% {:>7.2}W",
            hw.to_string(),
            s.cycles,
            err * 100.0,
            e.power_w
        );
    }
    println!(
        "\nmean |error| {:.1}% across {} generated designs",
        100.0 * errs.iter().sum::<f64>() / errs.len() as f64,
        errs.len()
    );
    Ok(())
}
