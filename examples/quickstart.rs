//! Quickstart: generate accelerator designs hitting a target runtime
//! through the unified DSE API (`Session` + `Objective` + `Optimizer`).
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use diffaxe::dse::{Budget, Objective, OptimizerKind, Session};
use diffaxe::models::DiffAxE;
use diffaxe::workload::Gemm;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    anyhow::ensure!(
        DiffAxE::artifacts_present(dir),
        "artifacts/ missing — run `make artifacts` first"
    );
    println!("loading + compiling AOT artifacts (one-time cost)...");
    let mut session = Session::load(dir)?;
    let stats = session.engine().unwrap().stats.clone();
    println!(
        "ready: scale={} T={} diffusion-batch={}",
        stats.scale, stats.t_steps, stats.gen_batch
    );

    // BERT-base QKV projection at sequence length 128
    let g = Gemm::new(128, 768, 2304);
    let st = stats.stats_for(&g);
    let (lo, hi) = st.runtime_range();
    let target = (lo.ln() * 0.5 + hi.ln() * 0.5).exp(); // mid-range target
    println!("\nworkload {g}: asking for designs with runtime ~{target:.0} cycles");

    let objective = Objective::Runtime { g, target_cycles: target };
    let outcome =
        session.search(OptimizerKind::DiffAxE, &objective, &Budget::evals(16), 7)?;

    println!(
        "generated {} designs in {:.0} ms ({:.1} ms each), ranked best-first:\n",
        outcome.evals,
        outcome.search_time_s * 1e3,
        outcome.search_time_s * 1e3 / outcome.evals.max(1) as f64
    );
    println!("{:<52} {:>12} {:>9} {:>8}", "design", "cycles", "err", "power");
    for d in &outcome.ranked {
        let err = (d.cycles - target) / target;
        println!(
            "{:<52} {:>12} {:>8.1}% {:>7.2}W",
            d.hw.to_string(),
            d.cycles as u64,
            err * 100.0,
            d.power_w
        );
    }
    println!(
        "\nmean |error| {:.1}% across {} generated designs; best {:.1}%",
        100.0 * outcome.mean_score(),
        outcome.evals,
        100.0 * outcome.best_score()
    );

    // the same session serves every other strategy; one-liner baseline:
    let random = session.search(
        OptimizerKind::RandomSearch,
        &Objective::MinEdp { g },
        &Budget::evals(256),
        7,
    )?;
    println!(
        "bonus: random-search min-EDP over 256 samples: {} edp={:.3e}",
        random.best().unwrap().hw,
        random.best().unwrap().edp
    );
    Ok(())
}
