//! LLM accelerator co-design (paper §VI): generate a specialized design for
//! each (model, stage) pair — the heterogeneous-chiplet scenario where
//! prefill and decode get different accelerators — and compare EDP against
//! NVDLA and a DOSA-style optimizer.
//!
//! ```bash
//! cargo run --release --example llm_codesign -- --model bert-base
//! ```

use diffaxe::baselines::FixedArch;
use diffaxe::dse::llm::{diffaxe_llm, dosa_llm, fixed_llm, Platform};
use diffaxe::models::DiffAxE;
use diffaxe::util::table::{fnum, Table};
use diffaxe::workload::{llm::DEFAULT_SEQ, LlmModel, Stage};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    anyhow::ensure!(
        DiffAxE::artifacts_present(Path::new("artifacts")),
        "artifacts/ missing — run `make artifacts` first"
    );
    let engine = DiffAxE::load(Path::new("artifacts"))?;

    let args: Vec<String> = std::env::args().collect();
    let model = match args.iter().position(|a| a == "--model").and_then(|i| args.get(i + 1)) {
        Some(s) if s == "opt-350m" => LlmModel::Opt350m,
        Some(s) if s == "llama-2-7b" => LlmModel::Llama2_7b,
        _ => LlmModel::BertBase,
    };
    println!("co-designing accelerators for {} (seq {DEFAULT_SEQ}, 32nm ASIC)\n", model.name());

    let mut t = Table::new(&["stage", "design", "per-layer orders", "cycles", "EDP (uJ-cyc)", "vs NVDLA", "vs DOSA"]);
    for stage in Stage::ALL {
        let (ours, secs) =
            diffaxe_llm(&engine, model, stage, DEFAULT_SEQ, 32, Platform::Asic32nm, 42)?;
        let (dosa, _) = dosa_llm(model, stage, DEFAULT_SEQ, Platform::Asic32nm, 17);
        let nvdla = fixed_llm(FixedArch::Nvdla, model, stage, DEFAULT_SEQ, Platform::Asic32nm);
        let orders: Vec<&str> = ours.cfg.orders.iter().map(|o| o.name()).collect();
        t.row(&[
            format!("{} ({secs:.1}s search)", stage.name()),
            ours.cfg.base.to_string(),
            orders.join(","),
            fnum(ours.sim.cycles as f64),
            fnum(ours.energy.edp),
            format!("{:.2}x", nvdla.energy.edp / ours.energy.edp),
            format!("{:.2}x", dosa.energy.edp / ours.energy.edp),
        ]);
    }
    println!("{}", t.render());
    println!(
        "paper §VI narrative to verify: prefill favors big arrays + large operand buffers; \
         decode (M=1) favors small R to avoid the (R-M) drain overhead."
    );
    Ok(())
}
