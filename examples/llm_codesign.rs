//! LLM accelerator co-design (paper §VI): generate a specialized design for
//! each (model, stage) pair — the heterogeneous-chiplet scenario where
//! prefill and decode get different accelerators — and compare EDP against
//! NVDLA and a DOSA-style optimizer, all three strategies through the same
//! `Optimizer` interface.
//!
//! ```bash
//! cargo run --release --example llm_codesign -- --model bert-base
//! ```

use diffaxe::baselines::{FixedArch, GdOptions};
use diffaxe::dse::llm::{eval_model, Platform};
use diffaxe::dse::{Budget, Objective, OptimizerKind, Session};
use diffaxe::models::DiffAxE;
use diffaxe::util::table::{fnum, Table};
use diffaxe::workload::{llm::DEFAULT_SEQ, LlmModel, Stage};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    anyhow::ensure!(
        DiffAxE::artifacts_present(Path::new("artifacts")),
        "artifacts/ missing — run `make artifacts` first"
    );
    let mut session = Session::load(Path::new("artifacts"))?;
    session.gd_opts = GdOptions { steps: 30, restarts: 3, ..Default::default() };

    let args: Vec<String> = std::env::args().collect();
    let model = args
        .iter()
        .position(|a| a == "--model")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| LlmModel::from_name(s))
        .unwrap_or(LlmModel::BertBase);
    println!("co-designing accelerators for {} (seq {DEFAULT_SEQ}, 32nm ASIC)\n", model.name());

    let platform = Platform::Asic32nm;
    let mut t = Table::new(&["stage", "design", "per-layer orders", "cycles", "EDP (uJ-cyc)", "vs NVDLA", "vs DOSA"]);
    for stage in Stage::ALL {
        let obj = Objective::LlmEdp { model, stage, seq: DEFAULT_SEQ, platform };
        let ours = session.search(
            OptimizerKind::DiffAxE,
            &obj,
            &Budget::default().with_per_class(32),
            42,
        )?;
        let dosa = session.search(OptimizerKind::DosaGd, &obj, &Budget::evals(1600), 17)?;
        let nvdla = session.search(
            OptimizerKind::Fixed(FixedArch::Nvdla),
            &obj,
            &Budget::evals(1),
            0,
        )?;
        // re-derive the winning sequence config for its per-layer orders
        let best = eval_model(&ours.best().unwrap().hw, model, stage, DEFAULT_SEQ, platform);
        let orders: Vec<&str> = best.cfg.orders.iter().map(|o| o.name()).collect();
        t.row(&[
            format!("{} ({:.1}s search)", stage.name(), ours.search_time_s),
            best.cfg.base.to_string(),
            orders.join(","),
            fnum(best.sim.cycles as f64),
            fnum(best.energy.edp),
            format!("{:.2}x", nvdla.best().unwrap().edp / best.energy.edp),
            format!("{:.2}x", dosa.best().unwrap().edp / best.energy.edp),
        ]);
    }
    println!("{}", t.render());
    println!(
        "paper §VI narrative to verify: prefill favors big arrays + large operand buffers; \
         decode (M=1) favors small R to avoid the (R-M) cycle drain overhead."
    );
    Ok(())
}
