//! The coordinator as a *service*: start the engine thread + TCP front end,
//! drive it over the wire with mixed concurrent requests, and print the
//! service metrics (batch occupancy, latencies).
//!
//! ```bash
//! cargo run --release --example dse_service            # self-driving demo
//! cargo run --release --example dse_service -- --serve 127.0.0.1:7979
//! ```
//!
//! Wire protocol: one JSON object per line, e.g.
//! `{"type":"generate","m":128,"k":768,"n":2304,"target_cycles":1e6,"count":8}`.

use diffaxe::coordinator::{server, Request, Response, Service, ServiceConfig};
use diffaxe::models::DiffAxE;
use diffaxe::workload::{Gemm, LlmModel, Stage};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    anyhow::ensure!(
        DiffAxE::artifacts_present(Path::new("artifacts")),
        "artifacts/ missing — run `make artifacts` first"
    );
    let svc = Service::start(ServiceConfig::new("artifacts"))?;

    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--serve") {
        let addr = args.get(i + 1).map(|s| s.as_str()).unwrap_or("127.0.0.1:7979");
        return server::serve(svc.handle(), addr);
    }

    // demo mode: ephemeral server + a burst of concurrent clients
    let addr = server::serve_ephemeral(svc.handle())?;
    println!("service listening on {addr}; sending a mixed burst over TCP\n");

    let mut handles = Vec::new();
    for i in 0..4u32 {
        let addr = addr;
        handles.push(std::thread::spawn(move || -> anyhow::Result<String> {
            let mut client = server::Client::connect(&addr)?;
            let g = Gemm::new(128, 768, 2304);
            let resp = client.request(&Request::GenerateRuntime {
                g,
                target_cycles: 4e5 * (i + 1) as f64,
                n: 8,
            })?;
            Ok(match resp {
                Response::Designs(d) => {
                    format!("client {i}: {} designs, best |err| cycles={:.0}", d.len(),
                            d.iter().map(|x| x.cycles).fold(f64::MAX, f64::min))
                }
                other => format!("client {i}: {other:?}"),
            })
        }));
    }
    for h in handles {
        println!("{}", h.join().unwrap()?);
    }

    // one EDP search and one LLM co-design over the same wire
    let mut client = server::Client::connect(&addr)?;
    if let Response::Designs(d) =
        client.request(&Request::EdpSearch { g: Gemm::new(128, 4096, 8192), n_per_class: 8 })?
    {
        println!("EDP search best: {} edp={:.3e}", d[0].hw, d[0].edp);
    }
    if let Response::Designs(d) = client.request(&Request::LlmSearch {
        model: LlmModel::Opt350m,
        stage: Stage::Decode,
        n_per_layer: 8,
    })? {
        println!("OPT-350M decode co-design: {} edp={:.3e}", d[0].hw, d[0].edp);
    }
    if let Response::MetricsText(m) = client.request(&Request::Metrics)? {
        println!("\nservice metrics: {m}");
    }
    Ok(())
}
