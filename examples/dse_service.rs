//! The coordinator as a *service*: start the engine thread + TCP front end,
//! drive it over the wire with mixed concurrent requests — generic
//! `search` requests, a multi-search `batch`, a deprecated v1 alias line,
//! and the v3 job lifecycle (submit → watch progress events → cancel) —
//! and print the service metrics (batch occupancy, job gauges, latencies).
//!
//! ```bash
//! cargo run --release --example dse_service            # self-driving demo
//! cargo run --release --example dse_service -- --serve 127.0.0.1:7979
//! ```
//!
//! Wire protocol: one JSON object per line, e.g.
//! `{"v":2,"type":"search","objective":{"kind":"runtime","m":128,"k":768,
//! "n":2304,"target_cycles":1e6},"budget":{"evals":8},"optimizer":"diffaxe"}`.

use diffaxe::coordinator::{server, Request, Response, SearchRequest, Service, ServiceConfig};
use diffaxe::dse::llm::Platform;
use diffaxe::dse::{Budget, Objective, OptimizerKind};
use diffaxe::models::DiffAxE;
use diffaxe::workload::{llm::DEFAULT_SEQ, Gemm, LlmModel, Stage};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    anyhow::ensure!(
        DiffAxE::artifacts_present(Path::new("artifacts")),
        "artifacts/ missing — run `make artifacts` first"
    );
    let svc = Service::start(ServiceConfig::new("artifacts"))?;

    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--serve") {
        let addr = args.get(i + 1).map(|s| s.as_str()).unwrap_or("127.0.0.1:7979");
        return server::serve(svc.handle(), addr);
    }

    // demo mode: ephemeral server + a burst of concurrent clients
    let addr = server::serve_ephemeral(svc.handle())?;
    println!("service listening on {addr}; sending a mixed burst over TCP\n");

    let mut handles = Vec::new();
    for i in 0..4u32 {
        let addr = addr;
        handles.push(std::thread::spawn(move || -> anyhow::Result<String> {
            let mut client = server::Client::connect(&addr)?;
            let g = Gemm::new(128, 768, 2304);
            let target = 4e5 * (i + 1) as f64;
            let resp = client.request(&Request::Search(SearchRequest::new(
                Objective::Runtime { g, target_cycles: target },
                Budget::evals(8),
                OptimizerKind::DiffAxE,
            )))?;
            Ok(match resp {
                Response::Outcome(o) => format!(
                    "client {i}: {} designs, best |err| {:.1}%",
                    o.evals,
                    100.0 * o.best_score()
                ),
                other => format!("client {i}: {other:?}"),
            })
        }));
    }
    for h in handles {
        println!("{}", h.join().unwrap()?);
    }

    let mut client = server::Client::connect(&addr)?;

    // one EDP search and one LLM co-design over the same wire — any
    // optimizer is selectable by name, not just the diffusion engine
    let g = Gemm::new(128, 4096, 8192);
    if let Response::Outcome(o) = client.request(&Request::Search(SearchRequest::new(
        Objective::MinEdp { g },
        Budget::default().with_per_class(8),
        OptimizerKind::DiffAxE,
    )))? {
        let d = o.best().unwrap();
        println!("EDP search best: {} edp={:.3e}", d.hw, d.edp);
    }
    if let Response::Outcome(o) = client.request(&Request::Search(SearchRequest::new(
        Objective::LlmEdp {
            model: LlmModel::Opt350m,
            stage: Stage::Decode,
            seq: DEFAULT_SEQ,
            platform: Platform::Asic32nm,
        },
        Budget::default().with_per_class(8),
        OptimizerKind::DiffAxE,
    )))? {
        let d = o.best().unwrap();
        println!("OPT-350M decode co-design: {} edp={:.3e}", d.hw, d.edp);
    }

    // a batch request: three strategies on one workload, one round-trip
    let batch = Request::Batch(vec![
        SearchRequest::new(Objective::MinEdp { g }, Budget::evals(64), OptimizerKind::RandomSearch),
        SearchRequest::new(Objective::MinEdp { g }, Budget::evals(64), OptimizerKind::VanillaBo),
        SearchRequest::new(
            Objective::MinEdp { g },
            Budget::evals(1),
            OptimizerKind::parse("fixed-nvdla").unwrap(),
        ),
    ]);
    if let Response::Batch(outs) = client.request(&batch)? {
        for o in &outs {
            println!(
                "batch: {:<16} best edp={:.3e} ({} evals, {:.2}s)",
                o.optimizer,
                o.best().unwrap().edp,
                o.evals,
                o.search_time_s
            );
        }
    }

    // deprecated v1 alias lines still parse (compatibility shim)
    if let Response::Outcome(o) = client.send_line(
        r#"{"type":"generate","m":128,"k":768,"n":2304,"target_cycles":1e6,"count":4}"#,
    )? {
        println!("legacy v1 'generate' alias: {} designs", o.evals);
    }

    // v3 jobs: a slow search as a first-class job — submit returns
    // immediately, watch streams coalesced progress, cancel keeps the
    // partial outcome
    let job_id = client.submit(&SearchRequest::new(
        Objective::MinEdp { g },
        Budget::evals(2_000_000),
        OptimizerKind::RandomSearch,
    ))?;
    println!("\nsubmitted {job_id}: {:?}", client.status(&job_id)?.state);
    std::thread::sleep(std::time::Duration::from_millis(100));
    client.cancel(&job_id)?;
    let mut events = 0;
    let terminal = client.watch(&job_id, |ev| {
        events += 1;
        println!("  event: evals={} elapsed={:.2}s", ev.evals, ev.elapsed_s);
    })?;
    if let Response::JobOutcome { outcome, .. } = terminal {
        println!(
            "cancelled after {} evals ({} events, stopped={}), best edp={:.3e}",
            outcome.evals,
            events,
            outcome.stopped.name(),
            outcome.best().map(|d| d.edp).unwrap_or(f64::NAN)
        );
    }

    if let Response::MetricsText(m) = client.request(&Request::Metrics)? {
        println!("\nservice metrics: {m}");
    }
    Ok(())
}
