//! END-TO-END DRIVER — proves all three layers compose on a real workload
//! mix (recorded in EXPERIMENTS.md):
//!
//!   L3 rust coordinator (batching service, native simulator/energy models)
//!     → PJRT executables AOT-compiled from
//!   L2 JAX models (AE+PP + conditional DDPM)
//!     → whose denoiser layers are
//!   L1 Pallas kernels (interpret-mode, lowered into the same HLO).
//!
//! The driver starts the service, then plays a realistic co-design session
//! over the generic v2 protocol: (1) runtime-conditioned generation across
//! a batch of transformer-layer workloads at three target speeds each,
//! (2) an EDP search per workload, and (3) full-LLM co-design for
//! BERT/OPT/LLaMA prefill+decode with the NVDLA and DOSA baselines served
//! by the same wire request — reporting the paper's headline metrics:
//! generation error, ms/design, and EDP improvement over NVDLA and DOSA.

use diffaxe::baselines::FixedArch;
use diffaxe::coordinator::{Request, Response, SearchRequest, Service, ServiceConfig};
use diffaxe::dse::llm::Platform;
use diffaxe::dse::{Budget, Objective, OptimizerKind};
use diffaxe::models::DiffAxE;
use diffaxe::util::stats::{geomean, Timer};
use diffaxe::util::table::{fnum, Table};
use diffaxe::workload::{llm::DEFAULT_SEQ, Gemm, LlmModel, Stage};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    anyhow::ensure!(
        DiffAxE::artifacts_present(Path::new("artifacts")),
        "artifacts/ missing — run `make artifacts` first"
    );
    println!("=== end-to-end driver: DiffAxE DSE service on a real workload mix ===\n");
    let t_boot = Timer::start();
    let svc = Service::start(ServiceConfig::new("artifacts"))?;
    println!("service up in {:.1}s (artifact compile, one-time)\n", t_boot.elapsed_s());

    // --- phase 1: runtime-conditioned generation over transformer layers --
    let layers = [
        ("BERT QKV", Gemm::new(128, 768, 2304)),
        ("BERT FFN1", Gemm::new(128, 768, 3072)),
        ("OPT-350M FFN2", Gemm::new(128, 4096, 1024)),
        ("LLaMA-2 down-proj", Gemm::new(128, 4096, 4096)),
    ];
    // ask each layer for designs at three target speeds, concurrently — the
    // engine thread packs all of it into shared sampler batches
    let mut errs = Vec::new();
    let mut designs_total = 0usize;
    let t_gen = Timer::start();
    let mut rxs = Vec::new();
    for (_, g) in &layers {
        for speed in [3e5, 1e6, 5e6] {
            rxs.push((*g, svc.handle().submit(Request::Search(SearchRequest::new(
                Objective::Runtime { g: *g, target_cycles: speed },
                Budget::evals(16),
                OptimizerKind::DiffAxE,
            )))));
        }
    }
    for (g, rx) in rxs {
        match rx.recv()? {
            Response::Outcome(o) => {
                designs_total += o.evals;
                // the trace IS the per-design |error| under Objective::Runtime
                errs.extend(o.trace.iter().copied());
                for d in &o.ranked {
                    assert!(d.hw.in_target_space(), "invalid design for {g}");
                }
            }
            other => anyhow::bail!("unexpected {other:?}"),
        }
    }
    let gen_s = t_gen.elapsed_s();
    println!(
        "phase 1 — generation: {designs_total} designs across {} (workload,target) pairs \
         in {:.1}s => {:.2} ms/design; mean |error| {:.1}%",
        layers.len() * 3,
        gen_s,
        gen_s * 1e3 / designs_total as f64,
        100.0 * errs.iter().sum::<f64>() / errs.len() as f64
    );

    // --- phase 2: EDP search per layer ------------------------------------
    let mut edp_rows = Vec::new();
    for (name, g) in &layers {
        let resp = svc.handle().request(Request::Search(SearchRequest::new(
            Objective::MinEdp { g: *g },
            Budget::default().with_per_class(16),
            OptimizerKind::DiffAxE,
        )));
        if let Response::Outcome(o) = resp {
            edp_rows.push((*name, *o.best().unwrap()));
        }
    }
    let mut t = Table::new(&["layer", "best design (EDP search)", "cycles", "power", "EDP"]);
    for (name, d) in &edp_rows {
        t.row(&[
            name.to_string(),
            d.hw.to_string(),
            fnum(d.cycles),
            fnum(d.power_w),
            fnum(d.edp),
        ]);
    }
    println!("\nphase 2 — EDP search:\n{}", t.render());

    // --- phase 3: whole-LLM co-design, the paper's headline ---------------
    // every strategy goes over the same wire: one Batch request per
    // (model, stage) carries DiffAxE + the NVDLA and DOSA baselines
    let mut nvdla_ratios = Vec::new();
    let mut dosa_ratios = Vec::new();
    let mut t3 = Table::new(&["model", "stage", "DiffAxE EDP", "NVDLA/DiffAxE", "DOSA/DiffAxE"]);
    for model in LlmModel::ALL {
        for stage in Stage::ALL {
            let obj = Objective::LlmEdp { model, stage, seq: DEFAULT_SEQ, platform: Platform::Asic32nm };
            let resp = svc.handle().request(Request::Batch(vec![
                SearchRequest::new(obj, Budget::default().with_per_class(16), OptimizerKind::DiffAxE),
                SearchRequest::new(obj, Budget::evals(1), OptimizerKind::Fixed(FixedArch::Nvdla)),
                // ~1600 FD evaluations matches the pre-refactor DOSA
                // schedule (30 steps x 3 restarts, 17 evals/step)
                SearchRequest::new(obj, Budget::evals(1600), OptimizerKind::DosaGd),
            ]));
            let outs = match resp {
                Response::Batch(outs) => outs,
                other => anyhow::bail!("unexpected {other:?}"),
            };
            let (ours, nvdla, dosa) =
                (outs[0].best().unwrap(), outs[1].best().unwrap(), outs[2].best().unwrap());
            nvdla_ratios.push(nvdla.edp / ours.edp);
            dosa_ratios.push(dosa.edp / ours.edp);
            t3.row(&[
                model.name().to_string(),
                stage.name().to_string(),
                fnum(ours.edp),
                fnum(nvdla.edp / ours.edp),
                fnum(dosa.edp / ours.edp),
            ]);
        }
    }
    println!("phase 3 — LLM co-design (32nm ASIC):\n{}", t3.render());

    let snap = svc.handle().metrics().snapshot();
    println!("service metrics: {snap}\n");
    println!("=== headline metrics (record in EXPERIMENTS.md) ===");
    println!(
        "EDP improvement geo-mean: {:.2}x vs NVDLA (paper: up to 4.3x), {:.2}x vs DOSA \
         (paper: 3.37x avg); generation {:.2} ms/design (paper: 1.83 ms on V100); \
         mean generation |error| {:.1}% (paper: 5.45% at 46.7M-sample scale)",
        geomean(&nvdla_ratios),
        geomean(&dosa_ratios),
        gen_s * 1e3 / designs_total as f64,
        100.0 * errs.iter().sum::<f64>() / errs.len() as f64
    );
    Ok(())
}
