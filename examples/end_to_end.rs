//! END-TO-END DRIVER — proves all three layers compose on a real workload
//! mix (recorded in EXPERIMENTS.md):
//!
//!   L3 rust coordinator (batching service, native simulator/energy models)
//!     → PJRT executables AOT-compiled from
//!   L2 JAX models (AE+PP + conditional DDPM)
//!     → whose denoiser layers are
//!   L1 Pallas kernels (interpret-mode, lowered into the same HLO).
//!
//! The driver starts the service, then plays a realistic co-design session:
//! (1) runtime-conditioned generation across a batch of transformer-layer
//! workloads at three target speeds each, (2) an EDP search per workload,
//! and (3) full-LLM co-design for BERT/OPT/LLaMA prefill+decode — reporting
//! the paper's headline metrics: generation error, ms/design, and EDP
//! improvement over NVDLA and DOSA.

use diffaxe::baselines::FixedArch;
use diffaxe::coordinator::{Request, Response, Service, ServiceConfig};
use diffaxe::dse::llm::{dosa_llm, fixed_llm, Platform};
use diffaxe::models::DiffAxE;
use diffaxe::util::stats::{geomean, Timer};
use diffaxe::util::table::{fnum, Table};
use diffaxe::workload::{llm::DEFAULT_SEQ, Gemm, LlmModel, Stage};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    anyhow::ensure!(
        DiffAxE::artifacts_present(Path::new("artifacts")),
        "artifacts/ missing — run `make artifacts` first"
    );
    println!("=== end-to-end driver: DiffAxE DSE service on a real workload mix ===\n");
    let t_boot = Timer::start();
    let svc = Service::start(ServiceConfig::new("artifacts"))?;
    println!("service up in {:.1}s (artifact compile, one-time)\n", t_boot.elapsed_s());

    // --- phase 1: runtime-conditioned generation over transformer layers --
    let layers = [
        ("BERT QKV", Gemm::new(128, 768, 2304)),
        ("BERT FFN1", Gemm::new(128, 768, 3072)),
        ("OPT-350M FFN2", Gemm::new(128, 4096, 1024)),
        ("LLaMA-2 down-proj", Gemm::new(128, 4096, 4096)),
    ];
    // targets derived from request results themselves: ask for 3 speeds
    let mut errs = Vec::new();
    let mut designs_total = 0usize;
    let t_gen = Timer::start();
    let mut rxs = Vec::new();
    for (_, g) in &layers {
        for speed in [3e5, 1e6, 5e6] {
            rxs.push((*g, speed, svc.handle().submit(Request::GenerateRuntime {
                g: *g,
                target_cycles: speed,
                n: 16,
            })));
        }
    }
    for (g, target, rx) in rxs {
        match rx.recv()? {
            Response::Designs(ds) => {
                designs_total += ds.len();
                for d in &ds {
                    errs.push(((d.cycles - target) / target).abs());
                    assert!(d.hw.in_target_space(), "invalid design for {g}");
                }
            }
            other => anyhow::bail!("unexpected {other:?}"),
        }
    }
    let gen_s = t_gen.elapsed_s();
    println!(
        "phase 1 — generation: {designs_total} designs across {} (workload,target) pairs \
         in {:.1}s => {:.2} ms/design; mean |error| {:.1}%",
        layers.len() * 3,
        gen_s,
        gen_s * 1e3 / designs_total as f64,
        100.0 * errs.iter().sum::<f64>() / errs.len() as f64
    );

    // --- phase 2: EDP search per layer ------------------------------------
    let mut edp_rows = Vec::new();
    for (name, g) in &layers {
        let resp = svc.handle().request(Request::EdpSearch { g: *g, n_per_class: 16 });
        if let Response::Designs(ds) = resp {
            edp_rows.push((*name, ds[0].clone()));
        }
    }
    let mut t = Table::new(&["layer", "best design (EDP search)", "cycles", "power", "EDP"]);
    for (name, d) in &edp_rows {
        t.row(&[
            name.to_string(),
            d.hw.to_string(),
            fnum(d.cycles),
            fnum(d.power_w),
            fnum(d.edp),
        ]);
    }
    println!("\nphase 2 — EDP search:\n{}", t.render());

    // --- phase 3: whole-LLM co-design, the paper's headline ---------------
    let mut nvdla_ratios = Vec::new();
    let mut dosa_ratios = Vec::new();
    let mut t3 = Table::new(&["model", "stage", "DiffAxE EDP", "NVDLA/DiffAxE", "DOSA/DiffAxE"]);
    for model in LlmModel::ALL {
        for stage in Stage::ALL {
            let resp = svc.handle().request(Request::LlmSearch {
                model,
                stage,
                n_per_layer: 16,
            });
            let ours = match resp {
                Response::Designs(ds) => ds[0].clone(),
                other => anyhow::bail!("unexpected {other:?}"),
            };
            let nvdla =
                fixed_llm(FixedArch::Nvdla, model, stage, DEFAULT_SEQ, Platform::Asic32nm);
            let (dosa, _) = dosa_llm(model, stage, DEFAULT_SEQ, Platform::Asic32nm, 17);
            nvdla_ratios.push(nvdla.energy.edp / ours.edp);
            dosa_ratios.push(dosa.energy.edp / ours.edp);
            t3.row(&[
                model.name().to_string(),
                stage.name().to_string(),
                fnum(ours.edp),
                fnum(nvdla.energy.edp / ours.edp),
                fnum(dosa.energy.edp / ours.edp),
            ]);
        }
    }
    println!("phase 3 — LLM co-design (32nm ASIC):\n{}", t3.render());

    let snap = svc.handle().metrics().snapshot();
    println!("service metrics: {snap}\n");
    println!("=== headline metrics (record in EXPERIMENTS.md) ===");
    println!(
        "EDP improvement geo-mean: {:.2}x vs NVDLA (paper: up to 4.3x), {:.2}x vs DOSA \
         (paper: 3.37x avg); generation {:.2} ms/design (paper: 1.83 ms on V100); \
         mean generation |error| {:.1}% (paper: 5.45% at 46.7M-sample scale)",
        geomean(&nvdla_ratios),
        geomean(&dosa_ratios),
        gen_s * 1e3 / designs_total as f64,
        100.0 * errs.iter().sum::<f64>() / errs.len() as f64
    );
    Ok(())
}
