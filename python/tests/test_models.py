"""L2 model correctness: AE/PP architecture contract, DDPM schedule and
sampler invariants, baseline model shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import nn
from compile.models import ae, baselines, ddm


@pytest.fixture(scope="module")
def ae_params():
    return ae.init(jax.random.PRNGKey(0), n_p=1)


def test_ae_shapes_follow_paper(ae_params):
    # ENC: 14->512->256->128, DEC symmetric (paper §III-A)
    assert ae_params["enc"]["l0"]["w"].shape == (14, 512)
    assert ae_params["enc"]["l1"]["w"].shape == (512, 256)
    assert ae_params["enc"]["l2"]["w"].shape == (256, 128)
    assert ae_params["dec"]["l2"]["w"].shape == (512, 14)
    # PP workload branch: 3->256->256->128->1
    assert ae_params["pp_w"]["l0"]["w"].shape == (3, 256)
    assert ae_params["pp_w"]["l3"]["w"].shape == (128, 1)
    # loop-order embedding: 2 -> 8
    assert ae_params["emb1"]["w"].shape == (2, 8)


def test_encode_decode_shapes(ae_params):
    hw = jax.random.uniform(jax.random.PRNGKey(1), (32, 8))
    v = ae.encode(ae_params, hw)
    assert v.shape == (32, 128)
    rec = ae.decode(ae_params, v)
    assert rec.shape == (32, 8)
    pred = ae.predict(ae_params, v, jnp.zeros((32, 3)))
    assert pred.shape == (32, 1)


def test_ae_loss_decreases_under_training(ae_params):
    key = jax.random.PRNGKey(2)
    hw = jax.random.uniform(key, (256, 8))
    # make loop slots a proper one-hot
    hot = (hw[:, 6] > hw[:, 7]).astype(jnp.float32)
    hw = hw.at[:, 6].set(hot).at[:, 7].set(1.0 - hot)
    w = jax.random.uniform(key, (256, 3))
    t = jnp.sum(hw[:, :2], axis=1, keepdims=True)
    params = ae_params
    opt = nn.adamw_init(params)

    @jax.jit
    def step(params, opt):
        (l, _), g = jax.value_and_grad(ae.loss, has_aux=True)(params, hw, w, t)
        params, opt = nn.adamw_update(params, g, opt, 1e-3)
        return params, opt, l

    losses = []
    for _ in range(60):
        params, opt, l = step(params, opt)
        losses.append(float(l))
    assert losses[-1] < 0.5 * losses[0], f"{losses[0]} -> {losses[-1]}"


def test_schedule_invariants():
    for t_steps in [16, 100, 1000]:
        s = ddm.Schedule.linear(t_steps)
        ab = np.asarray(s.alpha_bars)
        assert len(ab) == t_steps
        assert np.all(np.diff(ab) < 0), "alpha_bar must be strictly decreasing"
        assert 0.0 < ab[-1] < ab[0] < 1.0
        assert np.all(np.asarray(s.betas) > 0)
        assert np.allclose(np.asarray(s.alphas), 1.0 - np.asarray(s.betas))


def test_ddm_apply_shape_and_conditioning():
    cfg = ddm.DdmConfig(hidden=64, t_steps=8)
    params = ddm.init(jax.random.PRNGKey(3), cfg)
    v = jax.random.normal(jax.random.PRNGKey(4), (8, 128))
    p1 = jnp.zeros((8, 1))
    p2 = jnp.ones((8, 1))
    w = jnp.zeros((8, 3))
    t = jnp.full((8,), 3.0)
    e1 = ddm.apply(params, cfg, v, t, p1, w)
    e2 = ddm.apply(params, cfg, v, t, p2, w)
    assert e1.shape == (8, 128)
    # conditioning must influence the prediction
    assert float(jnp.abs(e1 - e2).max()) > 1e-4


def test_ddm_class_conditioning_mode():
    cfg = ddm.DdmConfig(hidden=64, t_steps=8, n_classes=9)
    params = ddm.init(jax.random.PRNGKey(5), cfg)
    v = jax.random.normal(jax.random.PRNGKey(6), (4, 128))
    w = jnp.zeros((4, 3))
    t = jnp.full((4,), 2.0)
    ca = ddm.apply(params, cfg, v, t, jnp.array([0, 1, 2, 3]), w)
    cb = ddm.apply(params, cfg, v, t, jnp.array([8, 8, 8, 8]), w)
    assert ca.shape == (4, 128)
    assert float(jnp.abs(ca - cb).max()) > 1e-4


def test_sampler_noise_free_final_step():
    """Eq. 5: z = 0 at t=1 — sampling twice with the same key is
    deterministic, and the loop runs exactly T steps."""
    cfg = ddm.DdmConfig(hidden=32, t_steps=6)
    sched = ddm.Schedule.linear(cfg.t_steps)
    params = ddm.init(jax.random.PRNGKey(7), cfg)
    p = jnp.full((3, 1), 0.5)
    w = jnp.full((3, 3), 0.5)
    a = ddm.sample(params, cfg, sched, jax.random.PRNGKey(9), p, w, use_pallas=False)
    b = ddm.sample(params, cfg, sched, jax.random.PRNGKey(9), p, w, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = ddm.sample(params, cfg, sched, jax.random.PRNGKey(10), p, w, use_pallas=False)
    assert float(jnp.abs(a - c).max()) > 1e-3


def test_pallas_and_plain_denoiser_agree():
    cfg = ddm.DdmConfig(hidden=64, t_steps=4)
    params = ddm.init(jax.random.PRNGKey(11), cfg)
    v = jax.random.normal(jax.random.PRNGKey(12), (8, 128))
    p = jnp.full((8, 1), 0.3)
    w = jnp.full((8, 3), 0.7)
    t = jnp.full((8,), 1.0)
    a = ddm.apply(params, cfg, v, t, p, w, use_pallas=False)
    b = ddm.apply(params, cfg, v, t, p, w, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_latent_standardization_roundtrip():
    v = np.random.default_rng(0).normal(3.0, 0.2, (500, 128)).astype(np.float32)
    stats = ddm.latent_stats(v)
    s = ddm.standardize(stats, jnp.asarray(v))
    assert abs(float(s.mean())) < 1e-2
    assert abs(float(s.std()) - 1.0) < 1e-2
    back = ddm.destandardize(stats, s)
    np.testing.assert_allclose(np.asarray(back), v, rtol=1e-4, atol=1e-4)


def test_forward_diffusion_matches_eq1():
    cfg = ddm.DdmConfig(hidden=32, t_steps=10)
    sched = ddm.Schedule.linear(cfg.t_steps)
    v0 = jnp.ones((2, 128))
    eps = jnp.full((2, 128), 0.5)
    t = 7
    ab = sched.alpha_bars[t]
    vt = jnp.sqrt(ab) * v0 + jnp.sqrt(1 - ab) * eps
    # reconstruct v0 from (vt, eps): Eq. 1 inverted
    rec = (vt - jnp.sqrt(1 - ab) * eps) / jnp.sqrt(ab)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(v0), rtol=1e-5)


def test_gandse_outputs_in_unit_range():
    params = baselines.gandse_init(jax.random.PRNGKey(13))
    hw = baselines.gandse_generate(params, jax.random.PRNGKey(14),
                                   jnp.full((16, 1), 0.5), jnp.zeros((16, 3)))
    arr = np.asarray(hw)
    assert arr.shape == (16, 8)
    assert (arr >= 0).all() and (arr <= 1).all()


def test_airchitect_models():
    rng = np.random.default_rng(1)
    grid = baselines.airchitect_grid(768, rng)
    assert grid.shape[1] == 8
    assert len(grid) <= 768
    v1 = baselines.airchitect_v1_init(jax.random.PRNGKey(15), len(grid))
    logits = baselines.airchitect_v1_apply(v1, jnp.zeros((4, 3)))
    assert logits.shape == (4, len(grid))
    v2 = baselines.airchitect_v2_init(jax.random.PRNGKey(16))
    hw, cls_logits = baselines.airchitect_v2_apply(v2, jnp.zeros((4, 3)))
    assert hw.shape == (4, 8)
    assert cls_logits.shape == (4, 64)
    # v2 must be smaller than v1 (Fig 18: 32% fewer parameters claim
    # direction: the recommender with regression head scales better)
    assert nn.param_count(v2) < nn.param_count(v1)


def test_surrogate_grad_shapes():
    params = baselines.surrogate_init(jax.random.PRNGKey(17))
    hw = jax.random.uniform(jax.random.PRNGKey(18), (8, 8))
    w = jnp.zeros((8, 3))
    t = jnp.full((8,), 0.5)
    losses, grads = baselines.surrogate_grad_fn(params, hw, w, t)
    assert losses.shape == (8,)
    assert grads.shape == (8, 8)
    # gradient check against finite differences on one coordinate
    eps = 1e-3
    hw_p = hw.at[0, 0].add(eps)
    hw_m = hw.at[0, 0].add(-eps)
    lp, _ = baselines.surrogate_grad_fn(params, hw_p, w, t)
    lm, _ = baselines.surrogate_grad_fn(params, hw_m, w, t)
    fd = (lp[0] - lm[0]) / (2 * eps)
    assert abs(float(fd - grads[0, 0])) < 1e-2, f"fd {fd} vs grad {grads[0, 0]}"
