"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes per the repo testing policy; tolerances
account for f32 accumulation-order differences on large K.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.fused_linear import fused_linear, vmem_bytes
from compile.kernels.layernorm import layernorm
from compile.kernels.ref import fused_linear_ref, layernorm_ref

hypothesis.settings.register_profile(
    "kernels", max_examples=25, deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)
hypothesis.settings.load_profile("kernels")


def _rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


@hypothesis.given(
    m=st.integers(1, 200),
    k=st.integers(1, 300),
    n=st.integers(1, 200),
    act=st.sampled_from(["none", "relu"]),
    residual=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_matches_ref(m, k, n, act, residual, seed):
    key = jax.random.PRNGKey(seed)
    kx, kw, kb, kr = jax.random.split(key, 4)
    x, w, b = _rand(kx, m, k), _rand(kw, k, n), _rand(kb, n)
    r = _rand(kr, m, n) if residual else None
    got = fused_linear(x, w, b, residual=r, activation=act)
    want = fused_linear_ref(x, w, b, residual=r, activation=act)
    scale = float(jnp.abs(want).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=2e-5 * scale)


@hypothesis.given(
    m=st.integers(1, 300),
    d=st.integers(2, 512),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_matches_ref(m, d, seed):
    key = jax.random.PRNGKey(seed)
    kx, kg, kb = jax.random.split(key, 3)
    x = _rand(kx, m, d) * 3.0
    gamma = _rand(kg, d)
    beta = _rand(kb, d)
    got = layernorm(x, gamma, beta)
    want = layernorm_ref(x, gamma, beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("blocks", [(32, 32, 32), (64, 128, 32), (128, 128, 128)])
def test_fused_linear_block_shape_invariance(blocks):
    """Result must not depend on the tiling choice."""
    bm, bn, bk = blocks
    key = jax.random.PRNGKey(7)
    x, w, b = _rand(key, 100, 200), _rand(key, 200, 90), _rand(key, 90)
    got = fused_linear(x, w, b, activation="relu", block_m=bm, block_n=bn, block_k=bk)
    want = fused_linear_ref(x, w, b, activation="relu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_fused_linear_rejects_bad_activation():
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError):
        fused_linear(_rand(key, 4, 4), _rand(key, 4, 4), _rand(key, 4),
                     activation="gelu")


def test_vmem_budget_under_16mb():
    """The §Perf contract: default tiling fits VMEM with double buffering."""
    assert 2 * vmem_bytes(128, 128, 128, residual=True) < 16 * 2**20


def test_fused_linear_lowers_to_hlo_text():
    """The kernel must survive the AOT interchange path (interpret=True →
    plain HLO, no Mosaic custom-calls)."""
    from jax._src.lib import xla_client as xc

    def fn(x, w, b):
        return (fused_linear(x, w, b, activation="relu"),)

    spec = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    wspec = jax.ShapeDtypeStruct((64, 16), jnp.float32)
    bspec = jax.ShapeDtypeStruct((16,), jnp.float32)
    lowered = jax.jit(fn).lower(spec, wspec, bspec)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    text = comp.as_hlo_text()
    assert "custom-call" not in text, "Mosaic custom-call leaked into AOT HLO"
    assert len(text) > 100
