"""Normalization contract tests: python/compile/norm.py must mirror the rust
side (design_space::encode / models::norm) exactly."""

import numpy as np
import pytest

from compile.norm import (N_EDP, N_PERF, N_POWER, WorkloadStats, bin_index,
                          normalize_workload, percentile_edges)


def test_workload_norm_matches_rust_formula():
    # golden values pinned against rust Gemm::norm_vec
    v = normalize_workload(np.array([[1, 1, 1], [1024, 4096, 30000]]))
    np.testing.assert_allclose(v[0], [0, 0, 0])
    np.testing.assert_allclose(v[1], [1, 1, 1])
    v = normalize_workload(np.array([[512, 2048, 15000]]))
    np.testing.assert_allclose(
        v[0],
        [(512 - 1) / 1023, (2048 - 1) / 4095, (15000 - 1) / 29999],
        rtol=1e-6,
    )


def test_bin_index_matches_rust_clamping():
    edges = np.array([0.0, 1.0, 2.0, 3.0])
    assert bin_index(edges, np.array([-5.0]))[0] == 0
    assert bin_index(edges, np.array([0.5]))[0] == 0
    assert bin_index(edges, np.array([1.5]))[0] == 1
    assert bin_index(edges, np.array([99.0]))[0] == 2


def test_percentile_edges_balanced():
    vals = np.arange(1000, dtype=np.float64)
    edges = percentile_edges(vals, 4)
    assert len(edges) == 5
    counts = np.bincount(bin_index(edges, vals), minlength=4)
    assert counts.min() > 200


@pytest.fixture
def stats():
    rng = np.random.default_rng(0)
    runtime = np.exp(rng.uniform(5, 15, 5000))
    power = rng.uniform(0.2, 3.0, 5000)
    edp = runtime * power
    return WorkloadStats(64, 128, 256, runtime, power, edp), runtime, power, edp


def test_runtime_norm_roundtrip(stats):
    s, runtime, _, _ = stats
    p = s.norm_runtime(runtime)
    assert p.min() >= -1e-6 and p.max() <= 1 + 1e-6
    back = s.denorm_runtime(p)
    np.testing.assert_allclose(back, runtime, rtol=1e-4)


def test_class_label_eq8(stats):
    s, runtime, power, edp = stats
    cls = s.power_perf_class(power, runtime)
    assert cls.min() >= 0 and cls.max() < N_POWER * N_PERF
    # Eq. 8 decomposition
    cp = bin_index(s.power_edges, power)
    cr = bin_index(s.rt_edges, runtime)
    np.testing.assert_array_equal(cls, cp + N_POWER * cr)
    ecls = s.edp_class(edp)
    assert ecls.min() == 0 and ecls.max() == N_EDP - 1
    # percentile classes are roughly balanced
    counts = np.bincount(ecls, minlength=N_EDP)
    assert counts.min() > len(edp) / N_EDP / 2


def test_stats_json_schema(stats):
    s, _, _, _ = stats
    j = s.to_json()
    for key in ["m", "k", "n", "log_rt_min", "log_rt_max", "power_min",
                "power_max", "log_edp_min", "log_edp_max", "power_edges",
                "rt_edges", "edp_edges"]:
        assert key in j, key
    assert len(j["edp_edges"]) == N_EDP + 1
    assert len(j["power_edges"]) == N_POWER + 1
