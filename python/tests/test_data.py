"""Dataset loader tests (rust binary format → numpy) and the AOT export
contract. Skips gracefully when artifacts/dataset has not been generated."""

import os

import numpy as np
import pytest

DATASET_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "dataset")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(DATASET_DIR, "train.json")),
    reason="artifacts/dataset missing — run `make artifacts` first",
)


@pytest.fixture(scope="module")
def data():
    from compile.data import TrainData

    return TrainData.load(DATASET_DIR)


def test_table_shape_and_ranges(data):
    from compile.data import COL_EDP, COL_POWER, COL_RUNTIME, ROW_WIDTH

    assert data.table.shape[1] == ROW_WIDTH
    assert (data.table[:, :8] >= 0).all() and (data.table[:, :8] <= 1).all(), \
        "hw encoding must be normalized"
    assert (data.table[:, COL_RUNTIME] > 0).all()
    assert (data.table[:, COL_POWER] > 0).all()
    assert (data.table[:, COL_EDP] > 0).all()
    # loop one-hot: exactly one of the two slots set
    assert np.allclose(data.table[:, 6] + data.table[:, 7], 1.0)


def test_workload_spans_partition_table(data):
    total = sum(w["count"] for w in data.workloads)
    assert total == len(data.table)
    offsets = sorted(w["offset"] for w in data.workloads)
    assert offsets[0] == 0


def test_phase1_arrays(data):
    for supervision, n_p in [("runtime", 1), ("runtime_power", 2), ("edp", 1)]:
        hw, w, t = data.phase1_arrays(supervision)
        assert hw.shape == (len(data.table), 8)
        assert w.shape == (len(data.table), 3)
        assert t.shape == (len(data.table), n_p)
        assert t.min() >= -1e-5 and t.max() <= 1 + 1e-5, supervision


def test_condition_arrays(data):
    from compile.norm import N_EDP, N_PERF, N_POWER

    p = data.condition_arrays("runtime")
    assert p.shape == (len(data.table), 1)
    c = data.condition_arrays("edp_class")
    assert c.min() >= 0 and c.max() < N_POWER * N_PERF
    e = data.condition_arrays("perfopt_class")
    assert e.min() >= 0 and e.max() < N_EDP
    # every class is populated for at least one workload
    assert len(np.unique(e)) == N_EDP


def test_runtime_spans_orders_of_magnitude(data):
    """Paper Fig 13: runtimes span ~3 orders of magnitude per workload."""
    from compile.data import COL_RUNTIME

    spans = []
    for i in range(data.n_workloads()):
        rt = data.workload_rows(i)[:, COL_RUNTIME]
        spans.append(rt.max() / rt.min())
    assert np.median(spans) > 100, f"median span {np.median(spans)}"


def test_hlo_export_has_no_elided_constants():
    """The AOT interchange regression that zeroed all weights: large
    constants must be printed in full (see aot.to_hlo_text)."""
    import jax
    import jax.numpy as jnp

    from compile import nn
    from compile.aot import to_hlo_text

    m = nn.mlp_init(jax.random.PRNGKey(0), [64, 32, 8])
    lowered = jax.jit(lambda x: (nn.mlp(m, x),)).lower(
        jax.ShapeDtypeStruct((4, 64), jnp.float32))
    text = to_hlo_text(lowered)
    assert "{...}" not in text
    assert "f32[64,32]" in text
