"""AOT compile path: train everything, export HLO text artifacts.

Run as ``python -m compile.aot --out ../artifacts`` (the Makefile does).
Python's final act — after this, the rust binary is self-contained.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Exported artifacts (B = fixed generation batch, from ScaleConfig):
  sampler_runtime.hlo.txt   (seed u32, p f32[B,1], w f32[B,3]) -> hw f32[B,8]
  sampler_edp.hlo.txt       (seed, class i32[B], w)            -> hw
  sampler_perfopt.hlo.txt   (seed, class i32[B], w)            -> hw
  encoder.hlo.txt           hw f32[Bp,8]                        -> v f32[Bp,128]
  decoder.hlo.txt           v                                   -> hw
  pp.hlo.txt                (v, w)                              -> pred f32[Bp,1]
  pp_grad.hlo.txt           (v, w, target f32[Bp,1]) -> (loss f32[Bp], grad f32[Bp,128])
  surrogate.hlo.txt         (hw, w)                             -> pred f32[Bp]
  surrogate_grad.hlo.txt    (hw, w, target f32[Bp]) -> (loss, grad f32[Bp,8])
  gandse.hlo.txt            (seed, p f32[B,1], w)               -> hw f32[B,8]
  airchitect1.hlo.txt       w f32[Bp,3]                         -> logits f32[Bp,768]
  airchitect2.hlo.txt       w f32[Bp,3]                         -> hw f32[Bp,8]
  norm_stats.json           per-workload stats, class edges, shapes, param counts
  train_log.json            loss curves (paper Figs 14/15a)
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import nn
from .data import TrainData
from .models import ae, baselines, ddm
from .train import ScaleConfig, train_airchitect, train_gandse, train_phase1, \
    train_phase2, train_surrogate

PP_BATCH = 256  # fixed batch of the encoder/decoder/pp/surrogate executables


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: the default HLO printer elides large constants to
    # `constant({...})`, which xla_extension 0.5.1's text parser silently
    # parses as ZEROS — wiping every trained weight. Print them in full.
    mod = comp.get_hlo_module()
    opts = xc._xla.HloPrintOptions.short_parsable()
    opts.print_large_constants = True
    text = mod.to_string(opts)
    assert "{...}" not in text, "elided constants leaked into AOT artifact"
    return text


def export(fn, example_args, path: str) -> None:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  exported {os.path.basename(path)} ({len(text) / 1e6:.2f} MB)")


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def u32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--dataset", default=None, help="defaults to <out>/dataset")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)
    dataset_dir = args.dataset or os.path.join(out, "dataset")

    sc = ScaleConfig.from_env()
    use_pallas = os.environ.get("DIFFAXE_NO_PALLAS", "") == ""
    print(f"aot: scale={sc.name} T={sc.t_steps} gen_batch={sc.gen_batch} "
          f"pallas={'on' if use_pallas else 'off'}")
    t0 = time.time()
    data = TrainData.load(dataset_dir)
    print(f"aot: dataset {data.table.shape[0]} rows, {data.n_workloads()} workloads")

    log: dict = {}
    params_count: dict = {}

    # ---- Phase 1 (three supervision modes; §III-A, §III-D, §III-E) -------
    ae_rt, l_ae_rt = train_phase1(data, "runtime", sc, seed=0)
    ae_pp2, l_ae_pp2 = train_phase1(data, "runtime_power", sc, seed=1)
    ae_edp, l_ae_edp = train_phase1(data, "edp", sc, seed=2)
    log["phase1_runtime"] = l_ae_rt
    log["phase1_runtime_power"] = l_ae_pp2
    log["phase1_edp"] = l_ae_edp
    params_count["ae_pp"] = nn.param_count(ae_rt)

    # ---- Phase 2 DDMs ------------------------------------------------------
    ddm_rt, cfg_rt, sched_rt, l_ddm_rt, vs_rt = train_phase2(data, ae_rt, "runtime", sc, seed=0)
    ddm_edp, cfg_edp, sched_edp, l_ddm_edp, vs_edp = train_phase2(data, ae_pp2, "edp_class", sc, seed=1)
    ddm_po, cfg_po, sched_po, l_ddm_po, vs_po = train_phase2(data, ae_edp, "perfopt_class", sc, seed=2)
    log["phase2_runtime"] = l_ddm_rt
    log["phase2_edp_class"] = l_ddm_edp
    log["phase2_perfopt_class"] = l_ddm_po
    params_count["ddm"] = nn.param_count(ddm_rt)

    # ---- learned baselines -------------------------------------------------
    surr, l_surr = train_surrogate(data, sc)
    gandse_p, l_gandse = train_gandse(data, surr, sc)
    air1, air2, grid = train_airchitect(data, sc)
    log["surrogate"] = l_surr
    log["gandse"] = l_gandse
    params_count["gandse"] = nn.param_count(gandse_p)
    params_count["airchitect_v1"] = nn.param_count(air1)
    params_count["airchitect_v2"] = nn.param_count(air2)
    params_count["surrogate"] = nn.param_count(surr)

    print(f"aot: training done in {time.time() - t0:.0f}s; exporting HLO...")

    # ---- exports -----------------------------------------------------------
    B = sc.gen_batch

    def sampler_runtime(seed, p, w):
        key = jax.random.PRNGKey(seed)
        return (ddm.generate_hw(ddm_rt, ae_rt, cfg_rt, sched_rt, key, p, w,
                                v_stats=vs_rt, use_pallas=use_pallas),)

    export(sampler_runtime, (u32(), f32(B, 1), f32(B, 3)),
           os.path.join(out, "sampler_runtime.hlo.txt"))

    def sampler_edp(seed, cls, w):
        key = jax.random.PRNGKey(seed)
        return (ddm.generate_hw(ddm_edp, ae_pp2, cfg_edp, sched_edp, key, cls, w,
                                v_stats=vs_edp, use_pallas=use_pallas),)

    export(sampler_edp, (u32(), i32(B), f32(B, 3)),
           os.path.join(out, "sampler_edp.hlo.txt"))

    def sampler_perfopt(seed, cls, w):
        key = jax.random.PRNGKey(seed)
        return (ddm.generate_hw(ddm_po, ae_edp, cfg_po, sched_po, key, cls, w,
                                v_stats=vs_po, use_pallas=use_pallas),)

    export(sampler_perfopt, (u32(), i32(B), f32(B, 3)),
           os.path.join(out, "sampler_perfopt.hlo.txt"))

    export(lambda hw: (ae.encode(ae_rt, hw),), (f32(PP_BATCH, 8),),
           os.path.join(out, "encoder.hlo.txt"))
    export(lambda v: (ae.decode(ae_rt, v),), (f32(PP_BATCH, ae.LATENT_DIM),),
           os.path.join(out, "decoder.hlo.txt"))
    export(lambda v, w: (ae.predict(ae_rt, v, w),),
           (f32(PP_BATCH, ae.LATENT_DIM), f32(PP_BATCH, 3)),
           os.path.join(out, "pp.hlo.txt"))

    def pp_grad(v, w, target):
        def one(vi, wi, ti):
            return jnp.sum((ae.predict(ae_rt, vi[None], wi[None])[0] - ti) ** 2)
        losses = jax.vmap(one)(v, w, target)
        grads = jax.vmap(jax.grad(one))(v, w, target)
        return losses, grads

    export(pp_grad, (f32(PP_BATCH, ae.LATENT_DIM), f32(PP_BATCH, 3), f32(PP_BATCH, 1)),
           os.path.join(out, "pp_grad.hlo.txt"))

    export(lambda hw, w: (baselines.surrogate_apply(surr, hw, w),),
           (f32(PP_BATCH, 8), f32(PP_BATCH, 3)),
           os.path.join(out, "surrogate.hlo.txt"))

    def surrogate_grad(hw, w, target):
        return baselines.surrogate_grad_fn(surr, hw, w, target)

    export(surrogate_grad, (f32(PP_BATCH, 8), f32(PP_BATCH, 3), f32(PP_BATCH)),
           os.path.join(out, "surrogate_grad.hlo.txt"))

    def gandse_gen(seed, p, w):
        key = jax.random.PRNGKey(seed)
        return (baselines.gandse_generate(gandse_p, key, p, w),)

    export(gandse_gen, (u32(), f32(B, 1), f32(B, 3)),
           os.path.join(out, "gandse.hlo.txt"))

    export(lambda w: (baselines.airchitect_v1_apply(air1, w),), (f32(PP_BATCH, 3),),
           os.path.join(out, "airchitect1.hlo.txt"))
    export(lambda w: (baselines.airchitect_v2_apply(air2, w)[0],), (f32(PP_BATCH, 3),),
           os.path.join(out, "airchitect2.hlo.txt"))

    # ---- metadata ----------------------------------------------------------
    stats = {
        "scale": sc.name,
        "t_steps": sc.t_steps,
        "gen_batch": B,
        "pp_batch": PP_BATCH,
        "latent_dim": ae.LATENT_DIM,
        "hw_dim": 8,
        "n_power": 3,
        "n_perf": 3,
        "n_edp": 10,
        "param_counts": params_count,
        "airchitect_grid": [list(map(float, row)) for row in np.asarray(grid)],
        "workloads": [s.to_json() for s in data.stats],
    }
    with open(os.path.join(out, "norm_stats.json"), "w") as f:
        json.dump(stats, f, sort_keys=True)
    with open(os.path.join(out, "train_log.json"), "w") as f:
        json.dump(log, f, sort_keys=True)
    print(f"aot: all artifacts written to {out} in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
