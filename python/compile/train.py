"""Build-time training loops for every learned component.

Runs once inside ``make artifacts`` (never on the request path). Scale knobs
come from ``DIFFAXE_SCALE`` (paper / default / quick — see DESIGN.md §3):
the paper trains H=512 models for 5+10 epochs on 46.7 M samples on a V100;
the default here shrinks widths/epochs so a single CPU core finishes in
minutes while exercising identical code paths.
"""

from __future__ import annotations

import os
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import nn
from .data import TrainData
from .models import ae, baselines, ddm


@dataclass(frozen=True)
class ScaleConfig:
    name: str
    ae_hidden: tuple[int, int]
    ddm_hidden: int
    t_steps: int
    ae_epochs: int
    ddm_epochs: int
    batch_ae: int
    batch_ddm: int
    ddm_max_rows: int           # subsample cap for DDM training
    gen_batch: int              # fixed batch of the exported sampler
    baseline_epochs: int

    @classmethod
    def from_env(cls) -> "ScaleConfig":
        scale = os.environ.get("DIFFAXE_SCALE", "default")
        if scale == "paper":
            return cls("paper", (512, 256), 512, 1000, 5, 10, 512, 128,
                       10**9, 1000, 10)
        if scale == "quick":
            return cls("quick", (128, 64), 64, 16, 2, 2, 256, 256,
                       4096, 16, 2)
        return cls("default", (256, 128), 256, 100, 4, 12, 512, 256,
                   190_000, 128, 6)


@dataclass
class TrainLog:
    """Loss curves recorded for Figs 14/15(a)."""
    curves: dict

    def add(self, name: str, losses: list[float]):
        self.curves[name] = [float(x) for x in losses]


def _batches(rng: np.random.Generator, n: int, batch: int):
    idx = rng.permutation(n)
    for s in range(0, n - batch + 1, batch):
        yield idx[s:s + batch]


# ---------------------------------------------------------------------------
# Phase-1: AE + PP
# ---------------------------------------------------------------------------

def train_phase1(data: TrainData, supervision: str, sc: ScaleConfig, seed: int = 0):
    """Returns (params, epoch_losses)."""
    hw, w, targets = data.phase1_arrays(supervision)
    n_p = targets.shape[1]
    params = ae.init(jax.random.PRNGKey(seed), n_p=n_p, hidden=sc.ae_hidden)
    opt = nn.adamw_init(params)

    @jax.jit
    def update(params, opt, hwb, wb, tb):
        (l, aux), grads = jax.value_and_grad(ae.loss, has_aux=True)(params, hwb, wb, tb)
        params, opt = nn.adamw_update(params, grads, opt, 1e-3, weight_decay=1e-3)
        return params, opt, l

    rng = np.random.default_rng(seed)
    losses = []
    t0 = time.time()
    for epoch in range(sc.ae_epochs):
        epoch_loss, nb = 0.0, 0
        for idx in _batches(rng, len(hw), sc.batch_ae):
            params, opt, l = update(params, opt, jnp.asarray(hw[idx]),
                                    jnp.asarray(w[idx]), jnp.asarray(targets[idx]))
            epoch_loss += float(l)
            nb += 1
        losses.append(epoch_loss / max(nb, 1))
        print(f"  phase1[{supervision}] epoch {epoch}: loss {losses[-1]:.5f} "
              f"({time.time() - t0:.0f}s)")
    return params, losses


# ---------------------------------------------------------------------------
# Phase-2: DDM on the latent space
# ---------------------------------------------------------------------------

def train_phase2(data: TrainData, ae_params, cond_mode: str, sc: ScaleConfig,
                 seed: int = 0):
    """cond_mode: 'runtime' | 'edp_class' | 'perfopt_class'."""
    from .norm import N_EDP, N_PERF, N_POWER, normalize_workload

    hw = data.table[:, :8].astype(np.float32)
    w = normalize_workload(data.table[:, [8, 9, 10]])
    cond = data.condition_arrays(cond_mode)
    n_classes = {"runtime": 0, "edp_class": N_POWER * N_PERF,
                 "perfopt_class": N_EDP}[cond_mode]
    cfg = ddm.DdmConfig(hidden=sc.ddm_hidden, t_steps=sc.t_steps, n_classes=n_classes)
    sched = ddm.Schedule.linear(cfg.t_steps)

    # encode all hardware rows to latents once (frozen AE), standardized for
    # the DDPM's unit-variance noise schedule
    v0 = np.asarray(jax.jit(ae.encode)(ae_params, jnp.asarray(hw)))
    v_stats = ddm.latent_stats(v0)
    v0 = np.asarray(ddm.standardize(v_stats, v0))

    # subsample for CPU budget
    rng = np.random.default_rng(seed + 1)
    if len(v0) > sc.ddm_max_rows:
        keep = rng.choice(len(v0), size=sc.ddm_max_rows, replace=False)
        v0, w, cond = v0[keep], w[keep], cond[keep]

    params = ddm.init(jax.random.PRNGKey(seed + 2), cfg)
    opt = nn.adamw_init(params)

    @jax.jit
    def update(params, opt, lr, key, vb, pb, wb):
        l, grads = jax.value_and_grad(ddm.loss)(params, cfg, sched, key, vb, pb, wb)
        params, opt = nn.adamw_update(params, grads, opt, lr, weight_decay=1e-2)
        return params, opt, l

    losses = []
    key = jax.random.PRNGKey(seed + 3)
    t0 = time.time()
    lr, patience = 1e-3, 0
    for epoch in range(sc.ddm_epochs):
        epoch_loss, nb = 0.0, 0
        for idx in _batches(rng, len(v0), sc.batch_ddm):
            key, sub = jax.random.split(key)
            params, opt, l = update(params, opt, jnp.float32(lr), sub,
                                    jnp.asarray(v0[idx]),
                                    jnp.asarray(cond[idx]), jnp.asarray(w[idx]))
            epoch_loss += float(l)
            nb += 1
        losses.append(epoch_loss / max(nb, 1))
        # ReduceLROnPlateau (paper: patience 2)
        if epoch >= 1 and losses[-1] > losses[-2] - 1e-4:
            patience += 1
            if patience >= 2:
                lr *= 0.5
                patience = 0
        else:
            patience = 0
        print(f"  phase2[{cond_mode}] epoch {epoch}: loss {losses[-1]:.5f} "
              f"lr {lr:.1e} ({time.time() - t0:.0f}s)")
    return params, cfg, sched, losses, v_stats


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------

def train_surrogate(data: TrainData, sc: ScaleConfig, seed: int = 10):
    from .norm import normalize_workload

    hw = data.table[:, :8].astype(np.float32)
    w = normalize_workload(data.table[:, [8, 9, 10]])
    target = data.condition_arrays("runtime")[:, 0]
    params = baselines.surrogate_init(jax.random.PRNGKey(seed))
    opt = nn.adamw_init(params)

    @jax.jit
    def update(params, opt, hwb, wb, tb):
        l, grads = jax.value_and_grad(baselines.surrogate_loss)(params, hwb, wb, tb)
        params, opt = nn.adamw_update(params, grads, opt, 1e-3)
        return params, opt, l

    rng = np.random.default_rng(seed)
    losses = []
    for _ in range(sc.baseline_epochs):
        tot, nb = 0.0, 0
        for idx in _batches(rng, len(hw), 512):
            params, opt, l = update(params, opt, jnp.asarray(hw[idx]),
                                    jnp.asarray(w[idx]), jnp.asarray(target[idx]))
            tot += float(l)
            nb += 1
        losses.append(tot / max(nb, 1))
    print(f"  surrogate: final loss {losses[-1]:.5f}")
    return params, losses


def train_gandse(data: TrainData, surr_params, sc: ScaleConfig, seed: int = 20):
    from .norm import normalize_workload

    w = normalize_workload(data.table[:, [8, 9, 10]])
    p = data.condition_arrays("runtime")
    params = baselines.gandse_init(jax.random.PRNGKey(seed))
    opt = nn.adamw_init(params)

    @jax.jit
    def update(params, opt, key, pb, wb):
        z = jax.random.normal(key, (pb.shape[0], baselines.GANDSE_Z))
        l, grads = jax.value_and_grad(baselines.gandse_loss)(params, surr_params, z, pb, wb)
        params, opt = nn.adamw_update(params, grads, opt, 1e-3)
        return params, opt, l

    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    losses = []
    for _ in range(sc.baseline_epochs):
        tot, nb = 0.0, 0
        for idx in _batches(rng, len(w), 512):
            key, sub = jax.random.split(key)
            params, opt, l = update(params, opt, sub, jnp.asarray(p[idx]), jnp.asarray(w[idx]))
            tot += float(l)
            nb += 1
        losses.append(tot / max(nb, 1))
    print(f"  gandse: final loss {losses[-1]:.5f}")
    return params, losses


def train_airchitect(data: TrainData, sc: ScaleConfig, seed: int = 30):
    """Train v1 (classification over a fixed grid) and v2 (cls+reg) to
    recommend the lowest-EDP design per workload."""
    from .norm import normalize_workload

    rng = np.random.default_rng(seed)
    grid = baselines.airchitect_grid(768, rng)

    # supervision: per workload, the best (lowest-EDP) training row
    ws, best_hw, best_cls = [], [], []
    for i in range(data.n_workloads()):
        rows = data.workload_rows(i)
        best = rows[np.argmin(rows[:, 13])]
        wv = normalize_workload(best[None, [8, 9, 10]])[0]
        ws.append(wv)
        best_hw.append(best[:8])
        d = np.linalg.norm(grid - best[None, :8], axis=1)
        best_cls.append(np.argmin(d))
    ws = np.array(ws, np.float32)
    best_hw = np.array(best_hw, np.float32)
    best_cls = np.array(best_cls, np.int64)

    v1 = baselines.airchitect_v1_init(jax.random.PRNGKey(seed), len(grid))
    v2 = baselines.airchitect_v2_init(jax.random.PRNGKey(seed + 1))

    def v1_loss(params):
        logits = baselines.airchitect_v1_apply(params, jnp.asarray(ws))
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(logp[jnp.arange(len(best_cls)), jnp.asarray(best_cls)])

    def v2_loss(params):
        hw, logits = baselines.airchitect_v2_apply(params, jnp.asarray(ws))
        coarse = jnp.argmax(logits, axis=-1)  # unsupervised coarse head ok
        del coarse
        return jnp.mean((hw - jnp.asarray(best_hw)) ** 2)

    def fit(params, lossfn, steps):
        opt = nn.adamw_init(params)

        @jax.jit
        def update(params, opt):
            l, g = jax.value_and_grad(lossfn)(params)
            params, opt = nn.adamw_update(params, g, opt, 1e-3)
            return params, opt, l

        final = None
        for _ in range(steps):
            params, opt, final = update(params, opt)
        return params, float(final)

    v1, l1 = fit(v1, v1_loss, 200 * sc.baseline_epochs)
    v2, l2 = fit(v2, v2_loss, 200 * sc.baseline_epochs)
    print(f"  airchitect_v1: final loss {l1:.5f}; v2: {l2:.5f}")
    return v1, v2, grid
