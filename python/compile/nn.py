"""Minimal neural-network library in pure JAX.

flax/optax are not available in this offline image, so the compile path
carries its own: parameter pytrees (nested dicts), linear/MLP/layernorm
initializers + applies, and an Adam(W) optimizer. Everything is a pure
function over pytrees, so models lower cleanly through ``jax.jit`` to HLO.
"""

from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def linear_init(key, in_dim: int, out_dim: int) -> dict:
    """He/Kaiming-uniform linear layer parameters."""
    bound = math.sqrt(1.0 / in_dim)
    wk, bk = jax.random.split(key)
    return {
        "w": jax.random.uniform(wk, (in_dim, out_dim), jnp.float32, -bound, bound),
        "b": jax.random.uniform(bk, (out_dim,), jnp.float32, -bound, bound),
    }


def mlp_init(key, dims: list[int]) -> dict:
    """Stack of linear layers: dims = [in, h1, ..., out]."""
    keys = jax.random.split(key, len(dims) - 1)
    return {f"l{i}": linear_init(keys[i], dims[i], dims[i + 1]) for i in range(len(dims) - 1)}


def layernorm_init(dim: int) -> dict:
    return {"gamma": jnp.ones((dim,), jnp.float32), "beta": jnp.zeros((dim,), jnp.float32)}


# ---------------------------------------------------------------------------
# applies
# ---------------------------------------------------------------------------

def linear(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    return x @ params["w"] + params["b"]


def mlp(params: dict, x: jnp.ndarray, act: Callable = jax.nn.relu, final_act=None) -> jnp.ndarray:
    """Apply an ``mlp_init`` stack with `act` between layers."""
    n = len(params)
    for i in range(n):
        x = linear(params[f"l{i}"], x)
        if i < n - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


def layernorm(params: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * params["gamma"] + params["beta"]


def dropout(key, x: jnp.ndarray, rate: float, train: bool) -> jnp.ndarray:
    if not train or rate <= 0.0:
        return x
    keep = jax.random.bernoulli(key, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)


def param_count(params) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# AdamW (decoupled weight decay, as the paper uses)
# ---------------------------------------------------------------------------

def adamw_init(params) -> dict:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, *, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0):
    """One AdamW step; returns (new_params, new_state)."""
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, m_, v_):
        step = lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        return p - step - lr * weight_decay * p

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# sinusoidal timestep embedding (DDPM-style, dim 128 per the paper)
# ---------------------------------------------------------------------------

def time_embedding(t: jnp.ndarray, dim: int = 128) -> jnp.ndarray:
    """Sinusoidal positional embedding of diffusion timestep(s).

    t: () or (B,) float/int array. Returns (..., dim).
    """
    t = jnp.asarray(t, jnp.float32)
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t[..., None] * freqs
    return jnp.concatenate([jnp.sin(args), jnp.cos(args)], axis=-1)
