"""Normalization conventions shared with the rust coordinator.

The hardware encoding (8-wide, min-max over Table I target ranges + loop
one-hot) is produced by rust (``design_space::encode_norm``) and arrives
pre-normalized in the dataset. This module owns the *label* and *workload*
transforms (paper §IV-A):

* runtime: ``log`` then per-workload min-max to [0,1] (runtimes span 3
  orders of magnitude within one workload, Fig 13);
* power: global min-max;
* EDP: ``log`` then per-workload min-max;
* workload (M,K,N): global min-max over the §IV-A ranges.

Per-workload stats and percentile class edges are serialized into
``artifacts/norm_stats.json`` for the rust side.
"""

from __future__ import annotations

import numpy as np

# paper §IV-A workload ranges (mirrors rust workload::gemm)
M_MAX, K_MAX, N_MAX = 1024, 4096, 30_000

# Eq. 8 class grid for the EDP-DSE mode (§IV-B.2: 3 x 3) and the number of
# EDP percentile classes for the perf-opt mode (§IV-B.3: 10).
N_POWER, N_PERF = 3, 3
N_EDP = 10


def normalize_workload(mkn: np.ndarray) -> np.ndarray:
    """(..., 3) raw M,K,N -> [0,1]^3 (must match rust Gemm::norm_vec)."""
    mkn = np.asarray(mkn, np.float64)
    lo = np.array([1.0, 1.0, 1.0])
    hi = np.array([M_MAX, K_MAX, N_MAX], np.float64)
    return ((mkn - lo) / (hi - lo)).astype(np.float32)


def percentile_edges(values: np.ndarray, n_bins: int) -> np.ndarray:
    """Equal-mass bin edges; length n_bins+1 (mirrors rust stats)."""
    qs = np.linspace(0.0, 100.0, n_bins + 1)
    return np.percentile(values, qs)


def bin_index(edges: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Vectorized twin of rust ``stats::bin_index`` (clamping)."""
    n_bins = len(edges) - 1
    idx = np.searchsorted(edges[1:-1], x, side="left")
    return np.clip(idx, 0, n_bins - 1)


class WorkloadStats:
    """Per-workload label statistics + class edges."""

    def __init__(self, m, k, n, runtime, power, edp):
        self.m, self.k, self.n = int(m), int(k), int(n)
        log_rt = np.log(runtime)
        log_edp = np.log(edp)
        self.log_rt_min = float(log_rt.min())
        self.log_rt_max = float(log_rt.max())
        self.power_min = float(power.min())
        self.power_max = float(power.max())
        self.log_edp_min = float(log_edp.min())
        self.log_edp_max = float(log_edp.max())
        self.power_edges = percentile_edges(power, N_POWER)
        self.rt_edges = percentile_edges(runtime, N_PERF)
        self.edp_edges = percentile_edges(edp, N_EDP)

    def _span(self, lo, hi):
        return max(hi - lo, 1e-9)

    def norm_runtime(self, runtime):
        return ((np.log(runtime) - self.log_rt_min)
                / self._span(self.log_rt_min, self.log_rt_max)).astype(np.float32)

    def denorm_runtime(self, p):
        return np.exp(np.asarray(p, np.float64)
                      * self._span(self.log_rt_min, self.log_rt_max) + self.log_rt_min)

    def norm_power(self, power):
        return ((power - self.power_min)
                / self._span(self.power_min, self.power_max)).astype(np.float32)

    def norm_edp(self, edp):
        return ((np.log(edp) - self.log_edp_min)
                / self._span(self.log_edp_min, self.log_edp_max)).astype(np.float32)

    def power_perf_class(self, power, runtime):
        """Eq. 8: class = class_power + N_power * class_perf."""
        cp = bin_index(self.power_edges, power)
        cr = bin_index(self.rt_edges, runtime)
        return (cp + N_POWER * cr).astype(np.int32)

    def edp_class(self, edp):
        return bin_index(self.edp_edges, edp).astype(np.int32)

    def to_json(self) -> dict:
        return {
            "m": self.m, "k": self.k, "n": self.n,
            "log_rt_min": self.log_rt_min, "log_rt_max": self.log_rt_max,
            "power_min": self.power_min, "power_max": self.power_max,
            "log_edp_min": self.log_edp_min, "log_edp_max": self.log_edp_max,
            "power_edges": list(map(float, self.power_edges)),
            "rt_edges": list(map(float, self.rt_edges)),
            "edp_edges": list(map(float, self.edp_edges)),
        }
