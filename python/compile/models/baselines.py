"""Learned baselines the paper compares against (§IV-B / Fig 16-18).

* **surrogate** — a differentiable performance model ŝ(hw, w) ≈ normalized
  log-runtime. Vanilla GD (DOSA-style) descends its gradient in hardware
  space; it is also GANDSE's training signal.
* **GANDSE** [32] — one-shot generator G(z, p, w) → hw trained to minimize
  |ŝ(G(·), w) − p| through the differentiable surrogate (the paper
  attributes GANDSE's ~34% error to exactly this surrogate approximation,
  which this reproduction preserves; the adversarial realism term is
  dropped as it does not affect the error mechanism — see DESIGN.md §3).
* **AIRCHITECT v1** [21] — classification over a fixed 768-point design
  space: w → logits(768).
* **AIRCHITECT v2** [20] — classification + regression hybrid: coarse class
  over a 64-point grid plus a regression refinement of the numeric
  parameters.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import nn
from .ae import HW_DIM

# ---------------------------------------------------------------------------
# differentiable surrogate (vanilla-GD / GANDSE substrate)
# ---------------------------------------------------------------------------

def surrogate_init(key, hidden: int = 256) -> dict:
    return nn.mlp_init(key, [HW_DIM + 3, hidden, hidden, 1])


def surrogate_apply(params, hw, w):
    """(B,8),(B,3) → (B,) predicted normalized log-runtime."""
    return nn.mlp(params, jnp.concatenate([hw, w], axis=-1))[:, 0]


def surrogate_loss(params, hw, w, target):
    return jnp.mean((surrogate_apply(params, hw, w) - target) ** 2)


def surrogate_grad_fn(params, hw, w, target):
    """Per-sample loss + gradient wrt hw — the exported vanilla-GD step.

    Returns (loss (B,), dloss/dhw (B, 8)).
    """
    def one(h, wi, ti):
        return (surrogate_apply(params, h[None], wi[None])[0] - ti) ** 2

    losses = jax.vmap(one)(hw, w, target)
    grads = jax.vmap(jax.grad(one))(hw, w, target)
    return losses, grads


# ---------------------------------------------------------------------------
# GANDSE generator
# ---------------------------------------------------------------------------

GANDSE_Z = 32


def gandse_init(key, hidden: int = 256) -> dict:
    return nn.mlp_init(key, [GANDSE_Z + 1 + 3, hidden, hidden, HW_DIM])


def gandse_apply(params, z, p, w):
    """(B,32),(B,1),(B,3) → hw (B,8) in [0,1] (sigmoid keeps it on-range)."""
    x = jnp.concatenate([z, p, w], axis=-1)
    return jax.nn.sigmoid(nn.mlp(params, x))


def gandse_loss(params, surr_params, z, p, w):
    """Surrogate-matching objective + diversity regularizer."""
    hw = gandse_apply(params, z, p, w)
    pred = surrogate_apply(surr_params, hw, w)
    match = jnp.mean((pred - p[:, 0]) ** 2)
    # diversity: discourage mode collapse across the z batch
    div = -jnp.mean(jnp.var(hw, axis=0))
    return match + 0.05 * div


def gandse_generate(params, key, p, w):
    z = jax.random.normal(key, (p.shape[0], GANDSE_Z))
    return gandse_apply(params, z, p, w)


# ---------------------------------------------------------------------------
# AIRCHITECT v1 / v2 recommenders
# ---------------------------------------------------------------------------

def airchitect_grid(n: int, rng: np.random.Generator) -> np.ndarray:
    """A fixed n-point sub-grid of the training space in normalized hw
    coordinates (AIRCHITECT's 768-config universe)."""
    from itertools import product

    dims = [0.0, 0.2258, 0.4516, 1.0]            # r/c slots (4,32,60,128 approx)
    bufs = [0.0, 0.25, 1.0]                      # buffer slots
    grid = []
    for r, c, b, bw, lo in product(dims, dims, bufs, [0.0, 1.0], [0, 1]):
        onehot = [1.0, 0.0] if lo == 0 else [0.0, 1.0]
        grid.append([r, c, b, b, b, bw] + onehot)
    arr = np.array(grid, np.float32)
    if len(arr) > n:
        idx = rng.choice(len(arr), size=n, replace=False)
        arr = arr[idx]
    return arr


def airchitect_v1_init(key, n_configs: int, hidden: int = 512) -> dict:
    # wide output layer: the scaling bottleneck the paper calls out
    return nn.mlp_init(key, [3, hidden, hidden, n_configs])


def airchitect_v1_apply(params, w):
    return nn.mlp(params, w)  # logits over the fixed grid


def airchitect_v2_init(key, n_classes: int = 64, hidden: int = 256) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "cls": nn.mlp_init(k1, [3, hidden, hidden, n_classes]),
        "reg": nn.mlp_init(k2, [3 + n_classes, hidden, HW_DIM]),
    }


def airchitect_v2_apply(params, w):
    """w (B,3) → hw (B,8): coarse class + regression refinement."""
    logits = nn.mlp(params["cls"], w)
    soft = jax.nn.softmax(logits, axis=-1)
    hw = jax.nn.sigmoid(nn.mlp(params["reg"], jnp.concatenate([w, soft], axis=-1)))
    return hw, logits
