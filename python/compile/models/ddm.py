"""Phase-2: conditional denoising diffusion model (paper §III-B, Fig 8).

Signal processor + asymmetric MLP U-Net denoiser:

* **time embedding** — sinusoidal (dim 128) → Linear(128, H);
* **condition embedding** — performance p and workload w processed by two
  independent 2-layer MLPs (hidden 64, ReLU, dropout), concatenated and
  projected to H. In the class-conditioned DSE modes (§III-D/E) p is a
  learnable class embedding instead of a scalar;
* **input projection** — noisy latent v_t (128) → H;
* **denoiser** — concat (3H) → down path 3H→H→H/2 with LayerNorm + ReLU +
  dropout → mid H/2 → up path with skip connection back to H → Linear(H, 128)
  predicting the injected noise ε_θ.

Paper scale is H = 512 (3.4 M parameters total); `DIFFAXE_SCALE` shrinks H
for CPU training (DESIGN.md §3). A DDPM linear-β schedule over T steps
(paper: 1000) drives both training and the exported reverse-diffusion
sampler. The exported sampler executes its hidden layers with the Pallas
kernels (L1); training uses the numerically identical jnp path (kernels are
pytest-equivalent) for build-time speed.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .. import nn
from ..kernels.fused_linear import fused_linear
from ..kernels.layernorm import layernorm as pallas_layernorm
from . import ae


@dataclass(frozen=True)
class DdmConfig:
    latent: int = ae.LATENT_DIM
    time_dim: int = 128
    hidden: int = 512          # H: projection width (paper 512)
    cond_hidden: int = 64
    t_steps: int = 1000        # T (paper 1000)
    n_classes: int = 0         # 0 => continuous scalar conditioning
    dropout: float = 0.1

    @property
    def concat_dim(self) -> int:
        return 3 * self.hidden

    @property
    def down2(self) -> int:
        return self.hidden // 2


@dataclass(frozen=True)
class Schedule:
    """DDPM linear-β schedule [37]."""

    betas: jnp.ndarray
    alphas: jnp.ndarray
    alpha_bars: jnp.ndarray

    @classmethod
    def linear(cls, t_steps: int, beta_start: float = 1e-4, beta_end: float = 0.02):
        betas = jnp.linspace(beta_start, beta_end, t_steps, dtype=jnp.float32)
        alphas = 1.0 - betas
        return cls(betas=betas, alphas=alphas, alpha_bars=jnp.cumprod(alphas))


def init(key, cfg: DdmConfig) -> dict:
    k = jax.random.split(key, 8)
    h = cfg.hidden
    cond_in = cfg.n_classes if cfg.n_classes > 0 else 1
    return {
        "time_proj": nn.linear_init(k[0], cfg.time_dim, h),
        "cond_p": nn.mlp_init(k[1], [cond_in, cfg.cond_hidden, cfg.cond_hidden]),
        "cond_w": nn.mlp_init(k[2], [3, cfg.cond_hidden, cfg.cond_hidden]),
        "cond_proj": nn.linear_init(k[3], 2 * cfg.cond_hidden, h),
        "in_proj": nn.linear_init(k[4], cfg.latent, h),
        "down1": nn.linear_init(k[5], cfg.concat_dim, h),
        "ln1": nn.layernorm_init(h),
        "down2": nn.linear_init(k[6], h, cfg.down2),
        "ln2": nn.layernorm_init(cfg.down2),
        "mid": nn.linear_init(k[7], cfg.down2, cfg.down2),
        # up path: skip-concat(mid, down2) -> H, then out to latent
        "up1": nn.linear_init(jax.random.fold_in(key, 100), 2 * cfg.down2, h),
        "out": nn.linear_init(jax.random.fold_in(key, 101), h, cfg.latent),
    }


def _cond_input(cfg: DdmConfig, p):
    """p: (B,1) float for continuous mode, (B,) int class ids otherwise."""
    if cfg.n_classes > 0:
        return jax.nn.one_hot(p, cfg.n_classes, dtype=jnp.float32)
    return p


def apply(params: dict, cfg: DdmConfig, v_t, t, p, w, *, train: bool = False,
          dropout_key=None, use_pallas: bool = False):
    """Predict the noise ε_θ(v_t, t | p, w). All inputs batched (B, ...)."""
    lin = (lambda prm, x, act: fused_linear(x, prm["w"], prm["b"], activation=act)) \
        if use_pallas else \
        (lambda prm, x, act: jax.nn.relu(nn.linear(prm, x)) if act == "relu" else nn.linear(prm, x))
    ln = (lambda prm, x: pallas_layernorm(x, prm["gamma"], prm["beta"])) \
        if use_pallas else (lambda prm, x: nn.layernorm(prm, x))

    te = nn.time_embedding(jnp.asarray(t, jnp.float32), cfg.time_dim)
    if te.ndim == 1:
        te = jnp.broadcast_to(te[None, :], (v_t.shape[0], cfg.time_dim))
    t_h = lin(params["time_proj"], te, "none")

    pc = nn.mlp(params["cond_p"], _cond_input(cfg, p))
    wc = nn.mlp(params["cond_w"], w)
    if train and cfg.dropout > 0:
        dk1, dk2 = jax.random.split(dropout_key)
        pc = nn.dropout(dk1, pc, cfg.dropout, train)
        wc = nn.dropout(dk2, wc, cfg.dropout, train)
    c_h = lin(params["cond_proj"], jnp.concatenate([pc, wc], axis=-1), "none")

    x_h = lin(params["in_proj"], v_t, "none")

    hcat = jnp.concatenate([x_h, t_h, c_h], axis=-1)
    d1 = ln(params["ln1"], lin(params["down1"], hcat, "relu"))
    d2 = ln(params["ln2"], lin(params["down2"], d1, "relu"))
    m = lin(params["mid"], d2, "relu")
    u1 = lin(params["up1"], jnp.concatenate([m, d2], axis=-1), "relu")
    return lin(params["out"], u1, "none")


def loss(params: dict, cfg: DdmConfig, sched: Schedule, key, v0, p, w):
    """DDPM simple loss (Eq. 2): sample t, noise v0, predict the noise."""
    kt, ke, kd = jax.random.split(key, 3)
    b = v0.shape[0]
    t = jax.random.randint(kt, (b,), 0, cfg.t_steps)
    eps = jax.random.normal(ke, v0.shape)
    ab = sched.alpha_bars[t][:, None]
    v_t = jnp.sqrt(ab) * v0 + jnp.sqrt(1.0 - ab) * eps
    pred = apply(params, cfg, v_t, t.astype(jnp.float32), p, w,
                 train=True, dropout_key=kd)
    return jnp.mean((pred - eps) ** 2)


def latent_stats(v0):
    """Per-dimension standardization stats of the latent training data.

    The DDPM's noise schedule assumes ~unit-variance data ("we always
    normalize data before feeding into a neural network", §III-C); the AE
    latents are not naturally standardized, so Phase-2 trains on
    (v − μ)/σ and the sampler de-standardizes before decoding.
    """
    mean = v0.mean(axis=0)
    std = v0.std(axis=0) + 1e-6
    return {"mean": jnp.asarray(mean), "std": jnp.asarray(std)}


def standardize(stats, v):
    return (v - stats["mean"]) / stats["std"]


def destandardize(stats, v):
    return v * stats["std"] + stats["mean"]


def sample(params: dict, cfg: DdmConfig, sched: Schedule, key, p, w, *,
           use_pallas: bool = True):
    """Reverse diffusion (Eqs. 4/5): noise → denoised latent v̂.

    Runs the full T-step loop inside one lax.fori_loop so the exported HLO
    is a single self-contained computation (no per-step host round trips).
    """
    b = p.shape[0]
    k_init, k_loop = jax.random.split(key)
    v = jax.random.normal(k_init, (b, cfg.latent))

    def step(i, v):
        t = cfg.t_steps - 1 - i  # T-1 .. 0
        tf = jnp.full((b,), t, jnp.float32)
        eps = apply(params, cfg, v, tf, p, w, use_pallas=use_pallas)
        alpha = sched.alphas[t]
        ab = sched.alpha_bars[t]
        mean = (v - (1.0 - alpha) / jnp.sqrt(1.0 - ab) * eps) / jnp.sqrt(alpha)
        sigma = jnp.sqrt(sched.betas[t])
        z = jax.random.normal(jax.random.fold_in(k_loop, i), v.shape)
        # Eq. 5: no noise on the final step (t == 0)
        return mean + jnp.where(t > 0, sigma, 0.0) * z

    return jax.lax.fori_loop(0, cfg.t_steps, step, v)


def generate_hw(ddm_params, ae_params, cfg: DdmConfig, sched: Schedule, key, p, w,
                *, v_stats=None, use_pallas: bool = True):
    """Full generation path: sample (standardized) latent, de-standardize,
    decode to the 8-wide hardware interchange vector (rust rounds it into
    the target space)."""
    v = sample(ddm_params, cfg, sched, key, p, w, use_pallas=use_pallas)
    if v_stats is not None:
        v = destandardize(v_stats, v)
    return ae.decode(ae_params, v)
