"""Phase-1: performance-guided encoding (paper §III-A, Figs 5/6).

An autoencoder (AE) maps the 7-parameter hardware configuration into a
128-d latent space; a jointly-trained performance predictor (PP) organizes
that space by performance so designs with similar performance cluster
(Fig 7). Architecture follows the paper exactly:

* loop order one-hot → learnable 8-d embedding (Emb₁), concat with 6
  numeric features → 14-d input;
* ENC: Linear(14,512) → Linear(512,256) → Linear(256,128);
* DEC: symmetric, and Emb₂ recovers loop-order logits from the embedded
  segment;
* PP: workload MLP Linear(3,256)→(256,256)→(256,128)→(128,1) plus a linear
  head on the latent; predicted performance = sum of both branches
  (extended to n_p > 1 for the joint [runtime, power] supervision of
  §III-D).

The hardware interchange vector is the 8-wide encoding produced by rust
(6 numeric + 2 loop one-hot); Emb₁/Emb₂ translate between that and the
14-d internal representation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import nn

HW_DIM = 8           # rust interchange: 6 numeric + 2 loop one-hot
NUMERIC_DIM = 6
LOOP_DIM = 2
EMB_DIM = 8          # paper: loop order embedded to 8-d
INPUT_DIM = NUMERIC_DIM + EMB_DIM  # 14
LATENT_DIM = 128


def init(key, *, n_p: int = 1, hidden: tuple[int, int] = (512, 256)) -> dict:
    """AE+PP parameter pytree. `n_p` = number of supervised metrics."""
    k = jax.random.split(key, 6)
    h1, h2 = hidden
    return {
        "emb1": nn.linear_init(k[0], LOOP_DIM, EMB_DIM),
        "enc": nn.mlp_init(k[1], [INPUT_DIM, h1, h2, LATENT_DIM]),
        "dec": nn.mlp_init(k[2], [LATENT_DIM, h2, h1, INPUT_DIM]),
        "emb2": nn.linear_init(k[3], EMB_DIM, LOOP_DIM),
        "pp_w": nn.mlp_init(k[4], [3, 256, 256, 128, n_p]),
        "pp_v": nn.linear_init(k[5], LATENT_DIM, n_p),
    }


def encode(params: dict, hw: jnp.ndarray) -> jnp.ndarray:
    """hw (B, 8) → latent (B, 128)."""
    numeric, loop = hw[:, :NUMERIC_DIM], hw[:, NUMERIC_DIM:]
    emb = nn.linear(params["emb1"], loop)
    x = jnp.concatenate([numeric, emb], axis=-1)
    return nn.mlp(params["enc"], x)


def decode(params: dict, v: jnp.ndarray) -> jnp.ndarray:
    """latent (B, 128) → hw (B, 8): 6 numeric + 2 loop-order logits."""
    x = nn.mlp(params["dec"], v)
    numeric, emb = x[:, :NUMERIC_DIM], x[:, NUMERIC_DIM:]
    loop_logits = nn.linear(params["emb2"], emb)
    return jnp.concatenate([numeric, loop_logits], axis=-1)


def predict(params: dict, v: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """PP: (latent (B,128), workload (B,3)) → predicted metrics (B, n_p)."""
    return nn.mlp(params["pp_w"], w) + nn.linear(params["pp_v"], v)


def loss(params: dict, hw: jnp.ndarray, w: jnp.ndarray, targets: jnp.ndarray):
    """L_total = L_recon + L_pred (Eq. 6). Loop reconstruction uses
    softmax-CE on the one-hot slots (the paper recovers the categorical
    loop order through Emb₂)."""
    v = encode(params, hw)
    rec = decode(params, v)
    num_loss = jnp.mean((rec[:, :NUMERIC_DIM] - hw[:, :NUMERIC_DIM]) ** 2)
    logp = jax.nn.log_softmax(rec[:, NUMERIC_DIM:], axis=-1)
    loop_loss = -jnp.mean(jnp.sum(hw[:, NUMERIC_DIM:] * logp, axis=-1))
    pred = predict(params, v, w)
    pred_loss = jnp.mean((pred - targets) ** 2)
    total = num_loss + 0.1 * loop_loss + pred_loss
    return total, {"recon": num_loss + 0.1 * loop_loss, "pred": pred_loss}
