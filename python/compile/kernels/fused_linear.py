"""Fused tiled linear kernel (Pallas): ``act(x @ w + b (+ residual))``.

This is the generation hot-spot: every layer of the DDM denoiser — evaluated
T times per reverse-diffusion sample — is one call of this kernel, so the
bias/activation/residual epilogue is fused into the matmul's final K-step to
avoid extra HBM↔VMEM round trips.

TPU mapping (DESIGN.md §6): the grid tiles (batch × out-features) onto
MXU-shaped 128×128 blocks with the contraction dimension streamed through
VMEM in ``block_k`` chunks and accumulated in the output block — the role
threadblock tiling plays in the paper's CUDA/V100 framing. ``interpret=True``
everywhere: the CPU PJRT plugin cannot execute Mosaic custom-calls, and
interpret-mode lowers to plain HLO that ships inside the AOT artifacts.

VMEM footprint per grid step = (bm·bk + bk·bn + 2·bm·bn) · 4 B; the default
128³ tiling uses 256 kB — far under the ~16 MB VMEM budget, leaving room for
double buffering (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, activation: str, has_residual: bool,
            r_ref=None):
    """One (i, j, k) grid step: accumulate x@w, epilogue on the last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = o_ref[...] + b_ref[...][None, :]
        if has_residual:
            acc = acc + r_ref[...]
        if activation == "relu":
            acc = jax.nn.relu(acc)
        o_ref[...] = acc


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_m", "block_n", "block_k"),
)
def fused_linear(x, w, b, residual=None, *, activation: str = "none",
                 block_m: int = 128, block_n: int = 128, block_k: int = 128):
    """act(x @ w + b (+ residual)) via a tiled Pallas kernel.

    x: (M, K), w: (K, N), b: (N,), residual: optional (M, N).
    Shapes need not be multiples of the block sizes (inputs are zero-padded
    and the result sliced back).
    """
    assert x.ndim == 2 and w.ndim == 2 and b.ndim == 1
    assert x.shape[1] == w.shape[0] and w.shape[1] == b.shape[0]
    if activation not in ("none", "relu"):
        raise ValueError(activation)
    m, k = x.shape
    n = w.shape[1]
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)

    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    bp = _pad_to(b, 0, bn)
    grid = (xp.shape[0] // bm, wp.shape[1] // bn, xp.shape[1] // bk)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
    ]
    args = [xp, wp, bp]
    has_residual = residual is not None
    if has_residual:
        assert residual.shape == (m, n)
        rp = _pad_to(_pad_to(residual, 0, bm), 1, bn)
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))
        args.append(rp)
        kernel = lambda x_ref, w_ref, b_ref, r_ref, o_ref: _kernel(  # noqa: E731
            x_ref, w_ref, b_ref, o_ref, nk=grid[2], activation=activation,
            has_residual=True, r_ref=r_ref)
    else:
        kernel = lambda x_ref, w_ref, b_ref, o_ref: _kernel(  # noqa: E731
            x_ref, w_ref, b_ref, o_ref, nk=grid[2], activation=activation,
            has_residual=False)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), jnp.float32),
        interpret=True,
    )(*args)
    return out[:m, :n]


def vmem_bytes(block_m: int, block_n: int, block_k: int, residual: bool = False) -> int:
    """Static VMEM footprint of one grid step (f32), for the §Perf analysis."""
    tiles = block_m * block_k + block_k * block_n + block_n + block_m * block_n
    if residual:
        tiles += block_m * block_n
    return 4 * tiles
