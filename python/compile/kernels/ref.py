"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in this package is pytest-checked against these references
(hypothesis sweeps shapes/dtypes in python/tests/test_kernels.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_linear_ref(x, w, b, residual=None, activation="none"):
    """y = act(x @ w + b (+ residual))."""
    y = x @ w + b
    if residual is not None:
        y = y + residual
    if activation == "relu":
        y = jax.nn.relu(y)
    elif activation != "none":
        raise ValueError(activation)
    return y


def layernorm_ref(x, gamma, beta, eps: float = 1e-5):
    """Row-wise layer normalization over the last axis."""
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta
