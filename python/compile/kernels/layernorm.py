"""Row-blocked LayerNorm Pallas kernel.

The denoiser's downsampling path normalizes every hidden activation; fusing
mean/variance/scale into one VMEM-resident pass avoids three separate HBM
sweeps. Grid tiles the batch dimension only — the feature dimension (≤1536
here) always fits one VMEM block. interpret=True for CPU-PJRT (see
fused_linear.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...]
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    o_ref[...] = (x - mean) * jax.lax.rsqrt(var + eps) * g_ref[...][None, :] + b_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("block_m", "eps"))
def layernorm(x, gamma, beta, *, block_m: int = 128, eps: float = 1e-5):
    """LayerNorm over the last axis of a (M, D) array."""
    assert x.ndim == 2
    m, d = x.shape
    assert gamma.shape == (d,) and beta.shape == (d,)
    bm = min(block_m, m)
    pad = (-m) % bm
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(xp.shape[0] // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.float32),
        interpret=True,
    )(xp, gamma, beta)
    return out[:m]
