"""Loader for the rust-generated training dataset (see rust/src/dataset/).

Row layout (f32 little-endian, width 14):
``[hw_norm(8) | M K N | runtime_cycles power_w edp_uj_cycles]``
"""

from __future__ import annotations

import json
import os

import numpy as np

from .norm import WorkloadStats, normalize_workload

ROW_WIDTH = 14
HW_DIM = 8
COL_M, COL_K, COL_N = 8, 9, 10
COL_RUNTIME, COL_POWER, COL_EDP = 11, 12, 13


class TrainData:
    """The dataset plus derived normalization stats."""

    def __init__(self, table: np.ndarray, workloads: list[dict]):
        assert table.ndim == 2 and table.shape[1] == ROW_WIDTH
        self.table = table
        self.workloads = workloads
        self.stats: list[WorkloadStats] = []
        for w in workloads:
            rows = self.workload_rows(len(self.stats))
            self.stats.append(
                WorkloadStats(
                    w["m"], w["k"], w["n"],
                    rows[:, COL_RUNTIME], rows[:, COL_POWER], rows[:, COL_EDP],
                )
            )

    @classmethod
    def load(cls, dataset_dir: str) -> "TrainData":
        with open(os.path.join(dataset_dir, "train.json")) as f:
            header = json.load(f)
        assert header["row_width"] == ROW_WIDTH, header
        table = np.fromfile(os.path.join(dataset_dir, "train.bin"), dtype="<f4")
        table = table.reshape(-1, ROW_WIDTH)
        assert table.shape[0] == header["n_rows"]
        return cls(table, header["workloads"])

    def workload_rows(self, w: int) -> np.ndarray:
        meta = self.workloads[w]
        off, cnt = meta["offset"], meta["count"]
        return self.table[off:off + cnt]

    def n_workloads(self) -> int:
        return len(self.workloads)

    # ---- training arrays ---------------------------------------------------

    def phase1_arrays(self, supervision: str):
        """(hw_norm, w_norm, targets) for Phase-1 AE+PP training.

        supervision: 'runtime' -> (N,1) normalized log-runtime;
        'runtime_power' -> (N,2); 'edp' -> (N,1) normalized log-EDP.
        """
        hw = self.table[:, :HW_DIM]
        w_norm = normalize_workload(self.table[:, [COL_M, COL_K, COL_N]])
        cols = []
        rt, pw, edp = (np.concatenate([getattr(s, f)(self.workload_rows(i)[:, c])
                                       for i, s in enumerate(self.stats)])
                       for f, c in [("norm_runtime", COL_RUNTIME),
                                    ("norm_power", COL_POWER),
                                    ("norm_edp", COL_EDP)])
        if supervision == "runtime":
            cols = [rt]
        elif supervision == "runtime_power":
            cols = [rt, pw]
        elif supervision == "edp":
            cols = [edp]
        else:
            raise ValueError(supervision)
        targets = np.stack(cols, axis=1).astype(np.float32)
        return hw.astype(np.float32), w_norm, targets

    def condition_arrays(self, mode: str):
        """Conditioning signal per row for Phase-2 DDM training.

        mode 'runtime' -> (N,1) float; 'edp_class' -> (N,) int (Eq. 8 3x3
        power-perf grid); 'perfopt_class' -> (N,) int (10 EDP percentiles).
        """
        if mode == "runtime":
            vals = [self.stats[i].norm_runtime(self.workload_rows(i)[:, COL_RUNTIME])
                    for i in range(self.n_workloads())]
            return np.concatenate(vals)[:, None].astype(np.float32)
        if mode == "edp_class":
            vals = [self.stats[i].power_perf_class(
                        self.workload_rows(i)[:, COL_POWER],
                        self.workload_rows(i)[:, COL_RUNTIME])
                    for i in range(self.n_workloads())]
            return np.concatenate(vals)
        if mode == "perfopt_class":
            vals = [self.stats[i].edp_class(self.workload_rows(i)[:, COL_EDP])
                    for i in range(self.n_workloads())]
            return np.concatenate(vals)
        raise ValueError(mode)
