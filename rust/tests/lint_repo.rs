//! Corpus self-test for `diffaxe lint` (`util::lint`).
//!
//! Three properties, per the invariant doc (`docs/INVARIANTS.md`):
//! 1. the planted-violation fixture under `tests/fixtures/lint/` trips
//!    every rule exactly once,
//! 2. the allow-mechanism fixture under `tests/fixtures/lint_allowed/`
//!    lints clean (every directive carries a reason),
//! 3. the real tree — the very crate this test compiles into — lints
//!    clean, which is the invariant the blocking CI step enforces.

use std::collections::BTreeMap;
use std::path::Path;

use diffaxe::util::lint::{lint_tree, to_json, RULES};

fn manifest_dir() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn fixture_trips_every_rule_exactly_once() {
    let root = manifest_dir().join("tests/fixtures/lint");
    let diags = lint_tree(&root).expect("fixture tree readable");
    let mut by_rule: BTreeMap<&str, usize> = BTreeMap::new();
    for d in &diags {
        *by_rule.entry(d.rule).or_insert(0) += 1;
    }
    for r in RULES {
        assert_eq!(
            by_rule.get(r.name).copied().unwrap_or(0),
            1,
            "rule {} should fire exactly once on the fixture; all diagnostics:\n{}",
            r.name,
            render(&diags)
        );
    }
    assert_eq!(diags.len(), RULES.len(), "no extra diagnostics:\n{}", render(&diags));
    // and the planted dse-clock violation really came from the dse/ subtree
    let clock = diags.iter().find(|d| d.rule == "dse-clock").expect("checked above");
    assert!(clock.file.starts_with("src/dse/"), "{}", clock);
}

#[test]
fn allow_fixture_lints_clean() {
    let root = manifest_dir().join("tests/fixtures/lint_allowed");
    let diags = lint_tree(&root).expect("fixture tree readable");
    assert!(diags.is_empty(), "justified allows must suppress:\n{}", render(&diags));
}

#[test]
fn real_tree_lints_clean() {
    let diags = lint_tree(manifest_dir()).expect("crate tree readable");
    assert!(
        diags.is_empty(),
        "the migrated tree must lint clean (this is the blocking CI gate):\n{}",
        render(&diags)
    );
}

#[test]
fn json_output_carries_all_fields() {
    let root = manifest_dir().join("tests/fixtures/lint");
    let diags = lint_tree(&root).expect("fixture tree readable");
    let json = to_json(&diags).to_string();
    for key in ["\"file\"", "\"line\"", "\"rule\"", "\"message\""] {
        assert!(json.contains(key), "missing {key} in {json}");
    }
    for r in RULES {
        assert!(json.contains(r.name), "missing rule {} in {json}", r.name);
    }
}

fn render(diags: &[diffaxe::util::lint::Diagnostic]) -> String {
    diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
}
