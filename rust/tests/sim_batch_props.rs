//! Property suite for the SoA batch simulator's scalar-oracle guarantee:
//! `sim::batch::{simulate_batch, simulate_pairs}` must be **bit-identical**
//! to mapping the scalar `sim::simulate` over the batch — across the
//! training grid, every loop order, random target-space samples, and the
//! edge GEMMs (M=1 decode shapes, K=1, partial tiles) where tiling
//! remainders and chunk clamps exercise every arm of the model. All-integer
//! arithmetic: equality is exact, not approximate. Hermetic — pure
//! functions of seeded randomness.

use diffaxe::design_space::{HwConfig, LoopOrder, TargetSpace, TrainingSpace};
use diffaxe::sim::{simulate, simulate_batch, simulate_pairs};
use diffaxe::util::rng::Pcg32;
use diffaxe::workload::Gemm;

/// The adversarial shape set: decode-style skinny GEMMs, degenerate K,
/// partial tiles against every array dimension, and a large LLM layer.
fn edge_gemms() -> Vec<Gemm> {
    vec![
        Gemm::new(1, 4096, 12288), // M=1 decode (GPT-3-ish FFN)
        Gemm::new(1, 64, 1),       // single output column, skinny K
        Gemm::new(1, 1, 1),        // fully degenerate
        Gemm::new(128, 1, 128),    // K=1: one chunk regardless of order
        Gemm::new(5, 7, 3),        // partial tiles in every dimension
        Gemm::new(33, 129, 65),    // off-by-one past pow2 tile edges
        Gemm::new(512, 4096, 512), // square-ish large layer
        Gemm::new(100, 768, 3072), // BERT FFN with a partial M tile
    ]
}

/// TrainingSpace sample × `LoopOrder::ALL` × edge GEMMs: exact equality
/// of every `SimResult` counter, per shape.
#[test]
fn batch_bit_identical_on_training_grid_times_orders_times_edges() {
    // a deterministic stride through the training grid (covers every
    // parameter level; the full grid is ~40k points — too many per shape)
    let stride = TrainingSpace::len() / 97;
    let bases: Vec<HwConfig> =
        (0..97).map(|i| TrainingSpace::nth((i * stride + i) % TrainingSpace::len())).collect();
    for g in edge_gemms() {
        let cfgs: Vec<HwConfig> = bases
            .iter()
            .flat_map(|b| LoopOrder::ALL.iter().map(move |&lo| HwConfig { loop_order: lo, ..*b }))
            .collect();
        let batch = simulate_batch(&cfgs, &g);
        assert_eq!(batch.len(), cfgs.len());
        for (hw, got) in cfgs.iter().zip(&batch) {
            assert_eq!(*got, simulate(hw, &g), "{hw} on {g:?}");
        }
    }
}

/// Random target-space batches (mixed orders in one call) stay exact.
#[test]
fn batch_bit_identical_on_random_target_space() {
    let mut rng = Pcg32::seeded(2001);
    for trial in 0..20 {
        let g = Gemm::new(
            rng.int_range(1, 600) as u32,
            rng.int_range(1, 4096) as u32,
            rng.int_range(1, 600) as u32,
        );
        let cfgs: Vec<HwConfig> = (0..200).map(|_| TargetSpace::sample(&mut rng)).collect();
        let batch = simulate_batch(&cfgs, &g);
        for (hw, got) in cfgs.iter().zip(&batch) {
            assert_eq!(*got, simulate(hw, &g), "trial {trial}: {hw} on {g:?}");
        }
    }
}

/// `simulate_pairs` preserves input order across interleaved shapes and
/// orders, with duplicates allowed.
#[test]
fn pairs_bit_identical_in_input_order_with_duplicates() {
    let mut rng = Pcg32::seeded(2002);
    let shapes = edge_gemms();
    let mut pairs: Vec<(HwConfig, Gemm)> = Vec::new();
    for i in 0..300 {
        let hw = TargetSpace::sample(&mut rng);
        pairs.push((hw, shapes[i % shapes.len()]));
        if i % 7 == 0 {
            // exact duplicate of the previous pair
            pairs.push((hw, shapes[i % shapes.len()]));
        }
    }
    let batch = simulate_pairs(&pairs);
    assert_eq!(batch.len(), pairs.len());
    for ((hw, g), got) in pairs.iter().zip(&batch) {
        assert_eq!(*got, simulate(hw, g), "{hw} on {g:?}");
    }
}

/// Single-element and empty batches degenerate correctly.
#[test]
fn tiny_batches_degenerate_to_scalar() {
    let g = Gemm::new(64, 256, 64);
    assert!(simulate_batch(&[], &g).is_empty());
    assert!(simulate_pairs(&[]).is_empty());
    let mut rng = Pcg32::seeded(2003);
    for _ in 0..50 {
        let hw = TargetSpace::sample(&mut rng);
        assert_eq!(simulate_batch(&[hw], &g), vec![simulate(&hw, &g)]);
        assert_eq!(simulate_pairs(&[(hw, g)]), vec![simulate(&hw, &g)]);
    }
}
