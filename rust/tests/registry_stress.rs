//! Concurrency stress test for the [`JobRegistry`]: many threads
//! interleave submit / status / cancel / watch against a driver thread
//! running the engine-side lifecycle (start → publish → finalize), all
//! under the debug lock-rank assertions of `util::sync` (this suite runs
//! unoptimized, so the assertions are live — a lock-order inversion
//! anywhere in the registry/metrics cluster panics the test instead of
//! deadlocking CI; see docs/INVARIANTS.md).
//!
//! Invariants checked at the end:
//! - no ordering violation (no panic from the rank assertions),
//! - no lost terminal state: every job ends `Done` or `Cancelled` with a
//!   stored result,
//! - the metrics gauges balance back to zero and the cumulative counters
//!   add up to exactly one terminal transition per job.
//!
//! The registry is built with [`FaultPlan::from_env`], so CI can re-run
//! the whole interleaving under a **delay-only** plan (e.g.
//! `DIFFAXE_FAULT_PLAN="finalize:delay=1@1/4"`) to widen race windows at
//! the finalize site. Panic/error plans would violate the `jobs_failed ==
//! 0` accounting below — keep env plans for this suite delay-only.

use diffaxe::coordinator::{
    JobRegistry, JobState, Metrics, Response, SearchRequest, MAX_RETAINED_JOBS,
};
use diffaxe::dse::{Budget, Objective, OptimizerKind, SearchEvent, SearchOutcome, StopReason};
use diffaxe::util::fault::FaultPlan;
use diffaxe::workload::Gemm;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;

const JOBS: usize = 96;
const SUBMITTERS: usize = 4;

fn request() -> SearchRequest {
    SearchRequest::new(
        Objective::MinEdp { g: Gemm::new(8, 8, 8) },
        Budget::evals(4),
        OptimizerKind::RandomSearch,
    )
}

fn done_outcome(evals: usize) -> Response {
    Response::Outcome(SearchOutcome {
        evals,
        ..SearchOutcome::empty("random", StopReason::Completed)
    })
}

#[test]
fn interleaved_submit_status_cancel_watch_under_rank_assertions() {
    assert!(JOBS < MAX_RETAINED_JOBS, "GC must not reap jobs mid-assertion");
    let metrics = Arc::new(Metrics::new());
    // honour DIFFAXE_FAULT_PLAN so CI can inject finalize-site delays
    let reg = Arc::new(JobRegistry::with_faults(metrics.clone(), FaultPlan::from_env()));
    let (entry_tx, entry_rx) = channel();
    let churn = Arc::new(AtomicBool::new(true));

    // status/list hammer: exercises the registry.inner → job.core
    // acquisition order concurrently with every lifecycle transition
    let pollers: Vec<_> = (0..2)
        .map(|_| {
            let reg = reg.clone();
            let churn = churn.clone();
            std::thread::spawn(move || {
                let mut polls = 0usize;
                while churn.load(Ordering::SeqCst) {
                    for info in reg.list() {
                        let _ = reg.get(&info.id).map(|e| e.info());
                    }
                    polls += 1;
                }
                polls
            })
        })
        .collect();

    // submitters: submit, then immediately cancel a third of their jobs
    // (some still queued — terminal via the cancel path; some already
    // running — the driver's finalize wins and the cancel is a no-op)
    let submitters: Vec<_> = (0..SUBMITTERS)
        .map(|s| {
            let reg = reg.clone();
            let entry_tx = entry_tx.clone();
            std::thread::spawn(move || {
                for i in 0..JOBS / SUBMITTERS {
                    let entry = reg.submit(request());
                    entry_tx.send(entry.clone()).expect("driver alive");
                    if (s + i) % 3 == 0 {
                        // depending on the race, the job may already be
                        // running (driver finalize wins) or even done —
                        // but a cancel must never leave it Failed
                        let info = reg.cancel(&entry.id).expect("just submitted");
                        assert_ne!(info.state, JobState::Failed);
                    }
                }
            })
        })
        .collect();
    drop(entry_tx);

    // engine driver: the single-threaded lifecycle the real service runs.
    // Every 5th job also gets a watcher thread riding the condvar path,
    // draining next_event() until the terminal response lands.
    // start() returning false means a queued cancel already finalized the
    // job — the driver must skip it without touching the result.
    let driver = {
        let reg = reg.clone();
        std::thread::spawn(move || {
            let mut handled = 0usize;
            let mut watchers = Vec::new();
            for (i, entry) in entry_rx.iter().enumerate() {
                if i % 5 == 0 {
                    let e = entry.clone();
                    watchers.push(std::thread::spawn(move || {
                        let mut seq = 0u64;
                        loop {
                            let (s, _ev, terminal) = e.next_event(seq);
                            seq = s;
                            if let Some((state, resp)) = terminal {
                                assert!(state.terminal(), "watch ended on {state:?}");
                                assert!(
                                    matches!(resp, Response::Outcome(_)),
                                    "terminal must carry the stored outcome"
                                );
                                return;
                            }
                        }
                    }));
                }
                if reg.start(&entry) {
                    for evals in 1..=2 {
                        reg.publish(
                            &entry,
                            SearchEvent { evals, best_score: 1.0, elapsed_s: 0.0 },
                        );
                    }
                    reg.finalize(&entry, JobState::Done, done_outcome(2));
                    handled += 1;
                }
            }
            for w in watchers {
                w.join().expect("watcher");
            }
            handled
        })
    };

    for s in submitters {
        s.join().expect("submitter");
    }
    let handled = driver.join().expect("driver");
    churn.store(false, Ordering::SeqCst);
    for p in pollers {
        assert!(p.join().expect("poller") > 0, "poller never ran");
    }

    // no lost terminal state: every job is Done or Cancelled and carries
    // its stored outcome
    let jobs = reg.list();
    assert_eq!(jobs.len(), JOBS);
    let mut done = 0usize;
    let mut cancelled = 0usize;
    for info in &jobs {
        match info.state {
            JobState::Done => done += 1,
            JobState::Cancelled => cancelled += 1,
            other => panic!("job {} not terminal: {other:?}", info.id),
        }
        let entry = reg.get(&info.id).expect("retained");
        match entry.result_now() {
            Response::Outcome(o) => {
                let want = if info.state == JobState::Done {
                    StopReason::Completed
                } else {
                    StopReason::Cancelled
                };
                assert_eq!(o.stopped, want, "{}", info.id);
            }
            other => panic!("job {} lost its result: {other:?}", info.id),
        }
    }
    assert_eq!(done, handled, "every driver-run job must read back Done");
    assert_eq!(done + cancelled, JOBS);

    // gauges balance: nothing queued, nothing active, no orphaned event
    // slots; counters account for exactly one terminal transition per job
    let s = metrics.snapshot();
    assert_eq!((s.jobs_queued, s.jobs_active, s.event_queue_depth), (0, 0, 0), "{s}");
    assert_eq!(s.jobs_submitted, JOBS as u64);
    assert_eq!(s.jobs_completed + s.jobs_cancelled + s.jobs_failed, JOBS as u64, "{s}");
    assert_eq!(s.jobs_completed, done as u64);
    assert_eq!(s.jobs_cancelled, cancelled as u64);
    assert_eq!(s.jobs_failed, 0);
}

/// The same interleaving pressure, end to end through a live 4-worker
/// fleet: concurrent submitters race least-loaded dispatch, work stealing
/// and the shared eval cache (instead of a single scripted driver). Every
/// job must complete and the fleet gauges must balance back to zero.
#[test]
fn service_backed_stress_at_four_workers() {
    use diffaxe::coordinator::{Request, Service, ServiceConfig};
    use std::time::{Duration, Instant};
    const FLEET_JOBS: usize = 64;
    let mut cfg = ServiceConfig::mock();
    cfg.workers = 4;
    cfg.max_queued = 2 * FLEET_JOBS;
    let svc = Service::start(cfg).expect("fleet starts");
    let handle = svc.handle();
    let submitters: Vec<_> = (0..SUBMITTERS)
        .map(|_| {
            let handle = handle.clone();
            std::thread::spawn(move || {
                let rxs: Vec<_> = (0..FLEET_JOBS / SUBMITTERS)
                    .map(|_| handle.submit(Request::Search(request())))
                    .collect();
                rxs.into_iter()
                    .map(|rx| match rx.recv().expect("fleet alive") {
                        Response::Outcome(o) => {
                            assert_eq!(o.stopped, StopReason::Completed);
                            o.evals
                        }
                        other => panic!("unexpected {other:?}"),
                    })
                    .sum::<usize>()
            })
        })
        .collect();
    let mut evals = 0usize;
    for s in submitters {
        evals += s.join().expect("submitter");
    }
    assert_eq!(evals, 4 * FLEET_JOBS, "every job ran its full budget");

    // replies land before the worker drops its busy guard — give the
    // gauges a moment to settle, then demand exact balance
    let t0 = Instant::now();
    let snap = loop {
        let s = handle.metrics().snapshot();
        if (s.jobs_active, s.worker_busy) == (0, 0) || t0.elapsed() > Duration::from_secs(10) {
            break s;
        }
        std::thread::sleep(Duration::from_millis(2));
    };
    assert_eq!(snap.workers, 4, "{snap}");
    assert_eq!(snap.jobs_submitted, FLEET_JOBS as u64, "{snap}");
    assert_eq!(snap.jobs_completed, FLEET_JOBS as u64, "{snap}");
    assert_eq!((snap.jobs_failed, snap.jobs_cancelled, snap.jobs_shed), (0, 0, 0), "{snap}");
    assert_eq!((snap.jobs_queued, snap.jobs_active, snap.worker_busy), (0, 0, 0), "{snap}");
    assert_eq!(snap.worker_restarts, 0, "{snap}");
}
