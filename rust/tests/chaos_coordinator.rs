//! Chaos tests for the fault-tolerant coordinator, driven by the
//! deterministic [`FaultPlan`] injection sites (`util::fault`).
//!
//! **Hermetic**: every service here runs the mock engine
//! (`ServiceConfig::mock()`), and every fault fires on an exact per-site
//! hit index, so the crashes, restarts, and recoveries below are scripted,
//! not raced. The scenarios mirror the robustness contract in
//! `docs/INVARIANTS.md`:
//!
//! 1. a panic inside a search fails *that job* and the worker survives;
//! 2. a worker crash outside the isolation barrier restarts the worker
//!    (with backoff) and retries the in-flight job;
//! 3. a worker that keeps dying exhausts the restart budget: pending jobs
//!    fail terminally and the service rejects new work;
//! 4. submits past `max_queued` are shed with a structured `overloaded`
//!    error carrying a retry hint;
//! 5. dropping the service drains gracefully — queued jobs finalize,
//!    running jobs stop at a batch boundary, every watcher wakes;
//! 6. an injected sampler error fails the whole gen batch cleanly;
//! 7. in a 4-worker fleet, one worker crash retries the in-flight job and
//!    every tenant's outcome is bit-identical to a fault-free run (per-job
//!    seeds make retry and steal invisible to results);
//! 8. a slot that burns its restart budget goes dead while its siblings
//!    keep accepting and completing new work — capacity degrades,
//!    availability does not;
//! 9. the eval cache is process-wide: a hit produced by a *different*
//!    tenant's session surfaces in the service's scrapeable snapshot.

use diffaxe::coordinator::{
    ErrorCode, JobState, Request, Response, SearchRequest, Service, ServiceConfig,
};
use diffaxe::dse::{Budget, Objective, OptimizerKind, StopReason};
use diffaxe::util::fault::FaultPlan;
use diffaxe::workload::Gemm;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn gemm() -> Gemm {
    Gemm::new(64, 256, 256)
}

/// A small simulator-backed search (no engine dependency in the job body).
fn request(evals: usize) -> SearchRequest {
    SearchRequest::new(Objective::MinEdp { g: gemm() }, Budget::evals(evals), OptimizerKind::RandomSearch)
}

fn search(evals: usize) -> Request {
    Request::Search(request(evals))
}

/// A mock-engine config with fast supervisor timing and the given plan.
fn chaos_cfg(plan: &str) -> ServiceConfig {
    let mut cfg = ServiceConfig::mock();
    cfg.restart_backoff = Duration::from_millis(1);
    cfg.fault_plan = Some(Arc::new(FaultPlan::parse(plan, 7).expect("plan parses")));
    cfg
}

/// Block until the engine worker has picked up a job (so later submits
/// stay queued deterministically).
fn wait_for_active(svc: &Service) {
    let t0 = Instant::now();
    while svc.handle().metrics().snapshot().jobs_active < 1 {
        assert!(t0.elapsed() < Duration::from_secs(10), "worker never started a job");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Block until no job is active and every worker dropped its busy guard
/// (replies are sent *before* `run_job` returns, so gauges can trail the
/// response by a scheduling quantum).
fn wait_for_idle(svc: &Service) {
    let t0 = Instant::now();
    loop {
        let s = svc.handle().metrics().snapshot();
        if s.jobs_active == 0 && s.worker_busy == 0 {
            return;
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "fleet never went idle: {s}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn panic_inside_search_fails_the_job_but_the_worker_survives() {
    // hit 0 at the search-entry site panics; hit 1 (the next job) passes
    let svc = Service::start(chaos_cfg("engine-sample:panic=chaos-monkey@0")).unwrap();
    match svc.handle().request(search(8)) {
        Response::Error { code, message, .. } => {
            assert_eq!(code, ErrorCode::Internal);
            assert!(message.contains("search panicked"), "{message}");
            assert!(message.contains("chaos-monkey"), "{message}");
        }
        other => panic!("unexpected {other:?}"),
    }
    // same worker, next job: the panic was isolated to the first job
    match svc.handle().request(search(4)) {
        Response::Outcome(o) => assert_eq!(o.evals, 4),
        other => panic!("unexpected {other:?}"),
    }
    let s = svc.handle().metrics().snapshot();
    assert_eq!(s.jobs_failed, 1);
    assert_eq!(s.jobs_completed, 1);
    assert_eq!(s.worker_restarts, 0, "an isolated panic must not cost a restart");
}

#[test]
fn worker_crash_restarts_the_worker_and_retries_the_inflight_job() {
    // the first finalize panics OUTSIDE the per-job isolation barrier, so
    // the whole worker dies mid-job; the supervisor must respawn it and
    // rerun the job (attempt 2 finalizes cleanly on hit 1)
    let mut cfg = chaos_cfg("finalize:panic=registry-crash@0");
    cfg.max_attempts = 2;
    let svc = Service::start(cfg).unwrap();
    match svc.handle().request(search(4)) {
        Response::Outcome(o) => assert_eq!(o.evals, 4),
        other => panic!("unexpected {other:?}"),
    }
    let s = svc.handle().metrics().snapshot();
    assert_eq!(s.worker_restarts, 1);
    assert_eq!(s.jobs_failed, 0);
    let jobs = svc.handle().registry().list();
    assert_eq!(jobs.len(), 1);
    assert_eq!(jobs[0].state, JobState::Done);
    assert_eq!(jobs[0].attempts, 2, "the crashed attempt counts");
}

#[test]
fn restart_budget_exhaustion_fails_pending_jobs_and_rejects_new_work() {
    // worker 0 starts fine but dies at its first finalize; every respawn
    // (worker-start hits 1, 2, ...) dies immediately, so the supervisor
    // burns its 2 restarts and gives up
    let mut cfg = chaos_cfg("finalize:panic=first-crash@0;worker-start:panic=respawn-crash@1+100");
    cfg.max_attempts = 2;
    cfg.max_worker_restarts = 2;
    let svc = Service::start(cfg).unwrap();
    match svc.handle().request(search(4)) {
        Response::Error { code, message, .. } => {
            assert_eq!(code, ErrorCode::Internal);
            assert!(message.contains("restarts exhausted"), "{message}");
        }
        other => panic!("unexpected {other:?}"),
    }
    let s = svc.handle().metrics().snapshot();
    assert_eq!(s.worker_restarts, 2);
    assert_eq!(s.jobs_failed, 1);
    // nothing is left running or queued — the job is terminal
    let jobs = svc.handle().registry().list();
    assert_eq!(jobs.len(), 1);
    assert_eq!(jobs[0].state, JobState::Failed);
    // and a dead service sheds new work instead of queueing it forever
    match svc.handle().request(Request::Submit(request(4))) {
        Response::Error { code, message, .. } => {
            assert_eq!(code, ErrorCode::Internal);
            assert!(message.contains("unavailable"), "{message}");
        }
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn over_capacity_submits_are_shed_with_a_retry_hint() {
    let mut cfg = ServiceConfig::mock();
    cfg.max_queued = 2;
    let svc = Service::start(cfg).unwrap();
    // occupy the worker so subsequent submits stay queued
    let blocker_rx = svc.handle().submit(Request::Search(SearchRequest::new(
        Objective::MinEdp { g: gemm() },
        Budget::evals(50_000_000),
        OptimizerKind::RandomSearch,
    )));
    wait_for_active(&svc);
    // two jobs fill the bounded queue
    for _ in 0..2 {
        match svc.handle().request(Request::Submit(request(4))) {
            Response::Submitted { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
    }
    // the third is shed with a structured overloaded error + retry hint
    match svc.handle().request(Request::Submit(request(4))) {
        Response::Error { code, message, retry_after_ms } => {
            assert_eq!(code, ErrorCode::Overloaded);
            assert!(message.contains("queue full"), "{message}");
            let ms = retry_after_ms.expect("overload rejection carries retry_after_ms");
            assert!(ms > 0);
        }
        other => panic!("unexpected {other:?}"),
    }
    assert_eq!(svc.handle().metrics().snapshot().jobs_shed, 1);
    // unblock: drop drains — cancel reaches the blocker at a batch
    // boundary and its waiter still gets a terminal response
    drop(svc);
    match blocker_rx.recv().unwrap() {
        Response::Outcome(o) => assert_eq!(o.stopped, StopReason::Cancelled),
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn shutdown_finalizes_queued_jobs_and_wakes_every_watcher() {
    let svc = Service::start(ServiceConfig::mock()).unwrap();
    let handle = svc.handle();
    let registry = handle.registry();
    // a long blocker occupies the worker; two jobs queue behind it
    let blocker_rx = handle.submit(Request::Search(SearchRequest::new(
        Objective::MinEdp { g: gemm() },
        Budget::evals(50_000_000),
        OptimizerKind::RandomSearch,
    )));
    wait_for_active(&svc);
    let ids: Vec<String> = (0..2)
        .map(|_| match handle.request(Request::Submit(request(1000))) {
            Response::Submitted { job_id, .. } => job_id,
            other => panic!("unexpected {other:?}"),
        })
        .collect();
    // watchers block on each queued job's event stream
    let watchers: Vec<_> = ids
        .iter()
        .map(|id| {
            let entry = registry.get(id).unwrap();
            std::thread::spawn(move || {
                let mut seq = 0u64;
                loop {
                    let (s, _ev, terminal) = entry.next_event(seq);
                    seq = s;
                    if let Some((state, _resp)) = terminal {
                        return state;
                    }
                }
            })
        })
        .collect();
    let t0 = Instant::now();
    svc.shutdown(Duration::from_secs(2));
    // every queued job finalized, so every watcher woke and joined
    for w in watchers {
        assert_eq!(w.join().unwrap(), JobState::Cancelled);
    }
    assert!(t0.elapsed() < Duration::from_secs(10), "drain overran its deadline");
    // the running blocker was cancelled at a batch boundary, its
    // synchronous waiter answered
    match blocker_rx.recv().unwrap() {
        Response::Outcome(o) => assert_eq!(o.stopped, StopReason::Cancelled),
        other => panic!("unexpected {other:?}"),
    }
    for id in &ids {
        assert!(registry.get(id).unwrap().state().terminal(), "{id} left non-terminal");
    }
}

#[test]
fn injected_sampler_error_fails_the_gen_batch_cleanly() {
    // the continuous batcher's sampler call errors on hit 0; the batched
    // job fails with a structured error and the worker keeps serving
    let svc = Service::start(chaos_cfg("engine-sample:error=link down@0")).unwrap();
    let gen = |target: f64| {
        Request::Search(SearchRequest::new(
            Objective::Runtime { g: gemm(), target_cycles: target },
            Budget::evals(4),
            OptimizerKind::DiffAxE,
        ))
    };
    match svc.handle().request(gen(1e6)) {
        Response::Error { code, message, .. } => {
            assert_eq!(code, ErrorCode::Internal);
            assert!(message.contains("sampler failed"), "{message}");
            assert!(message.contains("link down"), "{message}");
        }
        other => panic!("unexpected {other:?}"),
    }
    // hit 1 passes: the batcher still serves generation
    match svc.handle().request(gen(2e6)) {
        Response::Outcome(o) => assert_eq!(o.ranked.len(), 4),
        other => panic!("unexpected {other:?}"),
    }
    let s = svc.handle().metrics().snapshot();
    assert_eq!(s.jobs_failed, 1);
    assert_eq!(s.worker_restarts, 0);
}

#[test]
fn sampler_error_fails_only_the_round_that_owned_the_call() {
    use diffaxe::dse::llm::Platform;
    use diffaxe::workload::{LlmModel, Stage};
    // a generous batch window lets both generative jobs join `pending`
    // before the first flush, so they are provably co-pending when the
    // fault fires
    let mut cfg = chaos_cfg("engine-sample:error=blast radius@1");
    cfg.batch_window = Duration::from_millis(250);
    let svc = Service::start(cfg).unwrap();
    let rt_rx = svc.handle().submit(Request::Search(SearchRequest::new(
        Objective::Runtime { g: gemm(), target_cycles: 1e6 },
        Budget::evals(4),
        OptimizerKind::DiffAxE,
    )));
    let llm_rx = svc.handle().submit(Request::Search(SearchRequest::new(
        Objective::LlmEdp {
            model: LlmModel::BertBase,
            stage: Stage::Prefill,
            seq: 128,
            platform: Platform::Asic32nm,
        },
        Budget::evals(4),
        OptimizerKind::DiffAxE,
    )));
    // flush order is [Runtime, Class]: the runtime family's sampler call
    // consumes fault hit 0 and succeeds; the LLM class call lands on hit 1
    // and errors. The error must fail ONLY the class round's owner — the
    // co-pending runtime job already holds its draws and completes.
    match rt_rx.recv().unwrap() {
        Response::Outcome(o) => assert_eq!(o.evals, 4),
        other => panic!("runtime job must survive the class-round fault: {other:?}"),
    }
    match llm_rx.recv().unwrap() {
        Response::Error { code, message, .. } => {
            assert_eq!(code, ErrorCode::Internal);
            assert!(message.contains("sampler failed"), "{message}");
            assert!(message.contains("blast radius"), "{message}");
        }
        other => panic!("unexpected {other:?}"),
    }
    let s = svc.handle().metrics().snapshot();
    assert_eq!((s.jobs_completed, s.jobs_failed), (1, 1), "{s}");
    assert_eq!(s.worker_restarts, 0, "{s}");
}

/// Run the same 8 simulator-backed jobs on a 4-worker fleet and return
/// each job's (evals, best score) in submission order. `run_job` outcomes
/// depend only on the per-job seed (derived from the job number), never
/// on which worker executes the job, whether it was stolen, or how many
/// crash-retries it took — so two runs must agree bit-for-bit.
fn fleet_outcomes(cfg: ServiceConfig) -> (Vec<(usize, f64)>, Service) {
    let svc = Service::start(cfg).unwrap();
    let rxs: Vec<_> = (0..8).map(|i| svc.handle().submit(search(4 + i))).collect();
    let outs = rxs
        .into_iter()
        .map(|rx| match rx.recv().unwrap() {
            Response::Outcome(o) => (o.evals, o.best_score()),
            other => panic!("unexpected {other:?}"),
        })
        .collect();
    (outs, svc)
}

#[test]
fn fleet_worker_crash_retries_and_outcomes_match_a_fault_free_run() {
    // baseline: healthy 4-worker fleet
    let mut base_cfg = ServiceConfig::mock();
    base_cfg.workers = 4;
    let (baseline, base_svc) = fleet_outcomes(base_cfg);
    drop(base_svc);

    // fault run: the first finalize anywhere in the fleet panics OUTSIDE
    // the isolation barrier, killing that worker mid-job; the supervisor
    // respawns it and the job re-runs under the same per-job seed
    let mut cfg = chaos_cfg("finalize:panic=fleet-crash@0");
    cfg.workers = 4;
    cfg.max_attempts = 2;
    let (outs, svc) = fleet_outcomes(cfg);
    assert_eq!(outs, baseline, "a worker crash must not change any tenant's outcome");

    wait_for_idle(&svc);
    let s = svc.handle().metrics().snapshot();
    assert_eq!(s.worker_restarts, 1, "{s}");
    assert_eq!(s.jobs_completed, 8, "{s}");
    assert_eq!((s.jobs_failed, s.jobs_shed), (0, 0), "shed/retry accounting: {s}");
    assert_eq!((s.jobs_queued, s.jobs_active, s.worker_busy), (0, 0, 0), "{s}");
    assert_eq!(s.workers, 4);
    // exactly one job carries the crashed attempt; every other ran once
    let attempts: Vec<u32> = svc.handle().registry().list().iter().map(|j| j.attempts).collect();
    assert_eq!(attempts.iter().sum::<u32>(), 9, "{attempts:?}");
    assert_eq!(attempts.iter().filter(|&&a| a == 2).count(), 1, "{attempts:?}");
}

#[test]
fn fleet_dead_slot_degrades_capacity_not_availability() {
    // startup consumes worker-start hits 0..2 (workers=2); every respawn
    // (hits 2..) dies, so the slot that crashes at its first finalize
    // burns the 2-restart budget and goes permanently dead
    let mut cfg = chaos_cfg("finalize:panic=perma@0;worker-start:panic=respawn@2+100");
    cfg.workers = 2;
    cfg.max_attempts = 3;
    cfg.max_worker_restarts = 2;
    let svc = Service::start(cfg).unwrap();
    // the triggering job either gets stolen by the sibling before the
    // dying slot gives up (Outcome) or drains with the slot (Error) —
    // both are terminal; what must NOT happen is a hang or a lost reply
    match svc.handle().submit(search(4)).recv().unwrap() {
        Response::Outcome(o) => assert_eq!(o.evals, 4),
        Response::Error { code, .. } => assert_eq!(code, ErrorCode::Internal),
        other => panic!("unexpected {other:?}"),
    }
    // wait until the restart budget is provably exhausted
    let t0 = Instant::now();
    while svc.handle().metrics().snapshot().worker_restarts < 2 {
        assert!(t0.elapsed() < Duration::from_secs(10), "slot never burned its restarts");
        std::thread::sleep(Duration::from_millis(2));
    }
    // unlike the single-worker case, the fleet still serves: admission
    // routes around the dead slot to its live sibling
    for evals in [4usize, 6, 8] {
        match svc.handle().request(search(evals)) {
            Response::Outcome(o) => assert_eq!(o.evals, evals),
            other => panic!("sibling refused work: {other:?}"),
        }
    }
    wait_for_idle(&svc);
    let s = svc.handle().metrics().snapshot();
    assert_eq!(s.worker_restarts, 2, "{s}");
    assert!(s.jobs_completed >= 3, "{s}");
    assert_eq!((s.jobs_queued, s.jobs_active, s.worker_busy), (0, 0, 0), "{s}");
}

#[test]
fn shared_eval_cache_hits_cross_tenants_and_surface_in_the_snapshot() {
    use diffaxe::design_space::{HwConfig, LoopOrder};
    use diffaxe::dse::Session;
    // tenant A: a plain in-process Session — it holds the same process-wide
    // eval cache the fleet workers do
    let tenant_a = Session::mock();
    let hw = HwConfig::new_kb(16, 16, 64.0, 64.0, 16.0, 8, LoopOrder::from_name("mnk").unwrap());
    let _ = tenant_a.evaluate_batch(&[hw], &gemm()); // cold: populates the shared cache
    let _ = tenant_a.evaluate_batch(&[hw], &gemm()); // warm: a guaranteed hit
    // tenant B: the service. Its workers mirror the *shared* cumulative
    // cache counters into the snapshot after every evaluation burst, so
    // tenant A's hit must be visible through the service's metrics.
    let mut cfg = ServiceConfig::mock();
    cfg.workers = 2;
    let svc = Service::start(cfg).unwrap();
    match svc.handle().request(Request::Search(SearchRequest::new(
        Objective::Runtime { g: gemm(), target_cycles: 1e6 },
        Budget::evals(4),
        OptimizerKind::DiffAxE,
    ))) {
        Response::Outcome(o) => assert_eq!(o.evals, 4),
        other => panic!("unexpected {other:?}"),
    }
    let s = svc.handle().metrics().snapshot();
    assert!(s.cache_hits >= 1, "tenant A's cache hit must surface in the service snapshot: {s}");
}
