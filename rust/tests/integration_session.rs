//! Session-level integration tests for the unified DSE API: determinism of
//! every engine-backed optimizer and the batched evaluation contract.
//!
//! **Hermetic**: without `artifacts/` the suite runs every engine-kind
//! path against the deterministic mock engine ([`DiffAxE::mock`]) instead
//! of SKIPping; with artifacts present it runs the real engine (the
//! opt-in superset).
//!
//! PJRT handles are !Send, so the session cannot live in a shared static:
//! this binary runs all checks sequentially against ONE session instance
//! (artifact compilation is the expensive part).

use diffaxe::dse::{
    Budget, Objective, OptimizerKind, SearchCtx, SearchOutcome, Session, StopReason,
};
use diffaxe::models::DiffAxE;
use diffaxe::workload::Gemm;
use std::path::Path;

#[test]
fn session_integration_suite() {
    let dir = Path::new("artifacts");
    let mut s = if DiffAxE::artifacts_present(dir) {
        eprintln!("integration_session: running against real artifacts/");
        Session::load(dir).expect("session load")
    } else {
        eprintln!("integration_session: artifacts/ missing — running the hermetic mock engine");
        Session::mock()
    };
    every_optimizer_kind_is_deterministic_in_seed(&mut s);
    runtime_objective_deterministic_for_generative_methods(&mut s);
    diffaxe_honours_eval_budget(&mut s);
    batch_evaluation_matches_scalar_path(&s);
    every_optimizer_kind_honours_a_deadline(&mut s);
    cancellation_stops_engine_backed_searches(&mut s);
}

fn assert_same(a: &SearchOutcome, b: &SearchOutcome) {
    assert_eq!(a.optimizer, b.optimizer);
    assert_eq!(a.evals, b.evals, "{}", a.optimizer);
    assert_eq!(a.trace, b.trace, "{} trace differs", a.optimizer);
    assert_eq!(a.ranked, b.ranked, "{} ranking differs", a.optimizer);
}

fn every_optimizer_kind_is_deterministic_in_seed(session: &mut Session) {
    let g = Gemm::new(128, 768, 2304);
    let budget = Budget::evals(12).with_per_class(2);
    for kind in OptimizerKind::ALL {
        // GANDSE serves only runtime objectives; everything else is
        // exercised on MinEdp (plus a Runtime spot-check below)
        let obj = match kind {
            OptimizerKind::GanDse => Objective::Runtime { g, target_cycles: 1e6 },
            _ => Objective::MinEdp { g },
        };
        let a = session.search(kind, &obj, &budget, 77).unwrap();
        let b = session.search(kind, &obj, &budget, 77).unwrap();
        assert_same(&a, &b);
        assert!(!a.ranked.is_empty(), "{kind:?} produced nothing");
    }
}

fn runtime_objective_deterministic_for_generative_methods(session: &mut Session) {
    let g = Gemm::new(128, 768, 2304);
    let obj = Objective::Runtime { g, target_cycles: 1e6 };
    for kind in [OptimizerKind::DiffAxE, OptimizerKind::GanDse, OptimizerKind::LatentBo] {
        let a = session.search(kind, &obj, &Budget::evals(8), 5).unwrap();
        let b = session.search(kind, &obj, &Budget::evals(8), 5).unwrap();
        assert_same(&a, &b);
    }
}

fn diffaxe_honours_eval_budget(session: &mut Session) {
    let g = Gemm::new(128, 768, 2304);
    let obj = Objective::Runtime { g, target_cycles: 1e6 };
    for n in [1, 7, 40] {
        let out = session.search(OptimizerKind::DiffAxE, &obj, &Budget::evals(n), 9).unwrap();
        assert_eq!(out.evals, n);
        assert_eq!(out.trace.len(), n);
    }
}

/// Every kind — engine-backed included — must come back promptly under a
/// 50 ms deadline. Simulator-backed kinds poll between cheap evaluation
/// chunks (~2x is plenty); the generative kinds may straddle one diffusion
/// sampler call / encode prelude, so they get one-batch slack on top.
fn every_optimizer_kind_honours_a_deadline(session: &mut Session) {
    let g = Gemm::new(128, 768, 2304);
    for kind in OptimizerKind::ALL {
        let obj = match kind {
            OptimizerKind::GanDse => Objective::Runtime { g, target_cycles: 1e6 },
            _ => Objective::MinEdp { g },
        };
        let ctx = SearchCtx::background().with_deadline_in(0.05);
        let t = std::time::Instant::now();
        let out = session.search_ctx(kind, &ctx, &obj, &Budget::evals(1_000_000), 21).unwrap();
        let elapsed = t.elapsed().as_secs_f64();
        let one_shot = matches!(
            kind,
            OptimizerKind::Fixed(_) | OptimizerKind::AirchitectV1 | OptimizerKind::AirchitectV2
        );
        if one_shot {
            assert_eq!(out.stopped, StopReason::Completed, "{kind:?}");
        } else {
            assert_eq!(out.stopped, StopReason::DeadlineExceeded, "{kind:?}");
            assert!(out.evals < 1_000_000, "{kind:?}");
        }
        let bound = if kind.needs_engine() { 2.0 } else { 0.2 };
        assert!(elapsed < bound, "{kind:?} took {elapsed:.3}s against a 0.05s deadline");
    }
}

fn cancellation_stops_engine_backed_searches(session: &mut Session) {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    let g = Gemm::new(128, 768, 2304);
    let obj = Objective::MinEdp { g };
    let flag = Arc::new(AtomicBool::new(false));
    let canceller = {
        let flag = flag.clone();
        std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(50));
            flag.store(true, Ordering::SeqCst);
        })
    };
    let ctx = SearchCtx::background().with_cancel_flag(flag);
    let out = session
        .search_ctx(OptimizerKind::DiffAxE, &ctx, &obj, &Budget::evals(1_000_000), 23)
        .unwrap();
    canceller.join().unwrap();
    assert_eq!(out.stopped, StopReason::Cancelled);
    assert!(out.evals < 1_000_000);
}

fn batch_evaluation_matches_scalar_path(session: &Session) {
    let engine = session.engine().expect("engine");
    let g =
        engine.stats.workloads.first().map(|w| w.gemm).unwrap_or_else(|| Gemm::new(64, 256, 512));
    let cfgs: Vec<_> = (0..128)
        .map(|i| {
            let mut rng = diffaxe::util::rng::split(3, i);
            diffaxe::design_space::TargetSpace::sample(&mut rng)
        })
        .collect();
    for (hw, (s, e)) in cfgs.iter().zip(session.evaluate_batch(&cfgs, &g)) {
        let (s2, e2) = diffaxe::dse::evaluate(hw, &g);
        assert_eq!(s, s2);
        assert_eq!(e, e2);
    }
}
