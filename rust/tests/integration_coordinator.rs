//! Integration tests over the coordinator service + TCP server.
//!
//! **Hermetic**: without `artifacts/` the service runs the deterministic
//! mock engine (`ServiceConfig::mock()`), so every engine-kind wire path
//! executes in CI instead of SKIPping; with artifacts present the real
//! engine serves the same suite (the opt-in superset).

use diffaxe::baselines::FixedArch;
use diffaxe::coordinator::{
    server, ErrorCode, JobState, Request, Response, SearchRequest, Service, ServiceConfig,
};
use diffaxe::dse::{llm::Platform, Budget, Objective, OptimizerKind, StopReason, StructuredSpec};
use diffaxe::models::DiffAxE;
use diffaxe::workload::{Gemm, LlmModel, Stage};
use std::path::Path;

use std::sync::{Mutex, OnceLock};

/// One service for the whole test binary (artifact compilation is the
/// expensive part); a mutex serializes tests that read metrics counters.
fn service() -> Option<std::sync::MutexGuard<'static, Service>> {
    static SVC: OnceLock<Option<Mutex<Service>>> = OnceLock::new();
    SVC.get_or_init(|| {
        let cfg = if DiffAxE::artifacts_present(Path::new("artifacts")) {
            eprintln!("integration_coordinator: running against real artifacts/");
            ServiceConfig::new("artifacts")
        } else {
            eprintln!(
                "integration_coordinator: artifacts/ missing — serving the hermetic mock engine"
            );
            ServiceConfig::mock()
        };
        Some(Mutex::new(Service::start(cfg).expect("service start")))
    })
    .as_ref()
    .map(|m| m.lock().unwrap())
}

fn some_workload() -> Gemm {
    Gemm::new(128, 768, 2304)
}

fn generate(g: Gemm, target_cycles: f64, n: usize) -> Request {
    Request::Search(SearchRequest::new(
        Objective::Runtime { g, target_cycles },
        Budget::evals(n),
        OptimizerKind::DiffAxE,
    ))
}

#[test]
fn generate_request_roundtrip() {
    let Some(svc) = service() else { return };
    let g = some_workload();
    let resp = svc.handle().request(generate(g, 1e6, 8));
    match resp {
        Response::Outcome(o) => {
            assert_eq!(o.evals, 8);
            assert_eq!(o.ranked.len(), 8);
            assert_eq!(o.trace.len(), 8);
            assert_eq!(o.optimizer, "DiffAxE");
            for d in &o.ranked {
                assert!(d.hw.in_target_space());
                assert!(d.cycles > 0.0 && d.power_w > 0.0 && d.edp > 0.0);
            }
            // ranked is best-first under |err|/T*
            let err = |d: &diffaxe::dse::DesignReport| ((d.cycles - 1e6) / 1e6).abs();
            for w in o.ranked.windows(2) {
                assert!(err(&w[0]) <= err(&w[1]));
            }
        }
        other => panic!("unexpected response {other:?}"),
    }
}

#[test]
fn concurrent_requests_are_batched_together() {
    let Some(svc) = service() else { return };
    let g = some_workload();
    // submit several requests before any can complete; the batcher should
    // pack them into shared sampler calls
    let rxs: Vec<_> = (0..6)
        .map(|i| svc.handle().submit(generate(g, 5e5 * (i + 1) as f64, 4)))
        .collect();
    for rx in rxs {
        match rx.recv().unwrap() {
            Response::Outcome(o) => assert_eq!(o.ranked.len(), 4),
            other => panic!("unexpected {other:?}"),
        }
    }
    let snap = svc.handle().metrics().snapshot();
    assert!(snap.requests >= 6);
    assert!(snap.sampler_calls >= 1);
    assert!(snap.batch_occupancy > 0.0);
}

#[test]
fn oversized_request_spans_batches() {
    let Some(svc) = service() else { return };
    let g = some_workload();
    // request more than any plausible sampler batch; ask to keep all ranks
    let n = 160;
    let mut req = SearchRequest::new(
        Objective::Runtime { g, target_cycles: 1e6 },
        Budget::evals(n),
        OptimizerKind::DiffAxE,
    );
    req.top_k = Some(n);
    match svc.handle().request(Request::Search(req)) {
        Response::Outcome(o) => {
            assert_eq!(o.evals, n);
            assert_eq!(o.ranked.len(), n);
        }
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn edp_and_perf_search_requests() {
    let Some(svc) = service() else { return };
    let g = some_workload();
    let req = Request::Search(SearchRequest::new(
        Objective::MinEdp { g },
        Budget::default().with_per_class(4),
        OptimizerKind::DiffAxE,
    ));
    match svc.handle().request(req) {
        Response::Outcome(o) => {
            assert!(!o.ranked.is_empty());
            assert!(o.ranked[0].edp > 0.0);
            // best-first by EDP
            assert!(o.ranked.first().unwrap().edp <= o.ranked.last().unwrap().edp);
        }
        other => panic!("unexpected {other:?}"),
    }
    let req = Request::Search(SearchRequest::new(
        Objective::MaxPerf { g },
        Budget::evals(16),
        OptimizerKind::DiffAxE,
    ));
    match svc.handle().request(req) {
        Response::Outcome(o) => assert_eq!(o.evals, 16),
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn llm_search_request() {
    let Some(svc) = service() else { return };
    let req = Request::Search(SearchRequest::new(
        Objective::LlmEdp {
            model: LlmModel::BertBase,
            stage: Stage::Decode,
            seq: diffaxe::workload::llm::DEFAULT_SEQ,
            platform: diffaxe::dse::llm::Platform::Asic32nm,
        },
        Budget::default().with_per_class(4),
        OptimizerKind::DiffAxE,
    ));
    match svc.handle().request(req) {
        Response::Outcome(o) => {
            assert!(!o.ranked.is_empty());
            assert!(o.ranked[0].hw.in_target_space());
        }
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn optimizers_selectable_by_name_over_the_wire() {
    let Some(svc) = service() else { return };
    let g = some_workload();
    // every strategy is reachable through the same generic request
    for (name, expect) in [
        ("random", "Random Search"),
        ("vanilla-bo", "Vanilla BO"),
        ("latent-bo", "Latent BO (VAESA)"),
        ("vanilla-gd", "Vanilla GD"),
        ("dosa-gd", "DOSA (coarse GD)"),
        ("polaris", "Polaris (latent GD)"),
        ("fixed-nvdla", "NVDLA"),
        ("diffaxe", "DiffAxE"),
    ] {
        let req = Request::Search(SearchRequest::new(
            Objective::MinEdp { g },
            Budget::evals(12),
            OptimizerKind::parse(name).unwrap(),
        ));
        match svc.handle().request(req) {
            Response::Outcome(o) => {
                assert_eq!(o.optimizer, expect, "wire name {name}");
                assert!(!o.ranked.is_empty());
            }
            other => panic!("{name}: unexpected {other:?}"),
        }
    }
}

#[test]
fn unsupported_pairing_is_a_bad_request_before_any_work() {
    let Some(svc) = service() else { return };
    let g = some_workload();
    // GANDSE is runtime-conditioned only: pairing it with min-EDP must be
    // rejected as a client error, not reported as an internal failure
    let req = Request::Search(SearchRequest::new(
        Objective::MinEdp { g },
        Budget::evals(8),
        OptimizerKind::GanDse,
    ));
    match svc.handle().request(req) {
        Response::Error { code, .. } => assert_eq!(code, ErrorCode::BadRequest),
        other => panic!("unexpected {other:?}"),
    }
    // in a batch, validation runs before any item executes
    let req = Request::Batch(vec![
        SearchRequest::new(Objective::MinEdp { g }, Budget::evals(8), OptimizerKind::RandomSearch),
        SearchRequest::new(Objective::MinEdp { g }, Budget::evals(8), OptimizerKind::GanDse),
    ]);
    match svc.handle().request(req) {
        Response::Error { code, message, .. } => {
            assert_eq!(code, ErrorCode::BadRequest);
            assert!(message.contains("batch item 1"), "{message}");
        }
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn batch_request_returns_outcomes_in_order() {
    let Some(svc) = service() else { return };
    let g = some_workload();
    let req = Request::Batch(vec![
        SearchRequest::new(Objective::MinEdp { g }, Budget::evals(8), OptimizerKind::RandomSearch),
        SearchRequest::new(
            Objective::MaxPerf { g },
            Budget::evals(1),
            OptimizerKind::Fixed(FixedArch::Eyeriss),
        ),
        SearchRequest::new(
            Objective::Runtime { g, target_cycles: 1e6 },
            Budget::evals(4),
            OptimizerKind::DiffAxE,
        ),
    ]);
    match svc.handle().request(req) {
        Response::Batch(outs) => {
            assert_eq!(outs.len(), 3);
            assert_eq!(outs[0].optimizer, "Random Search");
            assert_eq!(outs[1].optimizer, "Eyeriss");
            assert_eq!(outs[1].ranked[0].hw, FixedArch::Eyeriss.config());
            assert_eq!(outs[2].optimizer, "DiffAxE");
            assert_eq!(outs[2].evals, 4);
        }
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn tcp_server_end_to_end() {
    let Some(svc) = service() else { return };
    let addr = server::serve_ephemeral(svc.handle()).unwrap();
    let mut client = server::Client::connect(&addr).unwrap();
    let resp = client.request(&generate(some_workload(), 2e6, 4)).unwrap();
    match resp {
        Response::Outcome(o) => assert_eq!(o.ranked.len(), 4),
        other => panic!("unexpected {other:?}"),
    }
    let resp = client.request(&Request::Metrics).unwrap();
    match resp {
        Response::MetricsText(t) => assert!(t.contains("requests=")),
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn tcp_legacy_aliases_and_errors() {
    let Some(svc) = service() else { return };
    let addr = server::serve_ephemeral(svc.handle()).unwrap();
    let mut client = server::Client::connect(&addr).unwrap();

    // a v1 client line still works end to end
    let resp = client
        .send_line(r#"{"type":"generate","m":128,"k":768,"n":2304,"target_cycles":1e6,"count":4}"#)
        .unwrap();
    match resp {
        Response::Outcome(o) => assert_eq!(o.ranked.len(), 4),
        other => panic!("unexpected {other:?}"),
    }

    // a newer-versioned envelope gets a structured error, same connection
    let resp = client.send_line(r#"{"v":99,"type":"search"}"#).unwrap();
    match resp {
        Response::Error { code, .. } => assert_eq!(code, ErrorCode::UnsupportedVersion),
        other => panic!("unexpected {other:?}"),
    }

    // malformed JSON also answers instead of hanging up
    let resp = client.send_line("{not json").unwrap();
    match resp {
        Response::Error { code, .. } => assert_eq!(code, ErrorCode::BadRequest),
        other => panic!("unexpected {other:?}"),
    }

    // and the connection is still alive afterwards
    let resp = client.send_line(r#"{"type":"metrics"}"#).unwrap();
    assert!(matches!(resp, Response::MetricsText(_)));
}

#[test]
fn service_survives_unknown_workloads() {
    // nearest-stats fallback: a workload not in the training suite
    let Some(svc) = service() else { return };
    let g = Gemm::new(333, 777, 1234);
    match svc.handle().request(generate(g, 1e6, 4)) {
        Response::Outcome(o) => assert_eq!(o.ranked.len(), 4),
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn structured_search_over_the_wire() {
    let Some(svc) = service() else { return };
    let spec = StructuredSpec::new(LlmModel::BertBase, Stage::Prefill, 64, Platform::Asic32nm, 3);
    for kind in [
        OptimizerKind::DiffAxE,
        OptimizerKind::DosaGd,
        OptimizerKind::RandomSearch,
        OptimizerKind::VanillaBo,
    ] {
        let req = Request::Search(SearchRequest::new(
            Objective::StructuredEdp { spec },
            Budget::evals(24),
            kind,
        ));
        match svc.handle().request(req) {
            Response::Outcome(o) => {
                assert!(!o.ranked.is_empty(), "{kind:?} produced nothing");
                assert_eq!(o.segments.len(), o.ranked.len(), "{kind:?}");
                for (d, segs) in o.ranked.iter().zip(&o.segments) {
                    assert_eq!(segs.len(), 3, "{kind:?}");
                    let bw = segs[0].bw;
                    for s in segs {
                        assert!(s.in_target_space(), "{kind:?}: {s}");
                        assert!(spec.budget.admits(s), "{kind:?}: {s}");
                        assert_eq!(s.bw, bw, "{kind:?}: segments must share one DRAM link");
                    }
                    assert!(d.edp > 0.0 && d.cycles > 0.0, "{kind:?}");
                }
            }
            other => panic!("{kind:?}: unexpected {other:?}"),
        }
    }
    // a structured objective with a non-structured-capable optimizer is a
    // client error rejected before any budget is spent
    let req = Request::Search(SearchRequest::new(
        Objective::StructuredEdp { spec },
        Budget::evals(8),
        OptimizerKind::GanDse,
    ));
    match svc.handle().request(req) {
        Response::Error { code, .. } => assert_eq!(code, ErrorCode::BadRequest),
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn zero_budget_batched_request_returns_empty_outcome() {
    // PR-4 contract: Budget::evals(0) is answered with a well-formed empty
    // outcome from *every* path — including the continuous batcher, which
    // used to force a minimum of one generated design
    let Some(svc) = service() else { return };
    match svc.handle().request(generate(some_workload(), 1e6, 0)) {
        Response::Outcome(o) => {
            assert_eq!(o.evals, 0);
            assert!(o.ranked.is_empty());
            assert!(o.trace.is_empty());
            assert_eq!(o.stopped, StopReason::BudgetExhausted);
        }
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn batch_window_excludes_registry_queue_wait() {
    // batchable requests that sat queued behind a long non-batchable job
    // must still get a full batch window to coalesce — the window clock
    // starts when a request joins the batcher, not at submission. With
    // the old clock, each request "expired" the moment the blocker
    // finished and flushed alone (two sampler calls instead of one).
    let mut cfg = if DiffAxE::artifacts_present(Path::new("artifacts")) {
        ServiceConfig::new("artifacts")
    } else {
        ServiceConfig::mock()
    };
    cfg.batch_window = std::time::Duration::from_millis(200);
    let svc = Service::start(cfg).expect("service start");
    // occupy the engine loop well past the batch window
    let blocker = svc.handle().submit(Request::Search(SearchRequest::new(
        Objective::MinEdp { g: some_workload() },
        Budget::evals(50_000_000).with_wall_clock(0.4),
        OptimizerKind::RandomSearch,
    )));
    // two batchable requests queue behind it (~400 ms > the 200 ms window)
    let a = svc.handle().submit(generate(some_workload(), 1e6, 4));
    let b = svc.handle().submit(generate(some_workload(), 2e6, 4));
    blocker.recv().unwrap();
    for rx in [a, b] {
        match rx.recv().unwrap() {
            Response::Outcome(o) => assert_eq!(o.ranked.len(), 4),
            other => panic!("unexpected {other:?}"),
        }
    }
    let snap = svc.handle().metrics().snapshot();
    assert_eq!(
        snap.sampler_calls, 1,
        "queued batchable requests must coalesce into one sampler call"
    );
}

// ---------------------------------------------------------------------------
// v3: jobs, streaming, cancellation, deadlines
// ---------------------------------------------------------------------------

#[test]
fn v3_submit_watch_streams_events_then_outcome() {
    let Some(svc) = service() else { return };
    let addr = server::serve_ephemeral(svc.handle()).unwrap();
    let mut client = server::Client::connect(&addr).unwrap();
    let job_id = client
        .submit(&SearchRequest::new(
            Objective::Runtime { g: some_workload(), target_cycles: 1e6 },
            Budget::evals(16),
            OptimizerKind::DiffAxE,
        ))
        .unwrap();
    assert!(job_id.starts_with("job-"), "{job_id}");
    let mut events = 0usize;
    let mut last_evals = 0usize;
    let terminal = client
        .watch(&job_id, |ev| {
            events += 1;
            assert!(ev.evals >= last_evals, "progress went backwards");
            last_evals = ev.evals;
        })
        .unwrap();
    // acceptance: ≥1 progress event precedes the terminal outcome line
    assert!(events >= 1, "watch delivered no progress events");
    match terminal {
        Response::JobOutcome { job_id: id, outcome } => {
            assert_eq!(id, job_id);
            assert_eq!(outcome.stopped, StopReason::Completed);
            assert_eq!(outcome.evals, 16);
        }
        other => panic!("unexpected terminal {other:?}"),
    }
    // the registry retains the finished job for status queries
    let info = client.status(&job_id).unwrap();
    assert_eq!(info.state, JobState::Done);
    assert_eq!(info.evals, 16);
    // and it shows up in the listing with job gauges exported
    assert!(client.jobs().unwrap().iter().any(|j| j.id == job_id));
    let snap = svc.handle().metrics().snapshot();
    assert!(snap.jobs_submitted >= 1);
}

#[test]
fn v3_cancel_stops_a_running_search_with_partial_outcome() {
    let Some(svc) = service() else { return };
    let addr = server::serve_ephemeral(svc.handle()).unwrap();
    let mut client = server::Client::connect(&addr).unwrap();
    // a search far too large to finish quickly (the acceptance scenario:
    // an optimization baseline grinding a huge budget)
    for kind in [OptimizerKind::RandomSearch, OptimizerKind::VanillaBo] {
        let job_id = client
            .submit(&SearchRequest::new(
                Objective::MinEdp { g: some_workload() },
                Budget::evals(50_000_000),
                kind,
            ))
            .unwrap();
        std::thread::sleep(std::time::Duration::from_millis(150));
        let info = client.cancel(&job_id).unwrap();
        assert!(
            matches!(info.state, JobState::Running | JobState::Cancelled),
            "{kind:?}: {:?}",
            info.state
        );
        // watch after cancel: the stream still ends with the terminal line
        let terminal = client.watch(&job_id, |_| {}).unwrap();
        match terminal {
            Response::JobOutcome { outcome, .. } => {
                assert_eq!(outcome.stopped, StopReason::Cancelled, "{kind:?}");
                assert!(!outcome.ranked.is_empty(), "{kind:?} lost its partial designs");
                assert!(outcome.evals < 50_000_000);
            }
            other => panic!("{kind:?}: unexpected terminal {other:?}"),
        }
        assert_eq!(client.status(&job_id).unwrap().state, JobState::Cancelled);
    }
}

#[test]
fn v3_queued_job_cancels_without_running() {
    let Some(svc) = service() else { return };
    let addr = server::serve_ephemeral(svc.handle()).unwrap();
    let mut client = server::Client::connect(&addr).unwrap();
    // occupy the engine, then queue a second job behind it
    let blocker = client
        .submit(&SearchRequest::new(
            Objective::MinEdp { g: some_workload() },
            Budget::evals(50_000_000),
            OptimizerKind::RandomSearch,
        ))
        .unwrap();
    let queued = client
        .submit(&SearchRequest::new(
            Objective::MinEdp { g: some_workload() },
            Budget::evals(1000),
            OptimizerKind::RandomSearch,
        ))
        .unwrap();
    let info = client.cancel(&queued).unwrap();
    assert_eq!(info.state, JobState::Cancelled);
    assert_eq!(info.evals, 0, "a never-started job has an empty outcome");
    // the cancelled-while-queued job streams its synthetic event + outcome
    match client.watch(&queued, |_| {}).unwrap() {
        Response::JobOutcome { outcome, .. } => {
            assert_eq!(outcome.stopped, StopReason::Cancelled);
            assert!(outcome.ranked.is_empty());
        }
        other => panic!("unexpected terminal {other:?}"),
    }
    // clean up the blocker so later tests aren't queued behind it
    client.cancel(&blocker).unwrap();
    match client.watch(&blocker, |_| {}).unwrap() {
        Response::JobOutcome { outcome, .. } => {
            assert_eq!(outcome.stopped, StopReason::Cancelled)
        }
        other => panic!("unexpected terminal {other:?}"),
    }
}

#[test]
fn v3_wall_clock_deadline_over_the_wire() {
    let Some(svc) = service() else { return };
    let addr = server::serve_ephemeral(svc.handle()).unwrap();
    let mut client = server::Client::connect(&addr).unwrap();
    let job_id = client
        .submit(&SearchRequest::new(
            Objective::MinEdp { g: some_workload() },
            Budget::evals(50_000_000).with_wall_clock(0.05),
            OptimizerKind::RandomSearch,
        ))
        .unwrap();
    match client.watch(&job_id, |_| {}).unwrap() {
        Response::JobOutcome { outcome, .. } => {
            assert_eq!(outcome.stopped, StopReason::DeadlineExceeded);
            assert!(outcome.evals < 50_000_000);
            assert!(!outcome.ranked.is_empty());
        }
        other => panic!("unexpected terminal {other:?}"),
    }
    assert_eq!(client.status(&job_id).unwrap().state, JobState::Done);
}

#[test]
fn v3_unknown_job_is_a_bad_request_everywhere() {
    let Some(svc) = service() else { return };
    let addr = server::serve_ephemeral(svc.handle()).unwrap();
    let mut client = server::Client::connect(&addr).unwrap();
    for line in [
        r#"{"v":3,"type":"status","job_id":"job-999999"}"#,
        r#"{"v":3,"type":"cancel","job_id":"job-999999"}"#,
        r#"{"v":3,"type":"watch","job_id":"job-999999"}"#,
    ] {
        match client.send_line(line).unwrap() {
            Response::Error { code, message, .. } => {
                assert_eq!(code, ErrorCode::BadRequest, "{line}");
                assert!(message.contains("job-999999"), "{message}");
            }
            other => panic!("{line}: unexpected {other:?}"),
        }
    }
    // the connection still serves ordinary requests afterwards
    assert!(matches!(client.request(&Request::Metrics).unwrap(), Response::MetricsText(_)));
}
