//! Integration tests over the coordinator service + TCP server (skip
//! vacuously without artifacts, like integration_runtime).

use diffaxe::coordinator::{server, Request, Response, Service, ServiceConfig};
use diffaxe::models::DiffAxE;
use diffaxe::workload::{Gemm, LlmModel, Stage};
use std::path::Path;

use std::sync::{Mutex, OnceLock};

/// One service for the whole test binary (artifact compilation is the
/// expensive part); a mutex serializes tests that read metrics counters.
fn service() -> Option<std::sync::MutexGuard<'static, Service>> {
    static SVC: OnceLock<Option<Mutex<Service>>> = OnceLock::new();
    SVC.get_or_init(|| {
        if !DiffAxE::artifacts_present(Path::new("artifacts")) {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return None;
        }
        Some(Mutex::new(Service::start(ServiceConfig::new("artifacts")).expect("service start")))
    })
    .as_ref()
    .map(|m| m.lock().unwrap())
}

fn some_workload() -> Gemm {
    Gemm::new(128, 768, 2304)
}

#[test]
fn generate_request_roundtrip() {
    let Some(svc) = service() else { return };
    let g = some_workload();
    let resp = svc.handle().request(Request::GenerateRuntime {
        g,
        target_cycles: 1e6,
        n: 8,
    });
    match resp {
        Response::Designs(ds) => {
            assert_eq!(ds.len(), 8);
            for d in &ds {
                assert!(d.hw.in_target_space());
                assert!(d.cycles > 0.0 && d.power_w > 0.0 && d.edp > 0.0);
            }
        }
        other => panic!("unexpected response {other:?}"),
    }
}

#[test]
fn concurrent_requests_are_batched_together() {
    let Some(svc) = service() else { return };
    let g = some_workload();
    // submit several requests before any can complete; the batcher should
    // pack them into shared sampler calls
    let rxs: Vec<_> = (0..6)
        .map(|i| {
            svc.handle().submit(Request::GenerateRuntime {
                g,
                target_cycles: 5e5 * (i + 1) as f64,
                n: 4,
            })
        })
        .collect();
    for rx in rxs {
        match rx.recv().unwrap() {
            Response::Designs(ds) => assert_eq!(ds.len(), 4),
            other => panic!("unexpected {other:?}"),
        }
    }
    let snap = svc.handle().metrics().snapshot();
    assert!(snap.requests >= 6);
    assert!(snap.sampler_calls >= 1);
    assert!(snap.batch_occupancy > 0.0);
}

#[test]
fn oversized_request_spans_batches() {
    let Some(svc) = service() else { return };
    let g = some_workload();
    let b = {
        // gen_batch from a fresh engine handle is awkward; request more than
        // any plausible batch instead
        160
    };
    let resp = svc.handle().request(Request::GenerateRuntime {
        g,
        target_cycles: 1e6,
        n: b,
    });
    match resp {
        Response::Designs(ds) => assert_eq!(ds.len(), b),
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn edp_and_perf_search_requests() {
    let Some(svc) = service() else { return };
    let g = some_workload();
    match svc.handle().request(Request::EdpSearch { g, n_per_class: 4 }) {
        Response::Designs(ds) => {
            assert_eq!(ds.len(), 1);
            assert!(ds[0].edp > 0.0);
        }
        other => panic!("unexpected {other:?}"),
    }
    match svc.handle().request(Request::PerfSearch { g, n: 16 }) {
        Response::Designs(ds) => assert_eq!(ds.len(), 1),
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn llm_search_request() {
    let Some(svc) = service() else { return };
    match svc.handle().request(Request::LlmSearch {
        model: LlmModel::BertBase,
        stage: Stage::Decode,
        n_per_layer: 4,
    }) {
        Response::Designs(ds) => {
            assert_eq!(ds.len(), 1);
            assert!(ds[0].hw.in_target_space());
        }
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn tcp_server_end_to_end() {
    let Some(svc) = service() else { return };
    let addr = server::serve_ephemeral(svc.handle()).unwrap();
    let mut client = server::Client::connect(&addr).unwrap();
    let resp = client
        .request(&Request::GenerateRuntime { g: some_workload(), target_cycles: 2e6, n: 4 })
        .unwrap();
    match resp {
        Response::Designs(ds) => assert_eq!(ds.len(), 4),
        other => panic!("unexpected {other:?}"),
    }
    // malformed line must yield an error response, not kill the connection
    let resp = client.request(&Request::Metrics).unwrap();
    match resp {
        Response::MetricsText(t) => assert!(t.contains("requests=")),
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn service_survives_unknown_workloads() {
    // nearest-stats fallback: a workload not in the training suite
    let Some(svc) = service() else { return };
    let g = Gemm::new(333, 777, 1234);
    match svc.handle().request(Request::GenerateRuntime { g, target_cycles: 1e6, n: 4 }) {
        Response::Designs(ds) => assert_eq!(ds.len(), 4),
        other => panic!("unexpected {other:?}"),
    }
}
