//! Hermetic acceptance suite for the structured-DSE subsystem (§V):
//! every supporting `OptimizerKind` searches `Objective::StructuredEdp`
//! deterministically through the unified API (DiffAxE runs on the mock
//! engine — no artifacts needed), the quality ordering the paper reports
//! holds (engine + DOSA beat random search on the same budget), segment
//! evaluation is bit-identical between the cached/pooled hot path and the
//! scalar reference, and the drained-budget / empty-workload edge cases
//! return well-formed empty outcomes.

use diffaxe::baselines::{FixedArch, GdOptions};
use diffaxe::design_space::{SharedBudget, StructuredConfig};
use diffaxe::dse::llm::{eval_workload, Platform};
use diffaxe::dse::structured::{
    eval_structured, eval_structured_batch, eval_structured_scalar, partition, search_engine,
    search_engine_zip,
};
use diffaxe::dse::{
    Budget, Objective, OptimizerKind, SearchCtx, SearchOutcome, Session, StopReason,
    StructuredSpec,
};
use diffaxe::models::ClassMode;
use diffaxe::util::rng::Pcg32;
use diffaxe::workload::{LlmModel, ModelWorkload, Stage};

fn spec() -> StructuredSpec {
    StructuredSpec::new(LlmModel::BertBase, Stage::Prefill, 64, Platform::Asic32nm, 3)
}

fn structured_kinds() -> Vec<OptimizerKind> {
    vec![
        OptimizerKind::DiffAxE,
        OptimizerKind::DosaGd,
        OptimizerKind::VanillaGd,
        OptimizerKind::VanillaBo,
        OptimizerKind::Polaris,
        OptimizerKind::LatentBo,
        OptimizerKind::RandomSearch,
        OptimizerKind::Fixed(FixedArch::Eyeriss),
    ]
}

fn assert_well_formed(out: &SearchOutcome, spec: &StructuredSpec, kind: OptimizerKind) {
    assert!(!out.ranked.is_empty(), "{kind:?} produced nothing");
    assert_eq!(out.segments.len(), out.ranked.len(), "{kind:?}: segments not parallel");
    for segs in &out.segments {
        assert_eq!(segs.len(), spec.n_segments(), "{kind:?}");
        let bw = segs[0].bw;
        for s in segs {
            assert!(s.in_target_space(), "{kind:?}: {s} off-grid");
            assert!(spec.budget.admits(s), "{kind:?}: {s} exceeds the shared budget");
            assert_eq!(s.bw, bw, "{kind:?}: segments must share one DRAM link");
        }
    }
    // ranked is best-first under the structured score
    for w in out.ranked.windows(2) {
        assert!(w[0].edp <= w[1].edp, "{kind:?}: ranking out of order");
    }
}

/// Acceptance: `Objective::StructuredEdp` is searchable through ≥ 4
/// `OptimizerKind`s, each deterministic in its seed, on the mock engine.
#[test]
fn structured_edp_searchable_and_deterministic_across_kinds() {
    let sp = spec();
    let obj = Objective::StructuredEdp { spec: sp };
    let mut session = Session::mock();
    session.gd_opts = GdOptions { steps: 4, restarts: 1, ..Default::default() };
    let kinds = structured_kinds();
    assert!(kinds.len() >= 4);
    for kind in kinds {
        assert!(kind.supports(&obj), "{kind:?} must serve structured objectives");
        let budget = Budget::evals(24);
        let a = session.search(kind, &obj, &budget, 77).unwrap();
        let b = session.search(kind, &obj, &budget, 77).unwrap();
        assert_eq!(a.optimizer, b.optimizer);
        assert_eq!(a.ranked, b.ranked, "{kind:?} not deterministic");
        assert_eq!(a.trace, b.trace, "{kind:?} trace not deterministic");
        assert_eq!(a.segments, b.segments, "{kind:?} segments not deterministic");
        assert_well_formed(&a, &sp, kind);
    }
    // the non-structured kinds reject the pairing up front
    for kind in [OptimizerKind::GanDse, OptimizerKind::AirchitectV1] {
        assert!(!kind.supports(&obj), "{kind:?}");
        assert!(session.search(kind, &obj, &Budget::evals(4), 1).is_err(), "{kind:?}");
    }
}

/// Acceptance: the structured-perf objective ranks by cycles.
#[test]
fn structured_perf_ranks_by_cycles() {
    let sp = spec();
    let obj = Objective::StructuredPerf { spec: sp };
    let out = Session::mock()
        .search(OptimizerKind::RandomSearch, &obj, &Budget::evals(32), 5)
        .unwrap();
    assert_eq!(out.ranked.len(), 32);
    for w in out.ranked.windows(2) {
        assert!(w[0].cycles <= w[1].cycles);
    }
}

/// Acceptance: on the same evaluation budget and seed, the DiffAxE engine
/// (mock, per-segment conditioning) and the DOSA coarse-GD baseline both
/// find lower structured EDP than uniform random search — the paper's §V
/// quality ordering, held deterministically.
#[test]
fn engine_and_dosa_beat_random_on_the_same_budget() {
    let sp = spec();
    let obj = Objective::StructuredEdp { spec: sp };
    let seed = 7;
    let mut session = Session::mock();

    // per-segment conditioned generation vs the same number of uniform
    // joint draws: 64 candidates each
    let engine_out =
        session.search(OptimizerKind::DiffAxE, &obj, &Budget::evals(64), seed).unwrap();
    let random_small =
        session.search(OptimizerKind::RandomSearch, &obj, &Budget::evals(64), seed).unwrap();
    assert!(
        engine_out.best_score() < random_small.best_score(),
        "DiffAxE (mock) {:.4e} must beat random {:.4e} at 64 evals",
        engine_out.best_score(),
        random_small.best_score()
    );

    // coarse GD with a real step schedule vs the same larger budget
    session.gd_opts = GdOptions { steps: 12, restarts: 1, ..Default::default() };
    let dosa_out = session.search(OptimizerKind::DosaGd, &obj, &Budget::evals(700), seed).unwrap();
    let random_big =
        session.search(OptimizerKind::RandomSearch, &obj, &Budget::evals(700), seed).unwrap();
    assert!(
        dosa_out.best_score() < random_big.best_score(),
        "DOSA {:.4e} must beat random {:.4e} at 700 evals",
        dosa_out.best_score(),
        random_big.best_score()
    );
}

/// Acceptance: per-segment evaluation is bit-identical between the
/// memoized/pooled hot path and the scalar reference, on both platforms.
#[test]
fn structured_eval_bit_identical_cached_pooled_scalar() {
    for platform in [Platform::Asic32nm, Platform::FpgaVu13p] {
        let sp = StructuredSpec {
            platform,
            budget: SharedBudget { pe: 4096, buf_b: 768 * 1024, bw: 16 },
            ..spec()
        };
        let mut rng = Pcg32::seeded(97);
        let mut cfgs: Vec<StructuredConfig> = (0..40)
            .map(|_| {
                diffaxe::design_space::structured::sample_structured(
                    &mut rng,
                    &sp.budget,
                    sp.n_segments(),
                )
            })
            .collect();
        // recurring candidates: the memo's bread and butter
        let dups = cfgs[..10].to_vec();
        cfgs.extend(dups);
        for pass in 0..2 {
            let batch = eval_structured_batch(&sp, &cfgs);
            for (cfg, b) in cfgs.iter().zip(&batch) {
                let cached = eval_structured(&sp, cfg);
                let scalar = eval_structured_scalar(&sp, cfg);
                for d in [&cached, b] {
                    assert_eq!(d.config, scalar.config, "{platform:?} pass {pass}");
                    assert_eq!(
                        d.cycles.to_bits(),
                        scalar.cycles.to_bits(),
                        "{platform:?} pass {pass}"
                    );
                    assert_eq!(
                        d.power_w.to_bits(),
                        scalar.power_w.to_bits(),
                        "{platform:?} pass {pass}"
                    );
                    assert_eq!(d.edp.to_bits(), scalar.edp.to_bits(), "{platform:?} pass {pass}");
                }
            }
        }
    }
}

/// The joint sampler's surface contract: every returned group has exactly
/// one config per conditioning segment, already inside the shared budget
/// (the sampler constrains internally — callers never re-project), all
/// on one DRAM link, and the call is a pure function of its seed.
#[test]
fn sample_joint_groups_are_constrained_and_deterministic() {
    let session = Session::mock();
    let engine = session.engine().expect("mock session has an engine");
    let budget = SharedBudget { pe: 2048, buf_b: 256 * 1024, bw: 12 };
    let conds: Vec<(i32, [f32; 3])> = [
        diffaxe::workload::Gemm::new(64, 768, 768),
        diffaxe::workload::Gemm::new(64, 768, 3072),
        diffaxe::workload::Gemm::new(64, 3072, 768),
    ]
    .iter()
    .map(|g| (0, g.norm_vec()))
    .collect();
    let groups = engine.sample_joint(ClassMode::Edp, 41, &budget, &conds, 6).unwrap();
    assert_eq!(groups.len(), 6);
    for segs in &groups {
        assert_eq!(segs.len(), conds.len());
        let cfg = StructuredConfig { segments: segs.clone() };
        assert!(cfg.in_budget(&budget), "{cfg:?} escapes {budget:?}");
        // constrain is idempotent on the sampler's output: the projection
        // happened inside the call, never assembled by the caller
        let again = diffaxe::design_space::structured::constrain(&budget, segs.clone());
        assert_eq!(again, cfg, "sampler output not already constrained");
    }
    let replay = engine.sample_joint(ClassMode::Edp, 41, &budget, &conds, 6).unwrap();
    assert_eq!(replay, groups, "sample_joint not deterministic in its seed");
}

/// ISSUE-10 acceptance: learned boundaries + joint conditioning find
/// whole-model EDP at least as good as the fixed-partition
/// independently-zipped baseline on the same budget and seed set,
/// deterministically. The joint path's round-0 proposals sit on the very
/// canonical partition the zip baseline uses, but its selection ranks
/// whole constrained candidates (the final metric), where the zip ranks
/// segments independently *before* the shared-budget projection distorts
/// them — so the paired best-of comparison favours joint by construction.
#[test]
fn joint_learned_cuts_beat_or_match_the_indep_zip_baseline() {
    let sp = spec();
    let obj = Objective::StructuredEdp { spec: sp };
    let session = Session::mock();
    let engine = session.engine().expect("mock session has an engine");
    let ctx = SearchCtx::background();
    let budget = Budget::evals(96);
    let seeds = [11u64, 21, 77];
    let mut joint_best = f64::INFINITY;
    let mut zip_best = f64::INFINITY;
    for &seed in &seeds {
        let joint = search_engine(engine, &ctx, &obj, &sp, &budget, seed).unwrap();
        let zip = search_engine_zip(engine, &ctx, &obj, &sp, &budget, seed).unwrap();
        assert_well_formed(&joint, &sp, OptimizerKind::DiffAxE);
        // the learned cuts ride parallel to the ranked designs: one cut
        // vector per design, each a valid segmentation (or empty = the
        // canonical partition); the zip baseline never reports cuts
        assert_eq!(joint.boundaries.len(), joint.ranked.len());
        let n_layers = sp.workload().gemms.len();
        for b in &joint.boundaries {
            assert!(
                b.is_empty() || diffaxe::design_space::structured::boundaries_valid(b, n_layers),
                "invalid learned cuts {b:?} over {n_layers} layers"
            );
        }
        assert!(joint.boundaries.iter().any(|b| !b.is_empty()), "no learned cuts explored");
        assert!(zip.boundaries.is_empty(), "zip baseline must not report cuts");
        joint_best = joint_best.min(joint.best_score());
        zip_best = zip_best.min(zip.best_score());
    }
    assert!(
        joint_best <= zip_best,
        "joint+learned-cuts {joint_best:.6e} must not lose to indep-zip {zip_best:.6e} \
         on the same budget and seeds"
    );
    // bit-exact determinism of the full outcome, cuts included
    let a = search_engine(engine, &ctx, &obj, &sp, &budget, seeds[1]).unwrap();
    let b = search_engine(engine, &ctx, &obj, &sp, &budget, seeds[1]).unwrap();
    assert_eq!(a.ranked, b.ranked);
    assert_eq!(a.segments, b.segments);
    assert_eq!(a.boundaries, b.boundaries);
    assert_eq!(a.trace, b.trace);
}

/// Heterogeneity is real: the best heterogeneous candidate over a search
/// is at least as good as the best uniform-replication candidate drawn
/// from the same seeds (the structured space strictly contains the
/// uniform diagonal).
#[test]
fn structured_space_contains_the_uniform_diagonal() {
    let sp = spec();
    let obj = Objective::StructuredEdp { spec: sp };
    // uniform diagonal: Objective::evaluate replicates one HwConfig
    let mut rng = Pcg32::seeded(13);
    let hw = diffaxe::design_space::TargetSpace::sample(&mut rng);
    let uniform = obj.evaluate(&hw);
    assert!(uniform.edp > 0.0 && uniform.cycles > 0.0);
    // and the explicit structured evaluation of that diagonal agrees
    let cfg = diffaxe::design_space::structured::constrain(
        &sp.budget,
        vec![hw; sp.n_segments()],
    );
    let d = eval_structured(&sp, &cfg);
    assert_eq!(d.edp.to_bits(), uniform.edp.to_bits());
}

// ---------------------------------------------------------------------------
// drained-budget / empty-workload regressions
// ---------------------------------------------------------------------------

/// `Budget::evals(0)` returns a well-formed empty outcome
/// (`stopped: BudgetExhausted`) from every strategy — no forced minimum
/// evaluation, no divide-by-zero schedule, no panic.
#[test]
fn zero_eval_budget_returns_empty_budget_exhausted_outcome() {
    let g = diffaxe::workload::Gemm::new(64, 256, 512);
    let mut session = Session::mock();
    for kind in OptimizerKind::ALL {
        let obj = match kind {
            OptimizerKind::GanDse => Objective::Runtime { g, target_cycles: 1e6 },
            _ => Objective::MinEdp { g },
        };
        let out = session.search(kind, &obj, &Budget::evals(0), 3).unwrap();
        assert_eq!(out.evals, 0, "{kind:?}");
        assert!(out.ranked.is_empty(), "{kind:?}");
        assert!(out.trace.is_empty(), "{kind:?}");
        assert_eq!(out.stopped, StopReason::BudgetExhausted, "{kind:?}");
    }
    // the structured objective honours the same contract
    let obj = Objective::StructuredEdp { spec: spec() };
    for kind in structured_kinds() {
        let out = session.search(kind, &obj, &Budget::evals(0), 3).unwrap();
        assert_eq!(out.evals, 0, "{kind:?}");
        assert_eq!(out.stopped, StopReason::BudgetExhausted, "{kind:?}");
        assert!(out.segments.is_empty(), "{kind:?}");
    }
}

/// An empty workload (zero GEMMs) evaluates to the zero cost point
/// instead of panicking, and partitioning it yields no segments.
#[test]
fn empty_workload_is_well_formed_not_a_panic() {
    let empty = ModelWorkload {
        model: LlmModel::BertBase,
        stage: Stage::Prefill,
        seq: 1,
        gemms: Vec::new(),
        unique: Vec::new(),
        layer_to_unique: Vec::new(),
        blocks: 12,
    };
    let hw = FixedArch::Eyeriss.config();
    for platform in [Platform::Asic32nm, Platform::FpgaVu13p] {
        let ev = eval_workload(&hw, &empty, platform);
        assert_eq!(ev.sim.cycles, 0, "{platform:?}");
        assert_eq!(ev.energy.edp, 0.0, "{platform:?}");
        assert_eq!(ev.energy.power_w, 0.0, "{platform:?}");
        assert!(ev.cfg.orders.is_empty(), "{platform:?}");
    }
    assert!(partition(0, 0).is_empty());
}
