//! Integration tests over the PJRT runtime + model engine. These need the
//! AOT artifacts (`make artifacts`); without them each test prints a notice
//! and passes vacuously so plain `cargo test` stays green pre-build.

use diffaxe::design_space::encode_norm;
use diffaxe::models::{ClassMode, DiffAxE};
use std::path::Path;

/// PJRT handles are !Send, so the engine cannot live in a shared static:
/// this binary runs all checks sequentially against ONE engine instance
/// (artifact compilation is the expensive part).
#[test]
fn runtime_integration_suite() {
    let dir = Path::new("artifacts");
    if !DiffAxE::artifacts_present(dir) {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    }
    let e = DiffAxE::load(dir).expect("artifacts load");
    sampler_outputs_valid_target_space_configs(&e);
    sampler_is_deterministic_in_seed(&e);
    class_samplers_work_for_all_classes(&e);
    generated_configs_are_diverse(&e);
    encoder_decoder_roundtrip_is_faithful(&e);
    pp_prediction_correlates_with_simulated_runtime(&e);
    surrogate_grad_descends_loss(&e);
    airchitect_recommenders_return_valid_configs(&e);
}

fn sampler_outputs_valid_target_space_configs(e: &DiffAxE) {
    let g = e.stats.workloads[0].gemm;
    let st = e.stats.stats_for(&g);
    let p = st.norm_runtime(st.runtime_range().0 * 3.0);
    let conds: Vec<(f32, [f32; 3])> = (0..16).map(|_| (p, g.norm_vec())).collect();
    let cfgs = e.sample_runtime(3, &conds).unwrap();
    assert_eq!(cfgs.len(), 16);
    for c in &cfgs {
        assert!(c.in_target_space(), "{c}");
    }
}

fn sampler_is_deterministic_in_seed(e: &DiffAxE) {
    let g = e.stats.workloads[1].gemm;
    let conds: Vec<(f32, [f32; 3])> = (0..8).map(|_| (0.5, g.norm_vec())).collect();
    let a = e.sample_runtime(7, &conds).unwrap();
    let b = e.sample_runtime(7, &conds).unwrap();
    assert_eq!(a, b);
    let c = e.sample_runtime(8, &conds).unwrap();
    assert_ne!(a, c, "different seeds should generate different designs");
}

fn class_samplers_work_for_all_classes(e: &DiffAxE) {
    let g = e.stats.workloads[2].gemm;
    let n_classes = e.stats.n_power * e.stats.n_perf;
    let conds: Vec<(i32, [f32; 3])> =
        (0..n_classes as i32).map(|c| (c, g.norm_vec())).collect();
    let cfgs = e.sample_class(ClassMode::Edp, 5, &conds).unwrap();
    assert_eq!(cfgs.len(), n_classes);
    let conds: Vec<(i32, [f32; 3])> = (0..4).map(|_| (0, g.norm_vec())).collect();
    let cfgs = e.sample_class(ClassMode::PerfOpt, 5, &conds).unwrap();
    assert_eq!(cfgs.len(), 4);
}

// the paper's core claim about the many-to-one mapping: diffusion
// generates *diverse* configurations, not one design repeated
fn generated_configs_are_diverse(e: &DiffAxE) {
    let g = e.stats.workloads[0].gemm;
    let conds: Vec<(f32, [f32; 3])> = (0..64).map(|_| (0.5, g.norm_vec())).collect();
    let cfgs = e.sample_runtime(11, &conds).unwrap();
    let distinct: std::collections::HashSet<_> = cfgs.iter().collect();
    assert!(distinct.len() > 5, "only {} distinct designs in 64", distinct.len());
}

fn encoder_decoder_roundtrip_is_faithful(e: &DiffAxE) {
    use diffaxe::design_space::TargetSpace;
    use diffaxe::util::rng::Pcg32;
    let mut rng = Pcg32::seeded(13);
    let configs: Vec<_> = (0..32).map(|_| TargetSpace::sample(&mut rng)).collect();
    let rows: Vec<Vec<f32>> = configs.iter().map(|c| encode_norm(c).to_vec()).collect();
    let lat = e.encode(&rows).unwrap();
    assert_eq!(lat.len(), 32);
    assert_eq!(lat[0].len(), e.stats.latent_dim);
    let back = e.decode_rounded(&lat).unwrap();
    // the AE is lossy but must reconstruct the array dims within a few grid
    // steps for most samples
    let mut close = 0;
    for (orig, rec) in configs.iter().zip(&back) {
        let dr = (orig.r as f64 - rec.r as f64).abs() / 124.0;
        let dc = (orig.c as f64 - rec.c as f64).abs() / 124.0;
        if dr < 0.15 && dc < 0.15 {
            close += 1;
        }
    }
    assert!(close >= 24, "only {close}/32 reconstructions close");
}

fn pp_prediction_correlates_with_simulated_runtime(e: &DiffAxE) {
    use diffaxe::design_space::params::TrainingSpace;
    use diffaxe::sim::simulate;
    let st = &e.stats.workloads[0];
    let g = st.gemm;
    let configs: Vec<_> = (0..200).map(|i| TrainingSpace::nth(i * 311 % TrainingSpace::len())).collect();
    let rows: Vec<Vec<f32>> = configs.iter().map(|c| encode_norm(c).to_vec()).collect();
    let lat = e.encode(&rows).unwrap();
    let preds = e.pp_predict(&lat, &g).unwrap();
    let truth: Vec<f64> =
        configs.iter().map(|c| st.norm_runtime(simulate(c, &g).cycles as f64) as f64).collect();
    let preds64: Vec<f64> = preds.iter().map(|&p| p as f64).collect();
    let corr = pearson(&preds64, &truth);
    assert!(corr > 0.7, "PP–simulator correlation only {corr}");
}

fn surrogate_grad_descends_loss(e: &DiffAxE) {
    let g = e.stats.workloads[0].gemm;
    let hw = vec![vec![0.5f32; 8]];
    let target = [0.2f32];
    let (l0, g0) = e.surrogate_grad(&hw, &g, &target).unwrap();
    // one explicit GD step must reduce the per-sample loss
    let stepped: Vec<f32> =
        hw[0].iter().zip(&g0[0]).map(|(x, gr)| (x - 0.05 * gr).clamp(0.0, 1.0)).collect();
    let (l1, _) = e.surrogate_grad(&[stepped], &g, &target).unwrap();
    assert!(l1[0] <= l0[0] + 1e-6, "loss went up: {} -> {}", l0[0], l1[0]);
}

fn airchitect_recommenders_return_valid_configs(e: &DiffAxE) {
    let g = e.stats.workloads[3].gemm;
    let v1 = e.airchitect_v1(&g).unwrap();
    let v2 = e.airchitect_v2(&g).unwrap();
    assert!(v1.in_target_space());
    assert!(v2.in_target_space());
}

fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let cov: f64 = a.iter().zip(b).map(|(x, y)| (x - ma) * (y - mb)).sum();
    let va: f64 = a.iter().map(|x| (x - ma) * (x - ma)).sum();
    let vb: f64 = b.iter().map(|y| (y - mb) * (y - mb)).sum();
    cov / (va.sqrt() * vb.sqrt()).max(1e-12)
}
