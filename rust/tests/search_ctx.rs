//! Hermetic (no-artifact) tests for the interruptible search API: every
//! engine-free `OptimizerKind` must honour a `SearchCtx` deadline within
//! ~2x, stop promptly on cancellation with a well-formed *partial*
//! outcome, and stream monotonic progress events. The engine-backed kinds
//! run the same checks in `integration_session.rs` (artifact-gated).

use diffaxe::baselines::{BoOptions, FixedArch, GdOptions};
use diffaxe::dse::{
    Budget, Objective, OptimizerKind, SearchCtx, SearchEvent, Session, StopReason,
};
use diffaxe::workload::Gemm;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

const DEADLINE_S: f64 = 0.05;
// ~2x the deadline: one in-flight evaluation batch may straddle the poll
// point, plus CI scheduler slack
const RETURN_BOUND_S: f64 = 0.2;

fn obj() -> Objective {
    Objective::MinEdp { g: Gemm::new(64, 256, 512) }
}

/// A session whose BO/GD schedules are far too large to finish in 50 ms,
/// so a deadline (not schedule completion) is what ends each search.
fn slow_session() -> Session {
    let mut s = Session::simulator_only();
    s.bo_opts = BoOptions { n_init: 8, budget: 1_000_000, pool: 64, ..Default::default() };
    s.gd_opts = GdOptions { steps: 100_000, restarts: 100, ..Default::default() };
    s
}

fn engine_free_kinds() -> Vec<OptimizerKind> {
    vec![
        OptimizerKind::RandomSearch,
        OptimizerKind::VanillaBo,
        OptimizerKind::VanillaGd,
        OptimizerKind::DosaGd,
        OptimizerKind::Fixed(FixedArch::Eyeriss),
        OptimizerKind::Fixed(FixedArch::ShiDianNao),
        OptimizerKind::Fixed(FixedArch::Nvdla),
    ]
}

#[test]
fn every_engine_free_kind_returns_within_2x_of_a_50ms_deadline() {
    let mut session = slow_session();
    for kind in engine_free_kinds() {
        let ctx = SearchCtx::background().with_deadline_in(DEADLINE_S);
        let budget = Budget::evals(2_000_000);
        let t = Instant::now();
        let out = session.search_ctx(kind, &ctx, &obj(), &budget, 7).unwrap();
        let elapsed = t.elapsed().as_secs_f64();
        assert!(
            elapsed < RETURN_BOUND_S,
            "{kind:?} took {elapsed:.3}s against a {DEADLINE_S}s deadline"
        );
        match kind {
            // one-shot recommenders finish long before the deadline
            OptimizerKind::Fixed(_) => {
                assert_eq!(out.stopped, StopReason::Completed, "{kind:?}");
                assert_eq!(out.evals, 1);
            }
            _ => {
                assert_eq!(out.stopped, StopReason::DeadlineExceeded, "{kind:?}");
                assert!(out.evals < 2_000_000, "{kind:?} claims a full run");
                // partial outcomes stay well-formed: ranked ⊆ trace order
                assert_eq!(out.trace.len(), out.evals, "{kind:?}");
                assert_eq!(out.ranked.len(), out.evals, "{kind:?}");
            }
        }
    }
}

#[test]
fn budget_wall_clock_behaves_like_a_ctx_deadline_for_every_kind() {
    // Budget::wall_clock_s routes through the same SearchRun deadline, so
    // the behaviour must match the ctx-deadline test above
    let mut session = slow_session();
    for kind in [OptimizerKind::RandomSearch, OptimizerKind::VanillaBo, OptimizerKind::DosaGd] {
        let budget = Budget::evals(2_000_000).with_wall_clock(DEADLINE_S);
        let t = Instant::now();
        let out =
            session.search_ctx(kind, &SearchCtx::background(), &obj(), &budget, 7).unwrap();
        let elapsed = t.elapsed().as_secs_f64();
        assert!(elapsed < RETURN_BOUND_S, "{kind:?} took {elapsed:.3}s");
        assert_eq!(out.stopped, StopReason::DeadlineExceeded, "{kind:?}");
    }
}

#[test]
fn cancellation_yields_prompt_partial_outcomes() {
    let mut session = slow_session();
    for kind in [OptimizerKind::RandomSearch, OptimizerKind::VanillaBo, OptimizerKind::DosaGd] {
        let flag = Arc::new(AtomicBool::new(false));
        let canceller = {
            let flag = flag.clone();
            std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(30));
                flag.store(true, Ordering::SeqCst);
            })
        };
        let ctx = SearchCtx::background().with_cancel_flag(flag);
        let t = Instant::now();
        let out = session.search_ctx(kind, &ctx, &obj(), &Budget::evals(2_000_000), 3).unwrap();
        let elapsed = t.elapsed().as_secs_f64();
        canceller.join().unwrap();
        assert_eq!(out.stopped, StopReason::Cancelled, "{kind:?}");
        assert!(elapsed < 1.0, "{kind:?} took {elapsed:.3}s to notice the cancel");
        assert!(!out.ranked.is_empty(), "{kind:?} lost its partial results");
        assert!(out.best_score().is_finite(), "{kind:?}");
    }
}

#[test]
fn progress_events_are_monotonic_and_scored() {
    let events = Arc::new(Mutex::new(Vec::<SearchEvent>::new()));
    let ctx = {
        let events = events.clone();
        SearchCtx::background().with_progress(move |ev: &SearchEvent| {
            events.lock().unwrap().push(*ev);
        })
    };
    let out = Session::simulator_only()
        .search_ctx(OptimizerKind::RandomSearch, &ctx, &obj(), &Budget::evals(5000), 11)
        .unwrap();
    assert_eq!(out.stopped, StopReason::Completed);
    let evs = events.lock().unwrap();
    assert!(!evs.is_empty(), "no progress events emitted");
    for w in evs.windows(2) {
        assert!(w[1].evals >= w[0].evals, "evals went backwards");
        assert!(w[1].best_score <= w[0].best_score, "best-so-far worsened");
        assert!(w[1].elapsed_s >= w[0].elapsed_s, "time went backwards");
    }
    assert_eq!(evs.last().unwrap().evals, 5000);
    assert!((evs.last().unwrap().best_score - out.best_score()).abs() < 1e-12);
}

#[test]
fn budget_exhaustion_is_reported_not_silently_completed() {
    // a 40-eval budget truncates the default 80-step x 4-restart DOSA
    // schedule: the outcome must say so
    let mut session = Session::simulator_only();
    session.gd_opts = GdOptions::default();
    let out = session
        .search_ctx(OptimizerKind::DosaGd, &SearchCtx::background(), &obj(), &Budget::evals(40), 5)
        .unwrap();
    assert_eq!(out.stopped, StopReason::BudgetExhausted);
    assert!(!out.ranked.is_empty());
}
