//! Golden wire-fixture corpus: literal v1/v2/v3 request and response
//! lines checked into `tests/fixtures/` that must keep parsing — and,
//! for the canonical files, keep *serializing byte-identically* — across
//! protocol evolution. Additive protocol changes (new objective kinds,
//! new outcome fields) must leave every line here untouched; a diff in
//! this suite means a wire break, not a refactor.

use diffaxe::coordinator::{ErrorCode, JobState, Request, Response, SearchRequest};
use diffaxe::dse::{Budget, Objective, OptimizerKind};
use diffaxe::util::json::Json;
use diffaxe::workload::Gemm;

/// Load one fixture file: non-empty lines, `#` comments stripped.
fn fixture_lines(name: &str) -> Vec<String> {
    let path = format!("{}/tests/fixtures/{name}", env!("CARGO_MANIFEST_DIR"));
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {path}: {e}"));
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(str::to_string)
        .collect()
}

/// Every compat line (legacy aliases, v2/v3 forms, structured additions)
/// parses, and survives a serialize → parse trip semantically unchanged.
#[test]
fn compat_request_corpus_keeps_parsing() {
    let lines = fixture_lines("wire_requests_compat.jsonl");
    assert!(lines.len() >= 15, "corpus shrank to {} lines", lines.len());
    for line in &lines {
        let j = Json::parse(line).unwrap_or_else(|e| panic!("bad fixture json {line}: {e}"));
        let req = Request::from_json(&j).unwrap_or_else(|e| panic!("{line}: {e}"));
        let rejoined = Request::from_json(&Json::parse(&req.to_json().to_string()).unwrap())
            .unwrap_or_else(|e| panic!("re-serialized form of {line} broke: {e}"));
        assert_eq!(rejoined, req, "serialize/parse drifted for {line}");
    }
}

/// Spot-check that specific legacy lines decode to the exact semantics
/// the v1 protocol promised (budgets, top_k pinning, default optimizer).
#[test]
fn legacy_lines_decode_to_pinned_semantics() {
    let parse = |s: &str| Request::from_json(&Json::parse(s).unwrap()).unwrap();
    let lines = fixture_lines("wire_requests_compat.jsonl");
    let generate = parse(&lines[0]);
    assert_eq!(
        generate,
        Request::Search(SearchRequest {
            objective: Objective::Runtime { g: Gemm::new(128, 768, 2304), target_cycles: 1e6 },
            budget: Budget::evals(8),
            optimizer: OptimizerKind::DiffAxE,
            top_k: Some(8),
        })
    );
    let edp = parse(&lines[1]);
    assert_eq!(
        edp,
        Request::Search(SearchRequest {
            objective: Objective::MinEdp { g: Gemm::new(1, 2, 3) },
            budget: Budget::default().with_per_class(5),
            optimizer: OptimizerKind::DiffAxE,
            top_k: Some(1),
        })
    );
    // the structured line at the end of the corpus decodes with defaults
    let structured = parse(lines.last().unwrap());
    match structured {
        Request::Search(SearchRequest {
            objective: Objective::StructuredPerf { spec }, ..
        }) => {
            assert_eq!(spec.segments, 2);
            assert_eq!(spec.budget, diffaxe::design_space::SharedBudget::default());
        }
        other => panic!("unexpected {other:?}"),
    }
}

/// Canonical request lines are byte-stable: parse → to_json reproduces
/// the line exactly (key order, number formatting, field set).
#[test]
fn canonical_request_corpus_is_byte_stable() {
    let lines = fixture_lines("wire_requests_canonical.jsonl");
    assert!(lines.len() >= 10, "corpus shrank to {} lines", lines.len());
    for line in &lines {
        let j = Json::parse(line).unwrap_or_else(|e| panic!("bad fixture json {line}: {e}"));
        let req = Request::from_json(&j).unwrap_or_else(|e| panic!("{line}: {e}"));
        assert_eq!(req.to_json().to_string(), *line, "request wire bytes drifted");
    }
}

/// Canonical response lines are byte-stable: parse → to_json reproduces
/// the line exactly. This is the guard that additive evolution (e.g. the
/// structured `segments` field) never perturbs pre-existing lines.
#[test]
fn canonical_response_corpus_is_byte_stable() {
    let lines = fixture_lines("wire_responses.jsonl");
    assert!(lines.len() >= 15, "corpus shrank to {} lines", lines.len());
    for line in &lines {
        let j = Json::parse(line).unwrap_or_else(|e| panic!("bad fixture json {line}: {e}"));
        let resp = Response::from_json(&j).unwrap_or_else(|e| panic!("{line}: {e}"));
        assert_eq!(resp.to_json().to_string(), *line, "response wire bytes drifted");
    }
}

/// The structured-outcome fixture really decodes its per-segment configs
/// (not just echoes bytes), and plain designs carry no `segments` key.
#[test]
fn structured_outcome_fixture_decodes_segments() {
    let lines = fixture_lines("wire_responses.jsonl");
    let structured = lines
        .iter()
        .find(|l| l.contains("\"segments\""))
        .expect("corpus holds a structured outcome line");
    match Response::from_json(&Json::parse(structured).unwrap()).unwrap() {
        Response::Outcome(o) => {
            assert_eq!(o.ranked.len(), 1);
            assert_eq!(o.segments.len(), 1);
            assert_eq!(o.segments[0].len(), 2);
            assert_eq!(o.segments[0][0].r, 64);
            assert_eq!(o.segments[0][1].c, 128);
            // envelope carries the per-resource maxima of its segments
            assert_eq!(o.ranked[0].hw.r, 64);
            assert_eq!(o.ranked[0].hw.c, 128);
        }
        other => panic!("unexpected {other:?}"),
    }
    let plain = lines
        .iter()
        .find(|l| l.contains("Random Search") && !l.contains("\"type\""))
        .expect("corpus holds a plain outcome line");
    match Response::from_json(&Json::parse(plain).unwrap()).unwrap() {
        Response::Outcome(o) => assert!(o.segments.is_empty()),
        other => panic!("unexpected {other:?}"),
    }
}

/// The PR-10 learned-segmentation line decodes its per-design cut vector
/// (`"boundaries"`, riding parallel to `"segments"`), and every
/// pre-PR-10 line — which never carries the key — normalizes to an empty
/// boundary list, keeping the old corpus byte-stable and semantically
/// unchanged. (Byte stability of the new line itself is covered by
/// `canonical_response_corpus_is_byte_stable`.)
#[test]
fn boundaries_fixture_line_decodes_cuts() {
    let lines = fixture_lines("wire_responses.jsonl");
    let line = lines
        .iter()
        .find(|l| l.contains("\"boundaries\""))
        .expect("corpus holds a learned-segmentation outcome line");
    match Response::from_json(&Json::parse(line).unwrap()).unwrap() {
        Response::Outcome(o) => {
            assert_eq!(o.ranked.len(), 1);
            assert_eq!(o.boundaries, vec![vec![1]]);
            assert_eq!(o.segments.len(), 1);
            assert_eq!(o.segments[0].len(), 2);
        }
        other => panic!("unexpected {other:?}"),
    }
    for l in lines.iter().filter(|l| !l.contains("\"boundaries\"")) {
        if let Response::Outcome(o) = Response::from_json(&Json::parse(l).unwrap()).unwrap() {
            assert!(o.boundaries.is_empty(), "phantom boundaries decoded from {l}");
        }
    }
}

/// The PR-8 robustness lines decode to their typed semantics: the
/// admission-control shed carries a machine-readable retry hint, the
/// crash-failed job surfaces its attempt count, and the drain-finalized
/// stream line is a cancelled outcome. (Byte stability is covered by
/// `canonical_response_corpus_is_byte_stable`.)
#[test]
fn robustness_fixture_lines_decode_typed() {
    let lines = fixture_lines("wire_responses.jsonl");
    let decode = |l: &str| Response::from_json(&Json::parse(l).unwrap()).unwrap();

    let shed = lines.iter().find(|l| l.contains("\"overloaded\"")).expect("shed line");
    match decode(shed) {
        Response::Error { code, message, retry_after_ms } => {
            assert_eq!(code, ErrorCode::Overloaded);
            assert!(message.contains("queue full"), "{message}");
            assert_eq!(retry_after_ms, Some(70));
        }
        other => panic!("unexpected {other:?}"),
    }

    let failed = lines.iter().find(|l| l.contains("\"failed\"")).expect("failed-job line");
    match decode(failed) {
        Response::Job(info) => {
            assert_eq!(info.state, JobState::Failed);
            assert_eq!(info.attempts, 2);
            assert_eq!(info.best_score, None);
        }
        other => panic!("unexpected {other:?}"),
    }

    let drained = lines
        .iter()
        .find(|l| l.contains("\"type\":\"outcome\"") && l.contains("Random Search"))
        .expect("drain-finalized line");
    match decode(drained) {
        Response::JobOutcome { job_id, outcome } => {
            assert_eq!(job_id, "job-9");
            assert_eq!(outcome.stopped, diffaxe::dse::StopReason::Cancelled);
            assert!(outcome.ranked.is_empty());
            assert_eq!(outcome.search_time_s, 1.5);
        }
        other => panic!("unexpected {other:?}"),
    }
}
