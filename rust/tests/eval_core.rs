//! Bit-equivalence property suite for the memoized, pooled evaluation core
//! (`dse::eval` + the `dse::llm` fast path). Everything here is hermetic —
//! no AOT artifacts needed — and holds the three optimized paths to *exact*
//! equality with their scalar references:
//!
//! * cached vs uncached: `Session::evaluate_batch` / `EvalCache::evaluate`
//!   vs scalar `dse::evaluate`,
//! * pooled vs inline: `par_map` vs a sequential map,
//! * fast-path `eval_model` vs the retained `eval_model_reference`,
//!   across every `LlmModel` × `Stage` × `Platform` combination.

use diffaxe::design_space::{HwConfig, LoopOrder, TargetSpace};
use diffaxe::dse::eval::{par_map, EvalCache, PAR_THRESHOLD};
use diffaxe::dse::llm::{eval_model, eval_model_reference, Platform, SeqEval};
use diffaxe::dse::{coarsen, Objective, Session};
use diffaxe::util::rng::Pcg32;
use diffaxe::workload::{Gemm, LlmModel, Stage};

fn assert_seq_eval_bit_identical(a: &SeqEval, b: &SeqEval, ctx: &str) {
    assert_eq!(a.cfg, b.cfg, "{ctx}: chosen per-layer orders differ");
    assert_eq!(a.sim, b.sim, "{ctx}: simulation counters differ");
    assert_eq!(a.energy.e_dyn_uj.to_bits(), b.energy.e_dyn_uj.to_bits(), "{ctx}: e_dyn");
    assert_eq!(a.energy.e_static_uj.to_bits(), b.energy.e_static_uj.to_bits(), "{ctx}: e_static");
    assert_eq!(a.energy.power_w.to_bits(), b.energy.power_w.to_bits(), "{ctx}: power");
    assert_eq!(a.energy.edp.to_bits(), b.energy.edp.to_bits(), "{ctx}: edp");
    assert_eq!(a.energy.runtime_s.to_bits(), b.energy.runtime_s.to_bits(), "{ctx}: runtime");
}

/// Fast path == reference, across every model × stage × platform, over
/// random target-space candidates plus grid-snapped (recurring) ones.
#[test]
fn fast_eval_model_bit_identical_to_reference_everywhere() {
    let mut rng = Pcg32::seeded(2024);
    for model in LlmModel::ALL {
        for stage in Stage::ALL {
            for platform in [Platform::Asic32nm, Platform::FpgaVu13p] {
                for i in 0..4 {
                    let sampled = TargetSpace::sample(&mut rng);
                    // odd draws exercise the coarse grid the searches revisit
                    let hw = if i % 2 == 1 { coarsen(&sampled) } else { sampled };
                    let seq = if i < 2 { 128 } else { 48 };
                    let fast = eval_model(&hw, model, stage, seq, platform);
                    let reference = eval_model_reference(&hw, model, stage, seq, platform);
                    let ctx = format!(
                        "{} {} seq={seq} {platform:?} hw={hw}",
                        model.name(),
                        stage.name()
                    );
                    assert_seq_eval_bit_identical(&fast, &reference, &ctx);
                }
            }
        }
    }
}

/// A second pass over identical inputs (now cache-hot) returns the same
/// bits: memoization is invisible to results.
#[test]
fn warm_cache_is_invisible_to_eval_model() {
    let mut rng = Pcg32::seeded(7);
    let hw = coarsen(&TargetSpace::sample(&mut rng));
    for platform in [Platform::Asic32nm, Platform::FpgaVu13p] {
        let cold = eval_model(&hw, LlmModel::Llama2_7b, Stage::Prefill, 128, platform);
        let warm = eval_model(&hw, LlmModel::Llama2_7b, Stage::Prefill, 128, platform);
        assert_seq_eval_bit_identical(&cold, &warm, &format!("warm {platform:?}"));
    }
}

/// Cached evaluation == scalar evaluation, and the second identical batch
/// is served from the table (hits grow, misses do not).
#[test]
fn cached_evaluate_bit_identical_to_scalar_with_hits() {
    let cache = EvalCache::new(8, 4096);
    let mut rng = Pcg32::seeded(41);
    let g = Gemm::new(128, 768, 2304);
    let cfgs: Vec<HwConfig> = (0..96).map(|_| TargetSpace::sample(&mut rng)).collect();
    for pass in 0..2 {
        for hw in &cfgs {
            let (s, e) = cache.evaluate(hw, &g);
            let (s2, e2) = diffaxe::dse::evaluate(hw, &g);
            assert_eq!(s, s2, "pass {pass}");
            assert_eq!(e, e2, "pass {pass}");
        }
    }
    let stats = cache.stats();
    assert_eq!(stats.misses, 96, "first pass misses everything");
    assert_eq!(stats.hits, 96, "second pass hits everything");
}

/// The loop order is part of the cache key: order variants of one base must
/// not collide.
#[test]
fn cache_key_distinguishes_loop_orders() {
    let cache = EvalCache::new(4, 1024);
    let g = Gemm::new(512, 512, 512);
    let base = HwConfig::new_kb(32, 32, 4.0, 4.0, 4.0, 4, LoopOrder::Mnk);
    let nmk_hw = HwConfig { loop_order: LoopOrder::Nmk, ..base };
    let mnk = cache.evaluate(&base, &g);
    let nmk = cache.evaluate(&nmk_hw, &g);
    assert_eq!(cache.stats().misses, 2, "distinct orders are distinct entries");
    assert_eq!(mnk.0, diffaxe::dse::evaluate(&base, &g).0);
    assert_eq!(nmk.0, diffaxe::dse::evaluate(&nmk_hw, &g).0);
}

/// Pooled map == inline map, order preserved, on batches above and below
/// the inline threshold.
#[test]
fn pooled_par_map_bit_identical_to_inline() {
    let mut rng = Pcg32::seeded(5);
    let g = Gemm::new(64, 256, 512);
    for n in [PAR_THRESHOLD - 1, PAR_THRESHOLD, 4 * PAR_THRESHOLD + 3] {
        let cfgs: Vec<HwConfig> = (0..n).map(|_| TargetSpace::sample(&mut rng)).collect();
        let pooled = par_map(&cfgs, move |hw| diffaxe::dse::evaluate(hw, &g));
        assert_eq!(pooled.len(), cfgs.len());
        for (hw, (s, e)) in cfgs.iter().zip(&pooled) {
            let (s2, e2) = diffaxe::dse::evaluate(hw, &g);
            assert_eq!(*s, s2, "n={n}");
            assert_eq!(*e, e2, "n={n}");
        }
    }
}

/// The full session hot path (pool + shared cache) == scalar objective
/// evaluation, for both GEMM and LLM objectives, with heavy duplication in
/// the batch (the many-to-one recurrence of Fig 2a).
#[test]
fn session_batch_and_llm_objective_match_scalar_path() {
    let session = Session::simulator_only();
    let mut rng = Pcg32::seeded(17);
    let g = Gemm::new(128, 768, 768);
    let mut cfgs: Vec<HwConfig> = (0..80).map(|_| coarsen(&TargetSpace::sample(&mut rng))).collect();
    let dups = cfgs[..40].to_vec();
    cfgs.extend(dups);
    for pass in 0..2 {
        let batch = session.evaluate_batch(&cfgs, &g);
        for (hw, (s, e)) in cfgs.iter().zip(&batch) {
            let (s2, e2) = diffaxe::dse::evaluate(hw, &g);
            assert_eq!(*s, s2, "pass {pass}");
            assert_eq!(*e, e2, "pass {pass}");
        }
    }
    let obj = Objective::LlmEdp {
        model: LlmModel::BertBase,
        stage: Stage::Decode,
        seq: 64,
        platform: Platform::Asic32nm,
    };
    let reports = obj.evaluate_all(&cfgs);
    for (hw, d) in cfgs.iter().zip(&reports) {
        assert_eq!(d.hw, *hw, "order preserved");
        let scalar = obj.evaluate(hw);
        assert_eq!(d.cycles.to_bits(), scalar.cycles.to_bits());
        assert_eq!(d.edp.to_bits(), scalar.edp.to_bits());
        assert_eq!(d.power_w.to_bits(), scalar.power_w.to_bits());
    }
}
