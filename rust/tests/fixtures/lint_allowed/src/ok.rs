// Allow-mechanism fixture for `tests/lint_repo.rs`: the same patterns
// as `lint/src/bad.rs`, every one suppressed by a justified directive
// (or a justification comment, for bare-allow). Must lint clean.
// Never compiled — fixture data.

pub fn shared_counter() {
    // lint:allow(raw-sync) fixture exercising the allow path; real code uses TrackedMutex
    let _counter = std::sync::Mutex::new(0u64);
}

pub fn fire_and_forget() {
    std::thread::spawn(|| {}); // lint:allow(thread-spawn) fixture exercising the allow path
}

pub fn fresh_rng(seed: u64) -> crate::util::rng::Pcg32 {
    // lint:allow(rng-construct) fixture exercising the allow path
    crate::util::rng::Pcg32::new(seed, 7)
}

pub fn sort_scores(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap()); // lint:allow(float-cmp-unwrap) fixture exercising the allow path
}

#[allow(dead_code)] // fixture exercising the justification-comment path
pub fn unused_helper() {}
