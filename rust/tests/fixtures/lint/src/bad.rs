// Planted-violation fixture for `tests/lint_repo.rs`: exactly one
// violation per src-scoped rule (the dse-clock violation lives in
// `dse/bad_clock.rs` because that rule only applies under `src/dse/`).
// This file is never compiled — `lint_tree` treats `tests/fixtures/`
// as data, and cargo does not build test-dir subdirectories.

pub fn sort_scores(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap()); // float-cmp-unwrap
}

pub fn shared_counter() {
    let _counter = std::sync::Mutex::new(0u64); // raw-sync
}

pub fn fire_and_forget() {
    std::thread::spawn(|| {}); // thread-spawn
}

pub fn fresh_rng(seed: u64) -> crate::util::rng::Pcg32 {
    crate::util::rng::Pcg32::new(seed, 7) // rng-construct
}

#[allow(dead_code)]
pub fn unused_helper() {}
