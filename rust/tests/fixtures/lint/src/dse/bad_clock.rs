// Planted dse-clock violation for `tests/lint_repo.rs` (the rule only
// fires for files under `src/dse/`). Never compiled — fixture data.

pub fn deadline_check() -> bool {
    let start = std::time::Instant::now(); // dse-clock
    start.elapsed().as_secs() < 1
}
