//! Property suite for the design-space encoding and rounding contract
//! (`design_space::{encode, round}`): randomized encode → decode → round
//! trips always land on valid in-space configurations, rounding is
//! idempotent, and the structured projection preserves both properties.
//! Hermetic — pure functions of seeded randomness.

use diffaxe::design_space::encode::RawConfig;
use diffaxe::design_space::params::{BUF_MAX_B, BUF_MIN_B, BUF_STEP_B, DIM_MAX, DIM_MIN};
use diffaxe::design_space::structured::{
    boundaries_valid, boundary_dim, constrain, decode_boundaries, decode_structured,
    decode_structured_with_boundaries, encode_boundaries, encode_structured,
    encode_structured_with_boundaries, round_boundaries, sample_structured,
    structured_dim_with_boundaries, SharedBudget,
};
use diffaxe::design_space::{
    decode_rounded, encode_norm, round_to_target, LoopOrder, TargetSpace, NORM_DIM,
};
use diffaxe::util::rng::Pcg32;

const TRIALS: usize = 2000;

/// encode → decode is the identity on every target-space configuration.
#[test]
fn encode_decode_roundtrip_identity_on_target_space() {
    let mut rng = Pcg32::seeded(1001);
    for _ in 0..TRIALS {
        let hw = TargetSpace::sample(&mut rng);
        let v = encode_norm(&hw);
        assert!(v.iter().all(|x| (0.0..=1.0).contains(x)), "{hw}: encoding out of unit box");
        assert_eq!(decode_rounded(&v), hw, "roundtrip moved {hw}");
    }
}

/// Arbitrary (wildly out-of-range) continuous vectors decode onto valid
/// in-space configurations, and decoding is idempotent through a second
/// encode → decode trip.
#[test]
fn arbitrary_vectors_decode_into_space_idempotently() {
    let mut rng = Pcg32::seeded(1002);
    for _ in 0..TRIALS {
        let v: Vec<f32> = (0..NORM_DIM).map(|_| (rng.f64() * 8.0 - 4.0) as f32).collect();
        let hw = decode_rounded(&v);
        assert!(hw.in_target_space(), "decode left the space: {hw}");
        let again = decode_rounded(&encode_norm(&hw));
        assert_eq!(again, hw, "decode not idempotent for {v:?}");
    }
}

/// `round_to_target` lands in-space and is idempotent for arbitrary raw
/// (continuous, out-of-range) configurations.
#[test]
fn rounding_is_idempotent_and_in_space() {
    let mut rng = Pcg32::seeded(1003);
    for _ in 0..TRIALS {
        let raw = RawConfig {
            r: rng.range_f64(-100.0, 500.0),
            c: rng.range_f64(-100.0, 500.0),
            ip_b: rng.range_f64(-2e6, 4e6),
            wt_b: rng.range_f64(-2e6, 4e6),
            op_b: rng.range_f64(-2e6, 4e6),
            bw: rng.range_f64(-20.0, 200.0),
            loop_order: *rng.choose(&LoopOrder::OS_ORDERS),
        };
        let hw = round_to_target(&raw);
        assert!(hw.in_target_space(), "{hw}");
        let again = round_to_target(&RawConfig {
            r: hw.r as f64,
            c: hw.c as f64,
            ip_b: hw.ip_b as f64,
            wt_b: hw.wt_b as f64,
            op_b: hw.op_b as f64,
            bw: hw.bw as f64,
            loop_order: hw.loop_order,
        });
        assert_eq!(hw, again, "rounding not idempotent");
    }
}

/// Rounding picks the *nearest* grid point on each axis (within half a
/// grid step for in-range inputs).
#[test]
fn rounding_is_nearest_on_each_axis() {
    let mut rng = Pcg32::seeded(1004);
    for _ in 0..TRIALS {
        let b = rng.range_f64(BUF_MIN_B as f64, BUF_MAX_B as f64);
        let raw = RawConfig {
            r: rng.range_f64(DIM_MIN as f64, DIM_MAX as f64),
            c: rng.range_f64(DIM_MIN as f64, DIM_MAX as f64),
            ip_b: b,
            wt_b: b,
            op_b: b,
            bw: 8.0,
            loop_order: LoopOrder::Mnk,
        };
        let hw = round_to_target(&raw);
        assert!((hw.r as f64 - raw.r).abs() <= 0.5);
        assert!((hw.c as f64 - raw.c).abs() <= 0.5);
        assert!((hw.ip_b as f64 - b).abs() <= BUF_STEP_B as f64 / 2.0);
    }
}

/// Boundary lanes inherit the same contract: `round_boundaries` repairs
/// arbitrary cut vectors into valid strictly-increasing interior cuts,
/// is idempotent, and encode → decode is the identity on valid cuts.
#[test]
fn boundary_round_is_valid_idempotent_and_roundtrips() {
    let mut rng = Pcg32::seeded(1006);
    for _ in 0..TRIALS {
        let n_layers = rng.int_range(2, 40) as usize;
        let segments = (rng.int_range(2, 6) as usize).min(n_layers);
        let raw: Vec<usize> =
            (1..segments).map(|_| rng.int_range(0, 2 * n_layers as i64) as usize).collect();
        let bounds = round_boundaries(&raw, n_layers);
        assert_eq!(bounds.len(), boundary_dim(segments));
        assert!(boundaries_valid(&bounds, n_layers), "{raw:?} -> {bounds:?} over {n_layers}");
        assert_eq!(round_boundaries(&bounds, n_layers), bounds, "repair not idempotent");
        let lanes = encode_boundaries(&bounds, n_layers);
        assert!(lanes.iter().all(|x| (0.0..=1.0).contains(x)));
        assert_eq!(decode_boundaries(&lanes, n_layers), bounds, "roundtrip moved {bounds:?}");
    }
}

/// Arbitrary (out-of-range) boundary lanes always decode onto a valid
/// segmentation, and decoding is idempotent through a second
/// encode → decode trip.
#[test]
fn arbitrary_boundary_lanes_decode_into_valid_cuts() {
    let mut rng = Pcg32::seeded(1007);
    for _ in 0..TRIALS {
        let n_layers = rng.int_range(2, 40) as usize;
        let segments = (rng.int_range(2, 6) as usize).min(n_layers);
        let lanes: Vec<f32> =
            (1..segments).map(|_| (rng.f64() * 6.0 - 3.0) as f32).collect();
        let bounds = decode_boundaries(&lanes, n_layers);
        assert!(boundaries_valid(&bounds, n_layers), "{lanes:?} -> {bounds:?} over {n_layers}");
        assert_eq!(decode_boundaries(&encode_boundaries(&bounds, n_layers), n_layers), bounds);
    }
}

/// The joint (configs + cuts) encoding round-trips both halves through
/// one vector of width `structured_dim_with_boundaries(s)`.
#[test]
fn joint_structured_boundary_encoding_roundtrips() {
    let budget = SharedBudget { pe: 2048, buf_b: 256 * 1024, bw: 12 };
    let mut rng = Pcg32::seeded(1008);
    for _ in 0..500 {
        let n_layers = rng.int_range(4, 48) as usize;
        let segments = (rng.int_range(2, 4) as usize).min(n_layers);
        let cfg = sample_structured(&mut rng, &budget, segments);
        let raw: Vec<usize> =
            (1..segments).map(|_| rng.int_range(1, n_layers as i64 - 1) as usize).collect();
        let bounds = round_boundaries(&raw, n_layers);
        let v = encode_structured_with_boundaries(&cfg, &bounds, n_layers);
        assert_eq!(v.len(), structured_dim_with_boundaries(segments));
        let (cfg2, bounds2) = decode_structured_with_boundaries(&v, &budget, segments, n_layers);
        assert_eq!(cfg2, cfg);
        assert_eq!(bounds2, bounds);
    }
}

/// The structured projection inherits the contract: encode → decode is
/// the identity on constrained configurations, and constraining is
/// idempotent, across a spread of budgets and segment counts.
#[test]
fn structured_encode_decode_and_constrain_properties() {
    let budgets = [
        SharedBudget::unconstrained(),
        SharedBudget { pe: 2048, buf_b: 256 * 1024, bw: 12 },
        SharedBudget { pe: 64, buf_b: 3 * BUF_MIN_B, bw: 2 },
    ];
    let mut rng = Pcg32::seeded(1005);
    for budget in budgets {
        budget.validate().unwrap();
        for segments in [1usize, 2, 4] {
            for _ in 0..200 {
                let cfg = sample_structured(&mut rng, &budget, segments);
                assert!(cfg.in_budget(&budget), "{cfg:?} vs {budget:?}");
                let v = encode_structured(&cfg);
                assert_eq!(decode_structured(&v, &budget, segments), cfg);
                let again = constrain(&budget, cfg.segments.clone());
                assert_eq!(again, cfg, "constrain not idempotent");
            }
        }
    }
}
