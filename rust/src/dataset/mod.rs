//! Training-dataset generation and loading (paper §IV-A).
//!
//! The rust simulator is the single source of truth for performance labels:
//! `diffaxe gen-dataset` enumerates the coarse training design space per
//! workload, simulates runtime/power/EDP on the 32 nm ASIC model, and writes
//! a flat little-endian f32 table + JSON header that both numpy
//! (`python/compile/data.py`) and [`Dataset::load`] read.
//!
//! Row layout (`ROW_WIDTH` = 14 f32s):
//! `[hw_norm(8) | M K N | runtime_cycles power_w edp_uj_cycles]`

use crate::design_space::{encode_norm, HwConfig, TrainingSpace, NORM_DIM};
use crate::energy::asic;
use crate::sim::simulate;
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use crate::workload::{Gemm, WorkloadSuite};
use anyhow::{bail, Context, Result};
use std::io::{BufWriter, Read, Write};
use std::path::Path;

/// f32s per dataset row.
pub const ROW_WIDTH: usize = NORM_DIM + 3 + 3;

/// Offsets into a row.
pub const COL_M: usize = NORM_DIM;
pub const COL_K: usize = NORM_DIM + 1;
pub const COL_N: usize = NORM_DIM + 2;
pub const COL_RUNTIME: usize = NORM_DIM + 3;
pub const COL_POWER: usize = NORM_DIM + 4;
pub const COL_EDP: usize = NORM_DIM + 5;

/// Generation parameters.
#[derive(Debug, Clone)]
pub struct GenConfig {
    /// number of workloads in the suite (paper: 600)
    pub n_workloads: usize,
    /// configurations sampled per workload from the 77,760-point training
    /// space (paper: all of them)
    pub n_configs_per_workload: usize,
    pub seed: u64,
}

impl GenConfig {
    /// Scaled-down default sized for single-core CPU training (see
    /// DESIGN.md §3 substitutions). `DIFFAXE_SCALE=paper` restores §IV-A.
    pub fn default_scaled() -> Self {
        GenConfig { n_workloads: 24, n_configs_per_workload: 7776, seed: 1 }
    }

    pub fn paper() -> Self {
        GenConfig {
            n_workloads: WorkloadSuite::PAPER_SIZE,
            n_configs_per_workload: TrainingSpace::len(),
            seed: 1,
        }
    }

    /// Resolve from the `DIFFAXE_SCALE` environment variable
    /// (`paper`/`quick`/default).
    pub fn from_env() -> Self {
        match std::env::var("DIFFAXE_SCALE").as_deref() {
            Ok("paper") => Self::paper(),
            Ok("quick") => GenConfig { n_workloads: 6, n_configs_per_workload: 1024, seed: 1 },
            _ => Self::default_scaled(),
        }
    }
}

/// In-memory dataset (also the loader for benches/tests).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub rows: Vec<f32>,
    pub workloads: Vec<Gemm>,
    /// per-workload (row offset, row count)
    pub spans: Vec<(usize, usize)>,
}

impl Dataset {
    pub fn n_rows(&self) -> usize {
        self.rows.len() / ROW_WIDTH
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.rows[i * ROW_WIDTH..(i + 1) * ROW_WIDTH]
    }

    /// Rows belonging to workload `w`.
    pub fn workload_rows(&self, w: usize) -> impl Iterator<Item = &[f32]> {
        let (off, cnt) = self.spans[w];
        (off..off + cnt).map(move |i| self.row(i))
    }

    /// Generate the dataset in memory.
    pub fn generate(cfg: &GenConfig) -> Dataset {
        let suite = WorkloadSuite::generate(cfg.n_workloads, cfg.seed);
        let full = TrainingSpace::len();
        let n_cfg = cfg.n_configs_per_workload.min(full);
        let mut rows = Vec::with_capacity(cfg.n_workloads * n_cfg * ROW_WIDTH);
        let mut spans = Vec::with_capacity(cfg.n_workloads);
        // lint:allow(rng-construct) stream 4242 pins the sampled config subsets across releases
        let mut rng = Pcg32::new(cfg.seed, 4242);
        for g in &suite.workloads {
            let offset = rows.len() / ROW_WIDTH;
            let indices: Vec<usize> = if n_cfg == full {
                (0..full).collect()
            } else {
                rng.sample_indices(full, n_cfg)
            };
            for idx in indices {
                let hw = TrainingSpace::nth(idx);
                push_row(&mut rows, &hw, g);
            }
            spans.push((offset, n_cfg));
        }
        Dataset { rows, workloads: suite.workloads, spans }
    }

    /// Write `<dir>/train.bin` + `<dir>/train.json`.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let bin_path = dir.join("train.bin");
        let mut w = BufWriter::new(std::fs::File::create(&bin_path)?);
        for v in &self.rows {
            w.write_all(&v.to_le_bytes())?;
        }
        w.flush()?;

        let wl_json: Vec<Json> = self
            .workloads
            .iter()
            .zip(&self.spans)
            .map(|(g, &(off, cnt))| {
                Json::obj(vec![
                    ("m", Json::Num(g.m as f64)),
                    ("k", Json::Num(g.k as f64)),
                    ("n", Json::Num(g.n as f64)),
                    ("offset", Json::Num(off as f64)),
                    ("count", Json::Num(cnt as f64)),
                ])
            })
            .collect();
        let header = Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("row_width", Json::Num(ROW_WIDTH as f64)),
            ("n_rows", Json::Num(self.n_rows() as f64)),
            ("dtype", Json::Str("f32le".into())),
            ("workloads", Json::Arr(wl_json)),
            (
                "fields",
                Json::Arr(
                    ["hw0", "hw1", "hw2", "hw3", "hw4", "hw5", "loop_mnk", "loop_nmk", "m",
                     "k", "n", "runtime_cycles", "power_w", "edp_uj_cycles"]
                        .iter()
                        .map(|s| Json::Str(s.to_string()))
                        .collect(),
                ),
            ),
        ]);
        std::fs::write(dir.join("train.json"), header.to_string())?;
        Ok(())
    }

    /// Load a dataset written by [`Dataset::save`].
    pub fn load(dir: &Path) -> Result<Dataset> {
        let header_text = std::fs::read_to_string(dir.join("train.json"))
            .with_context(|| format!("reading {}/train.json", dir.display()))?;
        let header = Json::parse(&header_text).context("parsing train.json")?;
        let row_width = header.get("row_width").as_usize().context("row_width")?;
        if row_width != ROW_WIDTH {
            bail!("dataset row_width {row_width} != expected {ROW_WIDTH}");
        }
        let n_rows = header.get("n_rows").as_usize().context("n_rows")?;
        let mut workloads = Vec::new();
        let mut spans = Vec::new();
        for w in header.get("workloads").as_arr().context("workloads")? {
            workloads.push(Gemm::new(
                w.get("m").as_usize().context("m")? as u32,
                w.get("k").as_usize().context("k")? as u32,
                w.get("n").as_usize().context("n")? as u32,
            ));
            spans.push((
                w.get("offset").as_usize().context("offset")?,
                w.get("count").as_usize().context("count")?,
            ));
        }
        let mut bytes = Vec::new();
        std::fs::File::open(dir.join("train.bin"))?.read_to_end(&mut bytes)?;
        if bytes.len() != n_rows * ROW_WIDTH * 4 {
            bail!("train.bin size {} != header promise {}", bytes.len(), n_rows * ROW_WIDTH * 4);
        }
        let rows: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Dataset { rows, workloads, spans })
    }
}

fn push_row(rows: &mut Vec<f32>, hw: &HwConfig, g: &Gemm) {
    let sim = simulate(hw, g);
    let e = asic::evaluate(hw, &sim);
    rows.extend_from_slice(&encode_norm(hw));
    rows.push(g.m as f32);
    rows.push(g.k as f32);
    rows.push(g.n as f32);
    rows.push(sim.cycles as f32);
    rows.push(e.power_w as f32);
    rows.push(e.edp as f32);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design_space::decode_rounded;

    fn tiny() -> GenConfig {
        GenConfig { n_workloads: 3, n_configs_per_workload: 128, seed: 9 }
    }

    #[test]
    fn generate_shapes() {
        let ds = Dataset::generate(&tiny());
        assert_eq!(ds.workloads.len(), 3);
        assert_eq!(ds.n_rows(), 3 * 128);
        assert_eq!(ds.spans, vec![(0, 128), (128, 128), (256, 128)]);
        for i in 0..ds.n_rows() {
            let r = ds.row(i);
            assert!(r[COL_RUNTIME] > 0.0);
            assert!(r[COL_POWER] > 0.0);
            assert!(r[COL_EDP] > 0.0);
        }
    }

    #[test]
    fn rows_decode_to_training_space_configs() {
        let ds = Dataset::generate(&tiny());
        for i in 0..ds.n_rows() {
            let hw = decode_rounded(&ds.row(i)[..NORM_DIM]);
            assert!(hw.in_target_space());
            // training-space configs use the coarse grid values
            assert!(TrainingSpace::DIMS.contains(&hw.r), "{hw}");
            assert!(TrainingSpace::BWS.contains(&hw.bw), "{hw}");
        }
    }

    #[test]
    fn labels_match_fresh_simulation() {
        let ds = Dataset::generate(&tiny());
        for w in 0..ds.workloads.len() {
            let g = ds.workloads[w];
            for r in ds.workload_rows(w).take(10) {
                let hw = decode_rounded(&r[..NORM_DIM]);
                let sim = simulate(&hw, &g);
                let e = asic::evaluate(&hw, &sim);
                assert_eq!(r[COL_RUNTIME], sim.cycles as f32);
                assert!((r[COL_EDP] - e.edp as f32).abs() <= 1e-4 * e.edp as f32);
            }
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let ds = Dataset::generate(&tiny());
        let dir = std::env::temp_dir().join(format!("diffaxe_ds_test_{}", std::process::id()));
        ds.save(&dir).unwrap();
        let back = Dataset::load(&dir).unwrap();
        assert_eq!(back.rows, ds.rows);
        assert_eq!(back.workloads, ds.workloads);
        assert_eq!(back.spans, ds.spans);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_corrupt_sizes() {
        let ds = Dataset::generate(&tiny());
        let dir = std::env::temp_dir().join(format!("diffaxe_ds_corrupt_{}", std::process::id()));
        ds.save(&dir).unwrap();
        // truncate the binary
        let bin = dir.join("train.bin");
        let bytes = std::fs::read(&bin).unwrap();
        std::fs::write(&bin, &bytes[..bytes.len() - 4]).unwrap();
        assert!(Dataset::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn full_enumeration_when_count_equals_space() {
        let cfg = GenConfig { n_workloads: 1, n_configs_per_workload: TrainingSpace::len(), seed: 1 };
        let ds = Dataset::generate(&cfg);
        assert_eq!(ds.n_rows(), TrainingSpace::len());
        // first row must be the first enumerated config
        let hw0 = decode_rounded(&ds.row(0)[..NORM_DIM]);
        assert_eq!(hw0, TrainingSpace::nth(0));
    }
}
