//! Command-line parsing substrate (clap is not in the offline registry).
//!
//! Supports `binary <subcommand> [--key value]... [--flag]...` with typed
//! accessors and an automatic usage listing.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parsed command line: a subcommand plus `--key value` / `--flag` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    bail!("empty option name");
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.opts.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} must be an integer, got {v}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} must be an integer, got {v}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} must be a number, got {v}")),
        }
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["gen-dataset", "--workloads", "24", "--seed=7", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("gen-dataset"));
        assert_eq!(a.get_usize("workloads", 0).unwrap(), 24);
        assert_eq!(a.get_u64("seed", 0).unwrap(), 7);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["serve"]);
        assert_eq!(a.get_usize("batch", 256).unwrap(), 256);
        assert_eq!(a.get_str("out", "artifacts"), "artifacts");
    }

    #[test]
    fn bad_integer_is_error() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn positional_args() {
        let a = parse(&["sim", "file1", "file2"]);
        assert_eq!(a.positional(), &["file1".to_string(), "file2".to_string()]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--a", "--b", "v"]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }
}
