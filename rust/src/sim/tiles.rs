//! Closed-form tile arithmetic for the analytical model's hot path.
//!
//! §Perf optimization: `simulate()` is called millions of times per DSE run
//! (dataset generation, candidate evaluation, random/BO baselines). The
//! original implementation materialized per-tile size vectors on every
//! call; tiling along one dimension only ever produces `n-1` full tiles
//! plus one remainder, so every per-tile sum collapses to two terms.

/// Tiling of `total` into tiles of size `t`: `full` tiles of `t` elements
/// plus an optional `last < t` remainder.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tiling {
    pub tiles: u64,
    pub full: u64,
    pub tile: u64,
    pub last: u64,
}

impl Tiling {
    pub fn new(total: u64, t: u64) -> Tiling {
        debug_assert!(total > 0 && t > 0);
        let tiles = total.div_ceil(t);
        let rem = total - (tiles - 1) * t;
        if rem == t {
            Tiling { tiles, full: tiles, tile: t, last: 0 }
        } else {
            Tiling { tiles, full: tiles - 1, tile: t, last: rem }
        }
    }

    /// Σ over tiles of `f(tile_size) * tile_size` where f maps a tile's
    /// working-set multiplier — two evaluations instead of `tiles`.
    pub fn sum_sized(&self, mut f: impl FnMut(u64) -> u64) -> u64 {
        let mut s = self.full * self.tile * f(self.tile);
        if self.last > 0 {
            s += self.last * f(self.last);
        }
        s
    }

    pub fn total(&self) -> u64 {
        self.full * self.tile + self.last
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_division() {
        let t = Tiling::new(64, 16);
        assert_eq!((t.tiles, t.full, t.last), (4, 4, 0));
        assert_eq!(t.total(), 64);
    }

    #[test]
    fn with_remainder() {
        let t = Tiling::new(70, 16);
        assert_eq!((t.tiles, t.full, t.last), (5, 4, 6));
        assert_eq!(t.total(), 70);
    }

    #[test]
    fn single_partial_tile() {
        let t = Tiling::new(5, 16);
        assert_eq!((t.tiles, t.full, t.last), (1, 0, 5));
        assert_eq!(t.total(), 5);
    }

    #[test]
    fn sum_sized_matches_naive() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(1);
        for _ in 0..500 {
            let total = rng.int_range(1, 500) as u64;
            let tile = rng.int_range(1, 64) as u64;
            let cap = rng.int_range(1, 400) as u64;
            let t = Tiling::new(total, tile);
            let f = |sz: u64| if sz * 7 <= cap { 1 } else { 3 };
            let naive: u64 = (0..t.tiles)
                .map(|i| {
                    let sz = (total - i * tile).min(tile);
                    sz * f(sz)
                })
                .sum();
            assert_eq!(t.sum_sized(f), naive, "total={total} tile={tile}");
        }
    }
}
