//! Performance simulator substrate — the role Scale-Sim [13] plays in the
//! paper: cycle counts and memory-access tallies for a GEMM on a systolic
//! array under output-stationary (OS) dataflow.
//!
//! Three implementations of the same model:
//!
//! * [`analytical`] — closed-form (used everywhere: dataset generation,
//!   candidate evaluation, benchmarks). O(1) per (hardware, workload) pair.
//! * [`batch`] — the same closed-form model restructured as
//!   structure-of-arrays over a *batch* of candidates
//!   ([`batch::simulate_batch`] / [`batch::simulate_pairs`]): candidates are
//!   grouped by [`LoopOrder`] so the reuse-breaker dispatch is hoisted out of
//!   the per-candidate loops and the all-integer tiling/traffic arithmetic
//!   runs over parallel arrays. The scalar [`simulate`] is its bit-identity
//!   oracle — the property suite asserts exact `SimResult` equality, so the
//!   batch path is a pure throughput optimization, never a second model.
//! * [`trace`] — a literal tile-loop-nest simulator with explicit buffer
//!   residency tracking. O(Tm·Tn·Tk) per pair; the *oracle* the analytical
//!   formulas are property-tested against.
//!
//! # Model definition
//!
//! Element size is 1 byte (int8 inference). The R×C array computes one
//! output tile (≤R rows × ≤C cols) per *fold*; the K-reduction streams
//! through the PEs while partial sums stay in PE registers (OS). A fold
//! costs `2R + C + K' − 2` cycles (Scale-Sim's OS fold latency: skew fill,
//! stream, and an R-cycle output drain — the paper's "(R−M) cycle overhead"
//! when R > M appears because the drain always costs R).
//!
//! The loop nest iterates output tiles `i < Tm = ⌈M/R⌉`, `j < Tn = ⌈N/C⌉`
//! and K-chunks `k < Tk` in the configured [`LoopOrder`]. When `k` is the
//! innermost loop the whole reduction happens per tile (`Tk = 1`); otherwise
//! K is chunked to what the operand buffers can hold and partial sums spill
//! through the output buffer (or DRAM if it cannot hold the revisited
//! working set).
//!
//! DRAM traffic per operand follows a *stationarity* analysis: an operand
//! granule is refetched once per trip of its reuse-breaker loop (the one
//! loop that does not index it) unless the working set it must retain fits
//! its buffer. [`trace`] implements the same policy operationally
//! (scope-keyed residency sets with overflow flush) and the property suite
//! checks exact agreement.
//!
//! Runtime = `max(compute cycles, DRAM bytes / BW)` — the Scale-Sim stall
//! model's global approximation under double buffering.
//!
//! The simulator is deterministic by construction (no clocks, no RNG, no
//! locks) and `diffaxe lint` keeps it that way — the rules and rationale
//! live in `docs/INVARIANTS.md`.

pub mod analytical;
pub mod batch;
pub mod tiles;
pub mod trace;

pub use batch::{simulate_batch, simulate_pairs};

use crate::design_space::{HwConfig, LoopOrder};
use crate::workload::Gemm;

/// DRAM traffic breakdown in bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct DramTraffic {
    pub a_reads: u64,
    pub b_reads: u64,
    pub out_writes: u64,
    /// partial-sum re-reads (only non-zero when K is chunked, i.e. the loop
    /// order is not k-innermost)
    pub out_reads: u64,
}

impl DramTraffic {
    pub fn total(&self) -> u64 {
        self.a_reads + self.b_reads + self.out_writes + self.out_reads
    }
}

/// On-chip SRAM access tallies in bytes (elements are 1 byte).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SramAccess {
    /// input-buffer reads feeding the array
    pub ip_reads: u64,
    /// weight-buffer reads feeding the array
    pub wt_reads: u64,
    /// output-buffer writes (results + partial spills)
    pub op_writes: u64,
    /// output-buffer reads (DRAM drain + partial reload)
    pub op_reads: u64,
    /// fills from DRAM into ip/wt buffers
    pub fills: u64,
}

impl SramAccess {
    pub fn total(&self) -> u64 {
        self.ip_reads + self.wt_reads + self.op_writes + self.op_reads + self.fills
    }
}

/// Full simulation result for one (hardware, GEMM) pair.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimResult {
    /// end-to-end runtime in cycles: max(compute, memory)
    pub cycles: u64,
    pub compute_cycles: u64,
    pub mem_cycles: u64,
    pub dram: DramTraffic,
    pub sram: SramAccess,
    /// useful multiply-accumulates (M·K·N)
    pub macs_useful: u64,
    /// PE-cycles clocked (R·C · compute cycles) — idle-PE overhead shows up
    /// as the gap to `macs_useful`
    pub pe_cycles: u64,
    /// number of K-chunks (1 ⇔ k-innermost loop order)
    pub tk: u64,
}

impl SimResult {
    /// The all-zero result: the identity of [`SimResult::add`], used for
    /// empty workloads (zero GEMMs simulate to zero cost, not a panic).
    pub fn zero() -> SimResult {
        SimResult {
            cycles: 0,
            compute_cycles: 0,
            mem_cycles: 0,
            dram: DramTraffic::default(),
            sram: SramAccess::default(),
            macs_useful: 0,
            pe_cycles: 0,
            tk: 0,
        }
    }

    /// Fraction of clocked PE-cycles doing useful MACs.
    pub fn utilization(&self) -> f64 {
        if self.pe_cycles == 0 {
            0.0
        } else {
            self.macs_useful as f64 / self.pe_cycles as f64
        }
    }

    pub fn is_memory_bound(&self) -> bool {
        self.mem_cycles > self.compute_cycles
    }

    /// Field-wise sum of two runs (sequence accumulation): every counter
    /// adds; `tk` keeps the max (it is a shape property, not a tally). The
    /// single accumulation point for sequence workloads — [`simulate_seq`]
    /// and the LLM whole-model evaluator both go through here, so adding a
    /// counter to [`SimResult`] cannot silently drift between copies.
    pub fn add(&self, o: &SimResult) -> SimResult {
        SimResult {
            cycles: self.cycles + o.cycles,
            compute_cycles: self.compute_cycles + o.compute_cycles,
            mem_cycles: self.mem_cycles + o.mem_cycles,
            dram: DramTraffic {
                a_reads: self.dram.a_reads + o.dram.a_reads,
                b_reads: self.dram.b_reads + o.dram.b_reads,
                out_writes: self.dram.out_writes + o.dram.out_writes,
                out_reads: self.dram.out_reads + o.dram.out_reads,
            },
            sram: SramAccess {
                ip_reads: self.sram.ip_reads + o.sram.ip_reads,
                wt_reads: self.sram.wt_reads + o.sram.wt_reads,
                op_writes: self.sram.op_writes + o.sram.op_writes,
                op_reads: self.sram.op_reads + o.sram.op_reads,
                fills: self.sram.fills + o.sram.fills,
            },
            macs_useful: self.macs_useful + o.macs_useful,
            pe_cycles: self.pe_cycles + o.pe_cycles,
            tk: self.tk.max(o.tk),
        }
    }

    /// Scale every counter by `k` (whole-model scaling: one transformer
    /// block repeated `k` times). `tk` is per-layer shape and stays.
    pub fn scale(&self, k: u64) -> SimResult {
        SimResult {
            cycles: self.cycles * k,
            compute_cycles: self.compute_cycles * k,
            mem_cycles: self.mem_cycles * k,
            dram: DramTraffic {
                a_reads: self.dram.a_reads * k,
                b_reads: self.dram.b_reads * k,
                out_writes: self.dram.out_writes * k,
                out_reads: self.dram.out_reads * k,
            },
            sram: SramAccess {
                ip_reads: self.sram.ip_reads * k,
                wt_reads: self.sram.wt_reads * k,
                op_writes: self.sram.op_writes * k,
                op_reads: self.sram.op_reads * k,
                fills: self.sram.fills * k,
            },
            macs_useful: self.macs_useful * k,
            pe_cycles: self.pe_cycles * k,
            tk: self.tk,
        }
    }
}

/// Simulate one GEMM on one configuration (the fast analytical model).
pub fn simulate(hw: &HwConfig, g: &Gemm) -> SimResult {
    analytical::simulate(hw, g)
}

/// A design point for *sequence* workloads (paper §VI / Fig 20): shared
/// systolic-array parameters plus an independent loop order per layer.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SeqConfig {
    pub base: HwConfig,
    /// per-layer loop orders; length = number of GEMMs in the sequence
    pub orders: Vec<LoopOrder>,
}

impl SeqConfig {
    pub fn uniform(base: HwConfig, n_layers: usize) -> Self {
        SeqConfig { base, orders: vec![base.loop_order; n_layers] }
    }

    /// The configuration used for layer `l`.
    pub fn layer_hw(&self, l: usize) -> HwConfig {
        HwConfig { loop_order: self.orders[l], ..self.base }
    }
}

/// Simulate a GEMM sequence layer by layer, summing cycles and traffic
/// through [`SimResult::add`].
pub fn simulate_seq(cfg: &SeqConfig, gemms: &[Gemm]) -> SimResult {
    assert_eq!(cfg.orders.len(), gemms.len(), "one loop order per layer");
    let mut acc: Option<SimResult> = None;
    for (l, g) in gemms.iter().enumerate() {
        let r = simulate(&cfg.layer_hw(l), g);
        acc = Some(match acc {
            None => r,
            Some(a) => a.add(&r),
        });
    }
    acc.expect("non-empty GEMM sequence")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design_space::LoopOrder;

    #[test]
    fn seq_sums_layers() {
        let hw = HwConfig::new_kb(16, 16, 64.0, 64.0, 64.0, 8, LoopOrder::Mnk);
        let g1 = Gemm::new(64, 64, 64);
        let g2 = Gemm::new(32, 128, 96);
        let cfg = SeqConfig::uniform(hw, 2);
        let seq = simulate_seq(&cfg, &[g1, g2]);
        let (r1, r2) = (simulate(&hw, &g1), simulate(&hw, &g2));
        assert_eq!(seq.cycles, r1.cycles + r2.cycles);
        assert_eq!(seq.macs_useful, r1.macs_useful + r2.macs_useful);
        assert_eq!(seq.dram.total(), r1.dram.total() + r2.dram.total());
    }

    #[test]
    fn add_and_scale_cover_every_counter() {
        let hw = HwConfig::new_kb(8, 8, 16.0, 16.0, 8.0, 4, LoopOrder::Nmk);
        let a = simulate(&hw, &Gemm::new(96, 512, 64));
        let b = simulate(&hw, &Gemm::new(256, 64, 256));
        let s = a.add(&b);
        assert_eq!(s.cycles, a.cycles + b.cycles);
        assert_eq!(s.compute_cycles, a.compute_cycles + b.compute_cycles);
        assert_eq!(s.mem_cycles, a.mem_cycles + b.mem_cycles);
        assert_eq!(s.dram.a_reads, a.dram.a_reads + b.dram.a_reads);
        assert_eq!(s.dram.b_reads, a.dram.b_reads + b.dram.b_reads);
        assert_eq!(s.dram.out_writes, a.dram.out_writes + b.dram.out_writes);
        assert_eq!(s.dram.out_reads, a.dram.out_reads + b.dram.out_reads);
        assert_eq!(s.sram.ip_reads, a.sram.ip_reads + b.sram.ip_reads);
        assert_eq!(s.sram.wt_reads, a.sram.wt_reads + b.sram.wt_reads);
        assert_eq!(s.sram.op_writes, a.sram.op_writes + b.sram.op_writes);
        assert_eq!(s.sram.op_reads, a.sram.op_reads + b.sram.op_reads);
        assert_eq!(s.sram.fills, a.sram.fills + b.sram.fills);
        assert_eq!(s.macs_useful, a.macs_useful + b.macs_useful);
        assert_eq!(s.pe_cycles, a.pe_cycles + b.pe_cycles);
        assert_eq!(s.tk, a.tk.max(b.tk));
        // scale(k) == k-fold self-addition on every counter; tk unchanged
        let k3 = a.scale(3);
        assert_eq!(k3, a.add(&a).add(&a));
        assert_eq!(k3.tk, a.tk);
        assert_eq!(a.scale(1), a);
    }

    #[test]
    fn seq_respects_per_layer_orders() {
        let base = HwConfig::new_kb(32, 32, 4.0, 4.0, 4.0, 4, LoopOrder::Mnk);
        let g = Gemm::new(512, 512, 512);
        let mixed = SeqConfig { base, orders: vec![LoopOrder::Mnk, LoopOrder::Nmk] };
        let seq = simulate_seq(&mixed, &[g, g]);
        let mnk = simulate(&base, &g);
        let nmk = simulate(&HwConfig { loop_order: LoopOrder::Nmk, ..base }, &g);
        assert_eq!(seq.dram.total(), mnk.dram.total() + nmk.dram.total());
    }
}
