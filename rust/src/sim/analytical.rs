//! Closed-form OS-dataflow performance model (see module docs in
//! [`super`]). Exactly matched by the literal loop-nest oracle in
//! [`super::trace`]; the property suite enforces bit-equality of traffic.
//!
//! §Perf: this function is the evaluation hot path (millions of calls per
//! DSE run). All per-tile sums use the two-term closed form of
//! [`super::tiles::Tiling`] — zero heap allocation per call (before/after
//! in EXPERIMENTS.md §Perf).

use super::tiles::Tiling;
use super::{DramTraffic, SimResult, SramAccess};
use crate::design_space::HwConfig;
use crate::workload::Gemm;
#[cfg(test)]
use crate::design_space::LoopOrder;

/// Position of the reuse-breaker loop relative to an operand's own loops.
/// Shared with [`super::batch`], which hoists the dispatch on it out of the
/// per-candidate inner loop (the position depends only on the loop order).
#[derive(Debug, Clone, Copy, PartialEq)]
pub(super) enum BreakerPos {
    /// breaker is the innermost loop — each granule visited once
    Inner,
    /// breaker sits between the operand's own loops — per-slice reuse
    Middle {
        /// the operand's own loop that is outer to the breaker is `k`
        /// (order k…breaker…tile) rather than the tile dimension
        k_outer: bool,
    },
    /// breaker is the outermost loop — whole tensor re-swept per trip
    Outer,
}

pub(super) fn breaker_pos(nest: [char; 3], tile_dim: char, breaker: char) -> BreakerPos {
    let pos = |c: char| nest.iter().position(|&x| x == c).unwrap();
    let pb = pos(breaker);
    let (pt, pk) = (pos(tile_dim), pos('k'));
    if pb > pt && pb > pk {
        BreakerPos::Inner
    } else if pb < pt && pb < pk {
        BreakerPos::Outer
    } else {
        BreakerPos::Middle { k_outer: pk < pb }
    }
}

/// K-chunk size when `k` is *not* the innermost loop: bounded by what the
/// input and weight buffers can hold per array row/column. The raw-field
/// form serves the SoA lanes of [`super::batch`]; both paths run this one
/// expression, so the chunking can never drift between them.
pub(super) fn k_chunk_parts(r: u64, c: u64, ip_b: u64, wt_b: u64, k: u64) -> u64 {
    let by_ip = ip_b / r;
    let by_wt = wt_b / c;
    by_ip.min(by_wt).clamp(1, k)
}

/// [`k_chunk_parts`] over a whole configuration.
pub(super) fn k_chunk(hw: &HwConfig, k: u32) -> u64 {
    k_chunk_parts(hw.r as u64, hw.c as u64, hw.ip_b, hw.wt_b, k as u64)
}

/// DRAM traffic for one streamed operand (A with its m-tiling / IPSz, or B
/// with its n-tiling / WTSz, by symmetry).
///
/// * `tile`: tiling of the operand's non-shared dimension;
/// * `chunks`: K-chunk tiling (shared dimension);
/// * `trips`: breaker-loop trip count;
/// * `cap`: the operand's buffer capacity in bytes.
fn operand_traffic(pos: BreakerPos, tile: Tiling, chunks: Tiling, cap: u64, trips: u64) -> u64 {
    let k_total = chunks.total();
    let total = tile.total() * k_total;
    if total <= cap {
        return total; // whole tensor resident after first sweep
    }
    match pos {
        BreakerPos::Inner => total,
        BreakerPos::Outer => total * trips,
        BreakerPos::Middle { k_outer: false } => {
            // slice = one tile row/col across all of K
            k_total * tile.sum_sized(|rows| if rows * k_total <= cap { 1 } else { trips })
        }
        BreakerPos::Middle { k_outer: true } => {
            // slice = one K-chunk across the whole non-shared extent
            let extent = tile.total();
            extent * chunks.sum_sized(|kd| if extent * kd <= cap { 1 } else { trips })
        }
    }
}

/// Output DRAM traffic `(writes, partial_reads)`.
///
/// k-innermost: outputs leave the PEs exactly once → writes = M·N.
/// Otherwise the output working set revisited between consecutive k-steps
/// must fit OPSz or partials spill to DRAM once per chunk boundary.
fn output_traffic(hw: &HwConfig, g: &Gemm, tk: u64, tm: Tiling, tn: Tiling) -> (u64, u64) {
    let mn = g.out_elems();
    if tk == 1 {
        return (mn, 0);
    }
    let nest = hw.loop_order.nest();
    let posn = |c: char| nest.iter().position(|&x| x == c).unwrap();
    let pk = posn('k');
    let m_inner = posn('m') > pk;
    let n_inner = posn('n') > pk;
    // Working-set slices revisited across k: full extent of the loops inner
    // to k × one tile of the others.
    let (mut writes, mut reads) = (0, 0);
    let mut add_slices = |slices: Tiling, other_extent: u64, cap: u64| {
        writes += other_extent
            * slices.sum_sized(|s| if s * other_extent <= cap { 1 } else { tk });
        reads += other_extent
            * slices.sum_sized(|s| if s * other_extent <= cap { 0 } else { tk - 1 });
    };
    match (m_inner, n_inner) {
        (true, true) => {
            if mn <= hw.op_b {
                writes = mn;
            } else {
                writes = mn * tk;
                reads = mn * (tk - 1);
            }
        }
        (true, false) => add_slices(tn, g.m as u64, hw.op_b),
        (false, true) => add_slices(tm, g.n as u64, hw.op_b),
        (false, false) => unreachable!("tk > 1 implies k is not innermost"),
    }
    (writes, reads)
}

/// The closed-form simulation (see module docs).
pub fn simulate(hw: &HwConfig, g: &Gemm) -> SimResult {
    let nest = hw.loop_order.nest();
    let tm = Tiling::new(g.m as u64, hw.r as u64);
    let tn = Tiling::new(g.n as u64, hw.c as u64);
    let k_innermost = nest[2] == 'k';
    let chunks = if k_innermost {
        Tiling::new(g.k as u64, g.k as u64)
    } else {
        Tiling::new(g.k as u64, k_chunk(hw, g.k))
    };
    let tk = chunks.tiles;

    // ---- compute cycles ----------------------------------------------
    // per (i,j,k) fold: 2R + C + K' - 2 (skew fill + stream + drain)
    let fold_overhead = 2 * hw.r as u64 + hw.c as u64 - 2;
    let compute_cycles = tm.tiles * tn.tiles * (tk * fold_overhead + g.k as u64);

    // ---- DRAM traffic --------------------------------------------------
    // operand A: own loops (m, k), breaker n; operand B: (n, k), breaker m
    let a_reads =
        operand_traffic(breaker_pos(nest, 'm', 'n'), tm, chunks, hw.ip_b, tn.tiles);
    let b_reads =
        operand_traffic(breaker_pos(nest, 'n', 'm'), tn, chunks, hw.wt_b, tm.tiles);
    let (out_writes, out_reads) = output_traffic(hw, g, tk, tm, tn);
    let dram = DramTraffic { a_reads, b_reads, out_writes, out_reads };

    // ---- SRAM accesses --------------------------------------------------
    // every fold streams its full operand tiles from SRAM into the array
    let ip_reads = tn.tiles * g.a_elems();
    let wt_reads = tm.tiles * g.b_elems();
    let op_writes = g.out_elems() + dram.out_reads; // results + partial respills
    let op_reads = dram.out_writes; // everything written to DRAM passes through
    let sram = SramAccess {
        ip_reads,
        wt_reads,
        op_writes,
        op_reads,
        fills: dram.a_reads + dram.b_reads,
    };

    // ---- runtime ---------------------------------------------------------
    let mem_cycles = dram.total().div_ceil(hw.bw as u64);
    let cycles = compute_cycles.max(mem_cycles);

    SimResult {
        cycles,
        compute_cycles,
        mem_cycles,
        dram,
        sram,
        macs_useful: g.macs(),
        pe_cycles: compute_cycles * hw.macs(),
        tk,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design_space::params::TrainingSpace;

    fn hw(r: u32, c: u32, ip: f64, wt: f64, op: f64, bw: u32, lo: LoopOrder) -> HwConfig {
        HwConfig::new_kb(r, c, ip, wt, op, bw, lo)
    }

    #[test]
    fn single_tile_compute_formula() {
        // M=R, N=C, one fold, k innermost
        let h = hw(16, 16, 1024.0, 1024.0, 1024.0, 32, LoopOrder::Mnk);
        let g = Gemm::new(16, 100, 16);
        let r = simulate(&h, &g);
        assert_eq!(r.compute_cycles, 2 * 16 + 16 + 100 - 2);
        assert_eq!(r.tk, 1);
        // big buffers: every operand loaded exactly once
        assert_eq!(r.dram.a_reads, 16 * 100);
        assert_eq!(r.dram.b_reads, 100 * 16);
        assert_eq!(r.dram.out_writes, 16 * 16);
        assert_eq!(r.dram.out_reads, 0);
    }

    #[test]
    fn weight_refetch_factor_mnk_small_wt_buffer() {
        // mnk with WTSz too small for whole B and K*C > WTSz: B refetched
        // once per m-tile (paper §V-C: factor ceil(M/R))
        let h = hw(8, 8, 1024.0, 4.0, 1024.0, 32, LoopOrder::Mnk);
        let g = Gemm::new(64, 1024, 64); // K*C = 8 kB > 4 kB
        let r = simulate(&h, &g);
        let tm = 64 / 8;
        assert_eq!(r.dram.b_reads, g.b_elems() * tm);
        // A row tile (8 x 1024 = 8 kB) fits the 1 MB input buffer: loaded once
        assert_eq!(r.dram.a_reads, g.a_elems());
    }

    #[test]
    fn input_refetch_factor_nmk_small_ip_buffer() {
        // nmk with IPSz too small for whole A: A refetched ceil(N/C) times
        // (paper §VI: "repetition in input activation loads by ceil(N/C)")
        let h = hw(8, 8, 4.0, 1024.0, 1024.0, 32, LoopOrder::Nmk);
        let g = Gemm::new(512, 512, 64);
        let r = simulate(&h, &g);
        let tn = 64 / 8;
        assert_eq!(r.dram.a_reads, g.a_elems() * tn);
    }

    #[test]
    fn full_residency_eliminates_refetch() {
        // nmk but whole A fits -> loaded once despite n-outer order
        let h = hw(8, 8, 512.0, 1024.0, 1024.0, 32, LoopOrder::Nmk);
        let g = Gemm::new(512, 512, 64); // A = 256 kB <= 512 kB
        let r = simulate(&h, &g);
        assert_eq!(r.dram.a_reads, g.a_elems());
    }

    #[test]
    fn partial_tiles_count_actual_bytes() {
        let h = hw(16, 16, 1024.0, 1024.0, 1024.0, 32, LoopOrder::Mnk);
        let g = Gemm::new(20, 10, 20); // partial edge tiles
        let r = simulate(&h, &g);
        assert_eq!(r.dram.a_reads, 200);
        assert_eq!(r.dram.b_reads, 200);
        assert_eq!(r.dram.out_writes, 400);
        let folds = 2 * 2; // Tm=2, Tn=2
        assert_eq!(r.compute_cycles, folds * (2 * 16 + 16 + 10 - 2));
    }

    #[test]
    fn k_outer_orders_spill_partials() {
        // kmn with a tiny output buffer: partial sums spill per chunk
        let h = hw(8, 8, 4.0, 4.0, 4.0, 32, LoopOrder::Kmn);
        let g = Gemm::new(128, 2048, 128); // out = 16 kB > 4 kB
        let r = simulate(&h, &g);
        assert!(r.tk > 1);
        assert_eq!(r.dram.out_writes, g.out_elems() * r.tk);
        assert_eq!(r.dram.out_reads, g.out_elems() * (r.tk - 1));
    }

    #[test]
    fn k_outer_orders_keep_partials_when_opsz_large() {
        let h = hw(8, 8, 4.0, 4.0, 64.0, 32, LoopOrder::Kmn);
        let g = Gemm::new(128, 2048, 128); // out = 16 kB <= 64 kB
        let r = simulate(&h, &g);
        assert!(r.tk > 1);
        assert_eq!(r.dram.out_writes, g.out_elems());
        assert_eq!(r.dram.out_reads, 0);
    }

    #[test]
    fn memory_bound_vs_compute_bound() {
        let g = Gemm::new(256, 256, 256);
        let fast_mem = simulate(&hw(8, 8, 1024.0, 1024.0, 1024.0, 32, LoopOrder::Mnk), &g);
        assert!(!fast_mem.is_memory_bound(), "big array small bw should be compute bound");
        let slow_mem = simulate(&hw(128, 128, 4.0, 4.0, 4.0, 2, LoopOrder::Mnk), &g);
        assert!(slow_mem.is_memory_bound());
        assert_eq!(slow_mem.cycles, slow_mem.mem_cycles);
    }

    #[test]
    fn bandwidth_monotonicity() {
        let g = Gemm::new(128, 512, 1024);
        let mut prev = u64::MAX;
        for bw in [2, 4, 8, 16, 32] {
            let r = simulate(&hw(16, 16, 4.0, 4.0, 4.0, bw, LoopOrder::Mnk), &g);
            assert!(r.cycles <= prev, "bw {bw} should not be slower");
            prev = r.cycles;
        }
    }

    #[test]
    fn bigger_array_never_more_compute_cycles() {
        let g = Gemm::new(333, 777, 555);
        let small = simulate(&hw(8, 8, 64.0, 64.0, 64.0, 16, LoopOrder::Mnk), &g);
        let big = simulate(&hw(64, 64, 64.0, 64.0, 64.0, 16, LoopOrder::Mnk), &g);
        assert!(big.compute_cycles < small.compute_cycles);
    }

    #[test]
    fn many_to_one_property_exists_in_training_space() {
        // paper Fig 2(a): distinct configs hitting identical runtime
        use std::collections::HashMap;
        let g = Gemm::new(64, 768, 768);
        let mut by_cycles: HashMap<u64, u32> = HashMap::new();
        for (idx, hwc) in TrainingSpace::enumerate().enumerate() {
            if idx % 7 != 0 {
                continue; // subsample for test speed
            }
            *by_cycles.entry(simulate(&hwc, &g).cycles).or_default() += 1;
        }
        let max_collisions = by_cycles.values().max().copied().unwrap_or(0);
        assert!(max_collisions >= 4, "expected many-to-one mapping, max {max_collisions}");
    }

    #[test]
    fn utilization_bounded() {
        let g = Gemm::new(100, 100, 100);
        for lo in LoopOrder::ALL {
            let r = simulate(&hw(16, 32, 64.0, 64.0, 64.0, 8, lo), &g);
            let u = r.utilization();
            assert!(u > 0.0 && u <= 1.0, "{lo:?} utilization {u}");
        }
    }

    #[test]
    fn r_bigger_than_m_wastes_cycles() {
        // paper §VI: R > M underutilizes and pays the drain overhead
        let g = Gemm::new(1, 512, 512); // decode-style M=1
        let small_r = simulate(&hw(4, 64, 64.0, 64.0, 64.0, 32, LoopOrder::Mnk), &g);
        let big_r = simulate(&hw(128, 64, 64.0, 64.0, 64.0, 32, LoopOrder::Mnk), &g);
        assert!(big_r.compute_cycles > small_r.compute_cycles);
        assert!(big_r.utilization() < small_r.utilization());
    }
}
