//! Structure-of-arrays batch simulator — the vectorized form of
//! [`super::analytical::simulate`] for candidate *batches* (ROADMAP
//! item 2).
//!
//! Every optimizer funnels through batched evaluation
//! ([`crate::dse::evaluate_batch`], the LLM probe loop, the structured
//! per-segment evaluator), yet the analytical model scored one
//! `(HwConfig, Gemm)` pair at a time: each call re-derived the loop-nest
//! character positions, re-dispatched on the reuse-breaker position, and
//! touched a fresh `HwConfig` struct — branchy, allocation-adjacent code
//! the compiler cannot vectorize across candidates.
//!
//! This module restructures the inner loop:
//!
//! * **Grouping by [`LoopOrder`]** — the breaker positions
//!   ([`BreakerPos`]), k-innermost flag and the output-traffic
//!   `(m_inner, n_inner)` case are pure functions of the loop order, so
//!   candidates are bucketed into (at most six) order groups and every
//!   such dispatch is hoisted *out* of the per-candidate loop. Inside a
//!   group the remaining branches are cheap data-dependent compares
//!   (buffer-residency short circuits).
//! * **SoA lanes** — per-candidate fields (`r`, `c`, `ip_b`, `wt_b`,
//!   `op_b`, `bw`, and the workload's `m`/`n`/`k`) are laid out in
//!   parallel `u64` arrays ([`Lanes`]); each pass (tilings, compute
//!   cycles, per-operand DRAM traffic, output traffic, SRAM/runtime
//!   assembly) streams straight-line integer arithmetic over those
//!   arrays, which the backend autovectorizes where profitable.
//!
//! # Scalar-oracle guarantee
//!
//! The arithmetic is transcribed term-for-term from the scalar model and
//! shares its helpers ([`Tiling`], [`breaker_pos`],
//! [`super::analytical::k_chunk_parts`]); every counter is `u64`, so
//! there is no floating-point reassociation to drift. [`simulate_batch`]
//! is therefore **bit-identical** to mapping the scalar
//! [`super::simulate`] over the batch — `tests/sim_batch_props.rs`
//! enforces this across a `TrainingSpace` sample × `LoopOrder::ALL` ×
//! edge GEMMs (M=1 decode shapes, K=1, partial tiles). The scalar path
//! stays the oracle, exactly as [`super::trace`] is the oracle for the
//! scalar path.

use super::analytical::{breaker_pos, k_chunk_parts, BreakerPos};
use super::tiles::Tiling;
use super::{DramTraffic, SimResult, SramAccess};
use crate::design_space::{HwConfig, LoopOrder};
use crate::workload::Gemm;

/// Per-candidate scalar fields of one loop-order group as parallel
/// arrays, plus each candidate's position in the caller's batch.
#[derive(Default)]
struct Lanes {
    idx: Vec<usize>,
    r: Vec<u64>,
    c: Vec<u64>,
    ip_b: Vec<u64>,
    wt_b: Vec<u64>,
    op_b: Vec<u64>,
    bw: Vec<u64>,
    m: Vec<u64>,
    n: Vec<u64>,
    k: Vec<u64>,
}

impl Lanes {
    fn push(&mut self, i: usize, hw: &HwConfig, g: &Gemm) {
        self.idx.push(i);
        self.r.push(hw.r as u64);
        self.c.push(hw.c as u64);
        self.ip_b.push(hw.ip_b);
        self.wt_b.push(hw.wt_b);
        self.op_b.push(hw.op_b);
        self.bw.push(hw.bw as u64);
        self.m.push(g.m as u64);
        self.n.push(g.n as u64);
        self.k.push(g.k as u64);
    }
}

/// Simulate a batch of configurations on one GEMM. Bit-identical to
/// mapping the scalar [`super::simulate`] over `cfgs` — the win is
/// layout and branch hoisting, never semantics.
pub fn simulate_batch(cfgs: &[HwConfig], g: &Gemm) -> Vec<SimResult> {
    simulate_lanes(cfgs.len(), |i| &cfgs[i], |_| g)
}

/// Simulate per-candidate `(configuration, GEMM)` pairs — the LLM
/// shape×order probe loop and the structured per-segment evaluator batch
/// across workloads as well as configurations.
pub fn simulate_pairs(pairs: &[(HwConfig, Gemm)]) -> Vec<SimResult> {
    simulate_lanes(pairs.len(), |i| &pairs[i].0, |i| &pairs[i].1)
}

/// Gather the batch into per-loop-order SoA groups and run each group
/// through the hoisted-branch passes.
fn simulate_lanes<'a>(
    n: usize,
    hw: impl Fn(usize) -> &'a HwConfig,
    g: impl Fn(usize) -> &'a Gemm,
) -> Vec<SimResult> {
    let mut out = vec![SimResult::zero(); n];
    let mut groups: [Lanes; LoopOrder::ALL.len()] = Default::default();
    for i in 0..n {
        let h = hw(i);
        let gi = LoopOrder::ALL
            .iter()
            .position(|&o| o == h.loop_order)
            .expect("LoopOrder::ALL is total");
        groups[gi].push(i, h, g(i));
    }
    for (gi, lanes) in groups.iter().enumerate() {
        if !lanes.idx.is_empty() {
            simulate_group(LoopOrder::ALL[gi], lanes, &mut out);
        }
    }
    out
}

/// One operand's DRAM traffic across the group — the [`BreakerPos`]
/// dispatch hoisted out of the candidate loop (it is a group constant);
/// only the buffer-residency compares remain per candidate.
fn operand_lane(
    pos: BreakerPos,
    tile: &[Tiling],
    chunks: &[Tiling],
    cap: &[u64],
    trips: &[u64],
    out: &mut [u64],
) {
    match pos {
        BreakerPos::Inner => {
            // each granule visited once: the residency short circuit and
            // the miss case coincide at `total`
            for i in 0..out.len() {
                out[i] = tile[i].total() * chunks[i].total();
            }
        }
        BreakerPos::Outer => {
            for i in 0..out.len() {
                let total = tile[i].total() * chunks[i].total();
                out[i] = if total <= cap[i] { total } else { total * trips[i] };
            }
        }
        BreakerPos::Middle { k_outer: false } => {
            // slice = one tile row/col across all of K
            for i in 0..out.len() {
                let k_total = chunks[i].total();
                let total = tile[i].total() * k_total;
                out[i] = if total <= cap[i] {
                    total
                } else {
                    let (c, t) = (cap[i], trips[i]);
                    k_total * tile[i].sum_sized(|rows| if rows * k_total <= c { 1 } else { t })
                };
            }
        }
        BreakerPos::Middle { k_outer: true } => {
            // slice = one K-chunk across the whole non-shared extent
            for i in 0..out.len() {
                let extent = tile[i].total();
                let total = extent * chunks[i].total();
                out[i] = if total <= cap[i] {
                    total
                } else {
                    let (c, t) = (cap[i], trips[i]);
                    extent * chunks[i].sum_sized(|kd| if extent * kd <= c { 1 } else { t })
                };
            }
        }
    }
}

/// Output DRAM traffic `(writes, partial_reads)` for one slice-revisit
/// arm (the `add_slices` body of the scalar model).
fn slice_arm(slices: &Tiling, other: u64, cap: u64, tk: u64) -> (u64, u64) {
    let writes = other * slices.sum_sized(|s| if s * other <= cap { 1 } else { tk });
    let reads = other * slices.sum_sized(|s| if s * other <= cap { 0 } else { tk - 1 });
    (writes, reads)
}

/// Run one loop-order group through the SoA passes and scatter results
/// into `out` at each candidate's original batch position.
fn simulate_group(order: LoopOrder, lanes: &Lanes, out: &mut [SimResult]) {
    let nc = lanes.idx.len();
    let nest = order.nest();
    // ---- group constants: everything the loop order determines --------
    let k_innermost = nest[2] == 'k';
    let pos_a = breaker_pos(nest, 'm', 'n');
    let pos_b = breaker_pos(nest, 'n', 'm');
    let posn = |ch: char| nest.iter().position(|&x| x == ch).unwrap();
    let pk = posn('k');
    let m_inner = posn('m') > pk;
    let n_inner = posn('n') > pk;

    // ---- tilings -------------------------------------------------------
    let mut tm = Vec::with_capacity(nc);
    let mut tn = Vec::with_capacity(nc);
    let mut chunks = Vec::with_capacity(nc);
    for i in 0..nc {
        tm.push(Tiling::new(lanes.m[i], lanes.r[i]));
        tn.push(Tiling::new(lanes.n[i], lanes.c[i]));
        chunks.push(if k_innermost {
            Tiling::new(lanes.k[i], lanes.k[i])
        } else {
            let kc =
                k_chunk_parts(lanes.r[i], lanes.c[i], lanes.ip_b[i], lanes.wt_b[i], lanes.k[i]);
            Tiling::new(lanes.k[i], kc)
        });
    }

    // ---- compute cycles ------------------------------------------------
    let mut compute = vec![0u64; nc];
    for i in 0..nc {
        let fold_overhead = 2 * lanes.r[i] + lanes.c[i] - 2;
        compute[i] = tm[i].tiles * tn[i].tiles * (chunks[i].tiles * fold_overhead + lanes.k[i]);
    }

    // ---- operand DRAM traffic (breaker dispatch hoisted) ---------------
    let trips_a: Vec<u64> = tn.iter().map(|t| t.tiles).collect();
    let trips_b: Vec<u64> = tm.iter().map(|t| t.tiles).collect();
    let mut a_reads = vec![0u64; nc];
    let mut b_reads = vec![0u64; nc];
    operand_lane(pos_a, &tm, &chunks, &lanes.ip_b, &trips_a, &mut a_reads);
    operand_lane(pos_b, &tn, &chunks, &lanes.wt_b, &trips_b, &mut b_reads);

    // ---- output DRAM traffic ((m_inner, n_inner) dispatch hoisted) -----
    // the per-candidate `tk == 1` short circuit stays: K can fit one
    // chunk even when k is not the innermost loop
    let mut out_writes = vec![0u64; nc];
    let mut out_reads = vec![0u64; nc];
    if k_innermost {
        for i in 0..out_writes.len() {
            out_writes[i] = lanes.m[i] * lanes.n[i];
        }
    } else {
        match (m_inner, n_inner) {
            (true, true) => {
                for i in 0..out_writes.len() {
                    let mn = lanes.m[i] * lanes.n[i];
                    let tk = chunks[i].tiles;
                    if tk == 1 || mn <= lanes.op_b[i] {
                        out_writes[i] = mn;
                    } else {
                        out_writes[i] = mn * tk;
                        out_reads[i] = mn * (tk - 1);
                    }
                }
            }
            (true, false) => {
                for i in 0..out_writes.len() {
                    let tk = chunks[i].tiles;
                    if tk == 1 {
                        out_writes[i] = lanes.m[i] * lanes.n[i];
                    } else {
                        (out_writes[i], out_reads[i]) =
                            slice_arm(&tn[i], lanes.m[i], lanes.op_b[i], tk);
                    }
                }
            }
            (false, true) => {
                for i in 0..out_writes.len() {
                    let tk = chunks[i].tiles;
                    if tk == 1 {
                        out_writes[i] = lanes.m[i] * lanes.n[i];
                    } else {
                        (out_writes[i], out_reads[i]) =
                            slice_arm(&tm[i], lanes.n[i], lanes.op_b[i], tk);
                    }
                }
            }
            (false, false) => unreachable!("k not innermost implies m or n is inner to k"),
        }
    }

    // ---- SRAM accesses, runtime, scatter -------------------------------
    for i in 0..nc {
        let dram = DramTraffic {
            a_reads: a_reads[i],
            b_reads: b_reads[i],
            out_writes: out_writes[i],
            out_reads: out_reads[i],
        };
        let sram = SramAccess {
            ip_reads: tn[i].tiles * lanes.m[i] * lanes.k[i],
            wt_reads: tm[i].tiles * lanes.k[i] * lanes.n[i],
            op_writes: lanes.m[i] * lanes.n[i] + dram.out_reads,
            op_reads: dram.out_writes,
            fills: dram.a_reads + dram.b_reads,
        };
        let mem_cycles = dram.total().div_ceil(lanes.bw[i]);
        out[lanes.idx[i]] = SimResult {
            cycles: compute[i].max(mem_cycles),
            compute_cycles: compute[i],
            mem_cycles,
            dram,
            sram,
            macs_useful: lanes.m[i] * lanes.k[i] * lanes.n[i],
            pe_cycles: compute[i] * lanes.r[i] * lanes.c[i],
            tk: chunks[i].tiles,
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design_space::params::TrainingSpace;
    use crate::sim::simulate;

    #[test]
    fn batch_matches_scalar_mixed_orders() {
        // the exhaustive sweep lives in tests/sim_batch_props.rs; this
        // guards the module in isolation across all six order groups
        let g = Gemm::new(96, 768, 320);
        let mut cfgs: Vec<HwConfig> = Vec::new();
        for (i, lo) in LoopOrder::ALL.iter().cycle().take(48).enumerate() {
            let base = TrainingSpace::nth(i * 97 % TrainingSpace::len());
            cfgs.push(HwConfig { loop_order: *lo, ..base });
        }
        let batch = simulate_batch(&cfgs, &g);
        for (hw, b) in cfgs.iter().zip(&batch) {
            assert_eq!(*b, simulate(hw, &g), "{hw:?}");
        }
    }

    #[test]
    fn pairs_match_scalar_and_preserve_order() {
        let shapes = [Gemm::new(1, 4096, 12288), Gemm::new(128, 768, 2304), Gemm::new(5, 7, 3)];
        let pairs: Vec<(HwConfig, Gemm)> = LoopOrder::ALL
            .iter()
            .enumerate()
            .flat_map(|(i, &lo)| {
                let base = TrainingSpace::nth(i * 131 % TrainingSpace::len());
                shapes.iter().map(move |g| (HwConfig { loop_order: lo, ..base }, *g))
            })
            .collect();
        let batch = simulate_pairs(&pairs);
        assert_eq!(batch.len(), pairs.len());
        for ((hw, g), b) in pairs.iter().zip(&batch) {
            assert_eq!(*b, simulate(hw, g), "{hw:?} {g:?}");
        }
    }

    #[test]
    fn empty_batch_is_empty() {
        assert!(simulate_batch(&[], &Gemm::new(8, 8, 8)).is_empty());
        assert!(simulate_pairs(&[]).is_empty());
    }
}
