//! Literal tile-loop-nest simulator — the *oracle* for the closed-form model
//! in [`super::analytical`].
//!
//! It executes the actual three-deep tile loop nest and tracks operand-buffer
//! residency operationally:
//!
//! * **whole-tensor bypass** — if an operand fits its buffer entirely it is
//!   fetched once, period;
//! * **scope-keyed residency** — otherwise the buffer retains granules while
//!   the operand's own loop indices *outer to the reuse-breaker loop* are
//!   unchanged (the tiling scope a double-buffered controller pins);
//! * **overflow flush** — inserting past capacity drops everything but the
//!   incoming granule (streaming fallback, no LRU).
//!
//! The property suite asserts the DRAM traffic and compute cycles here are
//! *bit-identical* to the analytical formulas across random configurations,
//! shapes and all six loop orders. Output-partial traffic is shared by
//! construction (same formula; OS partial-sum behaviour is not a loop-nest
//! property), so the oracle's signal is operand reuse + compute.

use super::analytical::k_chunk;
use super::{DramTraffic, SimResult, SramAccess};
use crate::design_space::HwConfig;
use crate::workload::Gemm;
use std::collections::HashSet;

/// Residency state for one streamed operand.
struct Buffer {
    cap: u64,
    whole_fits: bool,
    resident: HashSet<(u64, u64)>,
    bytes: u64,
    scope: Option<u64>,
    traffic: u64,
}

impl Buffer {
    fn new(cap: u64, total: u64) -> Self {
        Buffer {
            cap,
            whole_fits: total <= cap,
            resident: HashSet::new(),
            bytes: 0,
            scope: None,
            traffic: 0,
        }
    }

    /// Visit granule `id` of `size` bytes under scope key `scope`.
    fn visit(&mut self, id: (u64, u64), size: u64, scope: u64) {
        if self.whole_fits {
            if self.resident.insert(id) {
                self.traffic += size;
            }
            return;
        }
        if self.scope != Some(scope) {
            self.resident.clear();
            self.bytes = 0;
            self.scope = Some(scope);
        }
        if self.resident.contains(&id) {
            return; // hit
        }
        self.traffic += size;
        self.resident.insert(id);
        self.bytes += size;
        if self.bytes > self.cap {
            self.resident.clear();
            self.bytes = 0;
            // a granule larger than the buffer itself is pure streaming —
            // nothing is retained
            if size <= self.cap {
                self.resident.insert(id);
                self.bytes = size;
            }
        }
    }
}

/// Scope key: pack the operand's own loop indices that are outer to the
/// breaker into one u64 (indices are < 2^20 in any realistic shape).
fn scope_key(indices: &[(bool, u64)]) -> u64 {
    let mut key = 0u64;
    for &(active, v) in indices {
        key = key.wrapping_mul(1 << 21).wrapping_add(if active { v + 1 } else { 0 });
    }
    key
}

/// Run the literal loop nest; returns the same [`SimResult`] schema as the
/// analytical model.
pub fn simulate(hw: &HwConfig, g: &Gemm) -> SimResult {
    let nest = hw.loop_order.nest();
    let tm = g.m.div_ceil(hw.r) as u64;
    let tn = g.n.div_ceil(hw.c) as u64;
    let k_innermost = nest[2] == 'k';
    let kc = if k_innermost { g.k as u64 } else { k_chunk(hw, g.k) };
    let tk = (g.k as u64).div_ceil(kc);

    let trip = |c: char| match c {
        'm' => tm,
        'n' => tn,
        'k' => tk,
        _ => unreachable!(),
    };
    let posn = |c: char| nest.iter().position(|&x| x == c).unwrap();

    let tile_m = |i: u64| (g.m as u64 - i * hw.r as u64).min(hw.r as u64);
    let tile_n = |j: u64| (g.n as u64 - j * hw.c as u64).min(hw.c as u64);
    let tile_k = |k: u64| (g.k as u64 - k * kc).min(kc);

    let mut a_buf = Buffer::new(hw.ip_b, g.a_elems());
    let mut b_buf = Buffer::new(hw.wt_b, g.b_elems());

    // is loop `c` outer to loop `u`?
    let outer_to = |c: char, u: char| posn(c) < posn(u);

    let fold_overhead = 2 * hw.r as u64 + hw.c as u64 - 2;
    let mut compute_cycles = 0u64;

    // literal nest execution
    let (l0, l1, l2) = (nest[0], nest[1], nest[2]);
    for x0 in 0..trip(l0) {
        for x1 in 0..trip(l1) {
            for x2 in 0..trip(l2) {
                let idx = |c: char| {
                    if c == l0 {
                        x0
                    } else if c == l1 {
                        x1
                    } else {
                        x2
                    }
                };
                let (i, j, k) = (idx('m'), idx('n'), idx('k'));
                // A granule (i, k): scope = own loops outer to breaker 'n'
                a_buf.visit(
                    (i, k),
                    tile_m(i) * tile_k(k),
                    scope_key(&[(outer_to('m', 'n'), i), (outer_to('k', 'n'), k)]),
                );
                // B granule (j, k): breaker 'm'
                b_buf.visit(
                    (j, k),
                    tile_n(j) * tile_k(k),
                    scope_key(&[(outer_to('n', 'm'), j), (outer_to('k', 'm'), k)]),
                );
                compute_cycles += fold_overhead + tile_k(k);
            }
        }
    }

    // output traffic: shared formula (see module docs)
    let reference = super::analytical::simulate(hw, g);
    let dram = DramTraffic {
        a_reads: a_buf.traffic,
        b_reads: b_buf.traffic,
        out_writes: reference.dram.out_writes,
        out_reads: reference.dram.out_reads,
    };
    let sram = SramAccess {
        ip_reads: tn * g.a_elems(),
        wt_reads: tm * g.b_elems(),
        op_writes: g.out_elems() + dram.out_reads,
        op_reads: dram.out_writes,
        fills: dram.a_reads + dram.b_reads,
    };
    let mem_cycles = dram.total().div_ceil(hw.bw as u64);
    SimResult {
        cycles: compute_cycles.max(mem_cycles),
        compute_cycles,
        mem_cycles,
        dram,
        sram,
        macs_useful: g.macs(),
        pe_cycles: compute_cycles * hw.macs(),
        tk,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design_space::LoopOrder;
    use crate::util::rng::Pcg32;

    fn random_hw(rng: &mut Pcg32, lo: LoopOrder) -> HwConfig {
        let dims = [4u32, 8, 16, 32];
        let bufs = [0.5f64, 1.0, 2.0, 4.0, 16.0, 64.0];
        HwConfig {
            r: *rng.choose(&dims),
            c: *rng.choose(&dims),
            ip_b: (*rng.choose(&bufs) * 1024.0) as u64,
            wt_b: (*rng.choose(&bufs) * 1024.0) as u64,
            op_b: (*rng.choose(&bufs) * 1024.0) as u64,
            bw: rng.int_range(2, 32) as u32,
            loop_order: lo,
        }
    }

    fn random_gemm(rng: &mut Pcg32) -> Gemm {
        Gemm::new(
            rng.int_range(1, 96) as u32,
            rng.int_range(1, 512) as u32,
            rng.int_range(1, 96) as u32,
        )
    }

    /// The core correctness property of the whole simulator: the closed-form
    /// model and the literal loop-nest oracle agree exactly, for every loop
    /// order, across random configurations and shapes.
    #[test]
    fn analytical_matches_trace_exactly() {
        let mut rng = Pcg32::seeded(2024);
        for lo in LoopOrder::ALL {
            for case in 0..150 {
                let hw = random_hw(&mut rng, lo);
                let g = random_gemm(&mut rng);
                let t = simulate(&hw, &g);
                let a = crate::sim::analytical::simulate(&hw, &g);
                assert_eq!(
                    t.dram, a.dram,
                    "traffic mismatch [{lo:?} case {case}] hw={hw} g={g}\n trace={t:?}\n analytical={a:?}"
                );
                assert_eq!(t.compute_cycles, a.compute_cycles, "[{lo:?} case {case}] {hw} {g}");
                assert_eq!(t.cycles, a.cycles, "[{lo:?} case {case}] {hw} {g}");
                assert_eq!(t.sram, a.sram, "[{lo:?} case {case}] {hw} {g}");
            }
        }
    }

    /// Tiny-buffer corner: buffers smaller than a single granule must still
    /// agree (streaming fallback).
    #[test]
    fn agrees_with_sub_granule_buffers() {
        let mut rng = Pcg32::seeded(5);
        for lo in LoopOrder::ALL {
            for _ in 0..40 {
                let mut hw = random_hw(&mut rng, lo);
                hw.ip_b = 256;
                hw.wt_b = 128;
                hw.op_b = 128;
                let g = random_gemm(&mut rng);
                let t = simulate(&hw, &g);
                let a = crate::sim::analytical::simulate(&hw, &g);
                assert_eq!(t.dram, a.dram, "{lo:?} {hw} {g}");
            }
        }
    }

    /// Exhaustive small grid: all orders x dims on a fixed small GEMM.
    #[test]
    fn agrees_on_small_grid() {
        for lo in LoopOrder::ALL {
            for r in [4u32, 8] {
                for c in [4u32, 8] {
                    for buf in [256u64, 1024, 8192] {
                        let hw = HwConfig {
                            r,
                            c,
                            ip_b: buf,
                            wt_b: buf,
                            op_b: buf,
                            bw: 8,
                            loop_order: lo,
                        };
                        let g = Gemm::new(20, 40, 24);
                        let t = simulate(&hw, &g);
                        let a = crate::sim::analytical::simulate(&hw, &g);
                        assert_eq!(t.dram, a.dram, "{lo:?} {hw}");
                        assert_eq!(t.cycles, a.cycles, "{lo:?} {hw}");
                    }
                }
            }
        }
    }

    #[test]
    fn traffic_lower_bound_is_compulsory() {
        // DRAM reads can never be below one full load of each operand
        let mut rng = Pcg32::seeded(6);
        for _ in 0..200 {
            let lo = *rng.choose(&LoopOrder::ALL);
            let hw = random_hw(&mut rng, lo);
            let g = random_gemm(&mut rng);
            let t = simulate(&hw, &g);
            assert!(t.dram.a_reads >= g.a_elems(), "{hw} {g}");
            assert!(t.dram.b_reads >= g.b_elems(), "{hw} {g}");
            assert!(t.dram.out_writes >= g.out_elems(), "{hw} {g}");
        }
    }
}
