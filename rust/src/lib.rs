//! # DiffAxE — diffusion-driven accelerator generation and DSE
//!
//! Rust coordinator + substrates for the DiffAxE reproduction (see
//! DESIGN.md). The generative models live in `python/compile/` and are
//! AOT-lowered to HLO artifacts the [`runtime`] module executes via PJRT;
//! everything else — the Scale-Sim-like simulator, energy models, design
//! space, baselines and the DSE service — is native rust.
//!
//! ## The unified DSE API
//!
//! All design-space exploration goes through [`dse::api`]: an
//! [`dse::Objective`] (workload + metric) and a [`dse::Budget`] are handed
//! to any [`dse::Optimizer`] — the diffusion engine itself
//! ([`models::DiffAxE`]) or any paper baseline (BO, GD, random search,
//! fixed architectures, GANDSE, AIRCHITECT) — and come back as a ranked
//! [`dse::SearchOutcome`]. A [`dse::Session`] owns the engine handle,
//! dispatches strategies by name ([`dse::OptimizerKind`]), and runs
//! candidate scoring on the memoized, pooled evaluation core
//! ([`dse::eval`]): a persistent worker pool plus a sharded
//! `(config, workload)` memo table, bit-identical to scalar evaluation:
//!
//! ```no_run
//! use diffaxe::dse::{Budget, Objective, OptimizerKind, Session};
//! use diffaxe::workload::Gemm;
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut session = Session::load(std::path::Path::new("artifacts"))?;
//! let objective = Objective::MinEdp { g: Gemm::new(128, 768, 2304) };
//! let outcome =
//!     session.search(OptimizerKind::DiffAxE, &objective, &Budget::evals(256), 42)?;
//! println!("best: {} edp={:.3e}", outcome.best().unwrap().hw, outcome.best().unwrap().edp);
//! # Ok(())
//! # }
//! ```
//!
//! Long-running searches are interruptible: every `Optimizer::search`
//! takes a [`dse::SearchCtx`] (cancellation flag, wall-clock deadline,
//! progress sink) polled between evaluation batches, and the outcome's
//! [`dse::StopReason`] records whether it completed or returned partial
//! results.
//!
//! Structured DSE (§V) rides the same trait: a [`dse::StructuredSpec`]
//! partitions a DNN/LLM workload into layer segments, each with its own
//! sub-configuration under a shared accelerator budget — an O(10^17)
//! joint space searched via `Objective::StructuredEdp` /
//! `Objective::StructuredPerf` (see [`dse::structured`]). Without AOT
//! artifacts, [`models::DiffAxE::mock`] provides a deterministic hermetic
//! engine so every engine-backed strategy still runs.
//!
//! The [`coordinator`] serves the same types over a versioned
//! newline-JSON TCP protocol (generic `search` + multi-search `batch`
//! requests, plus v3 job forms: `submit`/`status`/`cancel`/`jobs` and a
//! streaming `watch`; see [`coordinator::protocol`] and the job lifecycle
//! in [`coordinator`]).

pub mod baselines;
pub mod cli;
pub mod coordinator;
pub mod dataset;
pub mod design_space;
pub mod dse;
pub mod energy;
pub mod models;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;
