//! # DiffAxE — diffusion-driven accelerator generation and DSE
//!
//! Rust coordinator + substrates for the DiffAxE reproduction (see
//! DESIGN.md). The generative models live in `python/compile/` and are
//! AOT-lowered to HLO artifacts the [`runtime`] module executes via PJRT;
//! everything else — the Scale-Sim-like simulator, energy models, design
//! space, baselines and the DSE service — is native rust.

pub mod baselines;
pub mod cli;
pub mod coordinator;
pub mod dataset;
pub mod design_space;
pub mod dse;
pub mod energy;
pub mod models;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;
