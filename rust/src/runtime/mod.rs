//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them from
//! the rust request path (no python anywhere near here).
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`. The
//! compile step happens once per artifact at service start; execution is
//! the only per-request cost.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Shared PJRT client (CPU plugin).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO text artifact.
    pub fn load_hlo(&self, path: &Path) -> Result<HloExec> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(HloExec { exe, path: path.to_path_buf() })
    }
}

/// One compiled executable.
pub struct HloExec {
    exe: xla::PjRtLoadedExecutable,
    path: PathBuf,
}

impl HloExec {
    /// Execute with the given inputs; returns the flattened output tuple
    /// (artifacts are lowered with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.path.display()))?;
        let lit = out[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.path.display()))?;
        lit.to_tuple().context("decomposing output tuple")
    }

    pub fn name(&self) -> String {
        self.path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default()
    }
}

// ---- Literal construction helpers -----------------------------------------

/// f32 matrix literal of shape `[rows, cols]` from a flat row-major slice.
pub fn mat_f32(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    anyhow::ensure!(data.len() == rows * cols, "shape mismatch");
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

/// i32 vector literal.
pub fn vec_i32(data: &[i32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// u32 scalar literal (e.g. PRNG seeds).
pub fn scalar_u32(v: u32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract an f32 literal into a Vec.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
