//! CACTI-7-style analytical SRAM/DRAM energy model at 32 nm.
//!
//! CACTI's per-access energy grows roughly with the square root of capacity
//! (bitline/wordline lengths scale with array edge). We use
//! `e(pJ/byte) = a + b·√(kB)` with constants chosen so the full-design-space
//! power span matches the paper's Fig 10 (0.17–3.3 W) and Fig 1(b)'s
//! DRAM-dominant-at-low-compute-density behaviour.

/// Per-byte dynamic read/write energy of an SRAM of `size_b` bytes (pJ).
pub fn sram_pj_per_byte(size_b: u64) -> f64 {
    let kb = size_b as f64 / 1024.0;
    0.05 + 0.012 * kb.sqrt()
}

/// Per-byte DRAM access energy (pJ) — LPDDR4-class interface at 32 nm.
pub const DRAM_PJ_PER_BYTE: f64 = 20.0;

/// SRAM leakage power per kB (watts).
pub const SRAM_LEAK_W_PER_KB: f64 = 90e-6;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_in_size() {
        let mut prev = 0.0;
        for kb in [4u64, 64, 128, 256, 512, 1024] {
            let e = sram_pj_per_byte(kb * 1024);
            assert!(e > prev, "energy must grow with capacity");
            prev = e;
        }
    }

    #[test]
    fn sram_cheaper_than_dram() {
        // on-chip access must stay well below DRAM for the reuse story
        assert!(sram_pj_per_byte(1024 * 1024) < DRAM_PJ_PER_BYTE / 10.0);
    }

    #[test]
    fn sublinear_scaling() {
        let e4 = sram_pj_per_byte(4 * 1024);
        let e1024 = sram_pj_per_byte(1024 * 1024);
        // 256x capacity should cost ~16x the size-dependent term, not 256x
        assert!(e1024 / e4 < 16.0);
    }
}
