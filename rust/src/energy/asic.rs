//! 32 nm ASIC energy model (the paper's primary evaluation platform).
//!
//! Dynamic energy = useful MACs · e_mac + PE clocking + SRAM accesses +
//! DRAM traffic; static power scales with PE count and total SRAM. The MAC
//! constant is NeuroSim-class for an 8-bit MAC at 32 nm; clocking energy
//! charges *all* R·C PEs each active cycle, which is what penalizes
//! under-utilized R > M decode configurations (paper §VI).

use super::cacti::{sram_pj_per_byte, DRAM_PJ_PER_BYTE, SRAM_LEAK_W_PER_KB};
use super::{EnergyCoeffs, EnergyResult};
use crate::design_space::HwConfig;
use crate::sim::SimResult;

/// ASIC clock frequency (32 nm, conservative).
pub const FREQ_HZ: f64 = 1e9;

/// Energy per useful 8-bit MAC (pJ), NeuroSim-class at 32 nm.
pub const E_MAC_PJ: f64 = 0.25;

/// Clock/idle energy per PE-cycle (pJ).
pub const E_PE_CLK_PJ: f64 = 0.008;

/// Leakage per PE (W).
pub const PE_LEAK_W: f64 = 9e-6;

/// Baseline controller/IO static power (W).
pub const BASE_STATIC_W: f64 = 0.04;

/// Per-access coefficient vector of a configuration — a pure function of
/// the array shape and buffer sizes (the loop order never enters), so one
/// vector prices every loop-order variant of a candidate.
pub fn coeffs(hw: &HwConfig) -> EnergyCoeffs {
    EnergyCoeffs {
        mac_pj: E_MAC_PJ,
        pe_cycle_pj: E_PE_CLK_PJ,
        compute_units: 0,
        compute_cycle_pj: 0.0,
        ip_pj: sram_pj_per_byte(hw.ip_b),
        wt_pj: sram_pj_per_byte(hw.wt_b),
        op_pj: sram_pj_per_byte(hw.op_b),
        fill_pj: fill_pj_per_byte(hw),
        dram_pj: DRAM_PJ_PER_BYTE,
        static_w: BASE_STATIC_W
            + PE_LEAK_W * hw.macs() as f64
            + SRAM_LEAK_W_PER_KB * hw.total_buf_b() as f64 / 1024.0,
        freq_hz: FREQ_HZ,
    }
}

/// Evaluate dynamic + static energy for a simulated run.
pub fn evaluate(hw: &HwConfig, sim: &SimResult) -> EnergyResult {
    coeffs(hw).evaluate(sim)
}

/// DRAM→SRAM fill writes: charged at the destination buffer's write energy
/// (approximated by the average of the two operand buffers).
fn fill_pj_per_byte(hw: &HwConfig) -> f64 {
    0.5 * (sram_pj_per_byte(hw.ip_b) + sram_pj_per_byte(hw.wt_b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design_space::{LoopOrder, TrainingSpace};
    use crate::sim::simulate;
    use crate::workload::Gemm;

    #[test]
    fn power_span_matches_fig10() {
        // paper Fig 10: (M,K,N) = (128, 4096, 8192), power 0.17 - 3.3 W
        let g = Gemm::new(128, 4096, 8192);
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for (i, hw) in TrainingSpace::enumerate().enumerate() {
            if i % 17 != 0 {
                continue;
            }
            let e = evaluate(&hw, &simulate(&hw, &g));
            lo = lo.min(e.power_w);
            hi = hi.max(e.power_w);
        }
        assert!(lo > 0.02 && lo < 0.5, "min power {lo} W outside plausible band");
        assert!(hi > 1.0 && hi < 8.0, "max power {hi} W outside plausible band");
        assert!(hi / lo > 5.0, "span {lo}..{hi} too narrow vs Fig 10");
    }

    #[test]
    fn energy_positive_and_consistent() {
        let hw = HwConfig::new_kb(32, 32, 128.0, 128.0, 32.0, 16, LoopOrder::Mnk);
        let g = Gemm::new(256, 512, 1024);
        let sim = simulate(&hw, &g);
        let e = evaluate(&hw, &sim);
        assert!(e.e_dyn_uj > 0.0 && e.e_static_uj > 0.0);
        assert!((e.edp - e.total_uj() * sim.cycles as f64).abs() < 1e-6 * e.edp);
        assert!((e.power_w - e.total_uj() * 1e-6 / e.runtime_s).abs() < 1e-9);
    }

    #[test]
    fn dram_dominates_at_low_compute_density() {
        // paper Fig 1(b): small array + poor reuse => DRAM energy dominates
        let hw = HwConfig::new_kb(4, 4, 4.0, 4.0, 4.0, 16, LoopOrder::Nmk);
        let g = Gemm::new(512, 512, 2048);
        let sim = simulate(&hw, &g);
        let e_dram_uj = sim.dram.total() as f64 * DRAM_PJ_PER_BYTE * 1e-6;
        let e = evaluate(&hw, &sim);
        assert!(
            e_dram_uj > 0.5 * e.e_dyn_uj,
            "DRAM {e_dram_uj} µJ should dominate dyn {} µJ",
            e.e_dyn_uj
        );
        // large array with big buffers: compute-side energy dominates
        let hw2 = HwConfig::new_kb(128, 128, 1024.0, 1024.0, 1024.0, 32, LoopOrder::Mnk);
        let sim2 = simulate(&hw2, &g);
        let e2 = evaluate(&hw2, &sim2);
        let e_dram2 = sim2.dram.total() as f64 * DRAM_PJ_PER_BYTE * 1e-6;
        assert!(e_dram2 < 0.5 * e2.e_dyn_uj);
    }

    #[test]
    fn under_utilized_rows_cost_energy() {
        // decode-style M=1: R=128 burns clock energy on idle PEs
        let g = Gemm::new(1, 1024, 1024);
        let small = HwConfig::new_kb(4, 64, 64.0, 64.0, 64.0, 32, LoopOrder::Mnk);
        let big = HwConfig::new_kb(128, 64, 64.0, 64.0, 64.0, 32, LoopOrder::Mnk);
        let e_small = evaluate(&small, &simulate(&small, &g));
        let e_big = evaluate(&big, &simulate(&big, &g));
        assert!(e_big.total_uj() > e_small.total_uj());
        assert!(e_big.edp > e_small.edp, "paper: avoid R >> M in decode");
    }
}
