//! Energy / power models — the roles CACTI 7 [41] (SRAM + DRAM energy),
//! NeuroSim [42] (MAC energy) and the Vivado flow (FPGA resources + power)
//! play in the paper. Analytical stand-ins calibrated to the paper's own
//! published numbers: the 0.17–3.3 W ASIC power span of Fig 10 and, for the
//! FPGA, the *exact* resource-utilization rows of Table VIII.
//!
//! Both platform models are linear in the [`SimResult`] counters: an
//! evaluation is a dot product of per-access coefficients against the
//! simulated access tallies plus a static term over the runtime. The
//! coefficients depend only on the array shape and buffer sizes — never on
//! the loop order — so they can be computed once per candidate
//! configuration and reused across loop-order probes (see [`EnergyCoeffs`]
//! and the LLM fast path in [`crate::dse::llm`]). `asic::evaluate` and
//! `fpga::evaluate` are themselves implemented through their coefficient
//! vectors, which makes coefficient-based evaluation bit-identical to the
//! scalar path by construction.

pub mod asic;
pub mod cacti;
pub mod fpga;

use crate::sim::SimResult;

/// Loop-order-independent per-access energy coefficients of one hardware
/// configuration on one platform.
///
/// # Coefficient derivation
///
/// Dynamic energy (pJ) is the dot product of this vector against the
/// [`SimResult`] counters, in this fixed term order:
///
/// `macs_useful·mac_pj + pe_cycles·pe_cycle_pj +
///  (compute_cycles·compute_units)·compute_cycle_pj + sram.ip_reads·ip_pj +
///  sram.wt_reads·wt_pj + (sram.op_writes + sram.op_reads)·op_pj +
///  sram.fills·fill_pj + dram.total()·dram_pj`
///
/// The ASIC model clocks PEs (`pe_cycle_pj`, `compute_units = 0`); the
/// FPGA model toggles DSPs (`compute_units` = DSP count, `pe_cycle_pj =
/// 0`). `compute_units` stays an integer multiplier so the
/// `compute_cycles · units` product is computed in u64 exactly as the
/// pre-coefficient scalar model did — reassociating it into an f64
/// coefficient would drift the FPGA result by an ulp. The static term
/// `static_w` (leakage + device floor, watts) multiplies the runtime at
/// `freq_hz`. Every field is a pure function of the array dimensions and
/// buffer sizes, so one `EnergyCoeffs` serves every loop order of a
/// candidate — the basis of the LLM order-selection fast path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyCoeffs {
    /// pJ per useful MAC
    pub mac_pj: f64,
    /// pJ per PE-cycle clocked (ASIC clock tree; 0 on FPGA)
    pub pe_cycle_pj: f64,
    /// integer units toggled per compute cycle (FPGA DSP count; 0 on ASIC)
    pub compute_units: u64,
    /// pJ per unit-compute-cycle (FPGA DSP toggling; 0 on ASIC)
    pub compute_cycle_pj: f64,
    /// pJ per input-buffer byte read
    pub ip_pj: f64,
    /// pJ per weight-buffer byte read
    pub wt_pj: f64,
    /// pJ per output-buffer byte accessed (reads + writes)
    pub op_pj: f64,
    /// pJ per DRAM→SRAM fill byte
    pub fill_pj: f64,
    /// pJ per DRAM byte
    pub dram_pj: f64,
    /// static (leakage + floor) power, watts
    pub static_w: f64,
    /// platform clock the runtime is priced at
    pub freq_hz: f64,
}

impl EnergyCoeffs {
    /// Price a simulated run. Bit-identical to the platform's `evaluate`
    /// for the configuration these coefficients were derived from (both
    /// run this exact arithmetic).
    pub fn evaluate(&self, sim: &SimResult) -> EnergyResult {
        let e_dyn_pj = sim.macs_useful as f64 * self.mac_pj
            + sim.pe_cycles as f64 * self.pe_cycle_pj
            + (sim.compute_cycles * self.compute_units) as f64 * self.compute_cycle_pj
            + sim.sram.ip_reads as f64 * self.ip_pj
            + sim.sram.wt_reads as f64 * self.wt_pj
            + (sim.sram.op_writes + sim.sram.op_reads) as f64 * self.op_pj
            + sim.sram.fills as f64 * self.fill_pj
            + sim.dram.total() as f64 * self.dram_pj;
        let runtime_s = sim.cycles as f64 / self.freq_hz;
        EnergyResult::from_parts(e_dyn_pj * 1e-6, self.static_w * runtime_s * 1e6, sim, self.freq_hz)
    }

    /// EDP (µJ·cycles) of a simulated run — the LLM order-selection metric.
    pub fn edp(&self, sim: &SimResult) -> f64 {
        self.evaluate(sim).edp
    }
}

/// Energy evaluation of one simulated run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyResult {
    /// dynamic energy, microjoules
    pub e_dyn_uj: f64,
    /// leakage/static energy over the runtime, microjoules
    pub e_static_uj: f64,
    /// average power, watts
    pub power_w: f64,
    /// energy–delay product in the paper's units: µJ · cycles
    pub edp: f64,
    /// runtime in seconds at the platform clock
    pub runtime_s: f64,
}

impl EnergyResult {
    pub fn total_uj(&self) -> f64 {
        self.e_dyn_uj + self.e_static_uj
    }

    pub(crate) fn from_parts(e_dyn_uj: f64, e_static_uj: f64, sim: &SimResult, freq_hz: f64) -> Self {
        let runtime_s = sim.cycles as f64 / freq_hz;
        let total = e_dyn_uj + e_static_uj;
        EnergyResult {
            e_dyn_uj,
            e_static_uj,
            power_w: total * 1e-6 / runtime_s,
            edp: total * sim.cycles as f64,
            runtime_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design_space::{HwConfig, LoopOrder};
    use crate::sim::simulate;
    use crate::workload::Gemm;

    fn bit_eq(a: &EnergyResult, b: &EnergyResult) {
        assert_eq!(a.e_dyn_uj.to_bits(), b.e_dyn_uj.to_bits());
        assert_eq!(a.e_static_uj.to_bits(), b.e_static_uj.to_bits());
        assert_eq!(a.power_w.to_bits(), b.power_w.to_bits());
        assert_eq!(a.edp.to_bits(), b.edp.to_bits());
        assert_eq!(a.runtime_s.to_bits(), b.runtime_s.to_bits());
    }

    #[test]
    fn coeffs_evaluate_bit_identical_to_platform_evaluate() {
        let g = Gemm::new(128, 768, 2304);
        for order in LoopOrder::OS_ORDERS {
            let hw = HwConfig::new_kb(32, 48, 128.0, 64.0, 32.0, 16, order);
            let sim = simulate(&hw, &g);
            bit_eq(&asic::coeffs(&hw).evaluate(&sim), &asic::evaluate(&hw, &sim));
            bit_eq(&fpga::coeffs(&hw).evaluate(&sim), &fpga::evaluate(&hw, &sim));
        }
    }

    #[test]
    fn coeffs_ignore_loop_order() {
        let a = HwConfig::new_kb(64, 64, 256.0, 256.0, 64.0, 8, LoopOrder::Mnk);
        let b = HwConfig { loop_order: LoopOrder::Nmk, ..a };
        assert_eq!(asic::coeffs(&a), asic::coeffs(&b));
        assert_eq!(fpga::coeffs(&a), fpga::coeffs(&b));
    }
}
