//! Energy / power models — the roles CACTI 7 [41] (SRAM + DRAM energy),
//! NeuroSim [42] (MAC energy) and the Vivado flow (FPGA resources + power)
//! play in the paper. Analytical stand-ins calibrated to the paper's own
//! published numbers: the 0.17–3.3 W ASIC power span of Fig 10 and, for the
//! FPGA, the *exact* resource-utilization rows of Table VIII.

pub mod asic;
pub mod cacti;
pub mod fpga;

use crate::sim::SimResult;

/// Energy evaluation of one simulated run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyResult {
    /// dynamic energy, microjoules
    pub e_dyn_uj: f64,
    /// leakage/static energy over the runtime, microjoules
    pub e_static_uj: f64,
    /// average power, watts
    pub power_w: f64,
    /// energy–delay product in the paper's units: µJ · cycles
    pub edp: f64,
    /// runtime in seconds at the platform clock
    pub runtime_s: f64,
}

impl EnergyResult {
    pub fn total_uj(&self) -> f64 {
        self.e_dyn_uj + self.e_static_uj
    }

    pub(crate) fn from_parts(e_dyn_uj: f64, e_static_uj: f64, sim: &SimResult, freq_hz: f64) -> Self {
        let runtime_s = sim.cycles as f64 / freq_hz;
        let total = e_dyn_uj + e_static_uj;
        EnergyResult {
            e_dyn_uj,
            e_static_uj,
            power_w: total * 1e-6 / runtime_s,
            edp: total * sim.cycles as f64,
            runtime_s,
        }
    }
}
