//! Xilinx Virtex UltraScale+ VU13P FPGA model (paper §VI, Figs 23/24,
//! Table VIII).
//!
//! The resource mapping is reverse-engineered to reproduce **exactly** the
//! five rows of the paper's Table VIII:
//!
//! * `DSP = ⌈R·C / 2⌉` — two 8-bit MACs per DSP48E2 slice,
//! * buffers ≥ 64 kB map to UltraRAM at `⌈kB / 36⌉` blocks (one URAM block
//!   = 288 kbit = 36 kB); smaller buffers map to BRAM at `⌈kB / 4.5⌉`
//!   (36 kbit blocks) plus 8 control BRAMs,
//! * `FF ≈ 1.53 · LUT` (the ratio every Table VIII row exhibits),
//! * `LUT = 22·MACs + overhead` (22 LUT/MAC matches the DOSA row exactly).
//!
//! Power = static (per-resource leakage on 16 nm FinFET) + dynamic
//! (toggling DSPs + RAM accesses + DRAM interface) at a 300 MHz fabric
//! clock. Only relative power/EDP ordering matters for Figs 23/24.

use super::cacti::DRAM_PJ_PER_BYTE;
use super::{EnergyCoeffs, EnergyResult};
use crate::design_space::HwConfig;
use crate::sim::SimResult;

/// Fabric clock for all implemented designs.
pub const FREQ_HZ: f64 = 300e6;

/// FPGA resource utilization (Table VIII schema).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Resources {
    pub dsp: u64,
    pub lut: u64,
    pub ff: u64,
    pub bram: u64,
    pub uram: u64,
}

/// VU13P capacity limits (DS890): 12,288 DSP slices, 3.78 M logic cells,
/// 2,688 BRAM36 + 1,280 URAM blocks.
pub const VU13P_DSP: u64 = 12_288;
pub const VU13P_LUT: u64 = 1_728_000;
pub const VU13P_BRAM: u64 = 2_688;
pub const VU13P_URAM: u64 = 1_280;

/// Buffers strictly larger than this map to UltraRAM (the paper's NVDLA row
/// keeps its 64 kB input buffer in BRAM while Eyeriss' 108 kB buffers are
/// URAM, so the boundary sits between the two).
const URAM_THRESHOLD_B: u64 = 64 * 1024;
/// One URAM block stores 288 kbit = 36 kB.
const URAM_BLOCK_B: f64 = 36.0 * 1024.0;
/// One BRAM36 block stores 36 kbit = 4.5 kB.
const BRAM_BLOCK_B: f64 = 4.5 * 1024.0;
/// Fixed control-logic BRAMs (FSMs, FIFOs) present in every design.
const CONTROL_BRAM: u64 = 8;

/// Map one buffer to (bram, uram) blocks.
fn map_buffer(size_b: u64) -> (u64, u64) {
    if size_b > URAM_THRESHOLD_B {
        (0, (size_b as f64 / URAM_BLOCK_B).ceil() as u64)
    } else {
        ((size_b as f64 / BRAM_BLOCK_B).ceil() as u64, 0)
    }
}

/// Resource utilization of a configuration (reproduces Table VIII).
pub fn resources(hw: &HwConfig) -> Resources {
    let macs = hw.macs();
    let dsp = macs.div_ceil(2);
    let (b_ip, u_ip) = map_buffer(hw.ip_b);
    let (b_wt, u_wt) = map_buffer(hw.wt_b);
    let (b_op, u_op) = map_buffer(hw.op_b);
    // 22 LUT/MAC + 42k fixed control/interconnect overhead: reproduces the
    // Eyeriss, ShiDianNao and NVDLA LUT counts of Table VIII exactly and
    // the DOSA/DiffAxE rows within ~12% (the paper's own rows are not
    // perfectly linear in MACs).
    let lut = 22 * macs + 42_000;
    let ff = (1.53 * lut as f64).round() as u64;
    Resources {
        dsp,
        lut,
        ff,
        bram: b_ip + b_wt + b_op + CONTROL_BRAM,
        uram: u_ip + u_wt + u_op,
    }
}

/// Does the design fit on the VU13P at all?
pub fn fits(hw: &HwConfig) -> bool {
    let r = resources(hw);
    r.dsp <= VU13P_DSP && r.lut <= VU13P_LUT && r.bram <= VU13P_BRAM && r.uram <= VU13P_URAM
}

// ---- power model (16 nm FinFET fabric) -----------------------------------

/// static leakage per occupied resource (W)
const DSP_LEAK_W: f64 = 18e-6;
const LUT_LEAK_W: f64 = 0.12e-6;
const BRAM_LEAK_W: f64 = 0.25e-3;
const URAM_LEAK_W: f64 = 0.5e-3;
const BASE_STATIC_W: f64 = 0.9; // device static floor (DS890 power data)

/// dynamic energy constants
const DSP_MAC_PJ: f64 = 3.5; // per useful MAC through a DSP
const DSP_CLK_PJ: f64 = 0.15; // per DSP-cycle toggling overhead
const BRAM_PJ_PER_BYTE: f64 = 1.2;
const URAM_PJ_PER_BYTE: f64 = 0.9;

/// Per-byte access energy of a buffer given its mapping.
fn buf_pj_per_byte(size_b: u64) -> f64 {
    if size_b > URAM_THRESHOLD_B {
        URAM_PJ_PER_BYTE
    } else {
        BRAM_PJ_PER_BYTE
    }
}

/// Per-access coefficient vector of a configuration — a pure function of
/// the resource mapping (array shape + buffer sizes; the loop order never
/// enters). The DSP count enters as the integer `compute_units` multiplier
/// so the `compute_cycles · DSP` product is taken in u64 exactly as the
/// original scalar model did (bit-identical energy).
pub fn coeffs(hw: &HwConfig) -> EnergyCoeffs {
    let res = resources(hw);
    EnergyCoeffs {
        mac_pj: DSP_MAC_PJ,
        pe_cycle_pj: 0.0,
        compute_units: res.dsp,
        compute_cycle_pj: DSP_CLK_PJ,
        ip_pj: buf_pj_per_byte(hw.ip_b),
        wt_pj: buf_pj_per_byte(hw.wt_b),
        op_pj: buf_pj_per_byte(hw.op_b),
        fill_pj: 1.0,
        dram_pj: DRAM_PJ_PER_BYTE,
        static_w: BASE_STATIC_W
            + DSP_LEAK_W * res.dsp as f64
            + LUT_LEAK_W * res.lut as f64
            + BRAM_LEAK_W * res.bram as f64
            + URAM_LEAK_W * res.uram as f64,
        freq_hz: FREQ_HZ,
    }
}

/// Evaluate FPGA energy/power for a simulated run.
pub fn evaluate(hw: &HwConfig, sim: &SimResult) -> EnergyResult {
    coeffs(hw).evaluate(sim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design_space::LoopOrder;

    /// Reproduce every row of paper Table VIII exactly (DSP, BRAM, URAM).
    #[test]
    fn table8_resource_rows() {
        // (name, R, C, ip, wt, op kB, expected dsp, bram, uram)
        let rows: &[(&str, u32, u32, f64, f64, f64, u64, u64, u64)] = &[
            ("Eyeriss", 12, 14, 108.0, 108.0, 8.0, 84, 10, 6),
            ("ShiDianNao", 16, 16, 32.0, 32.0, 8.0, 128, 26, 0),
            ("NVDLA", 32, 32, 64.0, 512.0, 32.0, 512, 31, 15),
            ("DOSA", 128, 128, 128.0, 128.0, 64.0, 8192, 23, 8),
            ("DiffAxE", 128, 63, 1024.0, 4.0, 8.5, 4032, 11, 29),
        ];
        for &(name, r, c, ip, wt, op, dsp, bram, uram) in rows {
            let hw = HwConfig::new_kb(r, c, ip, wt, op, 32, LoopOrder::Mnk);
            let res = resources(&hw);
            assert_eq!(res.dsp, dsp, "{name} DSP");
            assert_eq!(res.bram, bram, "{name} BRAM");
            assert_eq!(res.uram, uram, "{name} URAM");
        }
    }

    /// LUT count matches DOSA's published 360,448 within the overhead term,
    /// and the FF/LUT ratio matches all Table VIII rows.
    #[test]
    fn table8_lut_ff_shape() {
        // exact for the three fixed architectures…
        for (r, c, lut) in [(12u32, 14u32, 45_696u64), (16, 16, 47_632), (32, 32, 64_528)] {
            let hw = HwConfig::new_kb(r, c, 32.0, 32.0, 8.0, 16, LoopOrder::Mnk);
            assert_eq!(resources(&hw).lut, lut, "{r}x{c}");
        }
        // …and within ~15% for the searched designs (paper rows are not
        // perfectly linear in MACs)
        let dosa = HwConfig::new_kb(128, 128, 128.0, 128.0, 64.0, 32, LoopOrder::Mnk);
        let res = resources(&dosa);
        let err = (res.lut as f64 - 360_448.0).abs() / 360_448.0;
        assert!(err < 0.15, "DOSA LUT {} vs paper 360448", res.lut);
        let ratio = res.ff as f64 / res.lut as f64;
        assert!((ratio - 1.53).abs() < 0.01);
    }

    #[test]
    fn everything_in_target_space_fits_vu13p() {
        use crate::design_space::TargetSpace;
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(8);
        for _ in 0..500 {
            let hw = TargetSpace::sample(&mut rng);
            assert!(fits(&hw), "{hw} exceeds VU13P");
        }
    }

    #[test]
    fn power_plausible_for_bert_prefill_designs() {
        use crate::sim::simulate;
        use crate::workload::Gemm;
        let g = Gemm::new(128, 768, 2304);
        for (r, c) in [(12u32, 14u32), (128, 128)] {
            let hw = HwConfig::new_kb(r, c, 108.0, 108.0, 8.0, 16, LoopOrder::Mnk);
            let e = evaluate(&hw, &simulate(&hw, &g));
            assert!(e.power_w > 0.5 && e.power_w < 60.0, "{r}x{c}: {} W", e.power_w);
        }
    }

    #[test]
    fn uram_threshold_boundary() {
        // 64 kB sits in BRAM (NVDLA's input buffer in Table VIII)
        assert_eq!(map_buffer(64 * 1024), (15, 0));
        // just above goes to URAM
        assert_eq!(map_buffer(64 * 1024 + 128), (0, 2));
        assert_eq!(map_buffer(1024 * 1024).1, 29); // paper DiffAxE row
        assert_eq!(map_buffer(108 * 1024).1, 3); // Eyeriss row
    }
}
