//! GEMM workload type: `(M, K) x (K, N)` matrix multiply, the computation
//! that dominates LLM/ViT inference (paper §I).

/// A single GEMM workload `w = (M, K, N)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Gemm {
    pub m: u32,
    pub k: u32,
    pub n: u32,
}

/// Paper §IV-A workload ranges.
pub const M_MAX: u32 = 1024;
pub const K_MAX: u32 = 4096;
pub const N_MAX: u32 = 30_000;

impl Gemm {
    pub fn new(m: u32, k: u32, n: u32) -> Self {
        assert!(m >= 1 && k >= 1 && n >= 1, "GEMM dims must be positive");
        Gemm { m, k, n }
    }

    /// Total multiply-accumulates.
    pub fn macs(&self) -> u64 {
        self.m as u64 * self.k as u64 * self.n as u64
    }

    /// Operand footprints in elements.
    pub fn a_elems(&self) -> u64 {
        self.m as u64 * self.k as u64
    }
    pub fn b_elems(&self) -> u64 {
        self.k as u64 * self.n as u64
    }
    pub fn out_elems(&self) -> u64 {
        self.m as u64 * self.n as u64
    }

    /// Normalized workload vector for model conditioning: (M, K, N) min–max
    /// normalized over the §IV-A ranges (mirrored in python/compile/norm.py).
    pub fn norm_vec(&self) -> [f32; 3] {
        [
            (self.m - 1) as f32 / (M_MAX - 1) as f32,
            (self.k - 1) as f32 / (K_MAX - 1) as f32,
            (self.n - 1) as f32 / (N_MAX - 1) as f32,
        ]
    }
}

impl std::fmt::Display for Gemm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "({},{},{})", self.m, self.k, self.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macs_and_footprints() {
        let g = Gemm::new(2, 3, 4);
        assert_eq!(g.macs(), 24);
        assert_eq!(g.a_elems(), 6);
        assert_eq!(g.b_elems(), 12);
        assert_eq!(g.out_elems(), 8);
    }

    #[test]
    fn norm_vec_bounds() {
        let lo = Gemm::new(1, 1, 1).norm_vec();
        assert_eq!(lo, [0.0, 0.0, 0.0]);
        let hi = Gemm::new(M_MAX, K_MAX, N_MAX).norm_vec();
        assert_eq!(hi, [1.0, 1.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn rejects_zero_dims() {
        Gemm::new(0, 1, 1);
    }
}
