//! AI workloads. A workload is a GEMM `(M,K) x (K,N)` (paper §I), or — for
//! the §VI LLM extension — a *sequence* of GEMMs, one per DNN layer.

pub mod gemm;
pub mod llm;
pub mod suite;

pub use gemm::Gemm;
pub use llm::{model_workload, LlmModel, ModelWorkload, Stage};
pub use suite::WorkloadSuite;
