//! The evaluation workload suite (paper §IV-A: 600 distinct GEMM workloads
//! with M: 1–1024, K: 1–4096, N: 1–30000, Fig 12).
//!
//! The suite mixes (a) the GEMM layers of real transformer models at several
//! sequence lengths — the cluster structure visible in Fig 12 — and (b)
//! log-uniform random shapes filling the remaining volume. Generation is
//! deterministic in (seed, size) so every experiment sees the same suite.

use super::gemm::{Gemm, K_MAX, M_MAX, N_MAX};
use super::llm::{LlmModel, Stage};
use crate::util::rng::Pcg32;

/// A reproducible set of GEMM workloads.
#[derive(Debug, Clone)]
pub struct WorkloadSuite {
    pub workloads: Vec<Gemm>,
}

impl WorkloadSuite {
    /// Paper-scale suite size.
    pub const PAPER_SIZE: usize = 600;

    /// Build a suite of `size` workloads, deterministic in `seed`.
    pub fn generate(size: usize, seed: u64) -> Self {
        // lint:allow(rng-construct) stream 600 pins the published workload suite
        let mut rng = Pcg32::new(seed, 600);
        let mut set = std::collections::HashSet::new();
        let mut out = Vec::with_capacity(size);

        // (a) model-derived shapes first: LLM/ViT layers at several seq lens,
        // clamped into the §IV-A ranges.
        'outer: for model in LlmModel::ALL {
            for stage in Stage::ALL {
                for seq in [32, 128, 512] {
                    for g in model.layer_gemms(stage, seq) {
                        let g = Gemm::new(
                            g.m.min(M_MAX),
                            g.k.min(K_MAX),
                            g.n.min(N_MAX),
                        );
                        if out.len() >= size {
                            break 'outer;
                        }
                        if set.insert(g) {
                            out.push(g);
                        }
                    }
                }
            }
        }

        // (b) fill with log-uniform random shapes.
        while out.len() < size {
            let g = Gemm::new(
                log_uniform(&mut rng, 1, M_MAX),
                log_uniform(&mut rng, 1, K_MAX),
                log_uniform(&mut rng, 1, N_MAX),
            );
            if set.insert(g) {
                out.push(g);
            }
        }
        WorkloadSuite { workloads: out }
    }

    pub fn len(&self) -> usize {
        self.workloads.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workloads.is_empty()
    }
}

/// Integer sampled log-uniformly in `[lo, hi]`.
fn log_uniform(rng: &mut Pcg32, lo: u32, hi: u32) -> u32 {
    let (llo, lhi) = ((lo as f64).ln(), (hi as f64).ln());
    let v = rng.range_f64(llo, lhi).exp().round() as u32;
    v.clamp(lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_distinct() {
        let a = WorkloadSuite::generate(100, 7);
        let b = WorkloadSuite::generate(100, 7);
        assert_eq!(a.workloads, b.workloads);
        let set: std::collections::HashSet<_> = a.workloads.iter().collect();
        assert_eq!(set.len(), 100, "workloads must be distinct (paper: 600 distinct)");
    }

    #[test]
    fn different_seed_differs() {
        let a = WorkloadSuite::generate(100, 7);
        let b = WorkloadSuite::generate(100, 8);
        assert_ne!(a.workloads, b.workloads);
    }

    #[test]
    fn shapes_within_paper_ranges() {
        let s = WorkloadSuite::generate(WorkloadSuite::PAPER_SIZE, 1);
        assert_eq!(s.len(), 600);
        for g in &s.workloads {
            assert!(g.m >= 1 && g.m <= M_MAX, "{g}");
            assert!(g.k >= 1 && g.k <= K_MAX, "{g}");
            assert!(g.n >= 1 && g.n <= N_MAX, "{g}");
        }
    }

    #[test]
    fn includes_model_layers() {
        let s = WorkloadSuite::generate(200, 1);
        // BERT QKV prefill @128 must be present
        assert!(s.workloads.contains(&Gemm::new(128, 768, 2304)));
    }

    #[test]
    fn log_uniform_spans_range() {
        let mut rng = Pcg32::seeded(3);
        let vs: Vec<u32> = (0..5000).map(|_| log_uniform(&mut rng, 1, 30_000)).collect();
        assert!(vs.iter().any(|&v| v < 10));
        assert!(vs.iter().any(|&v| v > 10_000));
        assert!(vs.iter().all(|&v| (1..=30_000).contains(&v)));
    }
}
