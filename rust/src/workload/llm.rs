//! LLM workload extraction (paper §VI): a transformer layer is a sequence of
//! GEMMs whose shapes depend on the inference stage — *prefill* processes the
//! whole prompt (M = sequence length), *decode* generates one token
//! auto-regressively (M = 1, attended KV length = context).
//!
//! The paper evaluates BERT-base, OPT-350M and LLaMA-2-7B with a default
//! prefill sequence length of 128 tokens (Fig 22).

use super::gemm::Gemm;
use crate::util::sync::{rank, TrackedMutex};
use std::collections::HashMap;
use std::sync::{Arc, OnceLock};

/// Inference stage of an LLM forward pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stage {
    /// prompt processing; M = sequence length
    Prefill,
    /// auto-regressive generation; M = 1, attention spans the KV cache
    Decode,
}

impl Stage {
    pub const ALL: [Stage; 2] = [Stage::Prefill, Stage::Decode];

    pub fn name(&self) -> &'static str {
        match self {
            Stage::Prefill => "prefill",
            Stage::Decode => "decode",
        }
    }

    /// Parse a wire name (inverse of [`Stage::name`]).
    pub fn from_name(s: &str) -> Option<Stage> {
        Stage::ALL.iter().copied().find(|st| st.name() == s)
    }
}

/// Transformer architecture description (decoder-only or encoder).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LlmModel {
    BertBase,
    Opt350m,
    Llama2_7b,
}

impl LlmModel {
    pub const ALL: [LlmModel; 3] = [LlmModel::BertBase, LlmModel::Opt350m, LlmModel::Llama2_7b];

    pub fn name(&self) -> &'static str {
        match self {
            LlmModel::BertBase => "BERT-base",
            LlmModel::Opt350m => "OPT-350M",
            LlmModel::Llama2_7b => "LLaMA-2-7B",
        }
    }

    /// Stable lowercase wire name.
    pub fn wire_name(&self) -> &'static str {
        match self {
            LlmModel::BertBase => "bert-base",
            LlmModel::Opt350m => "opt-350m",
            LlmModel::Llama2_7b => "llama-2-7b",
        }
    }

    /// Parse a wire name (inverse of [`LlmModel::wire_name`]).
    pub fn from_name(s: &str) -> Option<LlmModel> {
        LlmModel::ALL.iter().copied().find(|m| m.wire_name() == s)
    }

    /// (hidden, ffn-intermediate, head_dim, gated-mlp?)
    fn dims(&self) -> (u32, u32, u32, bool) {
        match self {
            LlmModel::BertBase => (768, 3072, 64, false),
            LlmModel::Opt350m => (1024, 4096, 64, false),
            // LLaMA-2-7B: SwiGLU MLP with intermediate 11008
            LlmModel::Llama2_7b => (4096, 11008, 128, true),
        }
    }

    /// Number of transformer blocks (used only for whole-model energy
    /// scaling; the per-layer GEMM sequence repeats identically).
    pub fn n_blocks(&self) -> u32 {
        match self {
            LlmModel::BertBase => 12,
            LlmModel::Opt350m => 24,
            LlmModel::Llama2_7b => 32,
        }
    }

    /// The GEMM sequence of one transformer block at the given stage.
    ///
    /// `seq` is the prompt length for prefill / the KV-cache length for
    /// decode. Attention score/context GEMMs are expressed per-head with the
    /// head count folded into M (heads are data-parallel rows); projection
    /// GEMMs use the full hidden width. BERT-base yields the 6-GEMM sequence
    /// whose per-layer loop orders appear in paper Table VII.
    pub fn layer_gemms(&self, stage: Stage, seq: u32) -> Vec<Gemm> {
        let (h, ffn, dh, gated) = self.dims();
        let heads = h / dh;
        let m = match stage {
            Stage::Prefill => seq,
            Stage::Decode => 1,
        };
        let kv = seq; // attended length
        let mut gs = vec![
            // fused QKV projection: (m, h) x (h, 3h)
            Gemm::new(m, h, 3 * h),
            // attention scores per head, heads folded into rows:
            // (m*heads, dh) x (dh, kv)
            Gemm::new(m * heads, dh, kv),
            // attention context: (m*heads, kv) x (kv, dh)
            Gemm::new(m * heads, kv, dh),
            // output projection: (m, h) x (h, h)
            Gemm::new(m, h, h),
        ];
        if gated {
            // SwiGLU: gate+up fused, then down
            gs.push(Gemm::new(m, h, 2 * ffn));
            gs.push(Gemm::new(m, ffn, h));
        } else {
            gs.push(Gemm::new(m, h, ffn));
            gs.push(Gemm::new(m, ffn, h));
        }
        gs
    }
}

/// Default evaluation sequence length (paper Fig 22: "Prefill represents a
/// default sequence length of 128 tokens").
pub const DEFAULT_SEQ: u32 = 128;

/// Precomputed GEMM structure of one `(model, stage, seq)` workload: the
/// per-layer sequence, the deduplicated shape set, and the layer→shape
/// mapping. Candidate scoring evaluates thousands of configurations against
/// the *same* workload, so [`model_workload`] shares one immutable instance
/// instead of re-allocating the layer list per candidate, and the shape
/// dedup lets the evaluator simulate each distinct `(shape, loop order)`
/// pair exactly once.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelWorkload {
    pub model: LlmModel,
    pub stage: Stage,
    pub seq: u32,
    /// per-layer GEMMs of one transformer block, in layer order
    pub gemms: Vec<Gemm>,
    /// distinct shapes, in first-occurrence order
    pub unique: Vec<Gemm>,
    /// layer index → index into `unique`
    pub layer_to_unique: Vec<usize>,
    /// whole-model block count ([`LlmModel::n_blocks`])
    pub blocks: u64,
}

impl ModelWorkload {
    pub fn new(model: LlmModel, stage: Stage, seq: u32) -> ModelWorkload {
        let gemms = model.layer_gemms(stage, seq);
        let mut unique: Vec<Gemm> = Vec::with_capacity(gemms.len());
        let mut layer_to_unique = Vec::with_capacity(gemms.len());
        for g in &gemms {
            let idx = match unique.iter().position(|u| u == g) {
                Some(i) => i,
                None => {
                    unique.push(*g);
                    unique.len() - 1
                }
            };
            layer_to_unique.push(idx);
        }
        let blocks = model.n_blocks() as u64;
        ModelWorkload { model, stage, seq, gemms, unique, layer_to_unique, blocks }
    }

    pub fn n_layers(&self) -> usize {
        self.gemms.len()
    }
}

/// Process-wide memo of [`ModelWorkload`]s. The key space is tiny (3 models
/// × 2 stages × a handful of sequence lengths), so entries live for the
/// process lifetime.
pub fn model_workload(model: LlmModel, stage: Stage, seq: u32) -> Arc<ModelWorkload> {
    type Memo = TrackedMutex<HashMap<(LlmModel, Stage, u32), Arc<ModelWorkload>>>;
    static MEMO: OnceLock<Memo> = OnceLock::new();
    let memo = MEMO.get_or_init(|| {
        TrackedMutex::new("llm.workload-memo", rank::WORKLOAD_MEMO, HashMap::new())
    });
    let mut m = memo.lock();
    m.entry((model, stage, seq))
        .or_insert_with(|| Arc::new(ModelWorkload::new(model, stage, seq)))
        .clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_prefill_matches_paper_six_gemms() {
        let gs = LlmModel::BertBase.layer_gemms(Stage::Prefill, DEFAULT_SEQ);
        assert_eq!(gs.len(), 6); // Table VII lists 6 per-layer loop orders
        assert_eq!(gs[0], Gemm::new(128, 768, 2304)); // QKV
        assert_eq!(gs[1], Gemm::new(128 * 12, 64, 128)); // scores
        assert_eq!(gs[2], Gemm::new(128 * 12, 128, 64)); // context
        assert_eq!(gs[3], Gemm::new(128, 768, 768)); // out proj
        assert_eq!(gs[4], Gemm::new(128, 768, 3072)); // FFN up
        assert_eq!(gs[5], Gemm::new(128, 3072, 768)); // FFN down
    }

    #[test]
    fn decode_has_m_equal_one_for_projections() {
        for model in LlmModel::ALL {
            let gs = model.layer_gemms(Stage::Decode, DEFAULT_SEQ);
            // QKV, out-proj and FFN GEMMs must have M = 1 in decode
            assert_eq!(gs[0].m, 1, "{}", model.name());
            assert_eq!(gs[3].m, 1);
            assert_eq!(gs[4].m, 1);
            assert_eq!(gs[5].m, 1);
        }
    }

    #[test]
    fn llama_uses_gated_mlp() {
        let gs = LlmModel::Llama2_7b.layer_gemms(Stage::Prefill, 128);
        assert_eq!(gs[4], Gemm::new(128, 4096, 2 * 11008));
        assert_eq!(gs[5], Gemm::new(128, 11008, 4096));
    }

    #[test]
    fn workload_mapping_roundtrips_and_memo_shares() {
        for model in LlmModel::ALL {
            for stage in Stage::ALL {
                let wl = model_workload(model, stage, DEFAULT_SEQ);
                assert_eq!(wl.gemms, model.layer_gemms(stage, DEFAULT_SEQ));
                assert_eq!(wl.layer_to_unique.len(), wl.gemms.len());
                for (l, &u) in wl.layer_to_unique.iter().enumerate() {
                    assert_eq!(wl.unique[u], wl.gemms[l]);
                }
                // unique really is a set
                for (i, a) in wl.unique.iter().enumerate() {
                    for b in &wl.unique[i + 1..] {
                        assert_ne!(a, b);
                    }
                }
                assert_eq!(wl.blocks, model.n_blocks() as u64);
                // the memo hands back the same shared instance
                let again = model_workload(model, stage, DEFAULT_SEQ);
                assert!(Arc::ptr_eq(&wl, &again));
            }
        }
    }

    #[test]
    fn prefill_macs_exceed_decode() {
        for model in LlmModel::ALL {
            let pf: u64 =
                model.layer_gemms(Stage::Prefill, 128).iter().map(|g| g.macs()).sum();
            let dec: u64 =
                model.layer_gemms(Stage::Decode, 128).iter().map(|g| g.macs()).sum();
            assert!(pf > 10 * dec, "{}: prefill {pf} vs decode {dec}", model.name());
        }
    }
}
