//! Aligned ASCII table printer — every bench harness regenerating a paper
//! table/figure prints through this so outputs are uniform and diffable.

/// Builds and renders a column-aligned table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (w, c) in widths.iter_mut().zip(r) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("| ");
            for i in 0..ncols {
                line.push_str(&format!("{:<w$} ", cells[i], w = widths[i]));
                line.push_str("| ");
            }
            line.pop();
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
        }
        out
    }
}

/// Format a float with engineering-style precision for table cells.
pub fn fnum(x: f64) -> String {
    if !x.is_finite() {
        return format!("{x}");
    }
    let a = x.abs();
    if a == 0.0 {
        "0".into()
    } else if a >= 1e6 || a < 1e-3 {
        format!("{x:.3e}")
    } else if a >= 100.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row_strs(&["a", "1"]).row_strs(&["longer", "2.5"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(s.contains("longer"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        Table::new(&["a", "b"]).row_strs(&["only-one"]);
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(1.5), "1.500");
        assert_eq!(fnum(1234.5), "1234.5");
        assert!(fnum(1.23e9).contains('e'));
        assert!(fnum(1.0e-5).contains('e'));
    }
}
