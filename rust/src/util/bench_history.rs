//! Per-commit benchmark history and the CI regression gate (ROADMAP
//! item 3).
//!
//! Each CI run emits machine-readable bench snapshots
//! (`BENCH_eval_core.json`, `BENCH_structured.json`). This module
//! accumulates the **throughput** points from those snapshots into a
//! committed history file (`benchmarks/history.json`) shaped after the
//! flowistry `window.BENCHMARK_DATA` stream — an `entries` array of
//! `{commit{id, message, timestamp}, date, benches[{name, value, unit}]}`
//! records — and fails CI when the current run regresses more than a
//! tolerance against the last recorded entry. That turns every landed
//! speedup into an enforced floor instead of a one-off bragging number.
//!
//! Only *throughput* keys (higher is better) participate in the gate:
//! `*_candidates_per_s` from the eval-core stream and `structured_cps_*`
//! from the structured stream. Ratios (speedups) and hit rates ride along
//! in the history for plotting but are too noisy to gate on — a cache
//! speedup can legitimately halve when the baseline it divides by gets
//! faster.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// One named measurement in an entry.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchPoint {
    pub name: String,
    pub value: f64,
    pub unit: String,
}

/// Whether a bench key is a throughput metric the regression gate covers
/// (higher is strictly better).
pub fn is_throughput_key(name: &str) -> bool {
    name.ends_with("_candidates_per_s") || name.starts_with("structured_cps_")
}

/// Flatten one bench-snapshot JSON object (`{key: number, ...}`) into
/// named points; the `source` prefixes each name so the two streams never
/// collide (`eval_core/llm_cold_candidates_per_s`). Non-numeric values
/// are skipped.
pub fn points_from_snapshot(source: &str, snapshot: &Json) -> Vec<BenchPoint> {
    let Some(obj) = snapshot.as_obj() else { return Vec::new() };
    obj.iter()
        .filter_map(|(k, v)| {
            v.as_f64().map(|value| BenchPoint {
                name: format!("{source}/{k}"),
                value,
                unit: if is_throughput_key(k) { "candidates/sec" } else { "ratio" }.to_string(),
            })
        })
        .collect()
}

/// The commit identity stamped on one history entry.
#[derive(Debug, Clone, Default)]
pub struct CommitInfo {
    pub id: String,
    pub message: String,
    /// ISO-8601 or epoch seconds — recorded verbatim, never parsed.
    pub timestamp: String,
}

/// Parse `benchmarks/history.json`; a missing file is an empty history.
pub fn load(path: &Path) -> Result<Vec<Json>, String> {
    if !path.exists() {
        return Ok(Vec::new());
    }
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path:?}: {e}"))?;
    let root = Json::parse(&text).map_err(|e| format!("parse {path:?}: {e:?}"))?;
    match root.get("entries").as_arr() {
        Some(entries) => Ok(entries.to_vec()),
        None => Err(format!("{path:?}: missing entries array")),
    }
}

/// The throughput points of one history entry, keyed by name.
pub fn entry_throughputs(entry: &Json) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    if let Some(benches) = entry.get("benches").as_arr() {
        for b in benches {
            if let (Some(name), Some(value)) = (b.get("name").as_str(), b.get("value").as_f64()) {
                // names are prefixed "source/key"; gate on the key part
                let key = name.rsplit('/').next().unwrap_or(name);
                if is_throughput_key(key) {
                    out.insert(name.to_string(), value);
                }
            }
        }
    }
    out
}

/// Compare the current run's points against the last history entry.
/// Returns one line per throughput metric that fell below
/// `(1 - tolerance) ×` its previous value. Metrics absent on either side
/// are skipped (new benches enter the stream ungated; retired ones leave
/// it silently).
pub fn regressions(last: &Json, current: &[BenchPoint], tolerance: f64) -> Vec<String> {
    let prev = entry_throughputs(last);
    let mut out = Vec::new();
    for p in current {
        let key = p.name.rsplit('/').next().unwrap_or(&p.name);
        if !is_throughput_key(key) {
            continue;
        }
        if let Some(&was) = prev.get(&p.name) {
            let floor = was * (1.0 - tolerance);
            if was > 0.0 && p.value < floor {
                out.push(format!(
                    "{}: {:.0} -> {:.0} ({:+.1}% < -{:.0}% tolerance)",
                    p.name,
                    was,
                    p.value,
                    (p.value / was - 1.0) * 100.0,
                    tolerance * 100.0
                ));
            }
        }
    }
    out
}

/// Serialize one new entry in the flowistry `BENCHMARK_DATA` entry shape.
pub fn make_entry(commit: &CommitInfo, date_epoch_s: u64, points: &[BenchPoint]) -> Json {
    let benches: Vec<Json> = points
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("name", Json::Str(p.name.clone())),
                ("value", Json::Num(p.value)),
                ("unit", Json::Str(p.unit.clone())),
            ])
        })
        .collect();
    Json::obj(vec![
        (
            "commit",
            Json::obj(vec![
                ("id", Json::Str(commit.id.clone())),
                ("message", Json::Str(commit.message.clone())),
                ("timestamp", Json::Str(commit.timestamp.clone())),
            ]),
        ),
        ("date", Json::Num(date_epoch_s as f64)),
        ("tool", Json::Str("cargo".to_string())),
        ("benches", Json::Arr(benches)),
    ])
}

/// Rewrite the history file with `entries` (creating parent directories),
/// wrapped in the `{lastUpdate, entries: [...]}` envelope.
pub fn store(path: &Path, entries: &[Json], last_update_epoch_s: u64) -> Result<(), String> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| format!("mkdir {dir:?}: {e}"))?;
        }
    }
    let root = Json::obj(vec![
        ("lastUpdate", Json::Num(last_update_epoch_s as f64)),
        ("entries", Json::Arr(entries.to_vec())),
    ]);
    std::fs::write(path, root.to_string()).map_err(|e| format!("write {path:?}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(name: &str, value: f64) -> BenchPoint {
        BenchPoint { name: name.to_string(), value, unit: "candidates/sec".to_string() }
    }

    fn entry_with(points: &[BenchPoint]) -> Json {
        make_entry(
            &CommitInfo { id: "abc".into(), message: "m".into(), timestamp: "t".into() },
            1,
            points,
        )
    }

    #[test]
    fn throughput_keys_gate_ratios_do_not() {
        assert!(is_throughput_key("llm_cold_candidates_per_s"));
        assert!(is_throughput_key("sim_batch_candidates_per_s"));
        assert!(is_throughput_key("structured_cps_diffaxe"));
        assert!(!is_throughput_key("cache_hit_rate"));
        assert!(!is_throughput_key("llm_speedup_cold"));
        assert!(!is_throughput_key("structured_sp_random"));
    }

    #[test]
    fn regression_detected_only_past_tolerance() {
        let last = entry_with(&[
            pt("eval_core/llm_cold_candidates_per_s", 1000.0),
            pt("structured/structured_cps_diffaxe", 500.0),
        ]);
        // 10% down: inside the 15% tolerance
        let ok = regressions(&last, &[pt("eval_core/llm_cold_candidates_per_s", 900.0)], 0.15);
        assert!(ok.is_empty(), "{ok:?}");
        // 20% down: gated
        let bad = regressions(&last, &[pt("eval_core/llm_cold_candidates_per_s", 800.0)], 0.15);
        assert_eq!(bad.len(), 1, "{bad:?}");
        assert!(bad[0].contains("llm_cold_candidates_per_s"), "{bad:?}");
        // improvements and new metrics never fail
        let up = regressions(
            &last,
            &[
                pt("eval_core/llm_cold_candidates_per_s", 5000.0),
                pt("eval_core/brand_new_candidates_per_s", 1.0),
            ],
            0.15,
        );
        assert!(up.is_empty(), "{up:?}");
        // non-throughput keys are ignored even when lower
        let ratios = regressions(
            &last,
            &[BenchPoint { name: "eval_core/hit_rate".into(), value: 0.0, unit: "ratio".into() }],
            0.15,
        );
        assert!(ratios.is_empty(), "{ratios:?}");
    }

    #[test]
    fn snapshot_flattening_prefixes_and_filters() {
        let snap = Json::obj(vec![
            ("llm_cold_candidates_per_s", Json::Num(42.0)),
            ("cache_hit_rate", Json::Num(0.5)),
            ("label", Json::Str("not a number".into())),
        ]);
        let pts = points_from_snapshot("eval_core", &snap);
        assert_eq!(pts.len(), 2);
        assert!(pts.iter().any(
            |p| p.name == "eval_core/llm_cold_candidates_per_s" && p.unit == "candidates/sec"
        ));
        assert!(pts.iter().any(|p| p.name == "eval_core/cache_hit_rate" && p.unit == "ratio"));
    }

    #[test]
    fn history_roundtrip_appends_and_reloads() {
        let dir = std::env::temp_dir().join(format!("diffaxe_bench_hist_{}", std::process::id()));
        let path = dir.join("history.json");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(load(&path).unwrap().is_empty(), "missing file is an empty history");
        let mut entries = load(&path).unwrap();
        entries.push(entry_with(&[pt("eval_core/sim_batch_candidates_per_s", 123.0)]));
        store(&path, &entries, 7).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), 1);
        let tp = entry_throughputs(&back[0]);
        assert_eq!(tp.get("eval_core/sim_batch_candidates_per_s"), Some(&123.0));
        // append a second entry and confirm ordering survives
        entries.push(entry_with(&[pt("eval_core/sim_batch_candidates_per_s", 150.0)]));
        store(&path, &entries, 8).unwrap();
        assert_eq!(load(&path).unwrap().len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
