//! Per-commit benchmark history and the CI regression gate (ROADMAP
//! item 3).
//!
//! Each CI run emits machine-readable bench snapshots
//! (`BENCH_eval_core.json`, `BENCH_structured.json`). This module
//! accumulates the **throughput** points from those snapshots into a
//! committed history file (`benchmarks/history.json`) shaped after the
//! flowistry `window.BENCHMARK_DATA` stream — an `entries` array of
//! `{commit{id, message, timestamp}, date, benches[{name, value, unit}]}`
//! records — and fails CI when the current run regresses more than a
//! tolerance against the last recorded entry. That turns every landed
//! speedup into an enforced floor instead of a one-off bragging number.
//!
//! Only *throughput* keys (higher is better) participate in the gate:
//! `*_candidates_per_s` from the eval-core stream and `structured_cps_*`
//! from the structured stream. Ratios (speedups) and hit rates ride along
//! in the history for plotting but are too noisy to gate on — a cache
//! speedup can legitimately halve when the baseline it divides by gets
//! faster. The fleet stream (`fleet/*`) is ungated by construction: its
//! keys avoid both gate patterns so multi-worker scaling numbers can move
//! with runner core counts without wedging CI.
//!
//! [`render_html`] turns the accumulated history into a single static,
//! dependency-free HTML page (inline SVG, no scripts) so the trajectory
//! is browsable straight from the repository.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// One named measurement in an entry.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchPoint {
    pub name: String,
    pub value: f64,
    pub unit: String,
}

/// Whether a bench key is a throughput metric the regression gate covers
/// (higher is strictly better).
pub fn is_throughput_key(name: &str) -> bool {
    // fleet_* cps keys are deliberately ungated ride-alongs: fleet
    // scaling moves with the CI runner's core count, not with the code
    name.ends_with("_candidates_per_s")
        || name.starts_with("structured_cps_")
        || (name.ends_with("_cps") && !name.starts_with("fleet_"))
}

/// Whether a bench key is a solution-quality metric the regression gate
/// covers with the **lower-is-better** direction (best-EDP floors: the
/// search must keep finding designs at least this good).
pub fn is_quality_key(name: &str) -> bool {
    name.starts_with("structured_best_edp_") || name.ends_with("_best_edp")
}

/// Gate direction of a bench key: throughput entries fail when the value
/// *falls* past tolerance, quality (best-EDP) entries fail when it
/// *rises* past tolerance, everything else rides along ungated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GateClass {
    /// higher is better — fails below `(1 - tolerance) × previous`
    Throughput,
    /// lower is better — fails above `(1 + tolerance) × previous`
    Quality,
    /// recorded for plotting only
    Ungated,
}

/// Classify a bare bench key (no `source/` prefix).
pub fn gate_class(key: &str) -> GateClass {
    if is_throughput_key(key) {
        GateClass::Throughput
    } else if is_quality_key(key) {
        GateClass::Quality
    } else {
        GateClass::Ungated
    }
}

/// Flatten one bench-snapshot JSON object (`{key: number, ...}`) into
/// named points; the `source` prefixes each name so the two streams never
/// collide (`eval_core/llm_cold_candidates_per_s`). Non-numeric values
/// are skipped.
pub fn points_from_snapshot(source: &str, snapshot: &Json) -> Vec<BenchPoint> {
    let Some(obj) = snapshot.as_obj() else { return Vec::new() };
    obj.iter()
        .filter_map(|(k, v)| {
            v.as_f64().map(|value| BenchPoint {
                name: format!("{source}/{k}"),
                value,
                unit: if is_throughput_key(k) { "candidates/sec" } else { "ratio" }.to_string(),
            })
        })
        .collect()
}

/// The commit identity stamped on one history entry.
#[derive(Debug, Clone, Default)]
pub struct CommitInfo {
    pub id: String,
    pub message: String,
    /// ISO-8601 or epoch seconds — recorded verbatim, never parsed.
    pub timestamp: String,
}

/// Parse `benchmarks/history.json`; a missing file is an empty history.
pub fn load(path: &Path) -> Result<Vec<Json>, String> {
    if !path.exists() {
        return Ok(Vec::new());
    }
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path:?}: {e}"))?;
    let root = Json::parse(&text).map_err(|e| format!("parse {path:?}: {e:?}"))?;
    match root.get("entries").as_arr() {
        Some(entries) => Ok(entries.to_vec()),
        None => Err(format!("{path:?}: missing entries array")),
    }
}

/// The throughput points of one history entry, keyed by name.
pub fn entry_throughputs(entry: &Json) -> BTreeMap<String, f64> {
    entry_points(entry, |key| gate_class(key) == GateClass::Throughput)
}

/// Every *gated* point of one history entry (throughput + quality), keyed
/// by name.
pub fn entry_gated(entry: &Json) -> BTreeMap<String, f64> {
    entry_points(entry, |key| gate_class(key) != GateClass::Ungated)
}

fn entry_points(entry: &Json, keep: impl Fn(&str) -> bool) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    if let Some(benches) = entry.get("benches").as_arr() {
        for b in benches {
            if let (Some(name), Some(value)) = (b.get("name").as_str(), b.get("value").as_f64()) {
                // names are prefixed "source/key"; gate on the key part
                let key = name.rsplit('/').next().unwrap_or(name);
                if keep(key) {
                    out.insert(name.to_string(), value);
                }
            }
        }
    }
    out
}

/// Compare the current run's points against the last history entry,
/// direction-aware: throughput metrics fail when they fall below
/// `(1 - tolerance) ×` their previous value, quality (best-EDP) metrics
/// fail when they rise above `(1 + tolerance) ×` it. Metrics absent on
/// either side are skipped (new benches enter the stream ungated; retired
/// ones leave it silently).
pub fn regressions(last: &Json, current: &[BenchPoint], tolerance: f64) -> Vec<String> {
    let prev = entry_gated(last);
    let mut out = Vec::new();
    for p in current {
        let key = p.name.rsplit('/').next().unwrap_or(&p.name);
        let Some(&was) = prev.get(&p.name) else { continue };
        if was <= 0.0 {
            continue;
        }
        match gate_class(key) {
            GateClass::Throughput if p.value < was * (1.0 - tolerance) => {
                out.push(format!(
                    "{}: {:.0} -> {:.0} ({:+.1}% < -{:.0}% tolerance)",
                    p.name,
                    was,
                    p.value,
                    (p.value / was - 1.0) * 100.0,
                    tolerance * 100.0
                ));
            }
            GateClass::Quality if p.value > was * (1.0 + tolerance) => {
                out.push(format!(
                    "{}: {:.3e} -> {:.3e} ({:+.1}% > +{:.0}% tolerance, lower is better)",
                    p.name,
                    was,
                    p.value,
                    (p.value / was - 1.0) * 100.0,
                    tolerance * 100.0
                ));
            }
            _ => {}
        }
    }
    out
}

/// Serialize one new entry in the flowistry `BENCHMARK_DATA` entry shape.
pub fn make_entry(commit: &CommitInfo, date_epoch_s: u64, points: &[BenchPoint]) -> Json {
    let benches: Vec<Json> = points
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("name", Json::Str(p.name.clone())),
                ("value", Json::Num(p.value)),
                ("unit", Json::Str(p.unit.clone())),
            ])
        })
        .collect();
    Json::obj(vec![
        (
            "commit",
            Json::obj(vec![
                ("id", Json::Str(commit.id.clone())),
                ("message", Json::Str(commit.message.clone())),
                ("timestamp", Json::Str(commit.timestamp.clone())),
            ]),
        ),
        ("date", Json::Num(date_epoch_s as f64)),
        ("tool", Json::Str("cargo".to_string())),
        ("benches", Json::Arr(benches)),
    ])
}

/// Rewrite the history file with `entries` (creating parent directories),
/// wrapped in the `{lastUpdate, entries: [...]}` envelope.
pub fn store(path: &Path, entries: &[Json], last_update_epoch_s: u64) -> Result<(), String> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| format!("mkdir {dir:?}: {e}"))?;
        }
    }
    let root = Json::obj(vec![
        ("lastUpdate", Json::Num(last_update_epoch_s as f64)),
        ("entries", Json::Arr(entries.to_vec())),
    ]);
    std::fs::write(path, root.to_string()).map_err(|e| format!("write {path:?}: {e}"))
}

/// Escape text for embedding in HTML body text or attribute values.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '&' => out.push_str("&amp;"),
            '<' => out.push_str("&lt;"),
            '>' => out.push_str("&gt;"),
            '"' => out.push_str("&quot;"),
            _ => out.push(c),
        }
    }
    out
}

/// Compact numeric label for axis ticks and tooltips.
fn fmt_val(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 100.0 {
        format!("{v:.0}")
    } else if v.abs() >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.4}")
    }
}

/// One inline-SVG trajectory chart for a single metric. `pts` holds
/// `(entry index, value)` pairs (sparse — a metric may be absent from
/// older entries); `labels` is one hover label per history entry.
fn chart_svg(name: &str, pts: &[(usize, f64)], labels: &[String], n_entries: usize) -> String {
    const W: f64 = 720.0;
    const H: f64 = 170.0;
    const L: f64 = 64.0; // left gutter: y-axis tick labels
    const R: f64 = 12.0;
    const T: f64 = 14.0;
    const B: f64 = 22.0;
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &(_, v) in pts {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if !lo.is_finite() || !hi.is_finite() {
        return String::new();
    }
    // pad the value range so a flat series still draws mid-chart
    let span = if hi > lo { hi - lo } else { lo.abs().max(1.0) };
    let (vlo, vhi) = (lo - 0.05 * span, hi + 0.05 * span);
    let x = |i: usize| L + i as f64 * (W - L - R) / (n_entries.saturating_sub(1).max(1) as f64);
    let y = |v: f64| H - B - (v - vlo) / (vhi - vlo) * (H - T - B);
    let mut poly = String::new();
    let mut dots = String::new();
    for &(i, v) in pts {
        let (px, py) = (x(i), y(v));
        poly.push_str(&format!("{px:.1},{py:.1} "));
        let label = labels.get(i).map(String::as_str).unwrap_or("?");
        dots.push_str(&format!(
            "<circle cx=\"{px:.1}\" cy=\"{py:.1}\" r=\"3\"><title>{}: {}</title></circle>",
            esc(label),
            fmt_val(v)
        ));
    }
    let key = name.rsplit('/').next().unwrap_or(name);
    let badge = if gate_class(key) == GateClass::Ungated { "ride-along" } else { "gated" };
    let last = pts.last().map(|&(_, v)| fmt_val(v)).unwrap_or_default();
    let mut s = String::new();
    s.push_str(&format!(
        "<section><h2>{} <span class=\"badge {badge}\">{badge}</span> \
         <span class=\"last\">last {last}</span></h2>\n",
        esc(name)
    ));
    s.push_str(&format!(
        "<svg viewBox=\"0 0 {W} {H}\" role=\"img\" aria-label=\"{}\">\n",
        esc(name)
    ));
    s.push_str(&format!(
        "<line class=\"axis\" x1=\"{L}\" y1=\"{:.1}\" x2=\"{:.1}\" y2=\"{:.1}\"/>\n",
        H - B,
        W - R,
        H - B
    ));
    let tick = |ty: f64, v: f64| {
        format!("<text class=\"tick\" x=\"4\" y=\"{ty:.1}\">{}</text>\n", fmt_val(v))
    };
    s.push_str(&tick(T + 4.0, hi));
    s.push_str(&tick(H - B, lo));
    s.push_str(&format!("<polyline points=\"{}\"/>\n", poly.trim_end()));
    s.push_str(&dots);
    s.push_str("</svg></section>\n");
    s
}

/// Render the full history as one self-contained static HTML page: a
/// trajectory chart per metric, inline SVG only, no scripts and no
/// external assets — viewable from a `file://` URL or any bare static
/// host. Gated throughput metrics are badged apart from ride-along
/// ratios/hit-rates so a reader knows which lines CI enforces.
pub fn render_html(entries: &[Json]) -> String {
    let n = entries.len();
    let labels: Vec<String> = entries
        .iter()
        .map(|e| {
            let full_id = e.get("commit").get("id").as_str().unwrap_or("?");
            let id: String = full_id.chars().take(9).collect();
            match e.get("commit").get("message").as_str() {
                Some(msg) if !msg.is_empty() => format!("{id} {msg}"),
                _ => id,
            }
        })
        .collect();
    let mut series: BTreeMap<String, Vec<(usize, f64)>> = BTreeMap::new();
    for (i, e) in entries.iter().enumerate() {
        if let Some(benches) = e.get("benches").as_arr() {
            for b in benches {
                if let (Some(name), Some(v)) = (b.get("name").as_str(), b.get("value").as_f64()) {
                    series.entry(name.to_string()).or_default().push((i, v));
                }
            }
        }
    }
    let mut page = String::new();
    page.push_str(
        "<!DOCTYPE html>\n<html lang=\"en\"><head><meta charset=\"utf-8\">\n\
         <meta name=\"viewport\" content=\"width=device-width, initial-scale=1\">\n\
         <title>diffaxe bench trajectory</title>\n<style>\n\
         body{font:14px/1.5 system-ui,sans-serif;margin:2rem auto;max-width:760px;color:#1a1a2e}\n\
         h1{font-size:1.3rem} h2{font-size:0.95rem;margin:1.6rem 0 0.2rem}\n\
         .badge{font-size:0.7rem;padding:0.1rem 0.4rem;border-radius:0.6rem;vertical-align:middle}\n\
         .badge.gated{background:#dbeafe;color:#1d4ed8}\n\
         .badge.ride-along{background:#f1f5f9;color:#64748b}\n\
         .last{float:right;font-weight:normal;color:#64748b;font-size:0.8rem}\n\
         svg{width:100%;height:auto;background:#fafbfc;border:1px solid #e2e8f0;border-radius:4px}\n\
         polyline{fill:none;stroke:#2563eb;stroke-width:1.5}\n\
         circle{fill:#2563eb} circle:hover{fill:#dc2626}\n\
         .axis{stroke:#cbd5e1;stroke-width:1}\n\
         .tick{font:10px monospace;fill:#64748b}\n\
         footer{margin-top:2rem;color:#94a3b8;font-size:0.8rem}\n\
         </style></head><body>\n",
    );
    page.push_str(&format!(
        "<h1>diffaxe bench trajectory</h1>\n\
         <p>{n} committed run{} &middot; {} metric{} &middot; hover a point for its commit. \
         Badged <em>gated</em> metrics enforce the CI regression floor; <em>ride-along</em> \
         metrics are recorded for trend-watching only.</p>\n",
        if n == 1 { "" } else { "s" },
        series.len(),
        if series.len() == 1 { "" } else { "s" }
    ));
    for (name, pts) in &series {
        page.push_str(&chart_svg(name, pts, &labels, n));
    }
    page.push_str("<footer>generated by <code>diffaxe bench-history --html</code> from \
                   <code>benchmarks/history.json</code></footer>\n</body></html>\n");
    page
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(name: &str, value: f64) -> BenchPoint {
        BenchPoint { name: name.to_string(), value, unit: "candidates/sec".to_string() }
    }

    fn entry_with(points: &[BenchPoint]) -> Json {
        make_entry(
            &CommitInfo { id: "abc".into(), message: "m".into(), timestamp: "t".into() },
            1,
            points,
        )
    }

    #[test]
    fn throughput_keys_gate_ratios_do_not() {
        assert!(is_throughput_key("llm_cold_candidates_per_s"));
        assert!(is_throughput_key("sim_batch_candidates_per_s"));
        assert!(is_throughput_key("structured_cps_diffaxe"));
        assert!(is_throughput_key("structured_joint_cps"));
        assert!(!is_throughput_key("cache_hit_rate"));
        assert!(!is_throughput_key("llm_speedup_cold"));
        assert!(!is_throughput_key("structured_sp_random"));
        // fleet cps keys stay ungated: they track runner cores, not code
        assert!(!is_throughput_key("fleet_w1_cps"));
        assert!(!is_throughput_key("fleet_w4_cps"));
    }

    #[test]
    fn gate_classes_split_throughput_quality_and_ride_along() {
        assert_eq!(gate_class("structured_cps_diffaxe"), GateClass::Throughput);
        assert_eq!(gate_class("structured_joint_cps"), GateClass::Throughput);
        assert_eq!(gate_class("structured_best_edp_diffaxe"), GateClass::Quality);
        assert_eq!(gate_class("structured_joint_best_edp"), GateClass::Quality);
        assert_eq!(gate_class("structured_sp_random"), GateClass::Ungated);
        assert_eq!(gate_class("cache_hit_rate"), GateClass::Ungated);
        assert_eq!(gate_class("fleet_w4_cps"), GateClass::Ungated);
        // a quality key is never simultaneously a throughput key
        assert!(!is_throughput_key("structured_best_edp_diffaxe"));
        assert!(!is_quality_key("structured_cps_diffaxe"));
    }

    #[test]
    fn best_edp_gate_is_direction_aware() {
        let last = entry_with(&[
            pt("structured/structured_best_edp_diffaxe", 100.0),
            pt("structured/structured_joint_best_edp", 100.0),
            pt("structured/structured_joint_cps", 1000.0),
        ]);
        // EDP creeping up within tolerance: fine
        let ok = regressions(&last, &[pt("structured/structured_best_edp_diffaxe", 110.0)], 0.15);
        assert!(ok.is_empty(), "{ok:?}");
        // EDP past tolerance: gated, and the message states the direction
        let bad = regressions(&last, &[pt("structured/structured_joint_best_edp", 120.0)], 0.15);
        assert_eq!(bad.len(), 1, "{bad:?}");
        assert!(bad[0].contains("lower is better"), "{bad:?}");
        // EDP *improving* (falling) never fails, however far it drops
        let down = regressions(
            &last,
            &[
                pt("structured/structured_best_edp_diffaxe", 1.0),
                pt("structured/structured_joint_best_edp", 1.0),
            ],
            0.15,
        );
        assert!(down.is_empty(), "{down:?}");
        // the joint cps key keeps the higher-is-better direction
        let cps_bad = regressions(&last, &[pt("structured/structured_joint_cps", 500.0)], 0.15);
        assert_eq!(cps_bad.len(), 1, "{cps_bad:?}");
        let cps_up = regressions(&last, &[pt("structured/structured_joint_cps", 5000.0)], 0.15);
        assert!(cps_up.is_empty(), "{cps_up:?}");
    }

    #[test]
    fn regression_detected_only_past_tolerance() {
        let last = entry_with(&[
            pt("eval_core/llm_cold_candidates_per_s", 1000.0),
            pt("structured/structured_cps_diffaxe", 500.0),
        ]);
        // 10% down: inside the 15% tolerance
        let ok = regressions(&last, &[pt("eval_core/llm_cold_candidates_per_s", 900.0)], 0.15);
        assert!(ok.is_empty(), "{ok:?}");
        // 20% down: gated
        let bad = regressions(&last, &[pt("eval_core/llm_cold_candidates_per_s", 800.0)], 0.15);
        assert_eq!(bad.len(), 1, "{bad:?}");
        assert!(bad[0].contains("llm_cold_candidates_per_s"), "{bad:?}");
        // improvements and new metrics never fail
        let up = regressions(
            &last,
            &[
                pt("eval_core/llm_cold_candidates_per_s", 5000.0),
                pt("eval_core/brand_new_candidates_per_s", 1.0),
            ],
            0.15,
        );
        assert!(up.is_empty(), "{up:?}");
        // non-throughput keys are ignored even when lower
        let ratios = regressions(
            &last,
            &[BenchPoint { name: "eval_core/hit_rate".into(), value: 0.0, unit: "ratio".into() }],
            0.15,
        );
        assert!(ratios.is_empty(), "{ratios:?}");
    }

    #[test]
    fn snapshot_flattening_prefixes_and_filters() {
        let snap = Json::obj(vec![
            ("llm_cold_candidates_per_s", Json::Num(42.0)),
            ("cache_hit_rate", Json::Num(0.5)),
            ("label", Json::Str("not a number".into())),
        ]);
        let pts = points_from_snapshot("eval_core", &snap);
        assert_eq!(pts.len(), 2);
        assert!(pts.iter().any(
            |p| p.name == "eval_core/llm_cold_candidates_per_s" && p.unit == "candidates/sec"
        ));
        assert!(pts.iter().any(|p| p.name == "eval_core/cache_hit_rate" && p.unit == "ratio"));
    }

    #[test]
    fn history_roundtrip_appends_and_reloads() {
        let dir = std::env::temp_dir().join(format!("diffaxe_bench_hist_{}", std::process::id()));
        let path = dir.join("history.json");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(load(&path).unwrap().is_empty(), "missing file is an empty history");
        let mut entries = load(&path).unwrap();
        entries.push(entry_with(&[pt("eval_core/sim_batch_candidates_per_s", 123.0)]));
        store(&path, &entries, 7).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), 1);
        let tp = entry_throughputs(&back[0]);
        assert_eq!(tp.get("eval_core/sim_batch_candidates_per_s"), Some(&123.0));
        // append a second entry and confirm ordering survives
        entries.push(entry_with(&[pt("eval_core/sim_batch_candidates_per_s", 150.0)]));
        store(&path, &entries, 8).unwrap();
        assert_eq!(load(&path).unwrap().len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn html_renders_one_chart_per_metric_and_escapes_commit_text() {
        let commit = CommitInfo {
            id: "deadbeefcafe".into(),
            message: "tune <script>alert(1)</script> & more".into(),
            timestamp: "t".into(),
        };
        let entries = vec![
            make_entry(
                &commit,
                1,
                &[
                    pt("eval_core/llm_cold_candidates_per_s", 1000.0),
                    BenchPoint {
                        name: "fleet/fleet_scaling".into(),
                        value: 2.5,
                        unit: "ratio".into(),
                    },
                ],
            ),
            make_entry(
                &commit,
                2,
                &[
                    pt("eval_core/llm_cold_candidates_per_s", 1200.0),
                    BenchPoint {
                        name: "fleet/fleet_scaling".into(),
                        value: 2.7,
                        unit: "ratio".into(),
                    },
                ],
            ),
        ];
        let page = render_html(&entries);
        // self-contained: no external references, no scripts
        assert!(!page.contains("<script"), "page must not carry scripts");
        assert!(!page.contains("http://") && !page.contains("https://"), "no external assets");
        // one <section>/<svg> pair per metric
        assert_eq!(page.matches("<section>").count(), 2, "{page}");
        assert_eq!(page.matches("<svg ").count(), 2);
        // both entries plotted for each metric
        assert_eq!(page.matches("<circle ").count(), 4);
        // commit text is escaped, truncated id survives in tooltips
        assert!(page.contains("&lt;script&gt;alert(1)&lt;/script&gt; &amp; more"));
        assert!(page.contains("deadbeefc"), "9-char commit id in hover labels");
        // gate badge split: throughput gated, fleet ride-along
        assert!(page.contains("badge gated"));
        assert!(page.contains("badge ride-along"));
    }

    #[test]
    fn html_handles_empty_and_flat_histories() {
        let empty = render_html(&[]);
        assert!(empty.contains("0 committed runs"));
        assert!(!empty.contains("<svg "));
        // a flat series (zero span) must still render finite coordinates
        let flat = render_html(&[
            entry_with(&[pt("eval_core/sim_batch_candidates_per_s", 50.0)]),
            entry_with(&[pt("eval_core/sim_batch_candidates_per_s", 50.0)]),
        ]);
        assert_eq!(flat.matches("<svg ").count(), 1);
        assert!(!flat.contains("NaN") && !flat.contains("inf"), "{flat}");
    }
}
