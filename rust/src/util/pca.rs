//! Principal component analysis via power iteration with deflation.
//!
//! Used to regenerate the paper's latent-space visualizations (Figs 2(b), 7,
//! 11): we project hardware/latent vectors onto the top-2 principal
//! components and emit (pc1, pc2, metric) triples.

use super::linalg::{dot, norm2, Mat};
use super::rng::Pcg32;

/// Result of a PCA: component directions (rows) and explained variance.
#[derive(Debug, Clone)]
pub struct Pca {
    /// `k x d` matrix; row i is the i-th principal direction (unit norm).
    pub components: Mat,
    /// eigenvalue (variance) along each component.
    pub explained_variance: Vec<f64>,
    /// per-feature mean subtracted before projection.
    pub mean: Vec<f64>,
}

impl Pca {
    /// Fit the top-`k` principal components of `x` (`n x d`, rows = samples).
    pub fn fit(x: &Mat, k: usize, seed: u64) -> Pca {
        let (n, d) = (x.rows, x.cols);
        assert!(n >= 2, "need at least 2 samples");
        let k = k.min(d);
        // center
        let mut mean = vec![0.0; d];
        for i in 0..n {
            for (m, v) in mean.iter_mut().zip(x.row(i)) {
                *m += v;
            }
        }
        for m in &mut mean {
            *m /= n as f64;
        }
        // covariance (d x d) — d is small (<=128) in all our uses.
        let mut cov = Mat::zeros(d, d);
        for i in 0..n {
            let r = x.row(i);
            for a in 0..d {
                let xa = r[a] - mean[a];
                for b in a..d {
                    cov[(a, b)] += xa * (r[b] - mean[b]);
                }
            }
        }
        for a in 0..d {
            for b in a..d {
                let v = cov[(a, b)] / (n - 1) as f64;
                cov[(a, b)] = v;
                cov[(b, a)] = v;
            }
        }

        // lint:allow(rng-construct) stream 77 is part of the PCA golden outputs
        let mut rng = Pcg32::new(seed, 77);
        let mut components = Mat::zeros(k, d);
        let mut explained = Vec::with_capacity(k);
        let mut cov_defl = cov;
        for c in 0..k {
            let (vec_c, lam) = power_iteration(&cov_defl, &mut rng);
            for j in 0..d {
                components[(c, j)] = vec_c[j];
            }
            explained.push(lam);
            // deflate: cov -= lam * v v^T
            for a in 0..d {
                for b in 0..d {
                    cov_defl[(a, b)] -= lam * vec_c[a] * vec_c[b];
                }
            }
        }
        Pca { components, explained_variance: explained, mean }
    }

    /// Project samples (`n x d`) onto the fitted components (`n x k`).
    pub fn transform(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols, self.mean.len());
        let k = self.components.rows;
        let mut out = Mat::zeros(x.rows, k);
        let mut centered = vec![0.0; x.cols];
        for i in 0..x.rows {
            for (c, (v, m)) in centered.iter_mut().zip(x.row(i).iter().zip(&self.mean)) {
                *c = v - m;
            }
            for j in 0..k {
                out[(i, j)] = dot(&centered, self.components.row(j));
            }
        }
        out
    }
}

/// Dominant eigenpair of a symmetric matrix by power iteration.
fn power_iteration(a: &Mat, rng: &mut Pcg32) -> (Vec<f64>, f64) {
    let d = a.rows;
    let mut v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let nv = norm2(&v).max(1e-30);
    v.iter_mut().for_each(|x| *x /= nv);
    let mut lam = 0.0;
    for _ in 0..500 {
        let w = a.matvec(&v);
        let nw = norm2(&w);
        if nw < 1e-300 {
            // zero matrix (fully deflated): any unit vector, eigenvalue 0
            return (v, 0.0);
        }
        let v_new: Vec<f64> = w.iter().map(|x| x / nw).collect();
        let lam_new = dot(&v_new, &a.matvec(&v_new));
        let delta: f64 = v_new
            .iter()
            .zip(&v)
            .map(|(a, b)| (a - b).abs().min((a + b).abs()))
            .fold(0.0, f64::max);
        v = v_new;
        lam = lam_new;
        if delta < 1e-12 {
            break;
        }
    }
    (v, lam)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_dominant_axis() {
        // points along direction (3,4)/5 with small orthogonal noise
        let mut rng = Pcg32::seeded(99);
        let dir = [0.6, 0.8];
        let orth = [-0.8, 0.6];
        let rows: Vec<Vec<f64>> = (0..500)
            .map(|_| {
                let t = rng.normal() * 10.0;
                let s = rng.normal() * 0.1;
                vec![t * dir[0] + s * orth[0], t * dir[1] + s * orth[1]]
            })
            .collect();
        let x = Mat::from_rows(&rows);
        let pca = Pca::fit(&x, 2, 1);
        let c0 = pca.components.row(0);
        let cosine = (c0[0] * dir[0] + c0[1] * dir[1]).abs();
        assert!(cosine > 0.999, "pc1 {c0:?} not aligned with {dir:?}");
        assert!(pca.explained_variance[0] > 50.0 * pca.explained_variance[1]);
    }

    #[test]
    fn transform_centers_data() {
        let x = Mat::from_rows(&[
            vec![1.0, 0.0],
            vec![2.0, 0.0],
            vec![3.0, 0.0],
        ]);
        let pca = Pca::fit(&x, 1, 2);
        let proj = pca.transform(&x);
        let mean: f64 = (0..3).map(|i| proj[(i, 0)]).sum::<f64>() / 3.0;
        assert!(mean.abs() < 1e-9);
    }

    #[test]
    fn components_are_orthonormal() {
        let mut rng = Pcg32::seeded(4);
        let rows: Vec<Vec<f64>> =
            (0..200).map(|_| (0..5).map(|_| rng.normal()).collect()).collect();
        let x = Mat::from_rows(&rows);
        let pca = Pca::fit(&x, 3, 3);
        for i in 0..3 {
            for j in 0..3 {
                let d = dot(pca.components.row(i), pca.components.row(j));
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((d - expect).abs() < 1e-6, "({i},{j}) dot={d}");
            }
        }
    }
}
