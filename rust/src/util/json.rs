//! Minimal JSON substrate (serde is not in the offline registry).
//!
//! Supports the full JSON grammar we exchange with the python compile path:
//! objects, arrays, strings (with escapes), numbers, booleans, null. The
//! writer emits deterministic output (object keys in insertion order).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps key order deterministic for tests; python emits sorted
    /// keys too (json.dumps(..., sort_keys=True)).
    Obj(BTreeMap<String, Json>),
}

/// Error with byte offset into the input.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]`, returning Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array of f64s (errors collapsed to None).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        Some(self.as_f64_vec()?.into_iter().map(|v| v as f32).collect())
    }

    // ---- construction helpers --------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // NOTE: surrogate pairs are not needed for our
                            // ASCII-only interchange; reject them explicitly.
                            let c = char::from_u32(cp)
                                .ok_or_else(|| self.err("surrogate in \\u escape"))?;
                            out.push(c);
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let s = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x"));
        assert_eq!(*v.get("c"), Json::Null);
        assert_eq!(*v.get("missing"), Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\"}", "tru", "1.2.3", "\"unterminated", "[1] x"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"s":"he\"llo","t":true},"z":null}"#;
        let v = Json::parse(src).unwrap();
        let printed = v.to_string();
        let v2 = Json::parse(&printed).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn roundtrip_random_values() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(123);
        fn gen(rng: &mut Pcg32, depth: usize) -> Json {
            match if depth == 0 { rng.index(4) } else { rng.index(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.f64() < 0.5),
                2 => Json::Num((rng.f64() * 2000.0 - 1000.0 * 64.0).round() / 64.0),
                3 => Json::Str(format!("k{}", rng.next_u32())),
                4 => Json::Arr((0..rng.index(4)).map(|_| gen(rng, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..rng.index(4))
                        .map(|i| (format!("f{i}"), gen(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        for _ in 0..200 {
            let v = gen(&mut rng, 3);
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"xs": [1, 2, 3], "n": 4}"#).unwrap();
        assert_eq!(v.get("xs").as_f64_vec().unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(v.get("n").as_usize(), Some(4));
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
    }
}
