//! Deterministic fault injection for chaos-testing the coordinator.
//!
//! A [`FaultPlan`] is a small set of rules bound to named *sites* — fixed
//! points in the service where a fault can be injected: the engine sampler
//! ([`FaultSite::EngineSample`], checked at `Session::search_ctx` entry and
//! before the batcher's `sample_runtime` call), the batched evaluator
//! ([`FaultSite::BatchEval`]), worker startup ([`FaultSite::WorkerStart`],
//! checked before a supervised engine worker builds its `Session`), and job
//! finalization ([`FaultSite::Finalize`], checked at the top of
//! `JobRegistry::finalize`). Each rule fires a [`FaultAction`]: a panic, a
//! delay, or an error return.
//!
//! Determinism is the point: rules fire on exact per-site *hit indices*
//! (every site keeps an atomic occurrence counter), and probabilistic
//! thinning (`one_in`) draws its coin from [`rng::derive`] over the plan
//! seed and the hit index — two runs with the same plan, seed, and request
//! sequence inject the same faults at the same places. `tests/
//! chaos_coordinator.rs` leans on this to script worker crashes and
//! recoveries without any real flakiness.
//!
//! Plans are **off by default**: the coordinator carries an
//! `Option<Arc<FaultPlan>>` (via `ServiceConfig`) that is `None` outside
//! chaos tests, so production paths pay one pointer check. CI enables a
//! delay-only plan for the registry stress suite through the
//! [`ENV_PLAN`] / [`ENV_SEED`] environment variables (see
//! `docs/INVARIANTS.md` for the site table and how to add a site).

use crate::util::rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Environment variable holding a [`FaultPlan::parse`] spec; empty or
/// unset means no plan.
pub const ENV_PLAN: &str = "DIFFAXE_FAULT_PLAN";
/// Environment variable overriding the plan seed (default `0x5eed`).
pub const ENV_SEED: &str = "DIFFAXE_FAULT_SEED";

/// A named injection point. Adding a site means adding a variant here
/// (plus [`FaultSite::ALL`] / [`FaultSite::name`]), documenting it in the
/// site table in `docs/INVARIANTS.md`, and calling
/// [`FaultPlan::check`] at the new code location.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Engine sampling: `Session::search_ctx` entry and the continuous
    /// batcher's `sample_runtime` call.
    EngineSample,
    /// The batched simulator/evaluator inside the gen-batch flush.
    BatchEval,
    /// Supervised worker startup, before the worker builds its `Session`.
    WorkerStart,
    /// `JobRegistry::finalize` entry. Error actions have no return path
    /// here and are ignored; panic and delay apply.
    Finalize,
}

impl FaultSite {
    /// Every site, in counter-index order.
    pub const ALL: [FaultSite; 4] = [
        FaultSite::EngineSample,
        FaultSite::BatchEval,
        FaultSite::WorkerStart,
        FaultSite::Finalize,
    ];

    /// Stable spec/diagnostic name.
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::EngineSample => "engine-sample",
            FaultSite::BatchEval => "batch-eval",
            FaultSite::WorkerStart => "worker-start",
            FaultSite::Finalize => "finalize",
        }
    }

    /// Inverse of [`FaultSite::name`].
    pub fn from_name(name: &str) -> Option<FaultSite> {
        Self::ALL.into_iter().find(|s| s.name() == name)
    }

    fn index(self) -> usize {
        match self {
            FaultSite::EngineSample => 0,
            FaultSite::BatchEval => 1,
            FaultSite::WorkerStart => 2,
            FaultSite::Finalize => 3,
        }
    }
}

impl std::fmt::Display for FaultSite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// What a firing rule does at its site.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultAction {
    /// Panic with `injected fault at <site>: <msg>`.
    Panic(String),
    /// Sleep for the given number of milliseconds, then continue.
    DelayMs(u64),
    /// Return `Err("injected fault at <site>: <msg>")` from
    /// [`FaultPlan::check`].
    Error(String),
}

/// One injection rule: a site, a hit window, optional seeded thinning,
/// and an action.
#[derive(Debug, Clone)]
pub struct FaultRule {
    pub site: FaultSite,
    /// First per-site hit index (0-based) the rule can fire on.
    pub from: u64,
    /// Number of consecutive hit indices in the window (`u64::MAX` =
    /// unbounded).
    pub count: u64,
    /// Probabilistic thinning: fire on roughly one in `one_in` window
    /// hits, decided deterministically from the plan seed and the hit
    /// index. `1` (or `0`) means every window hit fires.
    pub one_in: u64,
    pub action: FaultAction,
}

impl FaultRule {
    /// Fire exactly once, on hit `hit`.
    pub fn at(site: FaultSite, hit: u64, action: FaultAction) -> FaultRule {
        FaultRule { site, from: hit, count: 1, one_in: 1, action }
    }

    /// Fire on every hit in `from .. from + count`.
    pub fn window(site: FaultSite, from: u64, count: u64, action: FaultAction) -> FaultRule {
        FaultRule { site, from, count, one_in: 1, action }
    }

    /// Fire on ~one in `one_in` hits, forever, seeded by the plan.
    pub fn thinned(site: FaultSite, one_in: u64, action: FaultAction) -> FaultRule {
        FaultRule { site, from: 0, count: u64::MAX, one_in, action }
    }
}

/// A deterministic injection schedule. See the module docs.
#[derive(Debug, Default)]
pub struct FaultPlan {
    seed: u64,
    rules: Vec<FaultRule>,
    hits: [AtomicU64; 4],
}

impl FaultPlan {
    /// An empty plan (no rules fire) with the given thinning seed.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, ..FaultPlan::default() }
    }

    /// Builder: append a rule.
    pub fn rule(mut self, rule: FaultRule) -> FaultPlan {
        self.rules.push(rule);
        self
    }

    /// Parse a plan spec: `;`-separated rules of the form
    /// `site:action[@window]` where
    ///
    /// * `site` is a [`FaultSite::name`],
    /// * `action` is `panic[=msg]`, `error[=msg]`, or `delay=MS`,
    /// * `window` is `N` (hit N only), `N+C` (hits `N..N+C`), or `1/K`
    ///   (seeded one-in-K thinning over every hit); omitted = every hit.
    ///
    /// Example: `finalize:delay=2@1/4;worker-start:panic=boom@1+2`.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::new(seed);
        for part in spec.split(';').map(str::trim).filter(|p| !p.is_empty()) {
            let (site_s, rest) =
                part.split_once(':').ok_or_else(|| format!("rule {part:?}: missing `:`"))?;
            let site = FaultSite::from_name(site_s.trim())
                .ok_or_else(|| format!("rule {part:?}: unknown site {site_s:?}"))?;
            let (action_s, window_s) = match rest.split_once('@') {
                Some((a, w)) => (a.trim(), Some(w.trim())),
                None => (rest.trim(), None),
            };
            let (name, arg) = match action_s.split_once('=') {
                Some((n, a)) => (n.trim(), Some(a.trim())),
                None => (action_s, None),
            };
            let action = match name {
                "panic" => FaultAction::Panic(arg.unwrap_or("injected panic").to_string()),
                "error" => FaultAction::Error(arg.unwrap_or("injected error").to_string()),
                "delay" => FaultAction::DelayMs(
                    arg.ok_or_else(|| format!("rule {part:?}: delay needs `=MS`"))?
                        .parse::<u64>()
                        .map_err(|e| format!("rule {part:?}: bad delay: {e}"))?,
                ),
                other => return Err(format!("rule {part:?}: unknown action {other:?}")),
            };
            let rule = match window_s {
                None => FaultRule::window(site, 0, u64::MAX, action),
                Some(w) => {
                    if let Some((one, k)) = w.split_once('/') {
                        if one.trim() != "1" {
                            return Err(format!("rule {part:?}: thinning window is `1/K`"));
                        }
                        let k = k
                            .trim()
                            .parse::<u64>()
                            .map_err(|e| format!("rule {part:?}: bad window: {e}"))?;
                        FaultRule::thinned(site, k, action)
                    } else if let Some((from, count)) = w.split_once('+') {
                        FaultRule::window(
                            site,
                            from.trim()
                                .parse::<u64>()
                                .map_err(|e| format!("rule {part:?}: bad window: {e}"))?,
                            count
                                .trim()
                                .parse::<u64>()
                                .map_err(|e| format!("rule {part:?}: bad window: {e}"))?,
                            action,
                        )
                    } else {
                        FaultRule::at(
                            site,
                            w.parse::<u64>()
                                .map_err(|e| format!("rule {part:?}: bad window: {e}"))?,
                            action,
                        )
                    }
                }
            };
            plan.rules.push(rule);
        }
        Ok(plan)
    }

    /// Build a plan from [`ENV_PLAN`] / [`ENV_SEED`]; `None` when the
    /// variable is unset or empty. A malformed spec panics loudly — a CI
    /// job with a broken plan should fail, not silently run fault-free.
    pub fn from_env() -> Option<Arc<FaultPlan>> {
        let spec = std::env::var(ENV_PLAN).ok()?;
        if spec.trim().is_empty() {
            return None;
        }
        let seed = std::env::var(ENV_SEED)
            .ok()
            .and_then(|s| s.trim().parse::<u64>().ok())
            .unwrap_or(0x5eed);
        match FaultPlan::parse(&spec, seed) {
            Ok(p) => Some(Arc::new(p)),
            Err(e) => panic!("bad {ENV_PLAN}: {e}"),
        }
    }

    /// Record one hit at `site` and run every rule that fires on it.
    /// Delays sleep then continue; errors return `Err`; panics panic.
    pub fn check(&self, site: FaultSite) -> Result<(), String> {
        let hit = self.hits[site.index()].fetch_add(1, Ordering::Relaxed);
        for r in &self.rules {
            if r.site != site || hit < r.from || hit - r.from >= r.count {
                continue;
            }
            if r.one_in > 1 {
                let coin = rng::derive(self.seed, ((site.index() as u64) << 32) | hit);
                if coin % r.one_in != 0 {
                    continue;
                }
            }
            match &r.action {
                FaultAction::DelayMs(ms) => std::thread::sleep(Duration::from_millis(*ms)),
                FaultAction::Error(msg) => return Err(format!("injected fault at {site}: {msg}")),
                FaultAction::Panic(msg) => panic!("injected fault at {site}: {msg}"),
            }
        }
        Ok(())
    }

    /// How many times `site` has been hit so far.
    pub fn hit_count(&self, site: FaultSite) -> u64 {
        self.hits[site.index()].load(Ordering::Relaxed)
    }
}

/// Render a caught panic payload (from `catch_unwind` or a joined
/// thread) as a message, mirroring the forwarding idiom in `dse/eval.rs`.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    #[test]
    fn empty_plan_never_fires() {
        let p = FaultPlan::new(1);
        for _ in 0..100 {
            assert!(p.check(FaultSite::Finalize).is_ok());
        }
        assert_eq!(p.hit_count(FaultSite::Finalize), 100);
        assert_eq!(p.hit_count(FaultSite::BatchEval), 0);
    }

    #[test]
    fn windowed_error_fires_on_exact_hits() {
        let p = FaultPlan::new(1).rule(FaultRule::window(
            FaultSite::EngineSample,
            2,
            2,
            FaultAction::Error("boom".into()),
        ));
        let fired: Vec<bool> =
            (0..6).map(|_| p.check(FaultSite::EngineSample).is_err()).collect();
        assert_eq!(fired, [false, false, true, true, false, false]);
        // other sites untouched
        assert!(p.check(FaultSite::WorkerStart).is_ok());
    }

    #[test]
    fn error_message_names_the_site() {
        let p = FaultPlan::new(1)
            .rule(FaultRule::at(FaultSite::BatchEval, 0, FaultAction::Error("wire down".into())));
        let err = p.check(FaultSite::BatchEval).unwrap_err();
        assert_eq!(err, "injected fault at batch-eval: wire down");
    }

    #[test]
    fn panic_action_panics_with_message() {
        let p = FaultPlan::new(1)
            .rule(FaultRule::at(FaultSite::WorkerStart, 0, FaultAction::Panic("melt".into())));
        let caught = catch_unwind(AssertUnwindSafe(|| p.check(FaultSite::WorkerStart)));
        let msg = panic_message(caught.unwrap_err().as_ref());
        assert_eq!(msg, "injected fault at worker-start: melt");
    }

    #[test]
    fn thinning_is_deterministic_across_plans() {
        let mk = || {
            FaultPlan::new(77).rule(FaultRule::thinned(
                FaultSite::Finalize,
                3,
                FaultAction::Error("thin".into()),
            ))
        };
        let (a, b) = (mk(), mk());
        let fa: Vec<bool> = (0..64).map(|_| a.check(FaultSite::Finalize).is_err()).collect();
        let fb: Vec<bool> = (0..64).map(|_| b.check(FaultSite::Finalize).is_err()).collect();
        assert_eq!(fa, fb);
        let n = fa.iter().filter(|&&f| f).count();
        assert!(n > 0 && n < 64, "thinning should fire sometimes, not always ({n}/64)");
    }

    #[test]
    fn parse_roundtrips_the_documented_forms() {
        let p = FaultPlan::parse(
            "finalize:delay=2@1/4; worker-start:panic=boom@1+2; engine-sample:error@5; \
             batch-eval:panic",
            9,
        )
        .unwrap();
        assert_eq!(p.rules.len(), 4);
        assert_eq!(p.rules[0].site, FaultSite::Finalize);
        assert_eq!(p.rules[0].action, FaultAction::DelayMs(2));
        assert_eq!(p.rules[0].one_in, 4);
        assert_eq!((p.rules[1].from, p.rules[1].count), (1, 2));
        assert_eq!(p.rules[2].action, FaultAction::Error("injected error".into()));
        assert_eq!((p.rules[3].from, p.rules[3].count, p.rules[3].one_in), (0, u64::MAX, 1));

        for bad in [
            "finalize",                 // missing action
            "nowhere:panic",            // unknown site
            "finalize:explode",         // unknown action
            "finalize:delay",           // delay needs ms
            "finalize:panic@2/3",       // thinning must be 1/K
            "finalize:panic@x",         // bad number
        ] {
            assert!(FaultPlan::parse(bad, 1).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn delay_action_returns_ok() {
        let p = FaultPlan::new(1)
            .rule(FaultRule::at(FaultSite::Finalize, 0, FaultAction::DelayMs(1)));
        assert!(p.check(FaultSite::Finalize).is_ok());
    }

    #[test]
    fn site_names_roundtrip() {
        for s in FaultSite::ALL {
            assert_eq!(FaultSite::from_name(s.name()), Some(s));
        }
        assert_eq!(FaultSite::from_name("nope"), None);
    }
}
