//! Small statistics substrate: summaries, percentiles, histograms, timers.

use std::time::Instant;

/// Summary statistics over a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

/// Compute mean/std/min/max of a slice. Empty input yields NaNs with n=0.
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary { n: 0, mean: f64::NAN, std: f64::NAN, min: f64::NAN, max: f64::NAN };
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    Summary { n: xs.len(), mean, std: var.sqrt(), min, max }
}

/// Percentile with linear interpolation (q in [0, 100]). Sorts a copy.
/// NaN-total: `total_cmp` orders NaNs after every number instead of
/// panicking, so a NaN-bearing sample degrades to a NaN-high percentile
/// rather than aborting a metrics scrape.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&q), "q out of range: {q}");
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    let pos = q / 100.0 * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

/// Percentile-based bin edges dividing data into `n_bins` equal-mass bins.
/// Returns `n_bins + 1` edges (first = min, last = max).
pub fn percentile_edges(xs: &[f64], n_bins: usize) -> Vec<f64> {
    assert!(n_bins >= 1);
    (0..=n_bins)
        .map(|i| percentile(xs, 100.0 * i as f64 / n_bins as f64))
        .collect()
}

/// Assign a value to a percentile bin given edges from [`percentile_edges`].
/// Values outside the range clamp to the first/last bin.
pub fn bin_index(edges: &[f64], x: f64) -> usize {
    let n_bins = edges.len() - 1;
    for i in 0..n_bins {
        if x <= edges[i + 1] {
            return i;
        }
    }
    n_bins - 1
}

/// Fixed-bin latency histogram (microseconds, exponential buckets), used by
/// the coordinator's metrics.
#[derive(Debug, Clone)]
pub struct LatencyHist {
    /// bucket i covers [2^i, 2^(i+1)) microseconds; bucket 0 covers [0, 2).
    counts: Vec<u64>,
    total: u64,
    sum_us: f64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        LatencyHist { counts: vec![0; 40], total: 0, sum_us: 0.0 }
    }

    pub fn record_us(&mut self, us: f64) {
        let b = if us < 2.0 { 0 } else { (us.log2().floor() as usize).min(self.counts.len() - 1) };
        self.counts[b] += 1;
        self.total += 1;
        self.sum_us += us;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.sum_us / self.total as f64
        }
    }

    /// Approximate percentile from the exponential buckets (upper bound of
    /// the containing bucket).
    pub fn percentile_us(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let target = (q / 100.0 * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return (1u64 << (i + 1)) as f64;
            }
        }
        f64::INFINITY
    }
}

/// Wall-clock timer for the hand-rolled bench harness.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// Geometric mean (for normalized-metric aggregation across workloads).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles_interp() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_survives_nan() {
        // regression: this used to panic in partial_cmp(..).unwrap().
        // total_cmp sorts positive NaN after every number, so low
        // percentiles stay numeric and the top ones surface the NaN.
        let xs = [3.0, f64::NAN, 1.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    fn percentile_bins_balanced() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let edges = percentile_edges(&xs, 4);
        assert_eq!(edges.len(), 5);
        let mut counts = [0usize; 4];
        for &x in &xs {
            counts[bin_index(&edges, x)] += 1;
        }
        for c in counts {
            assert!((230..=270).contains(&c), "unbalanced: {counts:?}");
        }
    }

    #[test]
    fn bin_index_clamps() {
        let edges = vec![0.0, 1.0, 2.0];
        assert_eq!(bin_index(&edges, -5.0), 0);
        assert_eq!(bin_index(&edges, 99.0), 1);
    }

    #[test]
    fn latency_hist() {
        let mut h = LatencyHist::new();
        for _ in 0..90 {
            h.record_us(100.0);
        }
        for _ in 0..10 {
            h.record_us(10_000.0);
        }
        assert_eq!(h.count(), 100);
        assert!(h.mean_us() > 100.0 && h.mean_us() < 10_000.0);
        assert!(h.percentile_us(50.0) <= 256.0);
        assert!(h.percentile_us(99.0) >= 8192.0);
    }

    #[test]
    fn geomean_of_equal_ratios() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }
}
