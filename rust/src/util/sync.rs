//! Ranked-lock synchronization facade — the only place in the crate that
//! touches `std::sync::{Mutex, RwLock}` directly (machine-enforced by the
//! `raw-sync` rule of `diffaxe lint`; see `docs/INVARIANTS.md`).
//!
//! Every lock in the codebase is a [`TrackedMutex`] / [`TrackedRwLock`]
//! carrying a static *rank* from the [`rank`] table. In debug builds each
//! thread keeps a stack of the ranks it currently holds and asserts that
//! every new acquisition has a **strictly greater** rank than the deepest
//! lock already held. Any two code paths that acquire the same pair of
//! locks in opposite orders — the classic deadlock — therefore panic
//! deterministically in tests instead of deadlocking rarely in
//! production. Release builds compile the tracking away entirely: the
//! wrappers are a `&'static str` name, a `u32`, and the std primitive.
//!
//! # Poisoning
//!
//! The scattered `.lock().unwrap()` this facade replaced turned a panic
//! on *any* thread into cascading panics on every thread that later
//! touched the same lock. The facade maps poisoning to an explicit
//! policy instead ([`PoisonPolicy`]):
//!
//! * [`PoisonPolicy::Recover`] (the default) — take the guard from the
//!   `PoisonError` and continue. Every critical section in this repo
//!   computes values *before* mutating guarded state (registry
//!   transitions are guarded and idempotent, metrics are plain counters,
//!   cache shards are insert-only maps), so value-level invariants hold
//!   even when a panic unwound mid-section.
//! * [`PoisonPolicy::Abort`] — print the lock name and abort the
//!   process. For state where a torn write would be worse than dying
//!   (none today; the worker-fleet coordinator may want it).
//!
//! # Lock-rank table
//!
//! The authoritative table (what may be held while acquiring what) lives
//! in [`rank`] and is documented for humans in `docs/INVARIANTS.md`.

use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Static lock ranks: a lock may only be acquired while every lock the
/// thread already holds has a **strictly lower** rank. Gaps between
/// values are deliberate — new locks slot in without renumbering.
pub mod rank {
    /// The supervisor's shared dispatch queue
    /// ([`crate::coordinator::supervisor::Shared`]) — held across
    /// admission (queue-depth check + `JobRegistry::submit` + push must
    /// be atomic), so it ranks below the registry.
    pub const SUPERVISOR_QUEUE: u32 = 6;
    /// The supervisor's in-flight job slots
    /// ([`crate::coordinator::supervisor::Shared`]); pruning reads each
    /// tracked entry's terminal state, so it ranks below `JOB_CORE`.
    pub const SUPERVISOR_INFLIGHT: u32 = 8;
    /// [`crate::coordinator::service::JobRegistry`] inner table — taken
    /// first among the registry-path locks: it is held while touching
    /// individual job cores (`list`).
    pub const REGISTRY: u32 = 10;
    /// The watch reactor's subscription list
    /// ([`crate::coordinator::server`]) — the event thread holds it while
    /// polling each watched job's core, so it ranks below `JOB_CORE`.
    pub const WATCH_SUBS: u32 = 15;
    /// One job's mutable core ([`crate::coordinator::service::JobEntry`]).
    pub const JOB_CORE: u32 = 20;
    /// The connection-cap semaphore in [`crate::coordinator::server`].
    pub const CONN_SEMAPHORE: u32 = 30;
    /// [`crate::coordinator::metrics::Metrics`] — always a leaf on the
    /// registry paths (taken after cores are released, never before).
    pub const METRICS: u32 = 40;
    /// [`crate::dse::eval::WorkerPool`] job-queue sender.
    pub const POOL_SENDER: u32 = 50;
    /// [`crate::dse::eval::WorkerPool`] shared receiver (worker side).
    pub const POOL_RECEIVER: u32 = 51;
    /// One [`crate::dse::eval::EvalCache`] shard. All shards share this
    /// rank: strict increase means a thread can never nest two shards,
    /// which is exactly the invariant the striped design relies on.
    pub const EVAL_SHARD: u32 = 60;
    /// The process-wide [`crate::workload::model_workload`] memo.
    pub const WORKLOAD_MEMO: u32 = 70;
}

/// What a lock does when it observes poisoning (a panic on another
/// thread while the lock was held). See the module docs for the
/// rationale; the default is [`PoisonPolicy::Recover`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoisonPolicy {
    /// Take the guard out of the `PoisonError` and continue.
    Recover,
    /// Print the lock name and abort the process.
    Abort,
}

#[cfg(debug_assertions)]
mod held {
    use std::cell::RefCell;

    thread_local! {
        /// (rank, name) of every tracked lock this thread currently holds,
        /// in acquisition order.
        static HELD: RefCell<Vec<(u32, &'static str)>> = const { RefCell::new(Vec::new()) };
    }

    pub fn push(rank: u32, name: &'static str) {
        HELD.with(|h| {
            let mut h = h.borrow_mut();
            if let Some(&(top_rank, top_name)) = h.last() {
                assert!(
                    rank > top_rank,
                    "lock-order violation: acquiring {name:?} (rank {rank}) while holding \
                     {top_name:?} (rank {top_rank}) — ranks must strictly increase; see the \
                     lock-rank table in docs/INVARIANTS.md"
                );
            }
            h.push((rank, name));
        });
    }

    pub fn pop(rank: u32, name: &'static str) {
        HELD.with(|h| {
            let mut h = h.borrow_mut();
            // guards may in principle drop out of acquisition order; remove
            // the newest matching entry
            if let Some(pos) = h.iter().rposition(|&(r, n)| r == rank && n == name) {
                h.remove(pos);
            }
        });
    }
}

#[cfg(not(debug_assertions))]
mod held {
    pub fn push(_rank: u32, _name: &'static str) {}
    pub fn pop(_rank: u32, _name: &'static str) {}
}

// ---------------------------------------------------------------------------
// TrackedMutex
// ---------------------------------------------------------------------------

/// A [`Mutex`] with a static lock rank and an explicit poison policy.
#[derive(Debug)]
pub struct TrackedMutex<T> {
    name: &'static str,
    rank: u32,
    policy: PoisonPolicy,
    inner: Mutex<T>,
}

impl<T> TrackedMutex<T> {
    /// A lock named for diagnostics, ranked per the [`rank`] table.
    pub fn new(name: &'static str, rank: u32, value: T) -> TrackedMutex<T> {
        Self::with_policy(name, rank, PoisonPolicy::Recover, value)
    }

    /// [`TrackedMutex::new`] with an explicit [`PoisonPolicy`].
    pub fn with_policy(
        name: &'static str,
        rank: u32,
        policy: PoisonPolicy,
        value: T,
    ) -> TrackedMutex<T> {
        TrackedMutex { name, rank, policy, inner: Mutex::new(value) }
    }

    /// Acquire, asserting rank order in debug builds. Poisoning is
    /// handled per the lock's [`PoisonPolicy`] — callers never see it.
    pub fn lock(&self) -> TrackedMutexGuard<'_, T> {
        held::push(self.rank, self.name);
        let guard = match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => self.on_poison(poisoned),
        };
        TrackedMutexGuard { guard: Some(guard), lock: self }
    }

    /// Non-blocking acquire; `None` if the lock is held elsewhere.
    pub fn try_lock(&self) -> Option<TrackedMutexGuard<'_, T>> {
        match self.inner.try_lock() {
            Ok(g) => {
                held::push(self.rank, self.name);
                Some(TrackedMutexGuard { guard: Some(g), lock: self })
            }
            Err(std::sync::TryLockError::WouldBlock) => None,
            Err(std::sync::TryLockError::Poisoned(poisoned)) => {
                held::push(self.rank, self.name);
                let guard = self.on_poison(poisoned);
                Some(TrackedMutexGuard { guard: Some(guard), lock: self })
            }
        }
    }

    /// This lock's diagnostic name.
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// This lock's static rank.
    pub fn rank(&self) -> u32 {
        self.rank
    }

    fn on_poison<'a>(
        &self,
        poisoned: std::sync::PoisonError<MutexGuard<'a, T>>,
    ) -> MutexGuard<'a, T> {
        match self.policy {
            PoisonPolicy::Recover => poisoned.into_inner(),
            PoisonPolicy::Abort => {
                eprintln!(
                    "fatal: lock {:?} poisoned (panic on another thread mid-section); \
                     policy is abort",
                    self.name
                );
                std::process::abort();
            }
        }
    }
}

/// Guard for [`TrackedMutex`]; pops the rank entry on drop.
pub struct TrackedMutexGuard<'a, T> {
    /// `None` only transiently inside [`TrackedMutexGuard::wait`].
    guard: Option<MutexGuard<'a, T>>,
    lock: &'a TrackedMutex<T>,
}

impl<'a, T> TrackedMutexGuard<'a, T> {
    /// Block on `cv` until notified, releasing and reacquiring the
    /// underlying mutex exactly like [`Condvar::wait`]. The rank entry
    /// stays on the thread's stack across the wait: the thread reoccupies
    /// the same ordering position when it wakes, so locks it still holds
    /// below this one keep their relative order.
    pub fn wait(mut self, cv: &Condvar) -> TrackedMutexGuard<'a, T> {
        let inner = self.guard.take().expect("guard present outside wait");
        let inner = match cv.wait(inner) {
            Ok(g) => g,
            Err(poisoned) => self.lock.on_poison(poisoned),
        };
        self.guard = Some(inner);
        self
    }

    /// [`Condvar::wait_timeout`] under the same rank semantics as
    /// [`TrackedMutexGuard::wait`]. Returns the guard and whether the
    /// wait timed out.
    pub fn wait_timeout(
        mut self,
        cv: &Condvar,
        dur: std::time::Duration,
    ) -> (TrackedMutexGuard<'a, T>, bool) {
        let inner = self.guard.take().expect("guard present outside wait");
        let (inner, timeout) = match cv.wait_timeout(inner, dur) {
            Ok((g, t)) => (g, t.timed_out()),
            Err(poisoned) => {
                let (g, t) = poisoned.into_inner();
                (self.lock.on_poison(std::sync::PoisonError::new(g)), t.timed_out())
            }
        };
        self.guard = Some(inner);
        (self, timeout)
    }
}

impl<T> std::ops::Deref for TrackedMutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.guard.as_ref().expect("guard present")
    }
}

impl<T> std::ops::DerefMut for TrackedMutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.guard.as_mut().expect("guard present")
    }
}

impl<T> Drop for TrackedMutexGuard<'_, T> {
    fn drop(&mut self) {
        held::pop(self.lock.rank, self.lock.name);
    }
}

// ---------------------------------------------------------------------------
// TrackedRwLock
// ---------------------------------------------------------------------------

/// An [`RwLock`] with a static lock rank and an explicit poison policy.
/// Read and write acquisitions occupy the same rank slot: a thread
/// holding a read guard cannot take the same lock again (std makes no
/// reentrancy guarantee), and the strict-increase assertion catches the
/// attempt in debug builds.
#[derive(Debug)]
pub struct TrackedRwLock<T> {
    name: &'static str,
    rank: u32,
    policy: PoisonPolicy,
    inner: RwLock<T>,
}

impl<T> TrackedRwLock<T> {
    pub fn new(name: &'static str, rank: u32, value: T) -> TrackedRwLock<T> {
        Self::with_policy(name, rank, PoisonPolicy::Recover, value)
    }

    pub fn with_policy(
        name: &'static str,
        rank: u32,
        policy: PoisonPolicy,
        value: T,
    ) -> TrackedRwLock<T> {
        TrackedRwLock { name, rank, policy, inner: RwLock::new(value) }
    }

    /// Shared acquire under the rank discipline.
    pub fn read(&self) -> TrackedReadGuard<'_, T> {
        held::push(self.rank, self.name);
        let guard = match self.inner.read() {
            Ok(g) => g,
            Err(poisoned) => match self.policy {
                PoisonPolicy::Recover => poisoned.into_inner(),
                PoisonPolicy::Abort => self.abort(),
            },
        };
        TrackedReadGuard { guard, rank: self.rank, name: self.name }
    }

    /// Exclusive acquire under the rank discipline.
    pub fn write(&self) -> TrackedWriteGuard<'_, T> {
        held::push(self.rank, self.name);
        let guard = match self.inner.write() {
            Ok(g) => g,
            Err(poisoned) => match self.policy {
                PoisonPolicy::Recover => poisoned.into_inner(),
                PoisonPolicy::Abort => self.abort(),
            },
        };
        TrackedWriteGuard { guard, rank: self.rank, name: self.name }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn rank(&self) -> u32 {
        self.rank
    }

    fn abort(&self) -> ! {
        eprintln!(
            "fatal: lock {:?} poisoned (panic on another thread mid-section); policy is abort",
            self.name
        );
        std::process::abort();
    }
}

/// Shared guard for [`TrackedRwLock`].
pub struct TrackedReadGuard<'a, T> {
    guard: RwLockReadGuard<'a, T>,
    rank: u32,
    name: &'static str,
}

impl<T> std::ops::Deref for TrackedReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.guard
    }
}

impl<T> Drop for TrackedReadGuard<'_, T> {
    fn drop(&mut self) {
        held::pop(self.rank, self.name);
    }
}

/// Exclusive guard for [`TrackedRwLock`].
pub struct TrackedWriteGuard<'a, T> {
    guard: RwLockWriteGuard<'a, T>,
    rank: u32,
    name: &'static str,
}

impl<T> std::ops::Deref for TrackedWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.guard
    }
}

impl<T> std::ops::DerefMut for TrackedWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.guard
    }
}

impl<T> Drop for TrackedWriteGuard<'_, T> {
    fn drop(&mut self) {
        held::pop(self.rank, self.name);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_round_trips_values() {
        let m = TrackedMutex::new("test.value", 10, 41);
        *m.lock() += 1;
        assert_eq!(*m.lock(), 42);
        assert_eq!(m.name(), "test.value");
        assert_eq!(m.rank(), 10);
    }

    #[test]
    fn ascending_ranks_nest() {
        let a = TrackedMutex::new("test.a", 10, ());
        let b = TrackedMutex::new("test.b", 20, ());
        let c = TrackedMutex::new("test.c", 30, ());
        let _ga = a.lock();
        let _gb = b.lock();
        let _gc = c.lock();
    }

    #[test]
    fn sequential_reacquisition_at_lower_rank_is_fine() {
        let a = TrackedMutex::new("test.a", 10, ());
        let b = TrackedMutex::new("test.b", 20, ());
        {
            let _ga = a.lock();
            let _gb = b.lock();
        }
        // everything released: low rank is legal again
        let _gb = b.lock();
        drop(_gb);
        let _ga = a.lock();
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "lock-order violation")]
    fn descending_ranks_panic_in_debug() {
        let a = TrackedMutex::new("test.low", 10, ());
        let b = TrackedMutex::new("test.high", 20, ());
        let _gb = b.lock();
        let _ga = a.lock(); // 10 while holding 20: inversion
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "lock-order violation")]
    fn same_rank_nesting_panics_in_debug() {
        // two eval-cache shards share one rank: nesting them is the striped
        // design's forbidden pattern
        let s1 = TrackedMutex::new("test.shard", 60, ());
        let s2 = TrackedMutex::new("test.shard", 60, ());
        let _g1 = s1.lock();
        let _g2 = s2.lock();
    }

    #[test]
    fn try_lock_contended_returns_none() {
        let m = TrackedMutex::new("test.try", 10, ());
        let g = m.lock();
        assert!(m.try_lock().is_none());
        drop(g);
        assert!(m.try_lock().is_some());
    }

    #[test]
    fn poison_recovers_by_default() {
        let m = Arc::new(TrackedMutex::new("test.poison", 10, 7));
        let m2 = m.clone();
        // the panicking thread poisons the std mutex underneath
        let t = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison it");
        });
        assert!(t.join().is_err());
        // Recover policy: the value is still reachable
        assert_eq!(*m.lock(), 7);
    }

    #[test]
    fn condvar_wait_wakes() {
        let m = Arc::new(TrackedMutex::new("test.cv", 10, false));
        let cv = Arc::new(Condvar::new());
        let (m2, cv2) = (m.clone(), cv.clone());
        let t = std::thread::spawn(move || {
            let mut g = m2.lock();
            while !*g {
                g = g.wait(&cv2);
            }
            *g
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        *m.lock() = true;
        cv.notify_all();
        assert!(t.join().unwrap());
    }

    #[test]
    fn condvar_wait_timeout_reports_timeout() {
        let m = TrackedMutex::new("test.cvt", 10, ());
        let cv = Condvar::new();
        let g = m.lock();
        let (_g, timed_out) = g.wait_timeout(&cv, std::time::Duration::from_millis(5));
        assert!(timed_out);
    }

    #[test]
    fn rwlock_read_write_round_trip() {
        let l = TrackedRwLock::new("test.rw", 10, 5u32);
        assert_eq!(*l.read(), 5);
        *l.write() = 9;
        assert_eq!(*l.read(), 9);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "lock-order violation")]
    fn rwlock_participates_in_rank_order() {
        let rw = TrackedRwLock::new("test.rw.low", 10, ());
        let m = TrackedMutex::new("test.m.high", 20, ());
        let _gm = m.lock();
        let _gr = rw.read(); // 10 while holding 20
    }
}
