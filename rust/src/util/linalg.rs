//! Dense linear-algebra substrate: the pieces the Gaussian-process baseline
//! (Cholesky solves) and PCA (covariance, power iteration) need. Row-major
//! `Mat` over f64; sizes here are small (≤ a few hundred), so clarity wins
//! over blocking.

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        assert!(!rows.is_empty());
        let cols = rows[0].len();
        assert!(rows.iter().all(|r| r.len() == cols), "ragged rows");
        Mat { rows: rows.len(), cols, data: rows.concat() }
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// `self * v` (matrix-vector).
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(v).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// `self * other` (matrix-matrix).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Lower-triangular Cholesky factor of a symmetric positive-definite matrix.
/// Returns `None` if the matrix is not (numerically) SPD.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Solve `L y = b` (forward substitution), `L` lower-triangular.
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * y[k];
        }
        y[i] = s / l[(i, i)];
    }
    y
}

/// Solve `L^T x = y` (back substitution).
pub fn solve_upper_t(l: &Mat, y: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(y.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in (i + 1)..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Solve `A x = b` for SPD `A` via Cholesky.
pub fn solve_spd(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let l = cholesky(a)?;
    Some(solve_upper_t(&l, &solve_lower(&l, b)))
}

pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.matmul(&Mat::eye(2)), a);
        assert_eq!(Mat::eye(2).matmul(&a), a);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Pcg32::seeded(17);
        for _ in 0..20 {
            let n = 1 + rng.index(8);
            // A = B B^T + n*I is SPD
            let b = Mat {
                rows: n,
                cols: n,
                data: (0..n * n).map(|_| rng.normal()).collect(),
            };
            let mut a = b.matmul(&b.transpose());
            for i in 0..n {
                a[(i, i)] += n as f64;
            }
            let l = cholesky(&a).expect("SPD");
            let rec = l.matmul(&l.transpose());
            for i in 0..n {
                for j in 0..n {
                    assert!((rec[(i, j)] - a[(i, j)]).abs() < 1e-9);
                }
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn spd_solve_random() {
        let mut rng = Pcg32::seeded(23);
        for _ in 0..20 {
            let n = 1 + rng.index(10);
            let b = Mat {
                rows: n,
                cols: n,
                data: (0..n * n).map(|_| rng.normal()).collect(),
            };
            let mut a = b.matmul(&b.transpose());
            for i in 0..n {
                a[(i, i)] += n as f64;
            }
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let rhs = a.matvec(&x_true);
            let x = solve_spd(&a, &rhs).unwrap();
            for (xi, ti) in x.iter().zip(&x_true) {
                assert!((xi - ti).abs() < 1e-8, "{x:?} vs {x_true:?}");
            }
        }
    }
}
