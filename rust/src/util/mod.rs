//! Cross-cutting substrates: PRNG, JSON, statistics, linear algebra, PCA,
//! table rendering. These exist as first-class modules because the offline
//! crate registry carries only the `xla` dependency closure (no serde / rand
//! / criterion), so the library provides its own implementations.

pub mod bench;
pub mod bench_history;
pub mod fault;
pub mod json;
pub mod linalg;
pub mod lint;
pub mod pca;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod table;
