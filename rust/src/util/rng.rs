//! Deterministic PRNG substrate.
//!
//! The offline crate registry only carries `rand_core`, so we implement a
//! small, well-understood generator ourselves: PCG32 (O'Neill 2014,
//! `PCG-XSH-RR 64/32`). Every stochastic component in the repo (dataset
//! sampling, baselines, property tests) takes an explicit [`Pcg32`] so runs
//! are reproducible from a single seed.

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit output with random rotation.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

/// One-way mix of `(seed, stream)` into a fresh 64-bit seed (SplitMix64
/// finalizer). This is the single seed-derivation function every public
/// search entry point uses: callers pass one `u64` seed and a logical
/// stream id (query index, class index, chunk counter, …) and get
/// decorrelated per-stream randomness without coordinating offsets.
pub fn derive(seed: u64, stream: u64) -> u64 {
    let mut z = seed ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// [`derive`] truncated to the 32-bit seeds the AOT sampler executables
/// take (top half — better mixed than the low bits of an LCG product).
pub fn derive_u32(seed: u64, stream: u64) -> u32 {
    (derive(seed, stream) >> 32) as u32
}

/// A generator on its own stream, decorrelated from every other
/// `(seed, stream)` pair: the canonical way to split one user-facing seed
/// into independent per-component RNGs.
pub fn split(seed: u64, stream: u64) -> Pcg32 {
    Pcg32::new(derive(seed, stream), stream)
}

impl Pcg32 {
    /// Create a generator from a seed and stream id (any values are valid).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor on stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output (two 32-bit draws).
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[lo, hi]` (inclusive). Uses Lemire-style rejection
    /// to avoid modulo bias.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "int_range: lo {lo} > hi {hi}");
        let span = (hi - lo) as u64 + 1;
        if span == 0 {
            // full u64 range
            return self.next_u64() as i64;
        }
        // rejection sampling on the top of the range
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + (v % span) as i64;
            }
        }
    }

    /// Uniform usize index in `[0, n)`. Panics if `n == 0`.
    pub fn index(&mut self, n: usize) -> usize {
        assert!(n > 0, "index: empty range");
        self.int_range(0, n as i64 - 1) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        // avoid log(0)
        let u1 = loop {
            let v = self.f64();
            if v > 0.0 {
                break v;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (floyd's algorithm when
    /// k << n, shuffle otherwise).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k {k} > n {n}");
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        } else {
            let mut chosen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.index(j + 1);
                let v = if chosen.contains(&t) { j } else { t };
                chosen.insert(v);
                out.push(v);
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_is_deterministic_and_stream_separated() {
        let mut a = split(42, 7);
        let mut b = split(42, 7);
        for _ in 0..64 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = split(42, 8);
        let same = (0..32).filter(|_| b.next_u32() == c.next_u32()).count();
        assert!(same < 4, "streams 7 and 8 should be decorrelated");
    }

    #[test]
    fn derive_changes_with_seed_and_stream() {
        assert_ne!(derive(1, 0), derive(2, 0));
        assert_ne!(derive(1, 0), derive(1, 1));
        assert_ne!(derive_u32(1, 0), derive_u32(1, 1));
        // stable across calls
        assert_eq!(derive(123, 456), derive(123, 456));
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 1);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval_and_uniformish() {
        let mut r = Pcg32::seeded(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn int_range_covers_bounds() {
        let mut r = Pcg32::seeded(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.int_range(-3, 3);
            assert!((-3..=3).contains(&v));
            seen_lo |= v == -3;
            seen_hi |= v == 3;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg32::seeded(5);
        for (n, k) in [(100, 5), (100, 90), (10, 10), (1, 1)] {
            let idx = r.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            let set: std::collections::HashSet<_> = idx.iter().collect();
            assert_eq!(set.len(), k);
            assert!(idx.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
