//! `diffaxe lint` — a dependency-free, token-level static-analysis pass
//! that machine-enforces the repo's concurrency and determinism
//! invariants (the conventions PRs 1–6 established by hand; the full
//! rule/invariant table lives in `docs/INVARIANTS.md`).
//!
//! The scanner walks `src/`, `tests/` and `benches/` under a crate root,
//! strips comments and string/char literals line by line (block comments,
//! raw strings and multi-line strings carry state across lines), tracks
//! `#[cfg(test)]` module regions by brace depth, and matches each rule's
//! token patterns against the stripped code. It is deliberately *not* a
//! parser: the rules are chosen so that substring matches on stripped
//! source are precise in this codebase, and the corpus self-test
//! (`tests/lint_repo.rs`) plants one violation per rule in a fixture tree
//! and asserts the scanner catches exactly those.
//!
//! # Allowlisting
//!
//! A justified exception is a comment containing `lint:allow(<rule>)` on
//! the violating line or the line directly above, followed by a non-empty
//! reason:
//!
//! ```text
//! // lint:allow(rng-construct) stream id predates the facade; re-deriving
//! // would change every golden output downstream
//! let mut rng = Pcg32::new(seed, 77);
//! ```
//!
//! An allow directive with no reason text after the closing parenthesis
//! does **not** suppress the diagnostic.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

// ---------------------------------------------------------------------------
// rules
// ---------------------------------------------------------------------------

/// Where a rule applies within the scanned tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scope {
    /// Everywhere: `src/`, `tests/`, `benches/`, including test modules.
    Everywhere,
    /// Production code only: `src/`, skipping `#[cfg(test)]` regions.
    SrcNonTest,
    /// `src/dse/` only, skipping `#[cfg(test)]` regions.
    DseNonTest,
}

/// One named, allowlistable invariant check.
#[derive(Debug, Clone, Copy)]
pub struct Rule {
    /// Stable diagnostic name (what `lint:allow(...)` references).
    pub name: &'static str,
    /// The invariant the rule guards (one line, shown in `--help`-ish
    /// listings and `docs/INVARIANTS.md`).
    pub invariant: &'static str,
    pub scope: Scope,
    /// Files (crate-root-relative, `/`-separated) exempt from this rule.
    pub allowed_files: &'static [&'static str],
}

/// The rule set, in diagnostic order.
pub const RULES: &[Rule] = &[
    Rule {
        name: "float-cmp-unwrap",
        invariant: "float ordering must use total_cmp: .partial_cmp(..).unwrap() panics on NaN",
        scope: Scope::Everywhere,
        allowed_files: &[],
    },
    Rule {
        name: "thread-spawn",
        invariant: "threads are created only by the WorkerPool, the server accept loop, the \
                    supervisor and the engine workers it owns — ad-hoc spawning bypasses the \
                    pool's nesting guard, the connection cap and the supervision tree",
        scope: Scope::SrcNonTest,
        allowed_files: &[
            "src/dse/eval.rs",
            "src/coordinator/server.rs",
            "src/coordinator/service.rs",
            "src/coordinator/supervisor.rs",
            // the fleet owns the supervision tree's root: it is the one
            // place allowed to stand up per-slot supervisor threads
            "src/coordinator/fleet.rs",
        ],
    },
    Rule {
        name: "raw-sync",
        invariant: "std::sync::{Mutex, RwLock} appear only inside util/sync.rs — every other \
                    lock site goes through the ranked TrackedMutex/TrackedRwLock facade",
        scope: Scope::SrcNonTest,
        allowed_files: &["src/util/sync.rs"],
    },
    Rule {
        name: "dse-clock",
        invariant: "search strategies read wall-clock time only through SearchCtx deadlines \
                    (dse/api.rs) — raw clocks make search results timing-dependent",
        scope: Scope::DseNonTest,
        allowed_files: &["src/dse/api.rs"],
    },
    Rule {
        name: "rng-construct",
        invariant: "production randomness derives from util::rng::{split, derive} — direct \
                    Pcg32 construction risks correlated streams across components",
        scope: Scope::SrcNonTest,
        allowed_files: &["src/util/rng.rs"],
    },
    Rule {
        name: "bare-allow",
        invariant: "#[allow(...)] needs a justification comment on the same or preceding line",
        scope: Scope::Everywhere,
        allowed_files: &[],
    },
];

/// Look a rule up by name.
pub fn rule(name: &str) -> Option<&'static Rule> {
    RULES.iter().find(|r| r.name == name)
}

// ---------------------------------------------------------------------------
// diagnostics
// ---------------------------------------------------------------------------

/// One lint finding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Path relative to the scanned crate root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{} {} {}", self.file, self.line, self.rule, self.message)
    }
}

/// Render diagnostics as a JSON array (the `--json` output mode).
pub fn to_json(diags: &[Diagnostic]) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::Arr(
        diags
            .iter()
            .map(|d| {
                Json::Obj(BTreeMap::from([
                    ("file".to_string(), Json::Str(d.file.clone())),
                    ("line".to_string(), Json::Num(d.line as f64)),
                    ("rule".to_string(), Json::Str(d.rule.to_string())),
                    ("message".to_string(), Json::Str(d.message.clone())),
                ]))
            })
            .collect(),
    )
}

// ---------------------------------------------------------------------------
// tree walking
// ---------------------------------------------------------------------------

/// Lint a crate tree: scans `root/{src,tests,benches}`, skipping
/// `tests/fixtures/` (wire-corpus and planted-violation files are data,
/// not code). Returns diagnostics sorted by (file, line).
pub fn lint_tree(root: &Path) -> std::io::Result<Vec<Diagnostic>> {
    let mut files = Vec::new();
    for sub in ["src", "tests", "benches"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        }
    }
    let mut out = Vec::new();
    for f in files {
        let rel = rel_path(root, &f);
        if rel.starts_with("tests/fixtures/") {
            continue;
        }
        let text = std::fs::read_to_string(&f)?;
        out.extend(lint_source(&rel, &text));
    }
    out.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(out)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(dir)?.map(|e| e.map(|e| e.path())).collect::<Result<_, _>>()?;
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, file: &Path) -> String {
    let rel = file.strip_prefix(root).unwrap_or(file);
    // normalize to `/` so rule file lists and diagnostics are stable
    // across platforms
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

// ---------------------------------------------------------------------------
// per-file scanner
// ---------------------------------------------------------------------------

/// Which tree a file belongs to (decides rule scope applicability).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FileKind {
    Src,
    Tests,
    Benches,
}

fn classify(rel: &str) -> FileKind {
    if rel.starts_with("tests/") {
        FileKind::Tests
    } else if rel.starts_with("benches/") {
        FileKind::Benches
    } else {
        FileKind::Src
    }
}

/// Lint one file's source text. `rel` is the crate-root-relative path
/// (used for scope decisions, per-rule file exemptions and diagnostics).
pub fn lint_source(rel: &str, text: &str) -> Vec<Diagnostic> {
    let kind = classify(rel);
    let raw_lines: Vec<&str> = text.lines().collect();
    let code_lines = strip_lines(&raw_lines);

    // ---- pass 1: mark #[cfg(test)] regions by brace depth --------------
    let mut in_test = vec![false; code_lines.len()];
    {
        let mut depth: i64 = 0;
        let mut regions: Vec<i64> = Vec::new();
        let mut pending = false;
        for (i, code) in code_lines.iter().enumerate() {
            in_test[i] = !regions.is_empty();
            if code.contains("#[cfg(test)]") {
                pending = true;
            }
            for ch in code.chars() {
                match ch {
                    '{' => {
                        if pending {
                            regions.push(depth);
                            pending = false;
                        }
                        depth += 1;
                    }
                    '}' => {
                        depth -= 1;
                        if regions.last() == Some(&depth) {
                            regions.pop();
                            // a region that closes mid-line still covers
                            // this line; `in_test` was latched above
                        }
                    }
                    _ => {}
                }
            }
        }
    }

    // ---- pass 2: rule matching ------------------------------------------
    let mut out = Vec::new();
    for r in RULES {
        let applies_to_file = match r.scope {
            Scope::Everywhere => true,
            Scope::SrcNonTest => kind == FileKind::Src,
            Scope::DseNonTest => kind == FileKind::Src && rel.starts_with("src/dse/"),
        };
        if !applies_to_file || r.allowed_files.contains(&rel) {
            continue;
        }
        for (i, code) in code_lines.iter().enumerate() {
            if r.scope != Scope::Everywhere && in_test[i] {
                continue;
            }
            let Some(message) = match_rule(r.name, code, &raw_lines, i) else { continue };
            if allowed(r.name, &raw_lines, i) {
                continue;
            }
            out.push(Diagnostic { file: rel.to_string(), line: i + 1, rule: r.name, message });
        }
    }
    out
}

/// Match one rule against one stripped line; `Some(message)` on a hit.
fn match_rule(name: &str, code: &str, raw_lines: &[&str], i: usize) -> Option<String> {
    match name {
        "float-cmp-unwrap" => {
            let pos = code.find("partial_cmp")?;
            if code[pos..].contains(".unwrap()") {
                Some("`.partial_cmp(..).unwrap()` panics on NaN; use `total_cmp`".to_string())
            } else {
                None
            }
        }
        "thread-spawn" => {
            if code.contains("thread::spawn") || code.contains("thread::Builder::new") {
                Some(
                    "thread creation outside the WorkerPool / accept loop / engine thread; \
                     route work through dse::eval::par_map or the service"
                        .to_string(),
                )
            } else {
                None
            }
        }
        "raw-sync" => {
            if has_ident(code, "Mutex") || has_ident(code, "RwLock") {
                Some(
                    "raw std::sync lock; use util::sync::{TrackedMutex, TrackedRwLock} with a \
                     rank from util::sync::rank"
                        .to_string(),
                )
            } else {
                None
            }
        }
        "dse-clock" => {
            if code.contains("Instant::now") || code.contains("SystemTime::now") {
                Some(
                    "raw clock read inside a search strategy; deadlines and elapsed time come \
                     from SearchCtx"
                        .to_string(),
                )
            } else {
                None
            }
        }
        "rng-construct" => {
            if code.contains("Pcg32::new") || code.contains("Pcg32::seeded") {
                Some(
                    "direct Pcg32 construction; derive per-component streams via \
                     util::rng::split / util::rng::derive"
                        .to_string(),
                )
            } else {
                None
            }
        }
        "bare-allow" => {
            let pos = code.find("#[allow(").or_else(|| code.find("#![allow("))?;
            // justified iff a `//` comment trails the attribute on the raw
            // line, or the raw line directly above is a non-doc comment
            // stripping is position-preserving, so `pos` indexes `raw` too
            let raw = raw_lines[i];
            let trailing = raw.get(pos..).is_some_and(|rest| rest.contains("//"));
            let above = i > 0 && {
                let p = raw_lines[i - 1].trim_start();
                p.starts_with("//") && !p.starts_with("///") && !p.starts_with("//!")
            };
            if trailing || above {
                None
            } else {
                Some(
                    "bare #[allow(...)]: add a justification comment on the same or preceding \
                     line"
                        .to_string(),
                )
            }
        }
        other => unreachable!("unknown rule {other}"),
    }
}

/// True when the violating line (or the one above it) carries a
/// `lint:allow(<rule>)` directive followed by a non-empty reason.
fn allowed(rule: &str, raw_lines: &[&str], i: usize) -> bool {
    let directive_ok = |line: &str| -> bool {
        let needle = format!("lint:allow({rule})");
        match line.find(&needle) {
            Some(pos) => !line[pos + needle.len()..].trim().is_empty(),
            None => false,
        }
    };
    directive_ok(raw_lines[i]) || (i > 0 && directive_ok(raw_lines[i - 1]))
}

/// Identifier-boundary substring match: `needle` present in `code` and
/// not embedded in a longer identifier (so `TrackedMutex` does not match
/// `Mutex`, but `MutexGuard` does — guard types are facade-internal).
fn has_ident(code: &str, needle: &str) -> bool {
    let is_ident = |c: u8| c.is_ascii_alphanumeric() || c == b'_';
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(off) = code[from..].find(needle) {
        let start = from + off;
        let boundary_before = start == 0 || !is_ident(bytes[start - 1]);
        if boundary_before {
            return true;
        }
        from = start + needle.len();
    }
    false
}

// ---------------------------------------------------------------------------
// lexical stripping
// ---------------------------------------------------------------------------

/// Lexer state carried across lines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LexState {
    Normal,
    /// Inside `/* */`, with nesting depth (rust block comments nest).
    Block(u32),
    /// Inside a `"…"` string (strings may span lines).
    Str,
    /// Inside a raw string terminated by `"` + this many `#`s.
    RawStr(u32),
}

/// Replace comment and string/char-literal interiors with spaces, one
/// output line per input line. Keeping byte positions stable makes the
/// diagnostics' column-free `file:line` reporting trivially correct.
fn strip_lines(raw_lines: &[&str]) -> Vec<String> {
    let mut state = LexState::Normal;
    let mut out = Vec::with_capacity(raw_lines.len());
    for line in raw_lines {
        let b = line.as_bytes();
        let mut code = Vec::with_capacity(b.len());
        let mut i = 0;
        while i < b.len() {
            match state {
                LexState::Block(depth) => {
                    if b[i..].starts_with(b"*/") {
                        state =
                            if depth <= 1 { LexState::Normal } else { LexState::Block(depth - 1) };
                        code.extend_from_slice(b"  ");
                        i += 2;
                    } else if b[i..].starts_with(b"/*") {
                        state = LexState::Block(depth + 1);
                        code.extend_from_slice(b"  ");
                        i += 2;
                    } else {
                        code.push(b' ');
                        i += 1;
                    }
                }
                LexState::Str => {
                    if b[i] == b'\\' && i + 1 < b.len() {
                        code.extend_from_slice(b"  ");
                        i += 2;
                    } else if b[i] == b'"' {
                        state = LexState::Normal;
                        code.push(b'"');
                        i += 1;
                    } else {
                        code.push(b' ');
                        i += 1;
                    }
                }
                LexState::RawStr(hashes) => {
                    let mut closed = false;
                    if b[i] == b'"' {
                        let h = hashes as usize;
                        if b[i + 1..].len() >= h && b[i + 1..i + 1 + h].iter().all(|&c| c == b'#')
                        {
                            state = LexState::Normal;
                            code.push(b'"');
                            code.extend(std::iter::repeat(b'#').take(h));
                            i += 1 + h;
                            closed = true;
                        }
                    }
                    if !closed {
                        code.push(b' ');
                        i += 1;
                    }
                }
                LexState::Normal => {
                    if b[i..].starts_with(b"//") {
                        // line comment (incl. doc comments): drop the rest
                        break;
                    } else if b[i..].starts_with(b"/*") {
                        state = LexState::Block(1);
                        code.extend_from_slice(b"  ");
                        i += 2;
                    } else if b[i] == b'"' {
                        state = LexState::Str;
                        code.push(b'"');
                        i += 1;
                    } else if b[i] == b'r'
                        && !prev_is_ident(&code)
                        && raw_str_hashes(&b[i + 1..]).is_some()
                    {
                        let h = raw_str_hashes(&b[i + 1..]).expect("checked above");
                        state = LexState::RawStr(h);
                        code.push(b'r');
                        code.extend(std::iter::repeat(b'#').take(h as usize));
                        code.push(b'"');
                        i += 2 + h as usize;
                    } else if b[i] == b'\'' {
                        // char literal vs lifetime: 'x' or '\x' is a literal,
                        // anything else ('a in generics, 'static) is a
                        // lifetime and passes through
                        if i + 2 < b.len() && b[i + 1] == b'\\' {
                            // escaped char literal: skip to the closing quote
                            let close = b[i + 2..].iter().position(|&c| c == b'\'');
                            let len = close.map(|c| c + 3).unwrap_or(2);
                            code.extend(std::iter::repeat(b' ').take(len));
                            i += len;
                        } else if i + 2 < b.len() && b[i + 2] == b'\'' {
                            code.extend_from_slice(b"   ");
                            i += 3;
                        } else {
                            code.push(b'\'');
                            i += 1;
                        }
                    } else {
                        code.push(b[i]);
                        i += 1;
                    }
                }
            }
        }
        out.push(String::from_utf8_lossy(&code).into_owned());
    }
    out
}

/// `Some(n)` when `rest` starts a raw string body: `#…#"` with `n` hashes
/// (including `n == 0` for a plain `r"`).
fn raw_str_hashes(rest: &[u8]) -> Option<u32> {
    let mut h = 0u32;
    for &c in rest {
        match c {
            b'#' => h += 1,
            b'"' => return Some(h),
            _ => return None,
        }
    }
    None
}

fn prev_is_ident(code: &[u8]) -> bool {
    code.last().is_some_and(|&c| c.is_ascii_alphanumeric() || c == b'_')
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diags(rel: &str, src: &str) -> Vec<Diagnostic> {
        lint_source(rel, src)
    }

    #[test]
    fn partial_cmp_unwrap_flagged_unwrap_or_not() {
        let bad = "fn f(xs: &mut [f64]) { xs.sort_by(|a, b| a.partial_cmp(b).unwrap()); }";
        let d = diags("src/x.rs", bad);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, "float-cmp-unwrap");
        assert_eq!(d[0].line, 1);
        let ok = "fn f(a: f64, b: f64) { a.partial_cmp(&b).unwrap_or(std::cmp::Ordering::Equal); }";
        assert!(diags("src/x.rs", ok).is_empty());
        let fixed = "fn f(xs: &mut [f64]) { xs.sort_by(|a, b| a.total_cmp(b)); }";
        assert!(diags("src/x.rs", fixed).is_empty());
    }

    #[test]
    fn patterns_inside_strings_and_comments_ignored() {
        let src = "fn f() {\n    // thread::spawn in a comment\n    let s = \"Mutex::new and Pcg32::seeded\";\n    let _ = s;\n}";
        assert!(diags("src/x.rs", src).is_empty(), "{:?}", diags("src/x.rs", src));
    }

    #[test]
    fn raw_sync_word_boundaries() {
        assert_eq!(diags("src/x.rs", "use std::sync::Mutex;").len(), 1);
        assert_eq!(diags("src/x.rs", "let l: RwLock<u8> = RwLock::new(0);").len(), 1);
        // the facade's own type names must not match
        assert!(diags("src/x.rs", "use crate::util::sync::TrackedMutex;").is_empty());
        assert!(diags("src/x.rs", "let x: TrackedRwLock<u8>;").is_empty());
        // ...but the facade file itself is exempt wholesale
        assert!(diags("src/util/sync.rs", "use std::sync::{Mutex, RwLock};").is_empty());
    }

    #[test]
    fn scope_limits_rules_to_src() {
        let spawn = "fn f() { std::thread::spawn(|| {}); }";
        assert_eq!(diags("src/x.rs", spawn).len(), 1);
        assert!(diags("tests/x.rs", spawn).is_empty());
        assert!(diags("benches/x.rs", spawn).is_empty());
        let clock = "fn f() { let _ = std::time::Instant::now(); }";
        assert_eq!(diags("src/dse/strategy.rs", clock).len(), 1);
        assert!(diags("src/dse/api.rs", clock).is_empty(), "SearchCtx home is exempt");
        assert!(diags("src/sim/x.rs", clock).is_empty(), "clock rule is dse-only");
    }

    #[test]
    fn cfg_test_regions_are_skipped_for_src_rules() {
        let src = "pub fn f() {}\n\n#[cfg(test)]\nmod tests {\n    use crate::util::rng::Pcg32;\n    #[test]\n    fn t() { let mut r = Pcg32::seeded(1); r.next_u32(); }\n}";
        assert!(diags("src/x.rs", src).is_empty(), "{:?}", diags("src/x.rs", src));
        // the same construction outside the region is flagged
        let prod = "pub fn f() { let _ = crate::util::rng::Pcg32::seeded(1); }";
        assert_eq!(diags("src/x.rs", prod).len(), 1);
    }

    #[test]
    fn allow_directive_needs_reason() {
        let with_reason =
            "// lint:allow(rng-construct) fixed stream predates the facade\nlet r = Pcg32::new(1, 2);";
        assert!(diags("src/x.rs", with_reason).is_empty());
        let bare = "// lint:allow(rng-construct)\nlet r = Pcg32::new(1, 2);";
        assert_eq!(diags("src/x.rs", bare).len(), 1, "reason-less directive must not suppress");
        let wrong_rule = "// lint:allow(raw-sync) reasons\nlet r = Pcg32::new(1, 2);";
        assert_eq!(diags("src/x.rs", wrong_rule).len(), 1);
    }

    #[test]
    fn bare_allow_justification_forms() {
        let bare = "#[allow(dead_code)]\nfn f() {}";
        assert_eq!(diags("src/x.rs", bare).len(), 1);
        let trailing = "#[allow(dead_code)] // kept for the v2 wire decoder\nfn f() {}";
        assert!(diags("src/x.rs", trailing).is_empty());
        let above = "// decoder keeps v1 fields it never reads\n#[allow(dead_code)]\nfn f() {}";
        assert!(diags("src/x.rs", above).is_empty());
        // a doc comment documents the item, not the allow
        let doc = "/// Decodes v1 frames.\n#[allow(dead_code)]\nfn f() {}";
        assert_eq!(diags("src/x.rs", doc).len(), 1);
    }

    #[test]
    fn diagnostic_format_and_json() {
        let d = diags("src/x.rs", "fn f() { let m = std::sync::Mutex::new(0); let _ = m; }");
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].to_string(), format!("src/x.rs:1 raw-sync {}", d[0].message));
        let j = to_json(&d).to_string();
        assert!(j.contains("\"rule\""), "{j}");
        assert!(j.contains("raw-sync"), "{j}");
        assert!(j.contains("\"line\""), "{j}");
    }

    #[test]
    fn multiline_and_raw_strings_stay_stripped() {
        let src = "const S: &str = \"line one\nMutex::new(0)\nthread::spawn\";\nfn f() {}";
        assert!(diags("src/x.rs", src).is_empty(), "{:?}", diags("src/x.rs", src));
        let raw = "const R: &str = r#\"Pcg32::seeded(7) \"quoted\" Instant::now\"#;\nfn f() {}";
        assert!(diags("src/dse/x.rs", raw).is_empty(), "{:?}", diags("src/dse/x.rs", raw));
    }

    #[test]
    fn block_comments_nest_across_lines() {
        let src = "/* outer /* inner Mutex::new */\nstill comment RwLock::new\n*/\nfn f() {}";
        assert!(diags("src/x.rs", src).is_empty());
    }

    #[test]
    fn char_literals_and_lifetimes() {
        // brace char literals must not corrupt depth tracking for the
        // cfg(test) pass, and lifetimes must survive stripping
        let src = "fn f<'a>(x: &'a str) -> char { let _ = x; '{' }\n#[cfg(test)]\nmod tests {\n    fn g() { let _ = Pcg32::seeded(1); }\n}";
        assert!(diags("src/x.rs", src).is_empty(), "{:?}", diags("src/x.rs", src));
    }

    #[test]
    fn every_rule_has_metadata() {
        for r in RULES {
            assert!(!r.name.is_empty() && !r.invariant.is_empty());
            assert!(rule(r.name).is_some());
        }
        assert!(rule("no-such-rule").is_none());
    }
}
