//! Shared plumbing for the paper-table bench harnesses (`rust/benches/`).
//! criterion is not in the offline registry, so benches are
//! `harness = false` binaries that time with [`crate::util::stats::Timer`]
//! and print through [`crate::util::table::Table`].

/// How much work each bench does. `DIFFAXE_BENCH=quick|full` overrides.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BenchScale {
    Quick,
    Default,
    Full,
}

impl BenchScale {
    pub fn from_env() -> Self {
        match std::env::var("DIFFAXE_BENCH").as_deref() {
            Ok("quick") => BenchScale::Quick,
            Ok("full") => BenchScale::Full,
            _ => BenchScale::Default,
        }
    }

    /// pick (quick, default, full)
    pub fn pick<T: Copy>(&self, q: T, d: T, f: T) -> T {
        match self {
            BenchScale::Quick => q,
            BenchScale::Default => d,
            BenchScale::Full => f,
        }
    }
}

/// Standard header every bench prints (so bench_output.txt is parseable).
pub fn banner(id: &str, what: &str) {
    println!("\n=== {id} — {what} ===");
    println!("(scale: {:?}; set DIFFAXE_BENCH=quick|full to resize)", BenchScale::from_env());
}

/// Time a closure over `iters` runs, reporting mean seconds.
pub fn time_mean<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t = crate::util::stats::Timer::start();
    for _ in 0..iters {
        f();
    }
    t.elapsed_s() / iters.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_pick() {
        assert_eq!(BenchScale::Quick.pick(1, 2, 3), 1);
        assert_eq!(BenchScale::Default.pick(1, 2, 3), 2);
        assert_eq!(BenchScale::Full.pick(1, 2, 3), 3);
    }

    #[test]
    fn time_mean_positive() {
        let t = time_mean(3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t >= 0.0);
    }
}
