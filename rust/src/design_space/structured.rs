//! Structured (per-segment heterogeneous) design space — paper §V.
//!
//! A *structured* accelerator configuration partitions a DNN/LLM workload
//! into contiguous layer segments and gives every segment its own
//! `(dataflow/loop-order, tiling dims, PE/buffer split)` sub-configuration
//! drawn from the Table II target grid, all under one **shared accelerator
//! budget** ([`SharedBudget`]): the chip provisions at most `pe`
//! multiply-accumulate units, `buf_b` bytes of SRAM and one DRAM link of
//! `bw` bytes/cycle, and each segment reconfigures within that envelope.
//! The DRAM link is physical, so every segment shares one bandwidth value.
//!
//! The joint space is the per-segment target space raised to the segment
//! count (bandwidth counted once): with the unconstrained default budget
//! and 3 segments that is ≈ (1.7·10¹⁶)³ · 31 ≫ 10¹⁷ — the O(10^17)
//! setting of the paper's structured-DSE results (§V: 9.8% lower EDP, 6%
//! higher performance, 145.6×/1312× faster search).
//!
//! [`constrain`] is the projection every decoder/sampler runs through: it
//! snaps each segment onto the target grid, scales it into the shared
//! budget, and unifies the bandwidth. It is deterministic and idempotent,
//! so encode → decode round-trips are exact on already-constrained
//! configurations (see the property tests here and in
//! `tests/design_space_props.rs`).

use super::encode::{decode_rounded, encode_norm, NORM_DIM};
use super::params::{
    HwConfig, LoopOrder, TargetSpace, BUF_MAX_B, BUF_MIN_B, BUF_STEP_B, BW_MAX, BW_MIN, DIM_MAX,
    DIM_MIN,
};
use crate::util::rng::Pcg32;

/// Shared accelerator envelope every segment configuration must fit in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SharedBudget {
    /// PE cap: a segment's `r·c` may not exceed this.
    pub pe: u32,
    /// total SRAM cap in bytes: `ip + wt + op` per segment may not exceed
    /// this (segments are time-multiplexed, so the cap is per segment)
    pub buf_b: u64,
    /// DRAM link bandwidth cap in bytes/cycle (shared by every segment)
    pub bw: u32,
}

impl Default for SharedBudget {
    fn default() -> Self {
        SharedBudget::unconstrained()
    }
}

impl SharedBudget {
    /// The full Table II envelope: no budget pressure, every target-space
    /// configuration is admissible per segment.
    pub fn unconstrained() -> SharedBudget {
        SharedBudget { pe: DIM_MAX * DIM_MAX, buf_b: 3 * BUF_MAX_B, bw: BW_MAX }
    }

    /// Reject budgets no target-space segment can satisfy.
    pub fn validate(&self) -> Result<(), String> {
        if self.pe < DIM_MIN * DIM_MIN {
            return Err(format!("pe budget {} below minimum array {}", self.pe, DIM_MIN * DIM_MIN));
        }
        if self.buf_b < 3 * BUF_MIN_B {
            return Err(format!(
                "buffer budget {} B below minimum {} B",
                self.buf_b,
                3 * BUF_MIN_B
            ));
        }
        if !(BW_MIN..=BW_MAX).contains(&self.bw) {
            return Err(format!("bw budget {} outside [{BW_MIN}, {BW_MAX}]", self.bw));
        }
        Ok(())
    }

    /// True iff `hw` fits this envelope.
    pub fn admits(&self, hw: &HwConfig) -> bool {
        hw.macs() <= self.pe as u64 && hw.total_buf_b() <= self.buf_b && hw.bw <= self.bw
    }
}

/// One structured design point: an independent [`HwConfig`] per layer
/// segment, every segment inside the shared budget and all segments on one
/// bandwidth.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct StructuredConfig {
    pub segments: Vec<HwConfig>,
}

impl StructuredConfig {
    /// The provisioned silicon: the per-resource maximum across segments
    /// (the chip must physically hold the largest array and buffers any
    /// segment uses). Loop order is the first segment's — the envelope is
    /// a reporting summary, not an evaluable dataflow.
    pub fn envelope(&self) -> HwConfig {
        let mut it = self.segments.iter();
        let first = *it.next().expect("structured config has at least one segment");
        it.fold(first, |acc, h| HwConfig {
            r: acc.r.max(h.r),
            c: acc.c.max(h.c),
            ip_b: acc.ip_b.max(h.ip_b),
            wt_b: acc.wt_b.max(h.wt_b),
            op_b: acc.op_b.max(h.op_b),
            bw: acc.bw.max(h.bw),
            loop_order: acc.loop_order,
        })
    }

    /// True iff every segment is on the target grid, inside `budget`, and
    /// the bandwidth is shared.
    pub fn in_budget(&self, budget: &SharedBudget) -> bool {
        let shared_bw = self.segments.first().map(|h| h.bw);
        self.segments.iter().all(|h| {
            h.in_target_space() && budget.admits(h) && Some(h.bw) == shared_bw
        })
    }
}

/// Shrink `(r, c)` multiplicatively (then by single steps) until `r·c`
/// fits the PE cap. No-op when already within the cap.
fn fit_dims(r: u32, c: u32, pe: u32) -> (u32, u32) {
    let mut r = r.clamp(DIM_MIN, DIM_MAX);
    let mut c = c.clamp(DIM_MIN, DIM_MAX);
    if (r as u64) * (c as u64) > pe as u64 {
        let scale = (pe as f64 / (r as f64 * c as f64)).sqrt();
        r = ((r as f64 * scale).floor() as u32).clamp(DIM_MIN, DIM_MAX);
        c = ((c as f64 * scale).floor() as u32).clamp(DIM_MIN, DIM_MAX);
        while (r as u64) * (c as u64) > pe as u64 && c > DIM_MIN {
            c -= 1;
        }
        while (r as u64) * (c as u64) > pe as u64 && r > DIM_MIN {
            r -= 1;
        }
    }
    (r, c)
}

/// Clamp a buffer size into the Table II range and snap *down* onto the
/// 128 B grid (idempotent on grid values).
fn snap_buf(b: u64) -> u64 {
    let b = b.clamp(BUF_MIN_B, BUF_MAX_B);
    BUF_MIN_B + ((b - BUF_MIN_B) / BUF_STEP_B) * BUF_STEP_B
}

/// Scale the three buffers into the shared SRAM cap: proportional shrink,
/// then largest-first single-step trimming until the total fits. With a
/// validated budget (`cap ≥ 3·BUF_MIN_B`) this always terminates inside
/// the cap; no-op when already within it.
fn fit_bufs(ip: u64, wt: u64, op: u64, cap: u64) -> (u64, u64, u64) {
    let mut bufs = [snap_buf(ip), snap_buf(wt), snap_buf(op)];
    if bufs.iter().sum::<u64>() > cap {
        let total = bufs.iter().sum::<u64>();
        let scale = cap as f64 / total as f64;
        for b in &mut bufs {
            *b = snap_buf((*b as f64 * scale) as u64);
        }
        while bufs.iter().sum::<u64>() > cap {
            // ties resolve to the last maximal index: deterministic
            let i = (0..3).max_by_key(|&i| bufs[i]).expect("three buffers");
            if bufs[i] <= BUF_MIN_B {
                break; // unreachable with a validated budget
            }
            bufs[i] -= BUF_STEP_B;
        }
    }
    (bufs[0], bufs[1], bufs[2])
}

/// Project one segment into the shared budget (grid-snapped, deterministic,
/// idempotent). The bandwidth is capped here; [`constrain`] then unifies
/// it across segments.
pub fn constrain_segment(budget: &SharedBudget, hw: &HwConfig) -> HwConfig {
    let (r, c) = fit_dims(hw.r, hw.c, budget.pe);
    let (ip_b, wt_b, op_b) = fit_bufs(hw.ip_b, hw.wt_b, hw.op_b, budget.buf_b);
    HwConfig {
        r,
        c,
        ip_b,
        wt_b,
        op_b,
        bw: hw.bw.clamp(BW_MIN, BW_MAX).min(budget.bw),
        loop_order: hw.loop_order,
    }
}

/// Project a per-segment configuration list into a valid
/// [`StructuredConfig`]: every segment constrained into the budget, then
/// the first segment's bandwidth imposed on all (one physical DRAM link).
pub fn constrain(budget: &SharedBudget, segments: Vec<HwConfig>) -> StructuredConfig {
    let mut segs: Vec<HwConfig> = segments.iter().map(|h| constrain_segment(budget, h)).collect();
    if let Some(bw) = segs.first().map(|h| h.bw) {
        for s in &mut segs {
            s.bw = bw;
        }
    }
    StructuredConfig { segments: segs }
}

/// Width of the structured encoding for `segments` segments.
pub fn structured_dim(segments: usize) -> usize {
    segments * NORM_DIM
}

/// Concatenated per-segment normalized encoding (segment-major,
/// [`NORM_DIM`] features each) — the search vector the generic BO/GD
/// baselines operate on.
pub fn encode_structured(cfg: &StructuredConfig) -> Vec<f32> {
    cfg.segments.iter().flat_map(encode_norm).collect()
}

/// Decode a (possibly continuous, out-of-range) structured vector back
/// into a valid in-budget configuration: per-segment [`decode_rounded`],
/// then [`constrain`]. Exact inverse of [`encode_structured`] on
/// already-constrained configurations.
pub fn decode_structured(v: &[f32], budget: &SharedBudget, segments: usize) -> StructuredConfig {
    assert_eq!(
        v.len(),
        structured_dim(segments),
        "structured vector must be {} wide for {segments} segments",
        structured_dim(segments)
    );
    constrain(budget, v.chunks(NORM_DIM).map(decode_rounded).collect())
}

/// Uniformly sample a structured configuration (per-segment target-space
/// draws, projected into the budget).
pub fn sample_structured(
    rng: &mut Pcg32,
    budget: &SharedBudget,
    segments: usize,
) -> StructuredConfig {
    constrain(budget, (0..segments).map(|_| TargetSpace::sample(rng)).collect())
}

/// Joint-space cardinality for the **unconstrained** budget (an upper
/// bound under tighter budgets): per-segment `dims² · bufs³ · orders`,
/// raised to the segment count, times the shared-bandwidth choices.
pub fn cardinality(budget: &SharedBudget, segments: usize) -> f64 {
    let per_segment = (TargetSpace::n_dims() as f64).powi(2)
        * (TargetSpace::n_buf() as f64).powi(3)
        * LoopOrder::OS_ORDERS.len() as f64;
    let bw_choices = (budget.bw.clamp(BW_MIN, BW_MAX) - BW_MIN + 1) as f64;
    per_segment.powi(segments as i32) * bw_choices
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wild(rng: &mut Pcg32) -> HwConfig {
        // deliberately off-grid / out-of-range inputs
        HwConfig {
            r: rng.int_range(0, 400) as u32,
            c: rng.int_range(0, 400) as u32,
            ip_b: rng.int_range(0, 3_000_000) as u64,
            wt_b: rng.int_range(0, 3_000_000) as u64,
            op_b: rng.int_range(0, 3_000_000) as u64,
            bw: rng.int_range(0, 99) as u32,
            loop_order: *rng.choose(&LoopOrder::OS_ORDERS),
        }
    }

    #[test]
    fn constrain_lands_in_budget_and_is_idempotent() {
        let budgets = [
            SharedBudget::unconstrained(),
            SharedBudget { pe: 1024, buf_b: 96 * 1024, bw: 8 },
            SharedBudget { pe: 16, buf_b: 3 * BUF_MIN_B, bw: BW_MIN },
        ];
        let mut rng = Pcg32::seeded(51);
        for budget in budgets {
            budget.validate().unwrap();
            for _ in 0..300 {
                let raw: Vec<HwConfig> = (0..3).map(|_| wild(&mut rng)).collect();
                let cfg = constrain(&budget, raw);
                assert!(cfg.in_budget(&budget), "{cfg:?} escapes {budget:?}");
                let again = constrain(&budget, cfg.segments.clone());
                assert_eq!(cfg, again, "constrain not idempotent under {budget:?}");
            }
        }
    }

    #[test]
    fn encode_decode_roundtrip_on_constrained_configs() {
        let budget = SharedBudget { pe: 4096, buf_b: 512 * 1024, bw: 16 };
        let mut rng = Pcg32::seeded(52);
        for _ in 0..200 {
            let cfg = sample_structured(&mut rng, &budget, 3);
            let v = encode_structured(&cfg);
            assert_eq!(v.len(), structured_dim(3));
            let back = decode_structured(&v, &budget, 3);
            assert_eq!(back, cfg);
        }
    }

    #[test]
    fn segments_share_one_bandwidth() {
        let mut rng = Pcg32::seeded(53);
        let budget = SharedBudget::unconstrained();
        for _ in 0..100 {
            let cfg = sample_structured(&mut rng, &budget, 4);
            let bw = cfg.segments[0].bw;
            assert!(cfg.segments.iter().all(|h| h.bw == bw));
        }
    }

    #[test]
    fn cardinality_reaches_paper_scale() {
        let b = SharedBudget::unconstrained();
        // one segment is the plain target space (§V baseline grid)
        let one = cardinality(&b, 1);
        assert!((one / TargetSpace::cardinality() - 1.0).abs() < 1e-9, "{one:e}");
        // the structured setting exceeds the paper's O(10^17)
        assert!(cardinality(&b, 2) > 1e17);
        assert!(cardinality(&b, 3) > cardinality(&b, 2));
    }

    #[test]
    fn envelope_is_per_resource_max() {
        let a = HwConfig::new_kb(8, 64, 4.0, 64.0, 16.0, 8, LoopOrder::Mnk);
        let b = HwConfig::new_kb(32, 16, 128.0, 8.0, 4.0, 8, LoopOrder::Nmk);
        let env = StructuredConfig { segments: vec![a, b] }.envelope();
        assert_eq!((env.r, env.c), (32, 64));
        assert_eq!(env.ip_b, b.ip_b);
        assert_eq!(env.wt_b, a.wt_b);
        assert_eq!(env.op_b, a.op_b);
        assert_eq!(env.loop_order, LoopOrder::Mnk);
    }

    #[test]
    fn budget_validation_rejects_impossible_envelopes() {
        assert!(SharedBudget { pe: 8, ..SharedBudget::unconstrained() }.validate().is_err());
        assert!(
            SharedBudget { buf_b: BUF_MIN_B, ..SharedBudget::unconstrained() }.validate().is_err()
        );
        assert!(SharedBudget { bw: 0, ..SharedBudget::unconstrained() }.validate().is_err());
        assert!(SharedBudget::unconstrained().validate().is_ok());
    }
}
