//! Structured (per-segment heterogeneous) design space — paper §V.
//!
//! A *structured* accelerator configuration partitions a DNN/LLM workload
//! into contiguous layer segments and gives every segment its own
//! `(dataflow/loop-order, tiling dims, PE/buffer split)` sub-configuration
//! drawn from the Table II target grid, all under one **shared accelerator
//! budget** ([`SharedBudget`]): the chip provisions at most `pe`
//! multiply-accumulate units, `buf_b` bytes of SRAM and one DRAM link of
//! `bw` bytes/cycle, and each segment reconfigures within that envelope.
//! The DRAM link is physical, so every segment shares one bandwidth value.
//!
//! The joint space is the per-segment target space raised to the segment
//! count (bandwidth counted once): with the unconstrained default budget
//! and 3 segments that is ≈ (1.7·10¹⁶)³ · 31 ≫ 10¹⁷ — the O(10^17)
//! setting of the paper's structured-DSE results (§V: 9.8% lower EDP, 6%
//! higher performance, 145.6×/1312× faster search).
//!
//! [`constrain`] is the projection every decoder/sampler runs through: it
//! snaps each segment onto the target grid, scales it into the shared
//! budget, and unifies the bandwidth. It is deterministic and idempotent,
//! so encode → decode round-trips are exact on already-constrained
//! configurations (see the property tests here and in
//! `tests/design_space_props.rs`).

use super::encode::{decode_rounded, encode_norm, NORM_DIM};
use super::params::{
    HwConfig, LoopOrder, TargetSpace, BUF_MAX_B, BUF_MIN_B, BUF_STEP_B, BW_MAX, BW_MIN, DIM_MAX,
    DIM_MIN,
};
use crate::util::rng::Pcg32;
use crate::workload::gemm::Gemm;

/// Shared accelerator envelope every segment configuration must fit in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SharedBudget {
    /// PE cap: a segment's `r·c` may not exceed this.
    pub pe: u32,
    /// total SRAM cap in bytes: `ip + wt + op` per segment may not exceed
    /// this (segments are time-multiplexed, so the cap is per segment)
    pub buf_b: u64,
    /// DRAM link bandwidth cap in bytes/cycle (shared by every segment)
    pub bw: u32,
}

impl Default for SharedBudget {
    fn default() -> Self {
        SharedBudget::unconstrained()
    }
}

impl SharedBudget {
    /// The full Table II envelope: no budget pressure, every target-space
    /// configuration is admissible per segment.
    pub fn unconstrained() -> SharedBudget {
        SharedBudget { pe: DIM_MAX * DIM_MAX, buf_b: 3 * BUF_MAX_B, bw: BW_MAX }
    }

    /// Reject budgets no target-space segment can satisfy.
    pub fn validate(&self) -> Result<(), String> {
        if self.pe < DIM_MIN * DIM_MIN {
            return Err(format!("pe budget {} below minimum array {}", self.pe, DIM_MIN * DIM_MIN));
        }
        if self.buf_b < 3 * BUF_MIN_B {
            return Err(format!(
                "buffer budget {} B below minimum {} B",
                self.buf_b,
                3 * BUF_MIN_B
            ));
        }
        if !(BW_MIN..=BW_MAX).contains(&self.bw) {
            return Err(format!("bw budget {} outside [{BW_MIN}, {BW_MAX}]", self.bw));
        }
        Ok(())
    }

    /// True iff `hw` fits this envelope.
    pub fn admits(&self, hw: &HwConfig) -> bool {
        hw.macs() <= self.pe as u64 && hw.total_buf_b() <= self.buf_b && hw.bw <= self.bw
    }
}

/// One structured design point: an independent [`HwConfig`] per layer
/// segment, every segment inside the shared budget and all segments on one
/// bandwidth.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct StructuredConfig {
    pub segments: Vec<HwConfig>,
}

impl StructuredConfig {
    /// The provisioned silicon: the per-resource maximum across segments
    /// (the chip must physically hold the largest array and buffers any
    /// segment uses). Loop order is the first segment's — the envelope is
    /// a reporting summary, not an evaluable dataflow.
    pub fn envelope(&self) -> HwConfig {
        let mut it = self.segments.iter();
        let first = *it.next().expect("structured config has at least one segment");
        it.fold(first, |acc, h| HwConfig {
            r: acc.r.max(h.r),
            c: acc.c.max(h.c),
            ip_b: acc.ip_b.max(h.ip_b),
            wt_b: acc.wt_b.max(h.wt_b),
            op_b: acc.op_b.max(h.op_b),
            bw: acc.bw.max(h.bw),
            loop_order: acc.loop_order,
        })
    }

    /// True iff every segment is on the target grid, inside `budget`, and
    /// the bandwidth is shared.
    pub fn in_budget(&self, budget: &SharedBudget) -> bool {
        let shared_bw = self.segments.first().map(|h| h.bw);
        self.segments.iter().all(|h| {
            h.in_target_space() && budget.admits(h) && Some(h.bw) == shared_bw
        })
    }
}

/// Shrink `(r, c)` multiplicatively (then by single steps) until `r·c`
/// fits the PE cap. No-op when already within the cap.
fn fit_dims(r: u32, c: u32, pe: u32) -> (u32, u32) {
    let mut r = r.clamp(DIM_MIN, DIM_MAX);
    let mut c = c.clamp(DIM_MIN, DIM_MAX);
    if (r as u64) * (c as u64) > pe as u64 {
        let scale = (pe as f64 / (r as f64 * c as f64)).sqrt();
        r = ((r as f64 * scale).floor() as u32).clamp(DIM_MIN, DIM_MAX);
        c = ((c as f64 * scale).floor() as u32).clamp(DIM_MIN, DIM_MAX);
        while (r as u64) * (c as u64) > pe as u64 && c > DIM_MIN {
            c -= 1;
        }
        while (r as u64) * (c as u64) > pe as u64 && r > DIM_MIN {
            r -= 1;
        }
    }
    (r, c)
}

/// Clamp a buffer size into the Table II range and snap *down* onto the
/// 128 B grid (idempotent on grid values).
fn snap_buf(b: u64) -> u64 {
    let b = b.clamp(BUF_MIN_B, BUF_MAX_B);
    BUF_MIN_B + ((b - BUF_MIN_B) / BUF_STEP_B) * BUF_STEP_B
}

/// Scale the three buffers into the shared SRAM cap: proportional shrink,
/// then largest-first single-step trimming until the total fits. With a
/// validated budget (`cap ≥ 3·BUF_MIN_B`) this always terminates inside
/// the cap; no-op when already within it.
fn fit_bufs(ip: u64, wt: u64, op: u64, cap: u64) -> (u64, u64, u64) {
    let mut bufs = [snap_buf(ip), snap_buf(wt), snap_buf(op)];
    if bufs.iter().sum::<u64>() > cap {
        let total = bufs.iter().sum::<u64>();
        let scale = cap as f64 / total as f64;
        for b in &mut bufs {
            *b = snap_buf((*b as f64 * scale) as u64);
        }
        while bufs.iter().sum::<u64>() > cap {
            // ties resolve to the last maximal index: deterministic
            let i = (0..3).max_by_key(|&i| bufs[i]).expect("three buffers");
            if bufs[i] <= BUF_MIN_B {
                break; // unreachable with a validated budget
            }
            bufs[i] -= BUF_STEP_B;
        }
    }
    (bufs[0], bufs[1], bufs[2])
}

/// Project one segment into the shared budget (grid-snapped, deterministic,
/// idempotent). The bandwidth is capped here; [`constrain`] then unifies
/// it across segments.
pub fn constrain_segment(budget: &SharedBudget, hw: &HwConfig) -> HwConfig {
    let (r, c) = fit_dims(hw.r, hw.c, budget.pe);
    let (ip_b, wt_b, op_b) = fit_bufs(hw.ip_b, hw.wt_b, hw.op_b, budget.buf_b);
    HwConfig {
        r,
        c,
        ip_b,
        wt_b,
        op_b,
        bw: hw.bw.clamp(BW_MIN, BW_MAX).min(budget.bw),
        loop_order: hw.loop_order,
    }
}

/// Project a per-segment configuration list into a valid
/// [`StructuredConfig`]: every segment constrained into the budget, then
/// the first segment's bandwidth imposed on all (one physical DRAM link).
pub fn constrain(budget: &SharedBudget, segments: Vec<HwConfig>) -> StructuredConfig {
    let mut segs: Vec<HwConfig> = segments.iter().map(|h| constrain_segment(budget, h)).collect();
    if let Some(bw) = segs.first().map(|h| h.bw) {
        for s in &mut segs {
            s.bw = bw;
        }
    }
    StructuredConfig { segments: segs }
}

/// Width of the structured encoding for `segments` segments.
pub fn structured_dim(segments: usize) -> usize {
    segments * NORM_DIM
}

/// Concatenated per-segment normalized encoding (segment-major,
/// [`NORM_DIM`] features each) — the search vector the generic BO/GD
/// baselines operate on.
pub fn encode_structured(cfg: &StructuredConfig) -> Vec<f32> {
    cfg.segments.iter().flat_map(encode_norm).collect()
}

/// Decode a (possibly continuous, out-of-range) structured vector back
/// into a valid in-budget configuration: per-segment [`decode_rounded`],
/// then [`constrain`]. Exact inverse of [`encode_structured`] on
/// already-constrained configurations.
pub fn decode_structured(v: &[f32], budget: &SharedBudget, segments: usize) -> StructuredConfig {
    assert_eq!(
        v.len(),
        structured_dim(segments),
        "structured vector must be {} wide for {segments} segments",
        structured_dim(segments)
    );
    constrain(budget, v.chunks(NORM_DIM).map(decode_rounded).collect())
}

/// Uniformly sample a structured configuration (per-segment target-space
/// draws, projected into the budget).
pub fn sample_structured(
    rng: &mut Pcg32,
    budget: &SharedBudget,
    segments: usize,
) -> StructuredConfig {
    constrain(budget, (0..segments).map(|_| TargetSpace::sample(rng)).collect())
}

/// Joint-space cardinality for the **unconstrained** budget (an upper
/// bound under tighter budgets): per-segment `dims² · bufs³ · orders`,
/// raised to the segment count, times the shared-bandwidth choices.
pub fn cardinality(budget: &SharedBudget, segments: usize) -> f64 {
    let per_segment = (TargetSpace::n_dims() as f64).powi(2)
        * (TargetSpace::n_buf() as f64).powi(3)
        * LoopOrder::OS_ORDERS.len() as f64;
    let bw_choices = (budget.bw.clamp(BW_MIN, BW_MAX) - BW_MIN + 1) as f64;
    per_segment.powi(segments as i32) * bw_choices
}

// ---------------------------------------------------------------------------
// learned segmentation: boundary variables over the layer axis
// ---------------------------------------------------------------------------
//
// A segmentation of `n_layers` contiguous layers into `s` segments is the
// (s-1)-vector of interior cut points `1 ≤ b₁ < b₂ < … < b_{s-1} ≤ n-1`
// (segment i is `[b_{i-1}, b_i)` with b₀ = 0, b_s = n). The cuts join the
// S×[`NORM_DIM`] config lanes in the structured encoding as `s-1` extra
// lanes, each normalized to `b/n ∈ (0, 1)`, so the continuous optimizers
// (BO/GD/Polaris) and the diffusion sampler search segmentation and
// configuration jointly — paper §V via AIRCHITECT v2's unified
// representation. [`round_boundaries`] is the projection (deterministic,
// idempotent) every decode runs through.

/// Number of boundary lanes for `segments` segments (`s - 1` interior cuts).
pub fn boundary_dim(segments: usize) -> usize {
    segments.saturating_sub(1)
}

/// Width of the joint (configs + boundaries) structured encoding.
pub fn structured_dim_with_boundaries(segments: usize) -> usize {
    structured_dim(segments) + boundary_dim(segments)
}

/// Repair an arbitrary interior-cut vector into a valid segmentation of
/// `n_layers` layers: each cut clamped into `[1, n-1]`, sorted, then made
/// strictly increasing by a forward max-pass and a backward min-pass.
/// Deterministic and idempotent (a valid vector passes through unchanged).
/// Requires `bounds.len() < n_layers` — i.e. `segments ≤ n_layers`, which
/// [`crate::dse::structured::StructuredSpec`] guarantees by capping the
/// segment count at the workload's layer count.
pub fn round_boundaries(bounds: &[usize], n_layers: usize) -> Vec<usize> {
    let k = bounds.len();
    if k == 0 {
        return Vec::new();
    }
    assert!(
        k < n_layers,
        "{k} interior cuts need at least {} layers, got {n_layers}",
        k + 1
    );
    let mut b: Vec<usize> = bounds.iter().map(|&x| x.clamp(1, n_layers - 1)).collect();
    b.sort_unstable();
    for i in 0..k {
        let floor = if i == 0 { 1 } else { b[i - 1] + 1 };
        b[i] = b[i].max(floor);
    }
    for i in (0..k).rev() {
        let ceil = if i == k - 1 { n_layers - 1 } else { b[i + 1] - 1 };
        b[i] = b[i].min(ceil);
    }
    b
}

/// True iff `bounds` is a valid strictly-increasing interior-cut vector
/// for `n_layers` layers.
pub fn boundaries_valid(bounds: &[usize], n_layers: usize) -> bool {
    bounds.iter().all(|&b| (1..n_layers).contains(&b)) && bounds.windows(2).all(|w| w[0] < w[1])
}

/// The canonical near-even segmentation's interior cuts — the same cut
/// points [`crate::dse::structured::partition`] uses, expressed as
/// boundary variables (the search's default/seed segmentation).
pub fn default_boundaries(n_layers: usize, segments: usize) -> Vec<usize> {
    if segments <= 1 || n_layers == 0 {
        return Vec::new();
    }
    let s = segments.min(n_layers);
    round_boundaries(&(1..s).map(|i| i * n_layers / s).collect::<Vec<_>>(), n_layers)
}

/// Layer ranges induced by an interior-cut vector: `[0, b₁), [b₁, b₂), …,
/// [b_{s-1}, n)`. With valid boundaries every range is non-empty.
pub fn ranges_from_boundaries(bounds: &[usize], n_layers: usize) -> Vec<std::ops::Range<usize>> {
    let mut starts = Vec::with_capacity(bounds.len() + 1);
    starts.push(0);
    starts.extend_from_slice(bounds);
    let mut ends = bounds.to_vec();
    ends.push(n_layers);
    starts.into_iter().zip(ends).map(|(a, b)| a..b).collect()
}

/// Encode interior cuts as normalized lanes (`b / n_layers ∈ (0, 1)`).
pub fn encode_boundaries(bounds: &[usize], n_layers: usize) -> Vec<f32> {
    assert!(n_layers > 0, "cannot encode boundaries over an empty workload");
    bounds.iter().map(|&b| b as f32 / n_layers as f32).collect()
}

/// Decode normalized boundary lanes back into a valid interior-cut
/// vector: round each lane to the nearest layer index, then repair via
/// [`round_boundaries`]. Exact inverse of [`encode_boundaries`] on
/// already-valid cut vectors.
pub fn decode_boundaries(v: &[f32], n_layers: usize) -> Vec<usize> {
    let raw: Vec<usize> = v
        .iter()
        .map(|&x| (x.clamp(0.0, 1.0) * n_layers as f32).round() as usize)
        .collect();
    round_boundaries(&raw, n_layers)
}

/// Number of ways to cut `n_layers` contiguous layers into `segments`
/// non-empty segments: the composition count `C(n-1, s-1)`. This is the
/// factor learned segmentation multiplies into the joint cardinality.
pub fn composition_count(n_layers: usize, segments: usize) -> f64 {
    if segments == 0 || segments > n_layers {
        return 0.0;
    }
    let (n, k) = ((n_layers - 1) as f64, (segments - 1) as u64);
    (0..k).fold(1.0, |acc, i| acc * (n - i as f64) / (i + 1) as f64)
}

/// [`cardinality`] grown by the segmentation choices: the joint
/// (configuration × boundary) space the learned-segmentation search
/// explores.
pub fn cardinality_with_boundaries(
    budget: &SharedBudget,
    segments: usize,
    n_layers: usize,
) -> f64 {
    cardinality(budget, segments) * composition_count(n_layers, segments).max(1.0)
}

/// Joint encoding: the S×[`NORM_DIM`] config lanes followed by the `s-1`
/// boundary lanes ([`structured_dim_with_boundaries`] wide).
pub fn encode_structured_with_boundaries(
    cfg: &StructuredConfig,
    bounds: &[usize],
    n_layers: usize,
) -> Vec<f32> {
    assert_eq!(bounds.len(), boundary_dim(cfg.segments.len()), "boundary/segment mismatch");
    let mut v = encode_structured(cfg);
    v.extend(encode_boundaries(bounds, n_layers));
    v
}

/// Decode a joint vector back into `(configs, boundaries)`: the config
/// lanes through [`decode_structured`] (per-segment rounding, then
/// [`constrain`]), the boundary lanes through [`decode_boundaries`].
/// Exact inverse of [`encode_structured_with_boundaries`] on constrained
/// configs with valid cuts.
pub fn decode_structured_with_boundaries(
    v: &[f32],
    budget: &SharedBudget,
    segments: usize,
    n_layers: usize,
) -> (StructuredConfig, Vec<usize>) {
    assert_eq!(
        v.len(),
        structured_dim_with_boundaries(segments),
        "joint vector must be {} wide for {segments} segments",
        structured_dim_with_boundaries(segments)
    );
    let (cfg_lanes, bound_lanes) = v.split_at(structured_dim(segments));
    (decode_structured(cfg_lanes, budget, segments), decode_boundaries(bound_lanes, n_layers))
}

/// Shape-clustered segmentation: snap each canonical near-even cut to the
/// nearest *shape change* in the layer sequence (an index `i` with
/// `shapes[i] ≠ shapes[i-1]`), so segment boundaries align with where the
/// workload's GEMM dimensions actually switch (attention → FFN etc.).
/// Falls back to the even cut when no shape change is available, and
/// repairs collisions via [`round_boundaries`]. Deterministic.
pub fn segment_layers_by_shape(shapes: &[Gemm], segments: usize) -> Vec<usize> {
    let n = shapes.len();
    if segments <= 1 || n == 0 {
        return Vec::new();
    }
    let change_points: Vec<usize> =
        (1..n).filter(|&i| shapes[i] != shapes[i - 1]).collect();
    let even = default_boundaries(n, segments);
    let snapped: Vec<usize> = even
        .iter()
        .map(|&cut| {
            change_points
                .iter()
                .copied()
                // ties resolve to the earlier change point: deterministic
                .min_by_key(|&cp| (cp.abs_diff(cut), cp))
                .unwrap_or(cut)
        })
        .collect();
    round_boundaries(&snapped, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wild(rng: &mut Pcg32) -> HwConfig {
        // deliberately off-grid / out-of-range inputs
        HwConfig {
            r: rng.int_range(0, 400) as u32,
            c: rng.int_range(0, 400) as u32,
            ip_b: rng.int_range(0, 3_000_000) as u64,
            wt_b: rng.int_range(0, 3_000_000) as u64,
            op_b: rng.int_range(0, 3_000_000) as u64,
            bw: rng.int_range(0, 99) as u32,
            loop_order: *rng.choose(&LoopOrder::OS_ORDERS),
        }
    }

    #[test]
    fn constrain_lands_in_budget_and_is_idempotent() {
        let budgets = [
            SharedBudget::unconstrained(),
            SharedBudget { pe: 1024, buf_b: 96 * 1024, bw: 8 },
            SharedBudget { pe: 16, buf_b: 3 * BUF_MIN_B, bw: BW_MIN },
        ];
        let mut rng = Pcg32::seeded(51);
        for budget in budgets {
            budget.validate().unwrap();
            for _ in 0..300 {
                let raw: Vec<HwConfig> = (0..3).map(|_| wild(&mut rng)).collect();
                let cfg = constrain(&budget, raw);
                assert!(cfg.in_budget(&budget), "{cfg:?} escapes {budget:?}");
                let again = constrain(&budget, cfg.segments.clone());
                assert_eq!(cfg, again, "constrain not idempotent under {budget:?}");
            }
        }
    }

    #[test]
    fn encode_decode_roundtrip_on_constrained_configs() {
        let budget = SharedBudget { pe: 4096, buf_b: 512 * 1024, bw: 16 };
        let mut rng = Pcg32::seeded(52);
        for _ in 0..200 {
            let cfg = sample_structured(&mut rng, &budget, 3);
            let v = encode_structured(&cfg);
            assert_eq!(v.len(), structured_dim(3));
            let back = decode_structured(&v, &budget, 3);
            assert_eq!(back, cfg);
        }
    }

    #[test]
    fn segments_share_one_bandwidth() {
        let mut rng = Pcg32::seeded(53);
        let budget = SharedBudget::unconstrained();
        for _ in 0..100 {
            let cfg = sample_structured(&mut rng, &budget, 4);
            let bw = cfg.segments[0].bw;
            assert!(cfg.segments.iter().all(|h| h.bw == bw));
        }
    }

    #[test]
    fn cardinality_reaches_paper_scale() {
        let b = SharedBudget::unconstrained();
        // one segment is the plain target space (§V baseline grid)
        let one = cardinality(&b, 1);
        assert!((one / TargetSpace::cardinality() - 1.0).abs() < 1e-9, "{one:e}");
        // the structured setting exceeds the paper's O(10^17)
        assert!(cardinality(&b, 2) > 1e17);
        assert!(cardinality(&b, 3) > cardinality(&b, 2));
    }

    #[test]
    fn envelope_is_per_resource_max() {
        let a = HwConfig::new_kb(8, 64, 4.0, 64.0, 16.0, 8, LoopOrder::Mnk);
        let b = HwConfig::new_kb(32, 16, 128.0, 8.0, 4.0, 8, LoopOrder::Nmk);
        let env = StructuredConfig { segments: vec![a, b] }.envelope();
        assert_eq!((env.r, env.c), (32, 64));
        assert_eq!(env.ip_b, b.ip_b);
        assert_eq!(env.wt_b, a.wt_b);
        assert_eq!(env.op_b, a.op_b);
        assert_eq!(env.loop_order, LoopOrder::Mnk);
    }

    #[test]
    fn round_boundaries_repairs_and_is_idempotent() {
        let mut rng = Pcg32::seeded(54);
        for _ in 0..500 {
            let n = rng.int_range(2, 24) as usize;
            let k = rng.int_range(1, (n - 1) as i64) as usize;
            let raw: Vec<usize> = (0..k).map(|_| rng.int_range(0, 40) as usize).collect();
            let b = round_boundaries(&raw, n);
            assert!(boundaries_valid(&b, n), "{raw:?} -> {b:?} invalid over n={n}");
            assert_eq!(round_boundaries(&b, n), b, "not idempotent on {b:?}");
            let ranges = ranges_from_boundaries(&b, n);
            assert_eq!(ranges.len(), k + 1);
            assert!(ranges.iter().all(|r| !r.is_empty()));
            assert_eq!(ranges.last().unwrap().end, n);
        }
    }

    #[test]
    fn boundary_encode_decode_roundtrip() {
        let mut rng = Pcg32::seeded(55);
        for _ in 0..300 {
            let n = rng.int_range(3, 32) as usize;
            let k = rng.int_range(1, (n - 1) as i64) as usize;
            let raw: Vec<usize> = (0..k).map(|_| rng.int_range(0, n as i64) as usize).collect();
            let b = round_boundaries(&raw, n);
            let v = encode_boundaries(&b, n);
            assert!(v.iter().all(|&x| (0.0..1.0).contains(&x)));
            assert_eq!(decode_boundaries(&v, n), b);
        }
    }

    #[test]
    fn default_boundaries_match_even_partition_cuts() {
        assert_eq!(default_boundaries(6, 3), vec![2, 4]);
        assert_eq!(default_boundaries(7, 3), vec![2, 4]);
        assert_eq!(default_boundaries(4, 4), vec![1, 2, 3]);
        assert!(default_boundaries(5, 1).is_empty());
        assert!(default_boundaries(0, 3).is_empty());
    }

    #[test]
    fn composition_count_grows_cardinality() {
        assert_eq!(composition_count(6, 1), 1.0);
        assert_eq!(composition_count(6, 3), 10.0); // C(5, 2)
        assert_eq!(composition_count(4, 4), 1.0);
        assert_eq!(composition_count(3, 4), 0.0);
        let b = SharedBudget::unconstrained();
        let plain = cardinality(&b, 3);
        assert!((cardinality_with_boundaries(&b, 3, 12) / plain - composition_count(12, 3)).abs()
            < 1e-6 * composition_count(12, 3));
    }

    #[test]
    fn joint_encode_decode_roundtrip() {
        let budget = SharedBudget { pe: 4096, buf_b: 512 * 1024, bw: 16 };
        let mut rng = Pcg32::seeded(56);
        let n_layers = 12;
        for _ in 0..200 {
            let cfg = sample_structured(&mut rng, &budget, 3);
            let raw: Vec<usize> =
                (0..2).map(|_| rng.int_range(0, n_layers as i64) as usize).collect();
            let bounds = round_boundaries(&raw, n_layers);
            let v = encode_structured_with_boundaries(&cfg, &bounds, n_layers);
            assert_eq!(v.len(), structured_dim_with_boundaries(3));
            let (cfg2, bounds2) = decode_structured_with_boundaries(&v, &budget, 3, n_layers);
            assert_eq!(cfg2, cfg);
            assert_eq!(bounds2, bounds);
        }
    }

    #[test]
    fn shape_clustering_snaps_to_shape_changes() {
        // 6 layers: 3 of shape A, 2 of shape B, 1 of shape C — change
        // points at 3 and 5. Even cuts for s=3 are [2, 4]; both snap.
        let a = Gemm::new(64, 256, 256);
        let b = Gemm::new(64, 256, 1024);
        let c = Gemm::new(128, 512, 512);
        let shapes = vec![a, a, a, b, b, c];
        assert_eq!(segment_layers_by_shape(&shapes, 3), vec![3, 5]);
        // uniform shapes: no change points, falls back to even cuts
        let uniform = vec![a; 6];
        assert_eq!(segment_layers_by_shape(&uniform, 3), default_boundaries(6, 3));
        // degenerate inputs
        assert!(segment_layers_by_shape(&shapes, 1).is_empty());
        assert!(segment_layers_by_shape(&[], 3).is_empty());
    }

    #[test]
    fn budget_validation_rejects_impossible_envelopes() {
        assert!(SharedBudget { pe: 8, ..SharedBudget::unconstrained() }.validate().is_err());
        assert!(
            SharedBudget { buf_b: BUF_MIN_B, ..SharedBudget::unconstrained() }.validate().is_err()
        );
        assert!(SharedBudget { bw: 0, ..SharedBudget::unconstrained() }.validate().is_err());
        assert!(SharedBudget::unconstrained().validate().is_ok());
    }
}
