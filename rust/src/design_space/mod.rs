//! The accelerator design space of paper Table I/II.
//!
//! A hardware configuration is the 7-tuple (R, C, IPSz, WTSz, OPSz, BW,
//! LoopOrder). Two grids matter:
//!
//! * the **training design space** — the coarse 77,760-point grid the
//!   diffusion model is trained on (Table II left column), and
//! * the **target design space** — the full 5.26·10^17-point deployable grid
//!   (Table II right column) that generated designs are rounded into.
//!
//! This module owns the canonical numeric encoding shared with the python
//! compile path: all features min–max normalized to [0, 1] over the target
//! ranges, loop order one-hot appended (see [`encode`]).

pub mod encode;
pub mod params;
pub mod round;
pub mod structured;

pub use encode::{decode_rounded, encode_norm, NORM_DIM};
pub use params::{HwConfig, LoopOrder, TargetSpace, TrainingSpace};
pub use round::round_to_target;
pub use structured::{SharedBudget, StructuredConfig};
