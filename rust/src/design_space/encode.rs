//! Canonical numeric encoding of a [`HwConfig`] shared with the python
//! compile path (python/compile/norm.py mirrors these formulas; the pytest
//! suite pins golden vectors emitted from here via the dataset header).
//!
//! Layout (NORM_DIM = 8):
//! `[r, c, ip, wt, op, bw, loop_mnk, loop_nmk]`
//! where the first six entries are min–max normalized to [0, 1] over the
//! *target-space* ranges of Table I, and the last two are a one-hot (or, on
//! the decode side, logits to argmax) over the OS loop orders.

use super::params::{
    HwConfig, LoopOrder, BUF_MAX_B, BUF_MIN_B, BW_MAX, BW_MIN, DIM_MAX, DIM_MIN,
};
use super::round::round_to_target;

/// Width of the interchange vector.
pub const NORM_DIM: usize = 8;

fn norm(v: f64, lo: f64, hi: f64) -> f32 {
    ((v - lo) / (hi - lo)) as f32
}

fn denorm(v: f32, lo: f64, hi: f64) -> f64 {
    lo + (hi - lo) * v as f64
}

/// Encode a configuration to the normalized interchange vector.
pub fn encode_norm(hw: &HwConfig) -> [f32; NORM_DIM] {
    let mut out = [0f32; NORM_DIM];
    out[0] = norm(hw.r as f64, DIM_MIN as f64, DIM_MAX as f64);
    out[1] = norm(hw.c as f64, DIM_MIN as f64, DIM_MAX as f64);
    out[2] = norm(hw.ip_b as f64, BUF_MIN_B as f64, BUF_MAX_B as f64);
    out[3] = norm(hw.wt_b as f64, BUF_MIN_B as f64, BUF_MAX_B as f64);
    out[4] = norm(hw.op_b as f64, BUF_MIN_B as f64, BUF_MAX_B as f64);
    out[5] = norm(hw.bw as f64, BW_MIN as f64, BW_MAX as f64);
    out[6 + hw.loop_order.os_index()] = 1.0;
    out
}

/// Decode a (possibly out-of-range, continuous) interchange vector produced
/// by the diffusion sampler back into a valid target-space configuration:
/// inverse min–max transform, then snap to the target grid (paper §III-C
/// "rounded off to their nearest allowed state").
pub fn decode_rounded(v: &[f32]) -> HwConfig {
    assert_eq!(v.len(), NORM_DIM, "interchange vector must be {NORM_DIM}-wide");
    let loop_order = if v[6] >= v[7] { LoopOrder::Mnk } else { LoopOrder::Nmk };
    let raw = RawConfig {
        r: denorm(v[0], DIM_MIN as f64, DIM_MAX as f64),
        c: denorm(v[1], DIM_MIN as f64, DIM_MAX as f64),
        ip_b: denorm(v[2], BUF_MIN_B as f64, BUF_MAX_B as f64),
        wt_b: denorm(v[3], BUF_MIN_B as f64, BUF_MAX_B as f64),
        op_b: denorm(v[4], BUF_MIN_B as f64, BUF_MAX_B as f64),
        bw: denorm(v[5], BW_MIN as f64, BW_MAX as f64),
        loop_order,
    };
    round_to_target(&raw)
}

/// Continuous (pre-rounding) configuration in physical units.
#[derive(Debug, Clone, Copy)]
pub struct RawConfig {
    pub r: f64,
    pub c: f64,
    pub ip_b: f64,
    pub wt_b: f64,
    pub op_b: f64,
    pub bw: f64,
    pub loop_order: LoopOrder,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design_space::params::TargetSpace;
    use crate::util::rng::Pcg32;

    #[test]
    fn encode_decode_roundtrip_on_grid() {
        let mut rng = Pcg32::seeded(31);
        for _ in 0..1000 {
            let hw = TargetSpace::sample(&mut rng);
            let v = encode_norm(&hw);
            let back = decode_rounded(&v);
            assert_eq!(back, hw, "roundtrip failed for {hw}");
        }
    }

    #[test]
    fn encoded_values_in_unit_interval() {
        let mut rng = Pcg32::seeded(32);
        for _ in 0..200 {
            let hw = TargetSpace::sample(&mut rng);
            for (i, x) in encode_norm(&hw).iter().enumerate() {
                assert!((0.0..=1.0).contains(x), "feature {i} = {x} for {hw}");
            }
        }
    }

    #[test]
    fn decode_clamps_out_of_range() {
        // all features far out of range must still land in the target space
        let hw = decode_rounded(&[-3.0, 7.0, -1.0, 2.0, 0.5, 9.0, 0.2, 0.9]);
        assert!(hw.in_target_space(), "{hw}");
        assert_eq!(hw.r, DIM_MIN);
        assert_eq!(hw.c, DIM_MAX);
        assert_eq!(hw.ip_b, BUF_MIN_B);
        assert_eq!(hw.wt_b, BUF_MAX_B);
        assert_eq!(hw.bw, BW_MAX);
        assert_eq!(hw.loop_order, LoopOrder::Nmk);
    }

    #[test]
    fn loop_tie_breaks_to_mnk() {
        let hw = decode_rounded(&[0.5; NORM_DIM]);
        assert_eq!(hw.loop_order, LoopOrder::Mnk);
    }
}
