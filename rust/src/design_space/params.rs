//! Hardware configuration type and the two design-space grids of Table II.

/// Tile-loop ordering of the GEMM loop nest (paper Table I). The training and
/// target spaces of Table II use only the two output-stationary-friendly
/// orders {mnk, nmk}; the other four exist for the full Table I space and the
/// simulator handles all six.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LoopOrder {
    Mnk,
    Nmk,
    Knm,
    Nkm,
    Mkn,
    Kmn,
}

impl LoopOrder {
    pub const ALL: [LoopOrder; 6] = [
        LoopOrder::Mnk,
        LoopOrder::Nmk,
        LoopOrder::Knm,
        LoopOrder::Nkm,
        LoopOrder::Mkn,
        LoopOrder::Kmn,
    ];

    /// The orders admitted by the Table II training/target spaces.
    pub const OS_ORDERS: [LoopOrder; 2] = [LoopOrder::Mnk, LoopOrder::Nmk];

    pub fn name(&self) -> &'static str {
        match self {
            LoopOrder::Mnk => "mnk",
            LoopOrder::Nmk => "nmk",
            LoopOrder::Knm => "knm",
            LoopOrder::Nkm => "nkm",
            LoopOrder::Mkn => "mkn",
            LoopOrder::Kmn => "kmn",
        }
    }

    pub fn from_name(s: &str) -> Option<LoopOrder> {
        Self::ALL.iter().copied().find(|o| o.name() == s)
    }

    /// Loop nest outer→inner as dimension characters.
    pub fn nest(&self) -> [char; 3] {
        let s = self.name().as_bytes();
        [s[0] as char, s[1] as char, s[2] as char]
    }

    /// Index within [`LoopOrder::OS_ORDERS`] (the one-hot slot used by the
    /// canonical encoding). Panics for non-OS orders.
    pub fn os_index(&self) -> usize {
        Self::OS_ORDERS
            .iter()
            .position(|o| o == self)
            .unwrap_or_else(|| panic!("{} is not in the OS training space", self.name()))
    }
}

/// Buffer-size grid constants (bytes). Table I: 4–1024 kB, step 128 B.
pub const BUF_MIN_B: u64 = 4 * 1024;
pub const BUF_MAX_B: u64 = 1024 * 1024;
pub const BUF_STEP_B: u64 = 128;

/// Array-dimension bounds. Table I: 4–128, integers.
pub const DIM_MIN: u32 = 4;
pub const DIM_MAX: u32 = 128;

/// DRAM bandwidth bounds (bytes/cycle). Table I: 2–32, step 1.
pub const BW_MIN: u32 = 2;
pub const BW_MAX: u32 = 32;

/// One accelerator configuration (the 7 design parameters of Table I).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct HwConfig {
    /// systolic array rows (maps to the GEMM M dimension under OS dataflow)
    pub r: u32,
    /// systolic array columns (maps to N)
    pub c: u32,
    /// input (activation) SRAM size in bytes
    pub ip_b: u64,
    /// weight SRAM size in bytes
    pub wt_b: u64,
    /// output SRAM size in bytes
    pub op_b: u64,
    /// DRAM link bandwidth, bytes per cycle
    pub bw: u32,
    pub loop_order: LoopOrder,
}

impl HwConfig {
    pub fn new_kb(
        r: u32,
        c: u32,
        ip_kb: f64,
        wt_kb: f64,
        op_kb: f64,
        bw: u32,
        loop_order: LoopOrder,
    ) -> Self {
        let to_b = |kb: f64| (kb * 1024.0).round() as u64;
        HwConfig { r, c, ip_b: to_b(ip_kb), wt_b: to_b(wt_kb), op_b: to_b(op_kb), bw, loop_order }
    }

    pub fn macs(&self) -> u64 {
        self.r as u64 * self.c as u64
    }

    pub fn total_buf_b(&self) -> u64 {
        self.ip_b + self.wt_b + self.op_b
    }

    pub fn ip_kb(&self) -> f64 {
        self.ip_b as f64 / 1024.0
    }
    pub fn wt_kb(&self) -> f64 {
        self.wt_b as f64 / 1024.0
    }
    pub fn op_kb(&self) -> f64 {
        self.op_b as f64 / 1024.0
    }

    /// True iff every parameter lies on the target-space grid.
    pub fn in_target_space(&self) -> bool {
        let dim_ok = |d: u32| (DIM_MIN..=DIM_MAX).contains(&d);
        let buf_ok = |b: u64| {
            (BUF_MIN_B..=BUF_MAX_B).contains(&b) && (b - BUF_MIN_B) % BUF_STEP_B == 0
        };
        dim_ok(self.r)
            && dim_ok(self.c)
            && buf_ok(self.ip_b)
            && buf_ok(self.wt_b)
            && buf_ok(self.op_b)
            && (BW_MIN..=BW_MAX).contains(&self.bw)
            && LoopOrder::OS_ORDERS.contains(&self.loop_order)
    }
}

impl std::fmt::Display for HwConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}x{} ip={:.1}kB wt={:.1}kB op={:.1}kB bw={}B/cy {}",
            self.r,
            self.c,
            self.ip_kb(),
            self.wt_kb(),
            self.op_kb(),
            self.bw,
            self.loop_order.name()
        )
    }
}

/// The coarse training grid of Table II (exactly 77,760 points).
#[derive(Debug, Clone)]
pub struct TrainingSpace;

impl TrainingSpace {
    pub const DIMS: [u32; 6] = [4, 8, 16, 32, 64, 128];
    pub const BUF_KB: [u32; 6] = [4, 64, 128, 256, 512, 1024];
    pub const BWS: [u32; 5] = [2, 4, 8, 16, 32];

    pub fn len() -> usize {
        6 * 6 * 6 * 6 * 6 * 5 * 2
    }

    /// Enumerate every configuration in a fixed, reproducible order.
    pub fn enumerate() -> impl Iterator<Item = HwConfig> {
        Self::DIMS.iter().flat_map(move |&r| {
            Self::DIMS.iter().flat_map(move |&c| {
                Self::BUF_KB.iter().flat_map(move |&ip| {
                    Self::BUF_KB.iter().flat_map(move |&wt| {
                        Self::BUF_KB.iter().flat_map(move |&op| {
                            Self::BWS.iter().flat_map(move |&bw| {
                                LoopOrder::OS_ORDERS.iter().map(move |&lo| {
                                    HwConfig::new_kb(
                                        r, c, ip as f64, wt as f64, op as f64, bw, lo,
                                    )
                                })
                            })
                        })
                    })
                })
            })
        })
    }

    /// The i-th configuration of [`TrainingSpace::enumerate`] without
    /// materializing the iterator (mixed-radix decode).
    pub fn nth(mut i: usize) -> HwConfig {
        assert!(i < Self::len());
        let lo = LoopOrder::OS_ORDERS[i % 2];
        i /= 2;
        let bw = Self::BWS[i % 5];
        i /= 5;
        let op = Self::BUF_KB[i % 6];
        i /= 6;
        let wt = Self::BUF_KB[i % 6];
        i /= 6;
        let ip = Self::BUF_KB[i % 6];
        i /= 6;
        let c = Self::DIMS[i % 6];
        i /= 6;
        let r = Self::DIMS[i % 6];
        HwConfig::new_kb(r, c, ip as f64, wt as f64, op as f64, bw, lo)
    }
}

/// The fine-grained deployable grid of Table II (≈5.26·10^17 points).
#[derive(Debug, Clone)]
pub struct TargetSpace;

impl TargetSpace {
    pub fn n_dims() -> u64 {
        (DIM_MAX - DIM_MIN + 1) as u64
    }

    pub fn n_buf() -> u64 {
        (BUF_MAX_B - BUF_MIN_B) / BUF_STEP_B + 1
    }

    pub fn n_bw() -> u64 {
        (BW_MAX - BW_MIN + 1) as u64
    }

    /// Total cardinality |D| (as f64; exceeds u64 range meaningfully close to
    /// the paper's 5.26e17).
    pub fn cardinality() -> f64 {
        (Self::n_dims() as f64).powi(2)
            * (Self::n_buf() as f64).powi(3)
            * Self::n_bw() as f64
            * LoopOrder::OS_ORDERS.len() as f64
    }

    /// Uniformly sample a configuration from the target grid.
    pub fn sample(rng: &mut crate::util::rng::Pcg32) -> HwConfig {
        let dim = |rng: &mut crate::util::rng::Pcg32| {
            rng.int_range(DIM_MIN as i64, DIM_MAX as i64) as u32
        };
        let buf = |rng: &mut crate::util::rng::Pcg32| {
            let steps = (BUF_MAX_B - BUF_MIN_B) / BUF_STEP_B;
            BUF_MIN_B + BUF_STEP_B * rng.int_range(0, steps as i64) as u64
        };
        HwConfig {
            r: dim(rng),
            c: dim(rng),
            ip_b: buf(rng),
            wt_b: buf(rng),
            op_b: buf(rng),
            bw: rng.int_range(BW_MIN as i64, BW_MAX as i64) as u32,
            loop_order: *rng.choose(&LoopOrder::OS_ORDERS),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn training_space_has_paper_cardinality() {
        assert_eq!(TrainingSpace::len(), 77_760); // 6^5 * 5 * 2, paper §IV-A
        assert_eq!(TrainingSpace::enumerate().count(), 77_760);
    }

    #[test]
    fn target_space_matches_paper_order() {
        // paper Table II: 5.26e17
        let card = TargetSpace::cardinality();
        assert!((card / 5.26e17 - 1.0).abs() < 0.01, "cardinality {card:e}");
    }

    #[test]
    fn nth_agrees_with_enumerate() {
        let all: Vec<HwConfig> = TrainingSpace::enumerate().collect();
        let mut rng = Pcg32::seeded(1);
        for _ in 0..200 {
            let i = rng.index(all.len());
            assert_eq!(TrainingSpace::nth(i), all[i], "index {i}");
        }
        assert_eq!(TrainingSpace::nth(0), all[0]);
        assert_eq!(TrainingSpace::nth(all.len() - 1), all[all.len() - 1]);
    }

    #[test]
    fn enumerate_yields_unique_valid_configs() {
        let mut seen = std::collections::HashSet::new();
        for hw in TrainingSpace::enumerate() {
            assert!(hw.in_target_space(), "{hw}");
            assert!(seen.insert(hw), "duplicate {hw}");
        }
    }

    #[test]
    fn target_samples_on_grid() {
        let mut rng = Pcg32::seeded(2);
        for _ in 0..500 {
            let hw = TargetSpace::sample(&mut rng);
            assert!(hw.in_target_space(), "{hw}");
        }
    }

    #[test]
    fn loop_order_names_roundtrip() {
        for o in LoopOrder::ALL {
            assert_eq!(LoopOrder::from_name(o.name()), Some(o));
        }
        assert_eq!(LoopOrder::from_name("zzz"), None);
        assert_eq!(LoopOrder::Mnk.os_index(), 0);
        assert_eq!(LoopOrder::Nmk.os_index(), 1);
    }

    #[test]
    fn in_target_space_rejects_off_grid() {
        let mut hw = HwConfig::new_kb(8, 8, 64.0, 64.0, 64.0, 8, LoopOrder::Mnk);
        assert!(hw.in_target_space());
        hw.ip_b += 1; // off the 128 B grid
        assert!(!hw.in_target_space());
        hw.ip_b -= 1;
        hw.r = 129;
        assert!(!hw.in_target_space());
        hw.r = 8;
        hw.loop_order = LoopOrder::Kmn;
        assert!(!hw.in_target_space());
    }
}
