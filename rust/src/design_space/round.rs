//! Snap continuous configurations onto the target-space grid (paper §III-C:
//! generated parameters are "rounded off to their nearest allowed state
//! depending on the target design space granularity").

use super::encode::RawConfig;
use super::params::{
    HwConfig, BUF_MAX_B, BUF_MIN_B, BUF_STEP_B, BW_MAX, BW_MIN, DIM_MAX, DIM_MIN,
};

fn round_clamp_int(v: f64, lo: u32, hi: u32) -> u32 {
    (v.round().max(lo as f64).min(hi as f64)) as u32
}

fn round_buf(v: f64) -> u64 {
    let clamped = v.max(BUF_MIN_B as f64).min(BUF_MAX_B as f64);
    let steps = ((clamped - BUF_MIN_B as f64) / BUF_STEP_B as f64).round() as u64;
    BUF_MIN_B + steps * BUF_STEP_B
}

/// Nearest valid target-space configuration to `raw`.
pub fn round_to_target(raw: &RawConfig) -> HwConfig {
    HwConfig {
        r: round_clamp_int(raw.r, DIM_MIN, DIM_MAX),
        c: round_clamp_int(raw.c, DIM_MIN, DIM_MAX),
        ip_b: round_buf(raw.ip_b),
        wt_b: round_buf(raw.wt_b),
        op_b: round_buf(raw.op_b),
        bw: round_clamp_int(raw.bw, BW_MIN, BW_MAX),
        loop_order: raw.loop_order,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design_space::params::LoopOrder;
    use crate::util::rng::Pcg32;

    fn random_raw(rng: &mut Pcg32) -> RawConfig {
        RawConfig {
            r: rng.range_f64(-50.0, 300.0),
            c: rng.range_f64(-50.0, 300.0),
            ip_b: rng.range_f64(-1e6, 3e6),
            wt_b: rng.range_f64(-1e6, 3e6),
            op_b: rng.range_f64(-1e6, 3e6),
            bw: rng.range_f64(-10.0, 100.0),
            loop_order: *rng.choose(&LoopOrder::OS_ORDERS),
        }
    }

    #[test]
    fn always_lands_in_target_space() {
        let mut rng = Pcg32::seeded(41);
        for _ in 0..2000 {
            let hw = round_to_target(&random_raw(&mut rng));
            assert!(hw.in_target_space(), "{hw}");
        }
    }

    #[test]
    fn rounding_is_idempotent() {
        let mut rng = Pcg32::seeded(42);
        for _ in 0..500 {
            let hw = round_to_target(&random_raw(&mut rng));
            let again = round_to_target(&RawConfig {
                r: hw.r as f64,
                c: hw.c as f64,
                ip_b: hw.ip_b as f64,
                wt_b: hw.wt_b as f64,
                op_b: hw.op_b as f64,
                bw: hw.bw as f64,
                loop_order: hw.loop_order,
            });
            assert_eq!(hw, again);
        }
    }

    #[test]
    fn rounds_to_nearest_grid_point() {
        // 4 kB + 63 B rounds down; + 65 B rounds up
        let base = RawConfig {
            r: 10.4,
            c: 10.6,
            ip_b: (BUF_MIN_B + 63) as f64,
            wt_b: (BUF_MIN_B + 65) as f64,
            op_b: BUF_MIN_B as f64,
            bw: 7.5,
            loop_order: LoopOrder::Mnk,
        };
        let hw = round_to_target(&base);
        assert_eq!(hw.r, 10);
        assert_eq!(hw.c, 11);
        assert_eq!(hw.ip_b, BUF_MIN_B);
        assert_eq!(hw.wt_b, BUF_MIN_B + BUF_STEP_B);
        assert_eq!(hw.bw, 8);
    }
}
