//! Typed wrappers over the AOT artifacts: the normalization contract
//! ([`norm`]) and the compiled model engine ([`engine`]).

pub mod engine;
pub mod norm;

pub use engine::{ClassMode, DiffAxE};
pub use norm::{NormStats, WorkloadStats};
