//! Typed wrappers over the AOT artifacts: the normalization contract
//! ([`norm`]), the compiled model engine ([`engine`]), and the hermetic
//! deterministic stand-in backend ([`mock`]) used when no artifacts exist.

pub mod engine;
pub mod mock;
pub mod norm;

pub use engine::{ClassMode, DiffAxE};
pub use mock::MockEngine;
pub use norm::{NormStats, WorkloadStats};
