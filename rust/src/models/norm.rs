//! `norm_stats.json` — the normalization contract between the python
//! compile path and the rust request path. Mirrors python/compile/norm.py.

use crate::util::json::Json;
use crate::util::stats::bin_index;
use crate::workload::Gemm;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Per-workload label statistics and class edges.
#[derive(Debug, Clone)]
pub struct WorkloadStats {
    pub gemm: Gemm,
    pub log_rt_min: f64,
    pub log_rt_max: f64,
    pub power_min: f64,
    pub power_max: f64,
    pub log_edp_min: f64,
    pub log_edp_max: f64,
    pub power_edges: Vec<f64>,
    pub rt_edges: Vec<f64>,
    pub edp_edges: Vec<f64>,
}

impl WorkloadStats {
    fn span(lo: f64, hi: f64) -> f64 {
        (hi - lo).max(1e-9)
    }

    /// runtime cycles → normalized conditioning value in [0,1]
    pub fn norm_runtime(&self, cycles: f64) -> f32 {
        ((cycles.ln() - self.log_rt_min) / Self::span(self.log_rt_min, self.log_rt_max)) as f32
    }

    /// normalized value → runtime cycles
    pub fn denorm_runtime(&self, p: f64) -> f64 {
        (p * Self::span(self.log_rt_min, self.log_rt_max) + self.log_rt_min).exp()
    }

    /// observed runtime range in the training data
    pub fn runtime_range(&self) -> (f64, f64) {
        (self.log_rt_min.exp(), self.log_rt_max.exp())
    }

    /// Eq. 8 power–performance class of a simulated design.
    pub fn power_perf_class(&self, power_w: f64, cycles: f64, n_power: usize) -> usize {
        bin_index(&self.power_edges, power_w) + n_power * bin_index(&self.rt_edges, cycles)
    }

    pub fn edp_class(&self, edp: f64) -> usize {
        bin_index(&self.edp_edges, edp)
    }
}

/// Parsed `norm_stats.json`.
#[derive(Debug, Clone)]
pub struct NormStats {
    pub scale: String,
    pub t_steps: usize,
    pub gen_batch: usize,
    pub pp_batch: usize,
    pub latent_dim: usize,
    pub hw_dim: usize,
    pub n_power: usize,
    pub n_perf: usize,
    pub n_edp: usize,
    pub param_counts: HashMap<String, usize>,
    pub airchitect_grid: Vec<Vec<f32>>,
    pub workloads: Vec<WorkloadStats>,
    by_mkn: HashMap<(u32, u32, u32), usize>,
}

impl NormStats {
    pub fn load(path: &Path) -> Result<NormStats> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing norm_stats.json")?;
        let usz = |key: &str| -> Result<usize> {
            j.get(key).as_usize().with_context(|| format!("norm_stats.{key}"))
        };
        let mut workloads = Vec::new();
        let mut by_mkn = HashMap::new();
        for (i, w) in j.get("workloads").as_arr().context("workloads")?.iter().enumerate() {
            let g = Gemm::new(
                w.get("m").as_usize().context("m")? as u32,
                w.get("k").as_usize().context("k")? as u32,
                w.get("n").as_usize().context("n")? as u32,
            );
            by_mkn.insert((g.m, g.k, g.n), i);
            let f = |key: &str| -> Result<f64> {
                w.get(key).as_f64().with_context(|| format!("workload.{key}"))
            };
            workloads.push(WorkloadStats {
                gemm: g,
                log_rt_min: f("log_rt_min")?,
                log_rt_max: f("log_rt_max")?,
                power_min: f("power_min")?,
                power_max: f("power_max")?,
                log_edp_min: f("log_edp_min")?,
                log_edp_max: f("log_edp_max")?,
                power_edges: w.get("power_edges").as_f64_vec().context("power_edges")?,
                rt_edges: w.get("rt_edges").as_f64_vec().context("rt_edges")?,
                edp_edges: w.get("edp_edges").as_f64_vec().context("edp_edges")?,
            });
        }
        let param_counts = j
            .get("param_counts")
            .as_obj()
            .map(|o| {
                o.iter()
                    .filter_map(|(k, v)| v.as_usize().map(|n| (k.clone(), n)))
                    .collect()
            })
            .unwrap_or_default();
        let airchitect_grid = j
            .get("airchitect_grid")
            .as_arr()
            .map(|rows| rows.iter().filter_map(|r| r.as_f32_vec()).collect())
            .unwrap_or_default();
        Ok(NormStats {
            scale: j.get("scale").as_str().unwrap_or("unknown").to_string(),
            t_steps: usz("t_steps")?,
            gen_batch: usz("gen_batch")?,
            pp_batch: usz("pp_batch")?,
            latent_dim: usz("latent_dim")?,
            hw_dim: usz("hw_dim")?,
            n_power: usz("n_power")?,
            n_perf: usz("n_perf")?,
            n_edp: usz("n_edp")?,
            param_counts,
            airchitect_grid,
            workloads,
            by_mkn,
        })
    }

    /// A self-consistent synthetic contract for the hermetic
    /// [`crate::models::DiffAxE::mock`] engine: no artifacts, no files.
    /// Per-workload label ranges and class edges are **calibrated** by
    /// probing a deterministic quick-scale spread of the training space
    /// through the real label pipeline (analytical simulator + 32 nm ASIC
    /// energy model — the same pipeline `diffaxe gen-dataset` writes), so
    /// mock conditioning tracks the real normalization contract instead
    /// of a MAC-count heuristic: `norm_runtime`/`denorm_runtime` span the
    /// cycle counts the simulator actually produces, and class edges sit
    /// at observed label quantiles. The probe runs once per process (the
    /// result is memoized); the AIRCHITECT grid is a spread of
    /// training-space encodings.
    pub fn synthetic() -> NormStats {
        use std::sync::OnceLock;
        static SYNTHETIC: OnceLock<NormStats> = OnceLock::new();
        SYNTHETIC.get_or_init(Self::build_synthetic).clone()
    }

    /// Training-space probe density per workload for the synthetic
    /// contract's calibration (a quick-scale dataset: deterministic
    /// stride over the full space, no sampling).
    pub const CALIBRATION_PROBES: usize = 256;

    /// The workloads the synthetic contract is calibrated over (a spread
    /// of transformer-ish layer shapes).
    pub fn synthetic_gemms() -> [Gemm; 4] {
        [
            Gemm::new(128, 768, 2304),
            Gemm::new(128, 768, 768),
            Gemm::new(64, 256, 512),
            Gemm::new(32, 128, 256),
        ]
    }

    /// Measure one workload's stats from the calibration probe: min/max
    /// label ranges plus quantile class edges (`bins + 1` edge values for
    /// `bins` classes, matching the python compile path's contract).
    pub fn calibrated_stats(g: &Gemm) -> WorkloadStats {
        use crate::design_space::TrainingSpace;
        let step = (TrainingSpace::len() / Self::CALIBRATION_PROBES).max(1);
        let mut rts = Vec::with_capacity(Self::CALIBRATION_PROBES);
        let mut powers = Vec::with_capacity(Self::CALIBRATION_PROBES);
        let mut edps = Vec::with_capacity(Self::CALIBRATION_PROBES);
        for i in 0..Self::CALIBRATION_PROBES {
            let hw = TrainingSpace::nth(i * step);
            let sim = crate::sim::simulate(&hw, g);
            let e = crate::energy::asic::evaluate(&hw, &sim);
            rts.push(sim.cycles as f64);
            powers.push(e.power_w);
            edps.push(e.edp);
        }
        rts.sort_by(f64::total_cmp);
        powers.sort_by(f64::total_cmp);
        edps.sort_by(f64::total_cmp);
        // quantile edges over the sorted probe labels: edge k of `bins`
        // sits at the k/bins quantile, so classes are balanced over what
        // the simulator actually produces
        let q = |v: &[f64], bins: usize| -> Vec<f64> {
            (0..=bins).map(|k| v[(v.len() - 1) * k / bins]).collect()
        };
        WorkloadStats {
            gemm: *g,
            log_rt_min: rts[0].ln(),
            log_rt_max: rts[rts.len() - 1].ln(),
            power_min: powers[0],
            power_max: powers[powers.len() - 1],
            log_edp_min: edps[0].ln(),
            log_edp_max: edps[edps.len() - 1].ln(),
            power_edges: q(&powers, 3),
            rt_edges: q(&rts, 3),
            edp_edges: q(&edps, 10),
        }
    }

    fn build_synthetic() -> NormStats {
        use crate::design_space::{encode_norm, TrainingSpace};
        let mut workloads = Vec::new();
        let mut by_mkn = HashMap::new();
        for (i, g) in Self::synthetic_gemms().iter().enumerate() {
            by_mkn.insert((g.m, g.k, g.n), i);
            workloads.push(Self::calibrated_stats(g));
        }
        // 32 spread training-grid points as the recommendation grid
        let step = TrainingSpace::len() / 32;
        let airchitect_grid = (0..32)
            .map(|i| encode_norm(&TrainingSpace::nth(i * step)).to_vec())
            .collect();
        NormStats {
            scale: "mock".to_string(),
            t_steps: 4,
            gen_batch: 16,
            pp_batch: 32,
            latent_dim: 16,
            hw_dim: crate::design_space::NORM_DIM,
            n_power: 3,
            n_perf: 3,
            n_edp: 10,
            param_counts: HashMap::new(),
            airchitect_grid,
            workloads,
            by_mkn,
        }
    }

    /// Stats for a workload: exact match, or nearest training workload in
    /// normalized (M,K,N) space for unseen shapes.
    pub fn stats_for(&self, g: &Gemm) -> &WorkloadStats {
        if let Some(&i) = self.by_mkn.get(&(g.m, g.k, g.n)) {
            return &self.workloads[i];
        }
        let target = g.norm_vec();
        let mut best = 0;
        let mut best_d = f64::INFINITY;
        for (i, w) in self.workloads.iter().enumerate() {
            let v = w.gemm.norm_vec();
            let d: f64 = target
                .iter()
                .zip(&v)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum();
            if d < best_d {
                best_d = d;
                best = i;
            }
        }
        &self.workloads[best]
    }

    /// Is this workload one the models were trained on?
    pub fn is_known(&self, g: &Gemm) -> bool {
        self.by_mkn.contains_key(&(g.m, g.k, g.n))
    }

    /// The joint conditioning vector of the structured (jointly-conditioned)
    /// sampler: the shared budget min–max normalized over the unconstrained
    /// Table II envelope, followed by each segment's `(class, w_norm)`
    /// conditioning with the class normalized over the Eq. 8 class count.
    /// Layout: `[pe, buf, bw, class₀, m₀, k₀, n₀, class₁, …]` — width
    /// `3 + 4·S`. Both backends derive their joint behaviour from this one
    /// vector, so the conditioning contract is shared (and testable) here.
    pub fn joint_cond_vec(
        &self,
        budget: &crate::design_space::SharedBudget,
        conds: &[(i32, [f32; 3])],
    ) -> Vec<f32> {
        use crate::design_space::params::{BUF_MAX_B, BUF_MIN_B, BW_MAX, BW_MIN, DIM_MAX, DIM_MIN};
        let norm = |v: f64, lo: f64, hi: f64| (((v - lo) / (hi - lo).max(1e-9)) as f32).clamp(0.0, 1.0);
        let n_classes = (self.n_power * self.n_perf).max(2);
        let mut v = Vec::with_capacity(3 + 4 * conds.len());
        v.push(norm(budget.pe as f64, (DIM_MIN * DIM_MIN) as f64, (DIM_MAX * DIM_MAX) as f64));
        v.push(norm(budget.buf_b as f64, (3 * BUF_MIN_B) as f64, (3 * BUF_MAX_B) as f64));
        v.push(norm(budget.bw as f64, BW_MIN as f64, BW_MAX as f64));
        for (class, w) in conds {
            v.push((*class).clamp(0, n_classes as i32 - 1) as f32 / (n_classes - 1) as f32);
            v.extend_from_slice(w);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> String {
        r#"{
          "scale": "quick", "t_steps": 16, "gen_batch": 16, "pp_batch": 256,
          "latent_dim": 128, "hw_dim": 8, "n_power": 3, "n_perf": 3, "n_edp": 10,
          "param_counts": {"ddm": 1000, "ae_pp": 2000},
          "airchitect_grid": [[0,0,0,0,0,0,1,0],[1,1,1,1,1,1,0,1]],
          "workloads": [
            {"m": 32, "k": 64, "n": 128,
             "log_rt_min": 6.0, "log_rt_max": 12.0,
             "power_min": 0.1, "power_max": 2.0,
             "log_edp_min": 10.0, "log_edp_max": 20.0,
             "power_edges": [0.1, 0.5, 1.0, 2.0],
             "rt_edges": [400.0, 1000.0, 10000.0, 160000.0],
             "edp_edges": [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0, 11.0]}
          ]
        }"#
        .to_string()
    }

    fn load_sample() -> NormStats {
        let dir = std::env::temp_dir().join(format!("diffaxe_norm_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("norm_stats.json");
        std::fs::write(&p, sample_json()).unwrap();
        let s = NormStats::load(&p).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        s
    }

    #[test]
    fn parses_all_fields() {
        let s = load_sample();
        assert_eq!(s.t_steps, 16);
        assert_eq!(s.gen_batch, 16);
        assert_eq!(s.workloads.len(), 1);
        assert_eq!(s.param_counts["ddm"], 1000);
        assert_eq!(s.airchitect_grid.len(), 2);
        assert_eq!(s.airchitect_grid[0].len(), 8);
    }

    #[test]
    fn runtime_norm_roundtrip() {
        let s = load_sample();
        let w = &s.workloads[0];
        for cycles in [500.0, 5_000.0, 120_000.0] {
            let p = w.norm_runtime(cycles);
            let back = w.denorm_runtime(p as f64);
            assert!((back / cycles - 1.0).abs() < 1e-5, "{cycles} -> {p} -> {back}");
        }
        assert!((w.norm_runtime(w.runtime_range().0) - 0.0).abs() < 1e-6);
        assert!((w.norm_runtime(w.runtime_range().1) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn class_assignment_matches_eq8() {
        let s = load_sample();
        let w = &s.workloads[0];
        // power 0.7 -> bin 1; runtime 50000 -> bin 2; class = 1 + 3*2 = 7
        assert_eq!(w.power_perf_class(0.7, 50_000.0, 3), 7);
        assert_eq!(w.edp_class(5.5), 4);
        assert_eq!(w.edp_class(-1.0), 0); // clamps
        assert_eq!(w.edp_class(99.0), 9);
    }

    #[test]
    fn synthetic_stats_are_calibrated_to_the_simulator() {
        use crate::design_space::TrainingSpace;
        let s = NormStats::synthetic();
        assert_eq!(s.scale, "mock");
        assert_eq!(s.workloads.len(), 4);
        let step = (TrainingSpace::len() / NormStats::CALIBRATION_PROBES).max(1);
        for w in &s.workloads {
            // regression pin: the label ranges are exactly the observed
            // extremes of the deterministic calibration probe through the
            // real simulate + asic::evaluate pipeline — the normalization
            // contract cannot drift from what the simulator produces
            let mut rt = (f64::INFINITY, f64::NEG_INFINITY);
            let mut pw = (f64::INFINITY, f64::NEG_INFINITY);
            let mut edp = (f64::INFINITY, f64::NEG_INFINITY);
            for i in 0..NormStats::CALIBRATION_PROBES {
                let hw = TrainingSpace::nth(i * step);
                let sim = crate::sim::simulate(&hw, &w.gemm);
                let e = crate::energy::asic::evaluate(&hw, &sim);
                rt = (rt.0.min(sim.cycles as f64), rt.1.max(sim.cycles as f64));
                pw = (pw.0.min(e.power_w), pw.1.max(e.power_w));
                edp = (edp.0.min(e.edp), edp.1.max(e.edp));
            }
            assert_eq!(w.log_rt_min, rt.0.ln(), "{}", w.gemm);
            assert_eq!(w.log_rt_max, rt.1.ln(), "{}", w.gemm);
            assert_eq!((w.power_min, w.power_max), pw, "{}", w.gemm);
            assert_eq!(w.log_edp_min, edp.0.ln(), "{}", w.gemm);
            assert_eq!(w.log_edp_max, edp.1.ln(), "{}", w.gemm);
            // edge vectors: bins + 1 quantile edges, monotone, spanning
            // the observed range
            assert_eq!(w.power_edges.len(), s.n_power + 1);
            assert_eq!(w.rt_edges.len(), s.n_perf + 1);
            assert_eq!(w.edp_edges.len(), s.n_edp + 1);
            for e in [&w.power_edges, &w.rt_edges, &w.edp_edges] {
                assert!(e.windows(2).all(|p| p[0] <= p[1]), "{e:?}");
            }
            assert_eq!(w.power_edges[0], pw.0);
            assert_eq!(*w.power_edges.last().unwrap(), pw.1);
            // the normalization round-trips over the calibrated range
            let (lo, hi) = w.runtime_range();
            assert!((w.norm_runtime(lo) - 0.0).abs() < 1e-6);
            assert!((w.norm_runtime(hi) - 1.0).abs() < 1e-6);
            assert!(lo < hi, "degenerate calibrated range for {}", w.gemm);
        }
        // memoization: a second call observes the identical contract
        let again = NormStats::synthetic();
        for (a, b) in s.workloads.iter().zip(&again.workloads) {
            assert_eq!(a.log_rt_min, b.log_rt_min);
            assert_eq!(a.edp_edges, b.edp_edges);
        }
    }

    #[test]
    fn joint_cond_vec_layout_and_normalization() {
        use crate::design_space::SharedBudget;
        let s = NormStats::synthetic();
        let g0 = Gemm::new(128, 768, 2304);
        let g1 = Gemm::new(64, 256, 512);
        let conds = [(0, g0.norm_vec()), (8, g1.norm_vec())];
        let v = s.joint_cond_vec(&SharedBudget::unconstrained(), &conds);
        assert_eq!(v.len(), 3 + 4 * conds.len());
        // unconstrained budget normalizes to the top of every range
        assert_eq!(&v[..3], &[1.0, 1.0, 1.0]);
        // classes: 0 -> 0.0, last (n_power*n_perf - 1 = 8) -> 1.0
        assert_eq!(v[3], 0.0);
        assert_eq!(v[8], 1.0);
        assert!(v.iter().all(|&x| (0.0..=1.0).contains(&x)));
        // the vector is sensitive to the budget (the joint conditioning
        // actually carries the shared envelope)
        let tight = SharedBudget { pe: 256, buf_b: 96 * 1024, bw: 8 };
        assert_ne!(s.joint_cond_vec(&tight, &conds)[..3], v[..3]);
    }

    #[test]
    fn nearest_workload_fallback() {
        let s = load_sample();
        let exact = Gemm::new(32, 64, 128);
        assert!(s.is_known(&exact));
        let near = Gemm::new(33, 64, 130);
        assert!(!s.is_known(&near));
        assert_eq!(s.stats_for(&near).gemm, exact);
    }
}
