//! Hermetic mock engine: a deterministic, artifact-free stand-in for the
//! compiled AOT executables behind the [`crate::models::DiffAxE`] surface.
//!
//! CI has no `artifacts/` directory, so every engine-kind code path
//! (samplers, latent plumbing, gradients, recommenders) used to SKIP
//! vacuously in the integration suites. The mock keeps those paths
//! executable: it speaks the exact batch/shape/seed contract of the
//! compiled engine ([`crate::models::engine`] enforces the shared
//! invariants before dispatch) and produces *quality-biased* candidates —
//! conditioned sampling internally draws a handful of seeded target-space
//! candidates and selects by the conditioning metric through the shared
//! [`EvalCache`], the way the learned diffusion model concentrates its
//! samples. Everything is a pure function of `(stats, seed, inputs)`, so
//! searches stay deterministic in their seed, exactly like the compiled
//! engine.
//!
//! This is a *test double with teeth*, not a model: it exists so the
//! DiffAxE/GANDSE/LatentBo/Polaris/AIRCHITECT code paths execute (and keep
//! their determinism / deadline / cancellation / protocol contracts) in
//! hermetic CI. Real-artifact runs remain the opt-in superset — every
//! suite prefers `artifacts/` when present.

use super::engine::ClassMode;
use super::norm::NormStats;
use crate::design_space::structured::constrain;
use crate::design_space::{decode_rounded, HwConfig, SharedBudget, TargetSpace};
use crate::dse::eval::EvalCache;
use crate::util::rng::{self, Pcg32};
use crate::workload::gemm::{K_MAX, M_MAX, N_MAX};
use crate::workload::Gemm;
use anyhow::Result;

/// Candidate pool per conditioned slot (runtime conditioning).
const K_RUNTIME: usize = 6;
/// Candidate pool per conditioned slot (class conditioning).
const K_CLASS: usize = 8;
/// Joint-candidate pool per structured slot: each joint candidate is S
/// correlated segment draws, so the per-slot eval cost (`K_JOINT · S`)
/// matches the independent path's `S · K_CLASS`.
const K_JOINT: usize = 8;
/// GANDSE draws fewer internal candidates: a deliberately weaker one-shot
/// generator, as the paper's baseline ordering expects.
const K_GANDSE: usize = 2;

/// The stateless mock backend (all behaviour derives from the call inputs).
#[derive(Debug, Clone, Copy, Default)]
pub struct MockEngine;

/// Invert [`Gemm::norm_vec`]: recover the conditioning workload from its
/// normalized vector (exact for in-range shapes).
fn gemm_from_norm(w: &[f32; 3]) -> Gemm {
    let un = |v: f32, max: u32| {
        (((v as f64) * (max - 1) as f64).round() as i64 + 1).clamp(1, max as i64) as u32
    };
    Gemm::new(un(w[0], M_MAX), un(w[1], K_MAX), un(w[2], N_MAX))
}

/// Draw `k` seeded target-space candidates and score each with the shared
/// (memoized) evaluator.
fn candidates(seed: u32, slot: usize, k: usize, g: &Gemm) -> Vec<(HwConfig, f64, f64)> {
    let mut rng = rng::split(seed as u64, slot as u64);
    (0..k)
        .map(|_| {
            let hw = TargetSpace::sample(&mut rng);
            let (s, e) = EvalCache::global().evaluate(&hw, g);
            (hw, s.cycles as f64, e.edp)
        })
        .collect()
}

impl MockEngine {
    /// Runtime-conditioned generation: per slot, the candidate whose cycle
    /// count lands closest to the denormalized target.
    pub fn sample_runtime(
        &self,
        stats: &NormStats,
        seed: u32,
        conds: &[(f32, [f32; 3])],
    ) -> Vec<HwConfig> {
        conds
            .iter()
            .enumerate()
            .map(|(i, (p, w))| {
                let g = gemm_from_norm(w);
                let target = stats.stats_for(&g).denorm_runtime(*p as f64);
                candidates(seed, i, K_RUNTIME, &g)
                    .into_iter()
                    .min_by(|a, b| (a.1 - target).abs().total_cmp(&(b.1 - target).abs()))
                    .map(|(hw, _, _)| hw)
                    .expect("non-empty candidate pool")
            })
            .collect()
    }

    /// Class-conditioned generation: per slot, rank the candidate pool by
    /// EDP and pick the order statistic the class index maps to — class 0
    /// is the best-EDP pick, the last class the worst, mirroring how the
    /// trained sampler's classes grade the metric space.
    pub fn sample_class(
        &self,
        stats: &NormStats,
        mode: ClassMode,
        seed: u32,
        conds: &[(i32, [f32; 3])],
    ) -> Vec<HwConfig> {
        let n_classes = match mode {
            ClassMode::Edp => stats.n_power * stats.n_perf,
            ClassMode::PerfOpt => stats.n_edp,
        }
        .max(1);
        conds
            .iter()
            .enumerate()
            .map(|(i, (class, w))| {
                let g = gemm_from_norm(w);
                let mut pool = candidates(seed, i, K_CLASS, &g);
                pool.sort_by(|a, b| a.2.total_cmp(&b.2));
                let class = (*class).clamp(0, n_classes as i32 - 1) as usize;
                let idx =
                    if n_classes == 1 { 0 } else { class * (pool.len() - 1) / (n_classes - 1) };
                pool[idx].0
            })
            .collect()
    }

    /// Jointly-conditioned structured generation (paper §V): each of the
    /// `n_joint` slots draws [`K_JOINT`] *joint* candidates — one
    /// correlated target-space draw per segment, projected through
    /// [`constrain`] into the shared budget **before** scoring — ranks
    /// them by summed per-segment EDP on the segment representative
    /// shapes, and picks the order statistic the (shared) class index
    /// maps to. The correlations are generated, not projected: selection
    /// sees only whole constrained joint candidates, so cross-segment
    /// trade-offs (one DRAM link, buffer splits under one SRAM cap) shape
    /// which candidate wins. Seeding folds in the joint conditioning
    /// vector so the draws respond to the budget like the trained
    /// sampler's conditioning would.
    pub fn sample_joint(
        &self,
        stats: &NormStats,
        mode: ClassMode,
        seed: u32,
        budget: &SharedBudget,
        conds: &[(i32, [f32; 3])],
        n_joint: usize,
    ) -> Vec<Vec<HwConfig>> {
        let n_classes = match mode {
            ClassMode::Edp => stats.n_power * stats.n_perf,
            ClassMode::PerfOpt => stats.n_edp,
        }
        .max(1);
        let gemms: Vec<Gemm> = conds.iter().map(|(_, w)| gemm_from_norm(w)).collect();
        // fold the conditioning vector into the seed: a different budget
        // (or class/shape mix) decorrelates the draw streams
        let cond_mix = stats
            .joint_cond_vec(budget, conds)
            .iter()
            .fold(seed as u64, |acc, &x| rng::derive(acc, x.to_bits() as u64));
        (0..n_joint)
            .map(|slot| {
                let mut rng = rng::split(cond_mix, slot as u64);
                let mut pool: Vec<(Vec<HwConfig>, f64)> = (0..K_JOINT)
                    .map(|_| {
                        let draws: Vec<HwConfig> =
                            gemms.iter().map(|_| TargetSpace::sample(&mut rng)).collect();
                        let joint = constrain(budget, draws);
                        let score: f64 = joint
                            .segments
                            .iter()
                            .zip(&gemms)
                            .map(|(hw, g)| EvalCache::global().evaluate(hw, g).1.edp)
                            .sum();
                        (joint.segments, score)
                    })
                    .collect();
                pool.sort_by(|a, b| a.1.total_cmp(&b.1));
                let class = conds[0].0.clamp(0, n_classes as i32 - 1) as usize;
                let idx =
                    if n_classes == 1 { 0 } else { class * (pool.len() - 1) / (n_classes - 1) };
                pool.swap_remove(idx).0
            })
            .collect()
    }

    /// GANDSE one-shot generation: the runtime selection over a smaller
    /// pool (a weaker generator than the diffusion sampler, by design).
    pub fn gandse_generate(
        &self,
        stats: &NormStats,
        seed: u32,
        conds: &[(f32, [f32; 3])],
    ) -> Vec<HwConfig> {
        conds
            .iter()
            .enumerate()
            .map(|(i, (p, w))| {
                let g = gemm_from_norm(w);
                let target = stats.stats_for(&g).denorm_runtime(*p as f64);
                candidates(seed, i, K_GANDSE, &g)
                    .into_iter()
                    .min_by(|a, b| (a.1 - target).abs().total_cmp(&(b.1 - target).abs()))
                    .map(|(hw, _, _)| hw)
                    .expect("non-empty candidate pool")
            })
            .collect()
    }

    /// Mock autoencoder: the hardware vector embedded in the first
    /// `hw_dim` latent coordinates, zero-padded — an exact-roundtrip
    /// (identity-on-subspace) encoder, so latent-space searches decode to
    /// meaningful configurations.
    pub fn encode(&self, stats: &NormStats, hw_rows: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        hw_rows
            .iter()
            .map(|row| {
                anyhow::ensure!(
                    row.len() == stats.hw_dim,
                    "row width {} != hw_dim {}",
                    row.len(),
                    stats.hw_dim
                );
                let mut lat = row.clone();
                lat.resize(stats.latent_dim, 0.0);
                Ok(lat)
            })
            .collect()
    }

    /// Inverse of [`MockEngine::encode`]: the first `hw_dim` coordinates.
    pub fn decode(&self, stats: &NormStats, latents: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        latents
            .iter()
            .map(|lat| {
                anyhow::ensure!(
                    lat.len() == stats.latent_dim,
                    "latent width {} != latent_dim {}",
                    lat.len(),
                    stats.latent_dim
                );
                Ok(lat[..stats.hw_dim].to_vec())
            })
            .collect()
    }

    /// Smooth PP proxy: prediction = mean of the hardware coordinates of
    /// the latent. Differentiable, so the latent-GD baselines have a real
    /// gradient to follow.
    fn pp_pred(&self, stats: &NormStats, lat: &[f32]) -> f32 {
        let d = stats.hw_dim.min(lat.len()).max(1);
        lat[..d].iter().sum::<f32>() / d as f32
    }

    pub fn pp_predict(
        &self,
        stats: &NormStats,
        latents: &[Vec<f32>],
        _w: &Gemm,
    ) -> Result<Vec<f32>> {
        Ok(latents.iter().map(|l| self.pp_pred(stats, l)).collect())
    }

    /// Loss `(pred − target)²` and its analytic latent gradient.
    #[allow(clippy::type_complexity)] // gradient tuple mirrors the engine-trait signature
    pub fn pp_grad(
        &self,
        stats: &NormStats,
        latents: &[Vec<f32>],
        _w: &Gemm,
        targets: &[f32],
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        anyhow::ensure!(latents.len() == targets.len());
        let mut losses = Vec::with_capacity(latents.len());
        let mut grads = Vec::with_capacity(latents.len());
        for (lat, t) in latents.iter().zip(targets) {
            let d = stats.hw_dim.min(lat.len()).max(1);
            let pred = self.pp_pred(stats, lat);
            let err = pred - t;
            losses.push(err * err);
            let g = 2.0 * err / d as f32;
            grads.push((0..lat.len()).map(|i| if i < d { g } else { 0.0 }).collect());
        }
        Ok((losses, grads))
    }

    /// Smooth surrogate proxy in hardware space (same shape of contract as
    /// the exported differentiable surrogate): prediction = row mean.
    pub fn surrogate_predict(&self, hw_rows: &[Vec<f32>], _w: &Gemm) -> Result<Vec<f32>> {
        Ok(hw_rows
            .iter()
            .map(|r| r.iter().sum::<f32>() / r.len().max(1) as f32)
            .collect())
    }

    #[allow(clippy::type_complexity)] // gradient tuple mirrors the engine-trait signature
    pub fn surrogate_grad(
        &self,
        hw_rows: &[Vec<f32>],
        _w: &Gemm,
        targets: &[f32],
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        anyhow::ensure!(hw_rows.len() == targets.len());
        let mut losses = Vec::with_capacity(hw_rows.len());
        let mut grads = Vec::with_capacity(hw_rows.len());
        for (row, t) in hw_rows.iter().zip(targets) {
            let d = row.len().max(1);
            let pred = row.iter().sum::<f32>() / d as f32;
            let err = pred - t;
            losses.push(err * err);
            grads.push(vec![2.0 * err / d as f32; row.len()]);
        }
        Ok((losses, grads))
    }

    /// AIRCHITECT v1: argmin-EDP over the fixed recommendation grid (the
    /// mock "classifier" is an oracle over its own grid).
    pub fn airchitect_v1(&self, stats: &NormStats, w: &Gemm) -> Result<HwConfig> {
        let best = stats
            .airchitect_grid
            .iter()
            .map(|row| decode_rounded(row))
            .min_by(|a, b| {
                let ea = EvalCache::global().evaluate(a, w).1.edp;
                let eb = EvalCache::global().evaluate(b, w).1.edp;
                ea.total_cmp(&eb)
            });
        best.ok_or_else(|| anyhow::anyhow!("mock airchitect grid is empty"))
    }

    /// AIRCHITECT v2: a direct "regression" — the best-EDP pick from a
    /// pool seeded deterministically by the workload shape.
    pub fn airchitect_v2(&self, _stats: &NormStats, w: &Gemm) -> Result<HwConfig> {
        let seed = rng::derive(rng::derive(w.m as u64, w.k as u64), w.n as u64);
        // lint:allow(rng-construct) stream 2 is baked into the mock's goldens
        let mut rng = Pcg32::new(seed, 2);
        let best = (0..16)
            .map(|_| {
                let hw = TargetSpace::sample(&mut rng);
                let edp = EvalCache::global().evaluate(&hw, w).1.edp;
                (hw, edp)
            })
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .map(|(hw, _)| hw);
        best.ok_or_else(|| anyhow::anyhow!("empty recommendation pool"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm_vec_inversion_is_exact() {
        for g in [Gemm::new(1, 1, 1), Gemm::new(128, 768, 2304), Gemm::new(M_MAX, K_MAX, N_MAX)] {
            assert_eq!(gemm_from_norm(&g.norm_vec()), g);
        }
    }

    #[test]
    fn samplers_are_deterministic_and_in_space() {
        let stats = NormStats::synthetic();
        let m = MockEngine;
        let conds: Vec<(f32, [f32; 3])> =
            (0..8).map(|i| (i as f32 / 8.0, Gemm::new(128, 768, 768).norm_vec())).collect();
        let a = m.sample_runtime(&stats, 9, &conds);
        let b = m.sample_runtime(&stats, 9, &conds);
        assert_eq!(a, b);
        assert_eq!(a.len(), conds.len());
        assert!(a.iter().all(|hw| hw.in_target_space()));
        // a different seed moves the draws
        let c = m.sample_runtime(&stats, 10, &conds);
        assert_ne!(a, c);
    }

    #[test]
    fn class_zero_is_the_best_edp_pick() {
        let stats = NormStats::synthetic();
        let m = MockEngine;
        let g = Gemm::new(128, 768, 2304);
        let n_classes = (stats.n_power * stats.n_perf) as i32;
        let lo = m.sample_class(&stats, ClassMode::Edp, 3, &[(0, g.norm_vec())]);
        let hi = m.sample_class(&stats, ClassMode::Edp, 3, &[(n_classes - 1, g.norm_vec())]);
        let edp = |hw: &HwConfig| EvalCache::global().evaluate(hw, &g).1.edp;
        assert!(edp(&lo[0]) <= edp(&hi[0]));
    }

    #[test]
    fn joint_sampler_is_deterministic_in_budget_and_correlated() {
        use crate::design_space::StructuredConfig;
        let stats = NormStats::synthetic();
        let m = MockEngine;
        let budget = SharedBudget { pe: 2048, buf_b: 384 * 1024, bw: 12 };
        let conds = [
            (0, Gemm::new(128, 768, 2304).norm_vec()),
            (0, Gemm::new(128, 768, 768).norm_vec()),
            (0, Gemm::new(64, 256, 512).norm_vec()),
        ];
        let a = m.sample_joint(&stats, ClassMode::Edp, 17, &budget, &conds, 4);
        let b = m.sample_joint(&stats, ClassMode::Edp, 17, &budget, &conds, 4);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
        for joint in &a {
            assert_eq!(joint.len(), conds.len());
            let cfg = StructuredConfig { segments: joint.clone() };
            assert!(cfg.in_budget(&budget), "{cfg:?} escapes {budget:?}");
        }
        // a different budget moves the draws (conditioning is live)
        let wide = m.sample_joint(
            &stats,
            ClassMode::Edp,
            17,
            &SharedBudget::unconstrained(),
            &conds,
            4,
        );
        assert_ne!(a, wide);
    }

    #[test]
    fn joint_class_zero_minimizes_summed_edp() {
        let stats = NormStats::synthetic();
        let m = MockEngine;
        let budget = SharedBudget::unconstrained();
        let g = Gemm::new(128, 768, 768);
        let conds_lo = [(0, g.norm_vec()), (0, g.norm_vec())];
        let n_hi = (stats.n_power * stats.n_perf) as i32 - 1;
        let conds_hi = [(n_hi, g.norm_vec()), (n_hi, g.norm_vec())];
        let score = |joint: &Vec<HwConfig>| -> f64 {
            joint.iter().map(|hw| EvalCache::global().evaluate(hw, &g).1.edp).sum()
        };
        // class 0 takes the best-of-pool joint candidate, the top class the
        // worst; compare across several slots so the ordering is robust to
        // the class-conditioned pools differing
        let lo = m.sample_joint(&stats, ClassMode::Edp, 5, &budget, &conds_lo, 6);
        let hi = m.sample_joint(&stats, ClassMode::Edp, 5, &budget, &conds_hi, 6);
        let sum = |js: &[Vec<HwConfig>]| js.iter().map(score).sum::<f64>();
        assert!(sum(&lo) <= sum(&hi));
    }

    #[test]
    fn encode_decode_roundtrip() {
        let stats = NormStats::synthetic();
        let m = MockEngine;
        let rows = vec![vec![0.25; stats.hw_dim], vec![0.75; stats.hw_dim]];
        let lat = m.encode(&stats, &rows).unwrap();
        assert!(lat.iter().all(|l| l.len() == stats.latent_dim));
        assert_eq!(m.decode(&stats, &lat).unwrap(), rows);
        // width mismatches are errors, not silent truncation
        assert!(m.encode(&stats, &[vec![0.0; 3]]).is_err());
        assert!(m.decode(&stats, &[vec![0.0; 3]]).is_err());
    }

    #[test]
    fn pp_grad_descends_toward_target() {
        let stats = NormStats::synthetic();
        let m = MockEngine;
        let mut lat = vec![0.9f32; stats.latent_dim];
        let g = Gemm::new(64, 256, 512);
        for _ in 0..50 {
            let (_, grads) = m.pp_grad(&stats, &[lat.clone()], &g, &[0.2]).unwrap();
            for (l, gr) in lat.iter_mut().zip(&grads[0]) {
                *l -= 0.5 * gr;
            }
        }
        let pred = m.pp_predict(&stats, &[lat], &g).unwrap()[0];
        assert!((pred - 0.2).abs() < 0.05, "pred {pred}");
    }
}
