//! The DiffAxE model engine: every AOT artifact compiled and wrapped behind
//! typed batch APIs. This is the only place that knows artifact file names
//! and executable input layouts.
//!
//! The engine surface is backed by one of two interchangeable backends:
//!
//! * **Compiled** — the PJRT executables loaded from `artifacts/` (the
//!   real diffusion/AE/PP/surrogate models), and
//! * **Mock** — the hermetic, deterministic, artifact-free stand-in
//!   ([`crate::models::mock::MockEngine`]) CI runs the engine-kind code
//!   paths against.
//!
//! Shared contract invariants (batch caps, non-empty requests, row widths)
//! are enforced *here*, before dispatch, so both backends are held to the
//! same wire-visible behaviour.

use super::mock::MockEngine;
use super::norm::NormStats;
use crate::design_space::structured::constrain;
use crate::design_space::{decode_rounded, HwConfig, SharedBudget};
use crate::runtime::{mat_f32, scalar_u32, to_vec_f32, vec_i32, HloExec, Runtime};
use crate::workload::Gemm;
use anyhow::Result;
use std::path::Path;

/// Which class-conditioned sampler to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClassMode {
    /// §III-D: Eq. 8 power–performance classes (N_power × N_perf)
    Edp,
    /// §III-E: EDP percentile classes (N_EDP)
    PerfOpt,
}

/// All compiled executables of the artifact set.
struct Compiled {
    sampler_runtime: HloExec,
    sampler_edp: HloExec,
    sampler_perfopt: HloExec,
    encoder: HloExec,
    decoder: HloExec,
    pp: HloExec,
    pp_grad: HloExec,
    surrogate: HloExec,
    surrogate_grad: HloExec,
    gandse: HloExec,
    airchitect1: HloExec,
    airchitect2: HloExec,
}

enum Backend {
    /// PJRT executables (raw C pointers — deliberately `!Send`).
    Compiled(Box<Compiled>),
    /// Hermetic deterministic stand-in (no artifacts, no files).
    Mock(MockEngine),
}

/// The engine: normalization contract + one backend.
pub struct DiffAxE {
    pub stats: NormStats,
    backend: Backend,
}

impl DiffAxE {
    /// Compile every artifact in `dir` (one-time service-start cost).
    pub fn load(dir: &Path) -> Result<DiffAxE> {
        let stats = NormStats::load(&dir.join("norm_stats.json"))?;
        let rt = Runtime::cpu()?;
        let load = |name: &str| rt.load_hlo(&dir.join(name));
        let compiled = Compiled {
            sampler_runtime: load("sampler_runtime.hlo.txt")?,
            sampler_edp: load("sampler_edp.hlo.txt")?,
            sampler_perfopt: load("sampler_perfopt.hlo.txt")?,
            encoder: load("encoder.hlo.txt")?,
            decoder: load("decoder.hlo.txt")?,
            pp: load("pp.hlo.txt")?,
            pp_grad: load("pp_grad.hlo.txt")?,
            surrogate: load("surrogate.hlo.txt")?,
            surrogate_grad: load("surrogate_grad.hlo.txt")?,
            gandse: load("gandse.hlo.txt")?,
            airchitect1: load("airchitect1.hlo.txt")?,
            airchitect2: load("airchitect2.hlo.txt")?,
        };
        Ok(DiffAxE { stats, backend: Backend::Compiled(Box::new(compiled)) })
    }

    /// The hermetic engine: a synthetic normalization contract plus the
    /// deterministic [`MockEngine`] backend. No files are touched; every
    /// engine-kind search path runs, seeded and reproducible.
    pub fn mock() -> DiffAxE {
        DiffAxE { stats: NormStats::synthetic(), backend: Backend::Mock(MockEngine) }
    }

    /// True when this engine runs the artifact-free mock backend.
    pub fn is_mock(&self) -> bool {
        matches!(self.backend, Backend::Mock(_))
    }

    /// True if `dir` holds a complete artifact set.
    pub fn artifacts_present(dir: &Path) -> bool {
        ["norm_stats.json", "sampler_runtime.hlo.txt", "decoder.hlo.txt"]
            .iter()
            .all(|f| dir.join(f).exists())
    }

    fn hw_dim(&self) -> usize {
        self.stats.hw_dim
    }

    /// Shared sampler-request invariants, enforced for both backends.
    fn check_sampler_request(&self, n: usize) -> Result<()> {
        let b = self.stats.gen_batch;
        anyhow::ensure!(n > 0, "empty generation request");
        anyhow::ensure!(n <= b, "request {n} exceeds sampler batch {b}; chunk upstream");
        Ok(())
    }

    // ---- diffusion samplers ------------------------------------------------

    /// Runtime-conditioned generation (§III-C): one request per batch slot
    /// `(p_norm, w_norm)`. Pads to the executable's fixed batch and truncates
    /// the result, so any `conds.len() <= gen_batch` works.
    pub fn sample_runtime(&self, seed: u32, conds: &[(f32, [f32; 3])]) -> Result<Vec<HwConfig>> {
        self.check_sampler_request(conds.len())?;
        match &self.backend {
            Backend::Compiled(c) => {
                c.run_sampler(&c.sampler_runtime, &self.stats, seed, SamplerCond::Float(conds))
            }
            Backend::Mock(m) => Ok(m.sample_runtime(&self.stats, seed, conds)),
        }
    }

    /// Class-conditioned generation (§III-D/E).
    pub fn sample_class(
        &self,
        mode: ClassMode,
        seed: u32,
        conds: &[(i32, [f32; 3])],
    ) -> Result<Vec<HwConfig>> {
        self.check_sampler_request(conds.len())?;
        match &self.backend {
            Backend::Compiled(c) => {
                let exe = match mode {
                    ClassMode::Edp => &c.sampler_edp,
                    ClassMode::PerfOpt => &c.sampler_perfopt,
                };
                c.run_sampler(exe, &self.stats, seed, SamplerCond::Class(conds))
            }
            Backend::Mock(m) => Ok(m.sample_class(&self.stats, mode, seed, conds)),
        }
    }

    /// Jointly-conditioned structured generation (§V): **one** sampler
    /// call for all `conds.len()` segment representative shapes under one
    /// shared budget, returning `n_joint` correlated per-segment groups
    /// (each already projected into the budget, one shared bandwidth).
    /// The call occupies `S × n_joint` slots of the sampler batch, so
    /// `conds.len() · n_joint ≤ gen_batch` — the continuous batcher packs
    /// each joint candidate as one contiguous group of a single call and
    /// never assembles a group across calls (docs/INVARIANTS.md).
    pub fn sample_joint(
        &self,
        mode: ClassMode,
        seed: u32,
        budget: &SharedBudget,
        conds: &[(i32, [f32; 3])],
        n_joint: usize,
    ) -> Result<Vec<Vec<HwConfig>>> {
        let s = conds.len();
        anyhow::ensure!(s > 0, "joint request needs at least one segment");
        anyhow::ensure!(n_joint > 0, "empty joint generation request");
        self.check_sampler_request(s.saturating_mul(n_joint))?;
        budget.validate().map_err(|e| anyhow::anyhow!("invalid shared budget: {e}"))?;
        match &self.backend {
            Backend::Compiled(c) => {
                // No joint artifact is exported yet: approximate through
                // the class sampler (still one call — S×n_joint slots),
                // then project each contiguous group into the budget. The
                // mock backend generates joint candidates natively.
                let exe = match mode {
                    ClassMode::Edp => &c.sampler_edp,
                    ClassMode::PerfOpt => &c.sampler_perfopt,
                };
                let mut flat = Vec::with_capacity(s * n_joint);
                for _ in 0..n_joint {
                    flat.extend_from_slice(conds);
                }
                let hw = c.run_sampler(exe, &self.stats, seed, SamplerCond::Class(&flat))?;
                Ok(hw.chunks(s).map(|g| constrain(budget, g.to_vec()).segments).collect())
            }
            Backend::Mock(m) => {
                Ok(m.sample_joint(&self.stats, mode, seed, budget, conds, n_joint))
            }
        }
    }

    // ---- latent-space plumbing (for latent-GD/BO baselines) ---------------

    /// Encode normalized hardware vectors into the Phase-1 latent space.
    pub fn encode(&self, hw_rows: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        match &self.backend {
            Backend::Compiled(c) => c.batched_map(
                &c.encoder,
                &self.stats,
                hw_rows,
                self.hw_dim(),
                self.stats.latent_dim,
            ),
            Backend::Mock(m) => m.encode(&self.stats, hw_rows),
        }
    }

    /// Decode latents back to normalized hardware vectors.
    pub fn decode(&self, latents: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        match &self.backend {
            Backend::Compiled(c) => c.batched_map(
                &c.decoder,
                &self.stats,
                latents,
                self.stats.latent_dim,
                self.hw_dim(),
            ),
            Backend::Mock(m) => m.decode(&self.stats, latents),
        }
    }

    /// Decode latents and round into the target design space.
    pub fn decode_rounded(&self, latents: &[Vec<f32>]) -> Result<Vec<HwConfig>> {
        Ok(self.decode(latents)?.iter().map(|v| decode_rounded(v)).collect())
    }

    /// PP prediction for (latent, workload) pairs → normalized metric.
    pub fn pp_predict(&self, latents: &[Vec<f32>], w: &Gemm) -> Result<Vec<f32>> {
        match &self.backend {
            Backend::Compiled(c) => c.pp_predict(&self.stats, latents, w),
            Backend::Mock(m) => m.pp_predict(&self.stats, latents, w),
        }
    }

    /// PP loss + gradient wrt latent, for latent-space gradient descent.
    /// Returns (losses, grads).
    #[allow(clippy::type_complexity)] // gradient tuple mirrors the engine-trait signature
    pub fn pp_grad(
        &self,
        latents: &[Vec<f32>],
        w: &Gemm,
        targets: &[f32],
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        anyhow::ensure!(latents.len() == targets.len());
        match &self.backend {
            Backend::Compiled(c) => c.pp_grad(&self.stats, latents, w, targets),
            Backend::Mock(m) => m.pp_grad(&self.stats, latents, w, targets),
        }
    }

    /// Differentiable surrogate prediction in hardware space (vanilla GD).
    pub fn surrogate_predict(&self, hw_rows: &[Vec<f32>], w: &Gemm) -> Result<Vec<f32>> {
        match &self.backend {
            Backend::Compiled(c) => c.surrogate_predict(&self.stats, hw_rows, w),
            Backend::Mock(m) => m.surrogate_predict(hw_rows, w),
        }
    }

    /// Surrogate loss + gradient wrt hw (vanilla GD step).
    #[allow(clippy::type_complexity)] // gradient tuple mirrors the engine-trait signature
    pub fn surrogate_grad(
        &self,
        hw_rows: &[Vec<f32>],
        w: &Gemm,
        targets: &[f32],
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        anyhow::ensure!(hw_rows.len() == targets.len());
        match &self.backend {
            Backend::Compiled(c) => c.surrogate_grad(&self.stats, hw_rows, w, targets),
            Backend::Mock(m) => m.surrogate_grad(hw_rows, w, targets),
        }
    }

    /// GANDSE one-shot generation.
    pub fn gandse_generate(&self, seed: u32, conds: &[(f32, [f32; 3])]) -> Result<Vec<HwConfig>> {
        self.check_sampler_request(conds.len())?;
        match &self.backend {
            Backend::Compiled(c) => {
                c.run_sampler(&c.gandse, &self.stats, seed, SamplerCond::Float(conds))
            }
            Backend::Mock(m) => Ok(m.gandse_generate(&self.stats, seed, conds)),
        }
    }

    /// AIRCHITECT v1 recommendation: argmax over the fixed grid.
    pub fn airchitect_v1(&self, w: &Gemm) -> Result<HwConfig> {
        match &self.backend {
            Backend::Compiled(c) => c.airchitect_v1(&self.stats, w),
            Backend::Mock(m) => m.airchitect_v1(&self.stats, w),
        }
    }

    /// AIRCHITECT v2 recommendation: direct regression.
    pub fn airchitect_v2(&self, w: &Gemm) -> Result<HwConfig> {
        match &self.backend {
            Backend::Compiled(c) => c.airchitect_v2(&self.stats, w),
            Backend::Mock(m) => m.airchitect_v2(&self.stats, w),
        }
    }
}

impl Compiled {
    fn run_sampler(
        &self,
        exe: &HloExec,
        stats: &NormStats,
        seed: u32,
        conds: SamplerCond,
    ) -> Result<Vec<HwConfig>> {
        let b = stats.gen_batch;
        let n = conds.len();
        let mut w_flat = Vec::with_capacity(b * 3);
        let cond_lit = match conds {
            SamplerCond::Float(cs) => {
                let mut p = Vec::with_capacity(b);
                for i in 0..b {
                    let (pv, wv) = cs[i.min(n - 1)];
                    p.push(pv);
                    w_flat.extend_from_slice(&wv);
                }
                mat_f32(&p, b, 1)?
            }
            SamplerCond::Class(cs) => {
                let mut c = Vec::with_capacity(b);
                for i in 0..b {
                    let (cv, wv) = cs[i.min(n - 1)];
                    c.push(cv);
                    w_flat.extend_from_slice(&wv);
                }
                vec_i32(&c)
            }
        };
        let w_lit = mat_f32(&w_flat, b, 3)?;
        let out = exe.run(&[scalar_u32(seed), cond_lit, w_lit])?;
        let hw = to_vec_f32(&out[0])?;
        let d = stats.hw_dim;
        anyhow::ensure!(hw.len() == b * d, "sampler output shape mismatch");
        Ok(hw.chunks(d).take(n).map(decode_rounded).collect())
    }

    fn pp_predict(&self, stats: &NormStats, latents: &[Vec<f32>], w: &Gemm) -> Result<Vec<f32>> {
        let b = stats.pp_batch;
        let d = stats.latent_dim;
        let mut out = Vec::with_capacity(latents.len());
        for chunk in latents.chunks(b) {
            let (v_lit, n) = pad_rows(chunk, d, b)?;
            let w_lit = broadcast_w(w, b)?;
            let res = self.pp.run(&[v_lit, w_lit])?;
            let preds = to_vec_f32(&res[0])?;
            out.extend(preds.chunks(preds.len() / b).take(n).map(|c| c[0]));
        }
        Ok(out)
    }

    #[allow(clippy::type_complexity)] // gradient tuple mirrors the engine-trait signature
    fn pp_grad(
        &self,
        stats: &NormStats,
        latents: &[Vec<f32>],
        w: &Gemm,
        targets: &[f32],
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        let b = stats.pp_batch;
        let d = stats.latent_dim;
        let mut losses = Vec::new();
        let mut grads = Vec::new();
        for (vchunk, tchunk) in latents.chunks(b).zip(targets.chunks(b)) {
            let (v_lit, n) = pad_rows(vchunk, d, b)?;
            let w_lit = broadcast_w(w, b)?;
            let mut t = tchunk.to_vec();
            t.resize(b, 0.0);
            let t_lit = mat_f32(&t, b, 1)?;
            let res = self.pp_grad.run(&[v_lit, w_lit, t_lit])?;
            losses.extend(to_vec_f32(&res[0])?.into_iter().take(n));
            let g = to_vec_f32(&res[1])?;
            grads.extend(g.chunks(d).take(n).map(|c| c.to_vec()));
        }
        Ok((losses, grads))
    }

    fn surrogate_predict(
        &self,
        stats: &NormStats,
        hw_rows: &[Vec<f32>],
        w: &Gemm,
    ) -> Result<Vec<f32>> {
        let b = stats.pp_batch;
        let d = stats.hw_dim;
        let mut out = Vec::new();
        for chunk in hw_rows.chunks(b) {
            let (h_lit, n) = pad_rows(chunk, d, b)?;
            let w_lit = broadcast_w(w, b)?;
            let res = self.surrogate.run(&[h_lit, w_lit])?;
            out.extend(to_vec_f32(&res[0])?.into_iter().take(n));
        }
        Ok(out)
    }

    #[allow(clippy::type_complexity)] // gradient tuple mirrors the engine-trait signature
    fn surrogate_grad(
        &self,
        stats: &NormStats,
        hw_rows: &[Vec<f32>],
        w: &Gemm,
        targets: &[f32],
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        let b = stats.pp_batch;
        let d = stats.hw_dim;
        let mut losses = Vec::new();
        let mut grads = Vec::new();
        for (hchunk, tchunk) in hw_rows.chunks(b).zip(targets.chunks(b)) {
            let (h_lit, n) = pad_rows(hchunk, d, b)?;
            let w_lit = broadcast_w(w, b)?;
            let mut t = tchunk.to_vec();
            t.resize(b, 0.0);
            let t_lit = xla::Literal::vec1(t.as_slice());
            let res = self.surrogate_grad.run(&[h_lit, w_lit, t_lit])?;
            losses.extend(to_vec_f32(&res[0])?.into_iter().take(n));
            let g = to_vec_f32(&res[1])?;
            grads.extend(g.chunks(d).take(n).map(|c| c.to_vec()));
        }
        Ok((losses, grads))
    }

    fn airchitect_v1(&self, stats: &NormStats, w: &Gemm) -> Result<HwConfig> {
        let b = stats.pp_batch;
        let w_lit = broadcast_w(w, b)?;
        let res = self.airchitect1.run(&[w_lit])?;
        let logits = to_vec_f32(&res[0])?;
        let n_cfg = logits.len() / b;
        let row = &logits[..n_cfg];
        // total_cmp: a NaN logit sorts below every number and degrades to a
        // deterministic pick instead of panicking the service thread
        let best = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .ok_or_else(|| anyhow::anyhow!("airchitect-v1 logits are empty"))?;
        let grid = &stats.airchitect_grid;
        anyhow::ensure!(best < grid.len(), "grid index out of range");
        Ok(decode_rounded(&grid[best]))
    }

    fn airchitect_v2(&self, stats: &NormStats, w: &Gemm) -> Result<HwConfig> {
        let b = stats.pp_batch;
        let w_lit = broadcast_w(w, b)?;
        let res = self.airchitect2.run(&[w_lit])?;
        let hw = to_vec_f32(&res[0])?;
        Ok(decode_rounded(&hw[..stats.hw_dim]))
    }

    fn batched_map(
        &self,
        exe: &HloExec,
        stats: &NormStats,
        rows: &[Vec<f32>],
        in_dim: usize,
        out_dim: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let b = stats.pp_batch;
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(b) {
            let (lit, n) = pad_rows(chunk, in_dim, b)?;
            let res = exe.run(&[lit])?;
            let flat = to_vec_f32(&res[0])?;
            anyhow::ensure!(flat.len() == b * out_dim, "{} output shape", exe.name());
            out.extend(flat.chunks(out_dim).take(n).map(|c| c.to_vec()));
        }
        Ok(out)
    }
}

enum SamplerCond<'a> {
    Float(&'a [(f32, [f32; 3])]),
    Class(&'a [(i32, [f32; 3])]),
}

impl SamplerCond<'_> {
    fn len(&self) -> usize {
        match self {
            SamplerCond::Float(c) => c.len(),
            SamplerCond::Class(c) => c.len(),
        }
    }
}

/// Pack `rows` (each `dim` wide) into a `[batch, dim]` literal, padding by
/// repeating the last row. Returns (literal, real row count).
fn pad_rows(rows: &[Vec<f32>], dim: usize, batch: usize) -> Result<(xla::Literal, usize)> {
    anyhow::ensure!(!rows.is_empty() && rows.len() <= batch);
    let mut flat = Vec::with_capacity(batch * dim);
    for i in 0..batch {
        let r = &rows[i.min(rows.len() - 1)];
        anyhow::ensure!(r.len() == dim, "row width {} != {dim}", r.len());
        flat.extend_from_slice(r);
    }
    Ok((mat_f32(&flat, batch, dim)?, rows.len()))
}

/// `[batch, 3]` literal with the workload's normalized vector in every row.
fn broadcast_w(w: &Gemm, batch: usize) -> Result<xla::Literal> {
    let v = w.norm_vec();
    let mut flat = Vec::with_capacity(batch * 3);
    for _ in 0..batch {
        flat.extend_from_slice(&v);
    }
    mat_f32(&flat, batch, 3)
}
