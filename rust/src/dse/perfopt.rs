//! Experiment 3 (§III-E, §IV-B.3, Figs 17/19, Table V): condition on the
//! lowest-EDP percentile class to discover high-performance designs —
//! including designs beating everything in the training data.

use super::runtime_of;
use crate::design_space::HwConfig;
use crate::models::{ClassMode, DiffAxE};
use crate::util::stats::Timer;
use crate::workload::Gemm;
use anyhow::Result;

/// Result of one perf-opt run on one workload.
#[derive(Debug, Clone)]
pub struct PerfOutcome {
    pub best_cycles: f64,
    pub best_hw: HwConfig,
    pub search_time_s: f64,
    /// all generated (config, cycles, power) triples — Fig 19's scatter
    pub generated: Vec<(HwConfig, f64, f64)>,
}

/// Generate `n` designs conditioned on class 0 (the lowest-EDP percentile),
/// evaluate, return the fastest (paper: N_EDP = 10, class 1).
pub fn diffaxe_perfopt(engine: &DiffAxE, g: &Gemm, n: usize, seed: u32) -> Result<PerfOutcome> {
    let timer = Timer::start();
    let b = engine.stats.gen_batch;
    let mut generated = Vec::with_capacity(n);
    let mut remaining = n;
    let mut chunk = 0u32;
    while remaining > 0 {
        let take = remaining.min(b);
        let conds: Vec<(i32, [f32; 3])> = (0..take).map(|_| (0, g.norm_vec())).collect();
        let configs =
            engine.sample_class(ClassMode::PerfOpt, seed.wrapping_add(chunk), &conds)?;
        for hw in configs {
            let (s, e) = super::evaluate(&hw, g);
            generated.push((hw, s.cycles as f64, e.power_w));
        }
        remaining -= take;
        chunk += 1;
    }
    let (best_hw, best_cycles, _) = generated
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .cloned()
        .unwrap();
    Ok(PerfOutcome { best_cycles, best_hw, search_time_s: timer.elapsed_s(), generated })
}

/// Best (lowest-runtime) configuration in the training design space for a
/// workload — the "training data" baseline of Fig 19 / Table V.
pub fn best_in_training_space(g: &Gemm) -> (HwConfig, f64) {
    use crate::design_space::params::TrainingSpace;
    let mut best: Option<(HwConfig, f64)> = None;
    for hw in TrainingSpace::enumerate() {
        let c = runtime_of(&hw, g);
        if best.as_ref().map(|(_, b)| c < *b).unwrap_or(true) {
            best = Some((hw, c));
        }
    }
    best.unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn training_best_is_a_training_config() {
        use crate::design_space::params::TrainingSpace;
        let g = Gemm::new(64, 256, 512);
        let (hw, cycles) = best_in_training_space(&g);
        assert!(TrainingSpace::DIMS.contains(&hw.r));
        assert!(cycles > 0.0);
        // sanity: it beats an arbitrary mid-grid config
        let mid = crate::design_space::HwConfig::new_kb(
            16, 16, 128.0, 128.0, 128.0, 8, crate::design_space::LoopOrder::Mnk);
        assert!(cycles <= runtime_of(&mid, &g));
    }
}
