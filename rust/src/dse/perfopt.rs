//! §IV-B.3 (Figs 17/19, Table V) support: the "best configuration in the
//! training data" reference point that perf-opt generation is measured
//! against. The search itself is `Objective::MaxPerf` through any
//! [`super::api::Optimizer`].

use super::runtime_of;
use crate::design_space::HwConfig;
use crate::workload::Gemm;

/// Best (lowest-runtime) configuration in the training design space for a
/// workload — the "training data" baseline of Fig 19 / Table V.
pub fn best_in_training_space(g: &Gemm) -> (HwConfig, f64) {
    use crate::design_space::params::TrainingSpace;
    let mut best: Option<(HwConfig, f64)> = None;
    for hw in TrainingSpace::enumerate() {
        let c = runtime_of(&hw, g);
        if best.as_ref().map(|(_, b)| c < *b).unwrap_or(true) {
            best = Some((hw, c));
        }
    }
    best.unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn training_best_is_a_training_config() {
        use crate::design_space::params::TrainingSpace;
        let g = Gemm::new(64, 256, 512);
        let (hw, cycles) = best_in_training_space(&g);
        assert!(TrainingSpace::DIMS.contains(&hw.r));
        assert!(cycles > 0.0);
        // sanity: it beats an arbitrary mid-grid config
        let mid = crate::design_space::HwConfig::new_kb(
            16, 16, 128.0, 128.0, 128.0, 8, crate::design_space::LoopOrder::Mnk);
        assert!(cycles <= runtime_of(&mid, &g));
    }

    #[test]
    fn maxperf_objective_improves_with_budget() {
        use crate::dse::api::{Budget, Optimizer, RandomSearch, SearchCtx};
        let g = Gemm::new(64, 256, 512);
        let obj = crate::dse::Objective::MaxPerf { g };
        let ctx = SearchCtx::background();
        // same seed => the 512-eval sample sequence extends the 64-eval one,
        // so the best can only improve
        let few = RandomSearch.search(&ctx, &obj, &Budget::evals(64), 11).unwrap();
        let many = RandomSearch.search(&ctx, &obj, &Budget::evals(512), 11).unwrap();
        assert!(many.best_score() <= few.best_score());
        assert!(few.best_score() > 0.0);
    }
}
