//! Design-space exploration, unified behind one API (see [`api`]).
//!
//! Every search setting is an [`api::Objective`] (workload + metric) and
//! every strategy — the diffusion engine and each paper baseline — is an
//! [`api::Optimizer`]: `optimizer.search(&ctx, &objective, &budget, seed)`
//! yields a ranked [`api::SearchOutcome`] whose `stopped` field records
//! whether the [`api::SearchCtx`] interrupted it (cancellation, deadline)
//! or it ran to completion. An [`api::Session`] owns the engine
//! handle, dispatches strategies by [`api::OptimizerKind`], and provides
//! the batched evaluation hot path [`api::evaluate_batch`] all searchers
//! share — backed by the memoized, pooled evaluation core in [`eval`]
//! (sharded `(config, workload)` memo table + persistent worker pool,
//! bit-identical to scalar evaluation). The paper's experiments map onto
//! the objectives as:
//!
//! * `Objective::Runtime` — §IV-B.1 / Table III / Fig 16: runtime-
//!   conditioned generation vs GD/BO/GANDSE baselines (protocol helpers in
//!   [`perfgen`]).
//! * `Objective::MinEdp` — §IV-B.2 / Table IV: power–performance class
//!   DSE, SP metric.
//! * `Objective::MaxPerf` — §IV-B.3 / Fig 17/19/Table V: low-EDP-class
//!   generation for performance ([`perfopt`] keeps the training-set-best
//!   reference point).
//! * `Objective::LlmEdp` — §VI / Figs 22-24 / Tables VII-VIII: LLM
//!   inference co-design on ASIC + FPGA ([`llm`] holds the whole-model
//!   sequence evaluator).
//! * `Objective::StructuredEdp` / `Objective::StructuredPerf` — §V:
//!   structured DSE with per-layer-segment heterogeneous sub-configs over
//!   a shared accelerator budget, an O(10^17) joint space ([`structured`]
//!   holds the spec, the segment evaluator and the per-strategy searches).
//!
//! The coordinator serves the same types over the wire
//! ([`crate::coordinator::protocol`]).
//!
//! Strategies are reproducible by contract: deadlines come from
//! [`api::SearchCtx`] (never a raw clock), RNG streams derive from the
//! call seed via [`crate::util::rng`], and the eval core's locks carry
//! static ranks via [`crate::util::sync`]. `diffaxe lint` enforces all
//! three — see `docs/INVARIANTS.md` for the rules and the lock-rank table.

pub mod api;
pub mod eval;
pub mod llm;
pub mod perfgen;
pub mod perfopt;
pub mod structured;

pub use api::{
    evaluate_batch, Budget, DesignReport, Objective, Optimizer, OptimizerKind, ProgressSink,
    SearchCtx, SearchEvent, SearchOutcome, SearchRun, Session, StopReason,
};
pub use eval::{par_map, CacheStats, EvalCache};
pub use structured::{StructuredDesign, StructuredSpec};

use crate::design_space::HwConfig;
use crate::energy::{asic, EnergyResult};
use crate::sim::{simulate, SimResult};
use crate::workload::Gemm;

/// Simulate + ASIC-evaluate one (config, workload) pair.
pub fn evaluate(hw: &HwConfig, g: &Gemm) -> (SimResult, EnergyResult) {
    let s = simulate(hw, g);
    let e = asic::evaluate(hw, &s);
    (s, e)
}

/// Runtime in cycles.
pub fn runtime_of(hw: &HwConfig, g: &Gemm) -> f64 {
    simulate(hw, g).cycles as f64
}

/// EDP in µJ·cycles.
pub fn edp_of(hw: &HwConfig, g: &Gemm) -> f64 {
    asic::evaluate(hw, &simulate(hw, g)).edp
}

/// Snap a config onto the coarse training grid — models the O(10^7)-grained
/// space DOSA/Polaris search over (Table IV notes both operate on a much
/// coarser granularity than the O(10^17) target space).
pub fn coarsen(hw: &HwConfig) -> HwConfig {
    use crate::design_space::params::TrainingSpace;
    let snap_dim = |v: u32| {
        *TrainingSpace::DIMS
            .iter()
            .min_by_key(|&&d| (d as i64 - v as i64).abs())
            .unwrap()
    };
    let snap_buf = |b: u64| {
        let kb = b as f64 / 1024.0;
        let best = TrainingSpace::BUF_KB
            .iter()
            .min_by(|&&a, &&c| (a as f64 - kb).abs().total_cmp(&(c as f64 - kb).abs()))
            .expect("BUF_KB grid is non-empty");
        *best as u64 * 1024
    };
    let snap_bw = |v: u32| {
        *TrainingSpace::BWS
            .iter()
            .min_by_key(|&&d| (d as i64 - v as i64).abs())
            .unwrap()
    };
    HwConfig {
        r: snap_dim(hw.r),
        c: snap_dim(hw.c),
        ip_b: snap_buf(hw.ip_b),
        wt_b: snap_buf(hw.wt_b),
        op_b: snap_buf(hw.op_b),
        bw: snap_bw(hw.bw),
        loop_order: hw.loop_order,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design_space::{LoopOrder, TargetSpace};
    use crate::util::rng::Pcg32;

    #[test]
    fn coarsen_lands_on_training_grid() {
        use crate::design_space::params::TrainingSpace;
        let mut rng = Pcg32::seeded(7);
        for _ in 0..200 {
            let hw = TargetSpace::sample(&mut rng);
            let c = coarsen(&hw);
            assert!(TrainingSpace::DIMS.contains(&c.r));
            assert!(TrainingSpace::DIMS.contains(&c.c));
            assert!(TrainingSpace::BUF_KB.contains(&((c.ip_b / 1024) as u32)));
            assert!(TrainingSpace::BWS.contains(&c.bw));
            assert_eq!(c.loop_order, hw.loop_order);
        }
    }

    #[test]
    fn coarsen_is_idempotent_on_grid_points() {
        let hw = HwConfig::new_kb(64, 8, 256.0, 4.0, 1024.0, 16, LoopOrder::Nmk);
        assert_eq!(coarsen(&hw), hw);
    }

    #[test]
    fn evaluate_consistency() {
        let hw = HwConfig::new_kb(32, 32, 128.0, 128.0, 32.0, 16, LoopOrder::Mnk);
        let g = Gemm::new(128, 768, 768);
        let (s, e) = evaluate(&hw, &g);
        assert_eq!(runtime_of(&hw, &g), s.cycles as f64);
        assert_eq!(edp_of(&hw, &g), e.edp);
    }
}
