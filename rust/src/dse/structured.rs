//! Structured DSE (§V): per-segment heterogeneous accelerator search.
//!
//! A [`StructuredSpec`] names a DNN/LLM workload, partitions its
//! transformer-block GEMM sequence into contiguous layer segments
//! ([`partition`]), and searches an independent `(loop order, array dims,
//! buffer split)` sub-configuration per segment under one
//! [`SharedBudget`] — the O(10^17)-point joint space of
//! [`crate::design_space::structured`]. Two objectives expose it through
//! the unified [`Optimizer`](super::api::Optimizer) trait:
//! `Objective::StructuredEdp` (whole-model EDP) and
//! `Objective::StructuredPerf` (whole-model cycles).
//!
//! # Evaluation
//!
//! [`eval_structured`] scores one candidate: each segment's layers are
//! simulated on that segment's sub-configuration (the segment's loop
//! order *is* its dataflow choice — heterogeneity across segments replaces
//! the per-layer order search of the shared-config LLM objective), energy
//! is priced per segment through its own [`EnergyCoeffs`]
//! (coefficients depend on the segment's array/buffer parameters), and
//! the totals combine into whole-model cycles / power / EDP. Layer
//! simulations run through the shared memoized [`EvalCache`], and
//! [`eval_structured_batch`] partitions candidates over the persistent
//! worker pool — both bit-identical to the scalar reference
//! [`eval_structured_scalar`] by construction (the evaluation is pure and
//! the accumulation order is fixed).
//!
//! # Strategies
//!
//! * [`search_engine`] — the DiffAxE engine with **joint conditioning
//!   over the learned segmentation space**: every round proposes segment
//!   boundaries (canonical cuts, shape-clustered cuts, then random cuts)
//!   and draws correlated per-segment groups in one
//!   [`DiffAxE::sample_joint`] call under the shared budget.
//! * [`search_engine_zip`] — the fixed-partition, independently-zipped
//!   reference the joint path is measured against.
//! * [`search_fd`] — finite-difference GD over the concatenated
//!   per-segment encoding with the boundary lanes appended (`DosaGd` on
//!   the coarse training grid, `VanillaGd` on the fine grid).
//! * [`search_bo`] — vanilla BO over the same joint encoding.
//! * [`search_latent_bo`] — BO over the concatenated per-segment *latent*
//!   encoding: a pool of random designs encoded through the engine in one
//!   batched call, candidates decoded per segment and projected into the
//!   shared budget.
//! * [`search_polaris`] — latent GD: an 8-d random subspace around
//!   per-segment encoded anchors, decoded through the engine.
//! * [`search_random`] — uniform sampling of the joint space.
//! * [`search_fixed`] — a fixed silicon replicated across segments.
//!
//! [`EnergyCoeffs`]: crate::energy::EnergyCoeffs

use super::api::{
    bo_opts_for, gd_opts_for, Budget, DesignReport, Objective, SearchCtx, SearchOutcome,
    SearchRun, StopReason, MAX_PREALLOC,
};
use super::coarsen;
use super::eval::{par_map, EvalCache};
use super::llm::Platform;
use crate::baselines::{bo, gd, BoOptions, FixedArch, GdOptions};
use crate::design_space::structured::{
    boundary_dim, cardinality_with_boundaries, constrain, decode_boundaries,
    decode_structured_with_boundaries, default_boundaries, encode_structured_with_boundaries,
    ranges_from_boundaries, round_boundaries, sample_structured, segment_layers_by_shape,
    structured_dim_with_boundaries, SharedBudget, StructuredConfig,
};
use crate::design_space::{encode_norm, HwConfig, TargetSpace};
use crate::models::{ClassMode, DiffAxE};
use crate::sim::SimResult;
use crate::util::rng::{self, Pcg32};
use crate::workload::{model_workload, Gemm, LlmModel, ModelWorkload, Stage};
use anyhow::Result;
use std::sync::Arc;

/// Candidate-evaluation chunk size (whole-model evaluations are the unit,
/// so chunks stay small to keep the deadline poll granularity tight).
const EVAL_CHUNK: usize = 16;

/// What a structured search optimizes over: the workload, its
/// segmentation, the platform, and the shared accelerator budget.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StructuredSpec {
    pub model: LlmModel,
    pub stage: Stage,
    pub seq: u32,
    pub platform: Platform,
    /// requested number of contiguous layer segments (effective count is
    /// capped at the workload's layer count — see
    /// [`StructuredSpec::n_segments`])
    pub segments: u32,
    pub budget: SharedBudget,
}

impl StructuredSpec {
    /// Cap on the requested segment count (a transformer block has 6
    /// GEMMs; more segments than layers collapse to one per layer).
    pub const MAX_SEGMENTS: u32 = 8;

    /// A spec over the unconstrained shared budget.
    pub fn new(
        model: LlmModel,
        stage: Stage,
        seq: u32,
        platform: Platform,
        segments: u32,
    ) -> StructuredSpec {
        StructuredSpec { model, stage, seq, platform, segments, budget: SharedBudget::default() }
    }

    /// Reject specs no search can serve (bad segment count / impossible
    /// budget). Callers surface this as a client error before any budget
    /// is spent.
    pub fn validate(&self) -> Result<(), String> {
        if self.segments < 1 || self.segments > Self::MAX_SEGMENTS {
            return Err(format!(
                "segments {} outside [1, {}]",
                self.segments,
                Self::MAX_SEGMENTS
            ));
        }
        self.budget.validate()
    }

    /// The shared (memoized) workload this spec partitions.
    pub fn workload(&self) -> Arc<ModelWorkload> {
        model_workload(self.model, self.stage, self.seq)
    }

    /// Effective segment count: the requested count capped at the layer
    /// count (zero only for an empty workload).
    pub fn n_segments(&self) -> usize {
        (self.segments as usize).min(self.workload().gemms.len())
    }

    /// Joint-space cardinality of this spec (the O(10^17) scale claim),
    /// including the segmentation choices: the per-segment configuration
    /// space times the composition count of cutting the layer sequence
    /// into that many contiguous segments.
    pub fn cardinality(&self) -> f64 {
        cardinality_with_boundaries(
            &self.budget,
            self.n_segments().max(1),
            self.workload().gemms.len(),
        )
    }
}

impl std::fmt::Display for StructuredSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} {} seq={} {:?} x{} segments",
            self.model.name(),
            self.stage.name(),
            self.seq,
            self.platform,
            self.segments
        )
    }
}

/// Contiguous near-even layer partition: segment `s` covers
/// `[s·n/k, (s+1)·n/k)`. The segment count is clamped to the layer count,
/// so every emitted segment is non-empty — `k > n` collapses to one
/// segment per layer instead of emitting empty ranges (direct callers get
/// the same guard [`StructuredSpec::n_segments`] gives the specs).
pub fn partition(n_layers: usize, segments: usize) -> Vec<std::ops::Range<usize>> {
    let k = segments.min(n_layers);
    (0..k).map(|s| (s * n_layers / k)..((s + 1) * n_layers / k)).collect()
}

/// One evaluated structured design.
#[derive(Debug, Clone, PartialEq)]
pub struct StructuredDesign {
    pub config: StructuredConfig,
    /// whole-model runtime in cycles
    pub cycles: f64,
    /// whole-model average power, watts
    pub power_w: f64,
    /// whole-model EDP, µJ·cycles
    pub edp: f64,
}

impl StructuredDesign {
    /// The wire/report view: the provisioned envelope as the
    /// representative [`HwConfig`], whole-model metrics attached. The
    /// per-segment sub-configurations ride next to it in
    /// [`SearchOutcome::segments`].
    pub fn report(&self) -> DesignReport {
        DesignReport {
            hw: self.config.envelope(),
            cycles: self.cycles,
            power_w: self.power_w,
            edp: self.edp,
        }
    }
}

/// The segment ranges a candidate's layers are grouped by: its learned
/// boundaries when it carries any, the canonical near-even [`partition`]
/// otherwise (empty `bounds` means "fixed partition" everywhere).
fn parts_for(
    wl: &ModelWorkload,
    cfg: &StructuredConfig,
    bounds: &[usize],
) -> Vec<std::ops::Range<usize>> {
    if bounds.is_empty() {
        partition(wl.gemms.len(), cfg.segments.len())
    } else {
        debug_assert_eq!(bounds.len() + 1, cfg.segments.len(), "boundary/segment mismatch");
        ranges_from_boundaries(bounds, wl.gemms.len())
    }
}

/// The one evaluation routine, parameterized by the layer simulator so
/// the memoized and scalar paths share every arithmetic step (fixed
/// segment-major accumulation order ⇒ bit-identical results).
fn eval_with(
    spec: &StructuredSpec,
    wl: &ModelWorkload,
    cfg: &StructuredConfig,
    parts: &[std::ops::Range<usize>],
    mut simulate: impl FnMut(&HwConfig, &Gemm) -> SimResult,
) -> StructuredDesign {
    let mut total: Option<SimResult> = None;
    let mut e_dyn = 0.0f64;
    let mut e_static = 0.0f64;
    for (seg_hw, range) in cfg.segments.iter().zip(parts) {
        let mut seg: Option<SimResult> = None;
        for li in range.clone() {
            let s = simulate(seg_hw, &wl.gemms[li]);
            seg = Some(match seg {
                None => s,
                Some(a) => a.add(&s),
            });
        }
        let Some(seg) = seg else { continue };
        // scale this segment's block cost to the whole model, then price
        // it with the segment's own coefficients
        let scaled = seg.scale(wl.blocks);
        let e = spec.platform.coeffs(seg_hw).evaluate(&scaled);
        e_dyn += e.e_dyn_uj;
        e_static += e.e_static_uj;
        total = Some(match total {
            None => scaled,
            Some(a) => a.add(&scaled),
        });
    }
    match total {
        // empty workload / zero segments: the zero cost point
        None => StructuredDesign { config: cfg.clone(), cycles: 0.0, power_w: 0.0, edp: 0.0 },
        Some(sim) => {
            let cycles = sim.cycles as f64;
            let total_uj = e_dyn + e_static;
            let freq_hz = spec.platform.coeffs(&cfg.segments[0]).freq_hz;
            let runtime_s = cycles / freq_hz;
            let power_w = if runtime_s > 0.0 { total_uj * 1e-6 / runtime_s } else { 0.0 };
            StructuredDesign { config: cfg.clone(), cycles, power_w, edp: total_uj * cycles }
        }
    }
}

/// Memoized evaluation with every layer simulation batched: build the
/// `(segment hw, layer GEMM)` pairs in the exact segment-major order
/// [`eval_with`] consumes them, pre-simulate through
/// [`EvalCache::simulate_pairs`] (cache misses become one SoA batch via
/// [`crate::sim::batch`]), then replay the results through the shared
/// arithmetic. Bit-identical to per-call cached simulation: same
/// traversal order, same accumulation, and the batch simulator carries
/// the scalar-oracle guarantee.
fn eval_structured_cached(
    spec: &StructuredSpec,
    wl: &ModelWorkload,
    cfg: &StructuredConfig,
    bounds: &[usize],
) -> StructuredDesign {
    let parts = parts_for(wl, cfg, bounds);
    let pairs: Vec<(HwConfig, Gemm)> = cfg
        .segments
        .iter()
        .zip(&parts)
        .flat_map(|(seg_hw, range)| range.clone().map(move |li| (*seg_hw, wl.gemms[li])))
        .collect();
    let sims = EvalCache::global().simulate_pairs(&pairs);
    let mut next = sims.into_iter();
    eval_with(spec, wl, cfg, &parts, move |_, _| {
        next.next().expect("one pre-simulated result per layer visit")
    })
}

/// Evaluate one structured candidate through the shared [`EvalCache`]
/// (canonical fixed partition).
pub fn eval_structured(spec: &StructuredSpec, cfg: &StructuredConfig) -> StructuredDesign {
    let wl = spec.workload();
    eval_structured_cached(spec, &wl, cfg, &[])
}

/// Evaluate one structured candidate under learned segment boundaries
/// (empty `bounds` falls back to the canonical partition).
pub fn eval_structured_at(
    spec: &StructuredSpec,
    cfg: &StructuredConfig,
    bounds: &[usize],
) -> StructuredDesign {
    let wl = spec.workload();
    eval_structured_cached(spec, &wl, cfg, bounds)
}

/// The scalar (uncached) reference: identical arithmetic on the raw
/// simulator — the equivalence oracle for `tests/structured_dse.rs`.
pub fn eval_structured_scalar(spec: &StructuredSpec, cfg: &StructuredConfig) -> StructuredDesign {
    eval_structured_scalar_at(spec, cfg, &[])
}

/// [`eval_structured_scalar`] under learned segment boundaries.
pub fn eval_structured_scalar_at(
    spec: &StructuredSpec,
    cfg: &StructuredConfig,
    bounds: &[usize],
) -> StructuredDesign {
    let wl = spec.workload();
    let parts = parts_for(&wl, cfg, bounds);
    eval_with(spec, &wl, cfg, &parts, |hw, g| crate::sim::simulate(hw, g))
}

/// Batch evaluation: memoized per layer and partitioned over the
/// persistent worker pool. Order-preserving and bit-identical to calling
/// [`eval_structured`] per element.
pub fn eval_structured_batch(
    spec: &StructuredSpec,
    cfgs: &[StructuredConfig],
) -> Vec<StructuredDesign> {
    let spec = *spec;
    let wl = spec.workload();
    par_map(cfgs, move |cfg| eval_structured_cached(&spec, &wl, cfg, &[]))
}

/// One joint candidate of the learned-segmentation search: a per-segment
/// configuration plus the interior cut points its segments cover (empty
/// cuts mean the canonical partition).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct JointCandidate {
    pub cfg: StructuredConfig,
    pub bounds: Vec<usize>,
}

/// [`eval_structured_batch`] over joint candidates (each evaluated under
/// its own boundaries). Order-preserving and bit-identical to calling
/// [`eval_structured_at`] per element.
pub fn eval_structured_batch_at(
    spec: &StructuredSpec,
    cands: &[JointCandidate],
) -> Vec<StructuredDesign> {
    let spec = *spec;
    let wl = spec.workload();
    par_map(cands, move |c| eval_structured_cached(&spec, &wl, &c.cfg, &c.bounds))
}

/// Single-config view of the structured space: `hw` replicated uniformly
/// across segments (how `Objective::evaluate` serves structured
/// objectives for non-structured callers).
pub fn eval_uniform(spec: &StructuredSpec, hw: &HwConfig) -> DesignReport {
    let s = spec.n_segments();
    if s == 0 {
        return DesignReport { hw: *hw, cycles: 0.0, power_w: 0.0, edp: 0.0 };
    }
    let cfg = constrain(&spec.budget, vec![*hw; s]);
    eval_structured(spec, &cfg).report()
}

/// Accumulator for chunked candidate evaluation: batch-evaluates one
/// chunk, tracks the running best, and emits one progress event — the
/// single scoring/progress body every chunked search shares.
struct ChunkAcc {
    reports: Vec<DesignReport>,
    segs: Vec<Vec<HwConfig>>,
    bounds: Vec<Vec<usize>>,
    best: f64,
}

impl ChunkAcc {
    fn with_capacity(n: usize) -> ChunkAcc {
        ChunkAcc {
            reports: Vec::with_capacity(n.min(MAX_PREALLOC)),
            segs: Vec::with_capacity(n.min(MAX_PREALLOC)),
            bounds: Vec::new(),
            best: f64::INFINITY,
        }
    }

    fn eval_chunk(
        &mut self,
        run: &SearchRun<'_>,
        obj: &Objective,
        spec: &StructuredSpec,
        chunk: &[StructuredConfig],
    ) {
        for d in eval_structured_batch(spec, chunk) {
            let r = d.report();
            self.best = self.best.min(obj.score_report(&r));
            self.segs.push(d.config.segments);
            self.reports.push(r);
        }
        run.progress(self.reports.len(), self.best);
    }

    /// [`ChunkAcc::eval_chunk`] over joint candidates, recording each
    /// candidate's learned boundaries next to its segments.
    fn eval_chunk_at(
        &mut self,
        run: &SearchRun<'_>,
        obj: &Objective,
        spec: &StructuredSpec,
        chunk: &[JointCandidate],
    ) {
        for (d, c) in eval_structured_batch_at(spec, chunk).into_iter().zip(chunk) {
            let r = d.report();
            self.best = self.best.min(obj.score_report(&r));
            self.segs.push(d.config.segments);
            self.bounds.push(c.bounds.clone());
            self.reports.push(r);
        }
        run.progress(self.reports.len(), self.best);
    }
}

/// Evaluate joint candidates in deadline-pollable chunks, emitting one
/// progress event per chunk; an interruption returns the prefix evaluated
/// so far.
fn evaluate_chunked(
    run: &mut SearchRun<'_>,
    obj: &Objective,
    spec: &StructuredSpec,
    cands: &[JointCandidate],
) -> ChunkAcc {
    let mut acc = ChunkAcc::with_capacity(cands.len());
    for chunk in cands.chunks(EVAL_CHUNK) {
        if run.should_stop() {
            break;
        }
        acc.eval_chunk_at(run, obj, spec, chunk);
    }
    acc
}

/// Validate the spec and resolve the effective segment count; a
/// degenerate spec (empty workload) short-circuits to a well-formed empty
/// outcome.
fn check_spec(name: &str, spec: &StructuredSpec) -> Result<Result<usize, SearchOutcome>> {
    spec.validate().map_err(|e| anyhow::anyhow!("invalid structured spec: {e}"))?;
    let s = spec.n_segments();
    if s == 0 {
        return Ok(Err(SearchOutcome::empty(name, StopReason::BudgetExhausted)));
    }
    Ok(Ok(s))
}

/// Assemble the outcome (ranked reports + parallel segment/boundary
/// lists; `bounds` empty for fixed-partition strategies).
fn finish(
    name: &str,
    obj: &Objective,
    reports: Vec<DesignReport>,
    segs: Vec<Vec<HwConfig>>,
    bounds: Vec<Vec<usize>>,
    run: &SearchRun<'_>,
) -> SearchOutcome {
    // all-canonical candidate lists collapse to "no boundaries": the
    // outcome (and its wire form) stays identical to the fixed-partition
    // representation
    let bounds = if bounds.iter().all(|b| b.is_empty()) { Vec::new() } else { bounds };
    SearchOutcome::from_reports_with_structure(name, obj, reports, segs, bounds, run.elapsed_s())
        .with_stopped(run.stop_reason())
}

// ---------------------------------------------------------------------------
// strategies
// ---------------------------------------------------------------------------

/// Uniform random search over the joint structured space.
pub fn search_random(
    ctx: &SearchCtx,
    obj: &Objective,
    spec: &StructuredSpec,
    budget: &Budget,
    seed: u64,
) -> Result<SearchOutcome> {
    const NAME: &str = "Random Search";
    let s = match check_spec(NAME, spec)? {
        Ok(s) => s,
        Err(out) => return Ok(out),
    };
    let mut run = SearchRun::start(ctx, budget);
    let mut rng = rng::split(seed, 40);
    let n = budget.evals.max(1);
    let mut acc = ChunkAcc::with_capacity(n);
    while acc.reports.len() < n && !run.should_stop() {
        let take = (n - acc.reports.len()).min(EVAL_CHUNK);
        let cfgs: Vec<StructuredConfig> =
            (0..take).map(|_| sample_structured(&mut rng, &spec.budget, s)).collect();
        acc.eval_chunk(&run, obj, spec, &cfgs);
    }
    Ok(finish(NAME, obj, acc.reports, acc.segs, acc.bounds, &run))
}

/// Drop repeated joint candidates, keeping first-occurrence order.
/// Generation and rounding are many-to-one (paper Fig 2a), so sampled
/// per-segment draws can collide after [`constrain`] snaps them onto the
/// budgeted grid — and a duplicate burns search budget on a repeat
/// evaluation (the eval cache hides the compute cost but not the
/// accounting). The key includes the boundaries: the same configuration
/// under a different segmentation is a different design point. Never
/// turns a non-empty list empty.
fn dedup_candidates(cands: Vec<JointCandidate>) -> Vec<JointCandidate> {
    let mut seen = std::collections::HashSet::new();
    cands.into_iter().filter(|c| seen.insert(c.clone())).collect()
}

/// The per-segment dominant (max-MACs) layer shapes under `parts` — each
/// segment's conditioning representative.
fn segment_reps(wl: &ModelWorkload, parts: &[std::ops::Range<usize>]) -> Vec<Gemm> {
    parts
        .iter()
        .map(|r| {
            *wl.gemms[r.clone()]
                .iter()
                .max_by_key(|g| g.macs())
                .expect("non-empty segment")
        })
        .collect()
}

/// The boundary proposal for generation round `round`: the canonical
/// near-even cuts first, the shape-clustered cuts second, then seeded
/// random segmentations — the alternating outer loop of the learned
/// segmentation search.
fn propose_boundaries(
    round: u64,
    wl: &ModelWorkload,
    s: usize,
    rng: &mut Pcg32,
) -> Vec<usize> {
    let n_layers = wl.gemms.len();
    match round {
        0 => default_boundaries(n_layers, s),
        1 => segment_layers_by_shape(&wl.gemms, s),
        _ => {
            let raw: Vec<usize> = (0..s.saturating_sub(1))
                .map(|_| rng.int_range(1, (n_layers - 1).max(1) as i64) as usize)
                .collect();
            round_boundaries(&raw, n_layers)
        }
    }
}

/// DiffAxE joint conditioning over the learned-segmentation space (§V):
/// every round proposes a segmentation ([`propose_boundaries`] — the
/// canonical partition, shape-clustered cuts, then random cuts), derives
/// each segment's dominant-layer conditioning shape under those cuts, and
/// asks the engine for *jointly* sampled per-segment groups in **one**
/// [`DiffAxE::sample_joint`] call per round — correlated draws under the
/// shared budget, not independently-conditioned zips. Candidates are
/// deduplicated on `(configuration, boundaries)` and evaluated through
/// the batched SoA path. The independently-conditioned fixed-partition
/// baseline lives on as [`search_engine_zip`].
pub fn search_engine(
    engine: &DiffAxE,
    ctx: &SearchCtx,
    obj: &Objective,
    spec: &StructuredSpec,
    budget: &Budget,
    seed: u64,
) -> Result<SearchOutcome> {
    const NAME: &str = "DiffAxE";
    let s = match check_spec(NAME, spec)? {
        Ok(s) => s,
        Err(out) => return Ok(out),
    };
    let mut run = SearchRun::start(ctx, budget);
    let wl = spec.workload();
    let n = budget.evals.max(1);
    // joint groups per sampler call: each group takes s contiguous slots
    let group = (engine.stats.gen_batch / s).max(1);
    let mut rng = rng::split(seed, 45);
    let mut cands: Vec<JointCandidate> = Vec::with_capacity(n.min(MAX_PREALLOC));
    let mut round = 0u64;
    while cands.len() < n && !run.should_stop() {
        let bounds = propose_boundaries(round, &wl, s, &mut rng);
        let parts = ranges_from_boundaries(&bounds, wl.gemms.len());
        let reps = segment_reps(&wl, &parts);
        let conds: Vec<(i32, [f32; 3])> = reps.iter().map(|g| (0, g.norm_vec())).collect();
        let take = (n - cands.len()).min(group);
        let sd = rng::derive_u32(seed, round);
        let joints = engine.sample_joint(ClassMode::Edp, sd, &spec.budget, &conds, take)?;
        cands.extend(joints.into_iter().map(|segments| JointCandidate {
            cfg: StructuredConfig { segments },
            bounds: bounds.clone(),
        }));
        round += 1;
    }
    let cands = dedup_candidates(cands);
    if cands.is_empty() {
        anyhow::ensure!(run.interrupted(), "joint generation produced no candidates");
        return Ok(finish(NAME, obj, Vec::new(), Vec::new(), Vec::new(), &run));
    }
    let acc = evaluate_chunked(&mut run, obj, spec, &cands);
    Ok(finish(NAME, obj, acc.reports, acc.segs, acc.bounds, &run))
}

/// The pre-learned-segmentation DiffAxE reference: per-segment
/// **independent** conditioning over the fixed canonical partition — for
/// every segment, draw low-EDP class samples conditioned on the segment's
/// dominant (max-MACs) layer shape; candidate `k` zips the `k`-th draw of
/// every segment into one joint configuration, projected into the shared
/// budget ([`constrain`]) after the fact. Kept as the baseline the
/// jointly-conditioned [`search_engine`] is measured against (tests and
/// the structured smoke bench).
pub fn search_engine_zip(
    engine: &DiffAxE,
    ctx: &SearchCtx,
    obj: &Objective,
    spec: &StructuredSpec,
    budget: &Budget,
    seed: u64,
) -> Result<SearchOutcome> {
    const NAME: &str = "DiffAxE (indep-zip)";
    let s = match check_spec(NAME, spec)? {
        Ok(s) => s,
        Err(out) => return Ok(out),
    };
    let mut run = SearchRun::start(ctx, budget);
    let wl = spec.workload();
    let reps = segment_reps(&wl, &partition(wl.gemms.len(), s));
    let n = budget.evals.max(1);
    let b = engine.stats.gen_batch;
    let mut pools: Vec<Vec<HwConfig>> = Vec::with_capacity(s);
    for (si, g) in reps.iter().enumerate() {
        if run.should_stop() {
            break;
        }
        let mut pool = Vec::with_capacity(n.min(MAX_PREALLOC));
        let mut chunk = 0u64;
        while pool.len() < n && !run.should_stop() {
            let take = (n - pool.len()).min(b);
            let conds: Vec<(i32, [f32; 3])> = vec![(0, g.norm_vec()); take];
            let sd = rng::derive_u32(seed, ((si as u64) << 32) | chunk);
            pool.extend(engine.sample_class(ClassMode::Edp, sd, &conds)?);
            chunk += 1;
        }
        pools.push(pool);
    }
    // an interruption mid-generation may leave fewer pools than segments:
    // zip only complete candidates (never a truncated segmentation)
    let n_joint = if pools.len() == s {
        pools.iter().map(|p| p.len()).min().unwrap_or(0).min(n)
    } else {
        0
    };
    let cands = dedup_candidates(
        (0..n_joint)
            .map(|k| JointCandidate {
                cfg: constrain(&spec.budget, pools.iter().map(|p| p[k]).collect()),
                bounds: Vec::new(),
            })
            .collect(),
    );
    if cands.is_empty() {
        anyhow::ensure!(run.interrupted(), "per-segment generation produced no candidates");
        return Ok(finish(NAME, obj, Vec::new(), Vec::new(), Vec::new(), &run));
    }
    let acc = evaluate_chunked(&mut run, obj, spec, &cands);
    Ok(finish(NAME, obj, acc.reports, acc.segs, acc.bounds, &run))
}

/// Finite-difference GD over the concatenated per-segment encoding.
/// `coarse` snaps every segment onto the training grid first (the DOSA
/// stand-in); the fine-grid variant serves `VanillaGd`.
#[allow(clippy::too_many_arguments)] // free function mirrors the paper's search knobs 1:1
pub fn search_fd(
    name: &'static str,
    coarse: bool,
    opts: &GdOptions,
    ctx: &SearchCtx,
    obj: &Objective,
    spec: &StructuredSpec,
    budget: &Budget,
    seed: u64,
) -> Result<SearchOutcome> {
    let s = match check_spec(name, spec)? {
        Ok(s) => s,
        Err(out) => return Ok(out),
    };
    let wl = spec.workload();
    let n_layers = wl.gemms.len();
    // the boundary lanes ride at the tail of the flattened encoding, so
    // the GD baseline searches segmentation jointly with configuration
    let dims = structured_dim_with_boundaries(s);
    let (opts, clamped) = gd_opts_for(opts, budget, 1 + 2 * dims);
    // FD probe spacing must straddle grid cells or the landscape reads as
    // a plateau: the coarse training grid is log-spaced (gaps up to ~0.5
    // in normalized coordinates), the fine target grid is dense
    let h = if coarse { 0.25 } else { 0.05 };
    let run = std::cell::RefCell::new(SearchRun::start(ctx, budget));
    let mut rng = rng::split(seed, 41);
    let decode = |x: &[f64]| -> (StructuredConfig, Vec<usize>) {
        let v: Vec<f32> = x.iter().map(|&t| t as f32).collect();
        let (cfg, bounds) = decode_structured_with_boundaries(&v, &spec.budget, s, n_layers);
        if coarse {
            (constrain(&spec.budget, cfg.segments.iter().map(coarsen).collect()), bounds)
        } else {
            (cfg, bounds)
        }
    };
    let mut reports = Vec::new();
    let mut segs = Vec::new();
    let mut bounds_acc = Vec::new();
    let mut best = f64::INFINITY;
    let res = gd::fd_gd(
        |x: &[f64]| {
            let (cfg, bounds) = decode(x);
            let d = eval_structured_at(spec, &cfg, &bounds);
            let r = d.report();
            let sc = obj.score_report(&r);
            reports.push(r);
            segs.push(d.config.segments);
            bounds_acc.push(bounds);
            best = best.min(sc);
            run.borrow().progress(reports.len(), best);
            obj.gd_loss(sc)
        },
        |r: &mut Pcg32| {
            sample_joint_vec(r, spec, s, n_layers).iter().map(|&x| x as f64).collect()
        },
        h,
        || run.borrow_mut().should_stop(),
        &opts,
        &mut rng,
    );
    if !res.best_x.is_empty() {
        let (cfg, bounds) = decode(&res.best_x);
        let d = eval_structured_at(spec, &cfg, &bounds);
        reports.push(d.report());
        segs.push(d.config.segments);
        bounds_acc.push(bounds);
    }
    let mut run = run.into_inner();
    if clamped {
        run.exhausted();
    }
    Ok(finish(name, obj, reports, segs, bounds_acc, &run))
}

/// Sample one flattened joint (configs + boundaries) search vector — the
/// shared init distribution of the GD/BO baselines over the learned
/// segmentation space.
fn sample_joint_vec(
    rng: &mut Pcg32,
    spec: &StructuredSpec,
    s: usize,
    n_layers: usize,
) -> Vec<f32> {
    let cfg = sample_structured(rng, &spec.budget, s);
    let raw: Vec<usize> = (0..s.saturating_sub(1))
        .map(|_| rng.int_range(1, (n_layers - 1).max(1) as i64) as usize)
        .collect();
    let bounds = round_boundaries(&raw, n_layers);
    encode_structured_with_boundaries(&cfg, &bounds, n_layers)
}

/// Vanilla BO over the concatenated per-segment encoding.
pub fn search_bo(
    opts: &BoOptions,
    ctx: &SearchCtx,
    obj: &Objective,
    spec: &StructuredSpec,
    budget: &Budget,
    seed: u64,
) -> Result<SearchOutcome> {
    const NAME: &str = "Vanilla BO";
    let s = match check_spec(NAME, spec)? {
        Ok(s) => s,
        Err(out) => return Ok(out),
    };
    let wl = spec.workload();
    let n_layers = wl.gemms.len();
    let (o, clamped) = bo_opts_for(opts, budget);
    let run = std::cell::RefCell::new(SearchRun::start(ctx, budget));
    let mut rng = rng::split(seed, 42);
    let mut reports = Vec::with_capacity(o.budget.min(MAX_PREALLOC));
    let mut segs = Vec::with_capacity(o.budget.min(MAX_PREALLOC));
    let mut bounds_acc = Vec::with_capacity(o.budget.min(MAX_PREALLOC));
    let mut best = f64::INFINITY;
    bo::minimize(
        |r: &mut Pcg32| {
            sample_joint_vec(r, spec, s, n_layers).iter().map(|&x| x as f64).collect()
        },
        |x| {
            let v: Vec<f32> = x.iter().map(|&t| t as f32).collect();
            let (cfg, bounds) = decode_structured_with_boundaries(&v, &spec.budget, s, n_layers);
            let d = eval_structured_at(spec, &cfg, &bounds);
            let r = d.report();
            let sc = obj.score_report(&r);
            reports.push(r);
            segs.push(d.config.segments);
            bounds_acc.push(bounds);
            best = best.min(sc);
            run.borrow().progress(reports.len(), best);
            sc
        },
        || run.borrow_mut().should_stop(),
        &o,
        &mut rng,
    );
    let mut run = run.into_inner();
    if clamped {
        run.exhausted();
    }
    Ok(finish(NAME, obj, reports, segs, bounds_acc, &run))
}

/// Latent BO (VAESA-style) over the concatenated per-segment latent
/// encoding: a pool of random joint candidates is encoded through the
/// engine in **one** batched call (the un-pollable encode prelude stays
/// bounded), BO proposes over the pooled latents, and every iterate is
/// decoded per segment and projected into the shared budget.
#[allow(clippy::too_many_arguments)] // free function mirrors the paper's search knobs 1:1
pub fn search_latent_bo(
    engine: &DiffAxE,
    opts: &BoOptions,
    ctx: &SearchCtx,
    obj: &Objective,
    spec: &StructuredSpec,
    budget: &Budget,
    seed: u64,
) -> Result<SearchOutcome> {
    const NAME: &str = "Latent BO (VAESA)";
    let s = match check_spec(NAME, spec)? {
        Ok(s) => s,
        Err(out) => return Ok(out),
    };
    let (o, clamped) = bo_opts_for(opts, budget);
    let run = std::cell::RefCell::new(SearchRun::start(ctx, budget));
    let mut rng = rng::split(seed, 44);
    // candidate pool: random joint designs, every segment row encoded in
    // one batched engine call (pool capped so a huge eval budget cannot
    // stall the search before the first pollable evaluation)
    let pool_n = (o.budget * 2).clamp(4, 256);
    let rows: Vec<Vec<f32>> = (0..pool_n * s)
        .map(|_| encode_norm(&TargetSpace::sample(&mut rng)).to_vec())
        .collect();
    let latents = engine.encode(&rows)?;
    let d_lat = latents.first().map(|l| l.len()).unwrap_or(0);
    anyhow::ensure!(d_lat > 0, "engine produced empty latents");
    let mut pool_iter = 0usize;
    let mut reports = Vec::with_capacity(o.budget.min(MAX_PREALLOC));
    let mut segs = Vec::with_capacity(o.budget.min(MAX_PREALLOC));
    let mut best = f64::INFINITY;
    bo::minimize(
        |_r: &mut Pcg32| {
            // candidate k: its s per-segment latents, concatenated
            let k = pool_iter % pool_n;
            pool_iter += 1;
            latents[k * s..(k + 1) * s]
                .iter()
                .flat_map(|l| l.iter().map(|&x| x as f64))
                .collect()
        },
        |x| {
            let flat: Vec<f32> = x.iter().map(|&v| v as f32).collect();
            let per_seg: Vec<Vec<f32>> = flat.chunks(d_lat).map(|c| c.to_vec()).collect();
            match engine.decode_rounded(&per_seg) {
                Ok(seg_cfgs) => {
                    let d = eval_structured(spec, &constrain(&spec.budget, seg_cfgs));
                    let r = d.report();
                    let sc = obj.score_report(&r);
                    reports.push(r);
                    segs.push(d.config.segments);
                    best = best.min(sc);
                    run.borrow().progress(reports.len(), best);
                    sc
                }
                Err(_) => f64::INFINITY,
            }
        },
        || run.borrow_mut().should_stop(),
        &o,
        &mut rng,
    );
    let mut run = run.into_inner();
    if clamped {
        run.exhausted();
    }
    anyhow::ensure!(
        !reports.is_empty() || run.interrupted(),
        "latent decode failed for every BO iterate"
    );
    Ok(finish(NAME, obj, reports, segs, Vec::new(), &run))
}

/// Polaris-style latent GD: per-segment anchors encoded through the
/// engine, an 8-d random subspace over the concatenated latents descended
/// by finite differences, every iterate decoded per segment and projected
/// into the shared budget.
#[allow(clippy::too_many_arguments)] // free function mirrors the paper's search knobs 1:1
pub fn search_polaris(
    engine: &DiffAxE,
    opts: &GdOptions,
    ctx: &SearchCtx,
    obj: &Objective,
    spec: &StructuredSpec,
    budget: &Budget,
    seed: u64,
) -> Result<SearchOutcome> {
    const NAME: &str = "Polaris (latent GD)";
    const SUBSPACE: usize = 8;
    let s = match check_spec(NAME, spec)? {
        Ok(s) => s,
        Err(out) => return Ok(out),
    };
    let wl = spec.workload();
    let n_layers = wl.gemms.len();
    let run = std::cell::RefCell::new(SearchRun::start(ctx, budget));
    let mut rng = rng::split(seed, 43);
    // one encoded anchor per segment
    let anchor_rows: Vec<Vec<f32>> =
        (0..s).map(|_| encode_norm(&TargetSpace::sample(&mut rng)).to_vec()).collect();
    let anchors = engine.encode(&anchor_rows)?;
    let d_lat = anchors.first().map(|a| a.len()).unwrap_or(0);
    anyhow::ensure!(d_lat > 0, "engine produced empty latents");
    let flat: Vec<f32> = anchors.concat();
    let dims = flat.len();
    let dirs: Vec<Vec<f32>> = (0..SUBSPACE)
        .map(|_| {
            let v: Vec<f32> = (0..dims).map(|_| rng.normal() as f32).collect();
            let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
            v.iter().map(|x| x / norm).collect()
        })
        .collect();
    let to_latents = |x: &[f64]| -> Vec<Vec<f32>> {
        let mut l = flat.clone();
        for (coef, dir) in x.iter().zip(&dirs) {
            for (li, di) in l.iter_mut().zip(dir) {
                *li += (*coef as f32 - 0.5) * 8.0 * di;
            }
        }
        l.chunks(d_lat).map(|c| c.to_vec()).collect()
    };
    // boundary lanes ride behind the subspace coefficients, so Polaris
    // descends segmentation jointly with the latent configuration
    let bdim = boundary_dim(s);
    let (opts, clamped) = gd_opts_for(opts, budget, 1 + 2 * (SUBSPACE + bdim));
    let mut reports = Vec::new();
    let mut segs = Vec::new();
    let mut bounds_acc = Vec::new();
    let mut best = f64::INFINITY;
    gd::fd_gd(
        |x: &[f64]| {
            let (sub, tail) = x.split_at(SUBSPACE);
            match engine.decode_rounded(&to_latents(sub)) {
                Ok(seg_cfgs) => {
                    let lanes: Vec<f32> = tail.iter().map(|&t| t as f32).collect();
                    let bounds = decode_boundaries(&lanes, n_layers);
                    let d =
                        eval_structured_at(spec, &constrain(&spec.budget, seg_cfgs), &bounds);
                    let r = d.report();
                    let sc = obj.score_report(&r);
                    reports.push(r);
                    segs.push(d.config.segments);
                    bounds_acc.push(bounds);
                    best = best.min(sc);
                    run.borrow().progress(reports.len(), best);
                    obj.gd_loss(sc)
                }
                Err(_) => f64::INFINITY,
            }
        },
        |r: &mut Pcg32| (0..SUBSPACE + bdim).map(|_| r.f64()).collect(),
        0.05,
        || run.borrow_mut().should_stop(),
        &opts,
        &mut rng,
    );
    let mut run = run.into_inner();
    if clamped {
        run.exhausted();
    }
    anyhow::ensure!(
        !reports.is_empty() || run.interrupted(),
        "latent decode failed for every iterate"
    );
    Ok(finish(NAME, obj, reports, segs, bounds_acc, &run))
}

/// A fixed silicon replicated uniformly across segments — the structured
/// view of the Table VI baselines.
pub fn search_fixed(
    arch: FixedArch,
    ctx: &SearchCtx,
    obj: &Objective,
    spec: &StructuredSpec,
    budget: &Budget,
) -> Result<SearchOutcome> {
    let name = FixedArch::name(&arch);
    let s = match check_spec(name, spec)? {
        Ok(s) => s,
        Err(out) => return Ok(out),
    };
    let mut run = SearchRun::start(ctx, budget);
    let (reports, segs) = if run.should_stop() {
        (Vec::new(), Vec::new())
    } else {
        let cfg = constrain(&spec.budget, vec![arch.config(); s]);
        let d = eval_structured(spec, &cfg);
        let r = d.report();
        run.progress(1, obj.score_report(&r));
        (vec![r], vec![d.config.segments])
    };
    Ok(finish(name, obj, reports, segs, Vec::new(), &run))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> StructuredSpec {
        StructuredSpec::new(LlmModel::BertBase, Stage::Prefill, 32, Platform::Asic32nm, 3)
    }

    #[test]
    fn partition_is_contiguous_and_total() {
        // includes k > n: the segment count clamps to the layer count, so
        // direct callers never see empty ranges
        for (n, k) in [(6, 1), (6, 2), (6, 3), (6, 6), (7, 3), (6, 7), (3, 8), (1, 4)] {
            let parts = partition(n, k);
            assert_eq!(parts.len(), k.min(n), "{n}/{k}");
            assert_eq!(parts[0].start, 0);
            assert_eq!(parts.last().unwrap().end, n);
            for w in parts.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            assert!(parts.iter().all(|r| !r.is_empty()), "{n}/{k}: {parts:?}");
        }
        assert!(partition(0, 0).is_empty());
        assert!(partition(0, 3).is_empty());
        assert!(partition(5, 0).is_empty());
    }

    #[test]
    fn boundary_eval_matches_canonical_on_default_cuts_and_scalar_oracle() {
        let sp = spec();
        let wl = sp.workload();
        let n_layers = wl.gemms.len();
        let s = sp.n_segments();
        let mut rng = Pcg32::seeded(64);
        let default = default_boundaries(n_layers, s);
        for _ in 0..8 {
            let cfg = sample_structured(&mut rng, &sp.budget, s);
            // canonical cuts expressed as boundaries evaluate identically
            let via_bounds = eval_structured_at(&sp, &cfg, &default);
            let canonical = eval_structured(&sp, &cfg);
            assert_eq!(via_bounds.edp.to_bits(), canonical.edp.to_bits());
            assert_eq!(via_bounds.cycles.to_bits(), canonical.cycles.to_bits());
            // learned cuts: cached path is bit-identical to the scalar oracle
            let raw: Vec<usize> =
                (0..s - 1).map(|_| rng.int_range(1, n_layers as i64 - 1) as usize).collect();
            let bounds = round_boundaries(&raw, n_layers);
            let cached = eval_structured_at(&sp, &cfg, &bounds);
            let scalar = eval_structured_scalar_at(&sp, &cfg, &bounds);
            assert_eq!(cached.edp.to_bits(), scalar.edp.to_bits());
            assert_eq!(cached.cycles.to_bits(), scalar.cycles.to_bits());
            assert_eq!(cached.power_w.to_bits(), scalar.power_w.to_bits());
        }
    }

    #[test]
    fn spec_validation_and_effective_segments() {
        let sp = spec();
        assert!(sp.validate().is_ok());
        assert_eq!(sp.n_segments(), 3);
        // more segments than layers collapse to one per layer
        let wide = StructuredSpec { segments: 8, ..sp };
        assert!(wide.validate().is_ok());
        assert_eq!(wide.n_segments(), 6);
        assert!(StructuredSpec { segments: 0, ..sp }.validate().is_err());
        assert!(StructuredSpec { segments: 99, ..sp }.validate().is_err());
        let bad_budget = SharedBudget { pe: 1, ..SharedBudget::default() };
        assert!(StructuredSpec { budget: bad_budget, ..sp }.validate().is_err());
    }

    #[test]
    fn spec_cardinality_reaches_paper_scale() {
        assert!(spec().cardinality() > 1e17, "{:e}", spec().cardinality());
    }

    #[test]
    fn cached_and_batch_eval_bit_identical_to_scalar() {
        let sp = spec();
        let mut rng = Pcg32::seeded(61);
        let cfgs: Vec<StructuredConfig> =
            (0..24).map(|_| sample_structured(&mut rng, &sp.budget, sp.n_segments())).collect();
        let batch = eval_structured_batch(&sp, &cfgs);
        for (cfg, b) in cfgs.iter().zip(&batch) {
            let cached = eval_structured(&sp, cfg);
            let scalar = eval_structured_scalar(&sp, cfg);
            for d in [&cached, b] {
                assert_eq!(d.config, scalar.config);
                assert_eq!(d.cycles.to_bits(), scalar.cycles.to_bits());
                assert_eq!(d.power_w.to_bits(), scalar.power_w.to_bits());
                assert_eq!(d.edp.to_bits(), scalar.edp.to_bits());
            }
        }
    }

    #[test]
    fn dedup_keeps_first_occurrence_order_and_never_empties() {
        let sp = spec();
        let mut rng = Pcg32::seeded(71);
        let mut cand = |bounds: Vec<usize>| JointCandidate {
            cfg: sample_structured(&mut rng, &sp.budget, sp.n_segments()),
            bounds,
        };
        let a = cand(Vec::new());
        let b = cand(vec![2, 4]);
        let c = cand(Vec::new());
        let deduped =
            dedup_candidates(vec![a.clone(), b.clone(), a.clone(), c.clone(), b.clone()]);
        assert_eq!(deduped, vec![a.clone(), b.clone(), c]);
        // the same configuration under different cuts is a different
        // design point, not a duplicate
        let a_recut = JointCandidate { cfg: a.cfg.clone(), bounds: vec![1, 3] };
        assert_eq!(
            dedup_candidates(vec![a.clone(), a_recut.clone()]),
            vec![a.clone(), a_recut]
        );
        // all-duplicates collapses to one, never to zero
        assert_eq!(dedup_candidates(vec![a.clone(), a.clone()]), vec![a]);
        assert!(dedup_candidates(Vec::new()).is_empty());
    }

    #[test]
    fn heterogeneous_segments_can_beat_the_uniform_envelope_constraint() {
        // sanity of the whole premise: evaluating a heterogeneous config
        // equals evaluating its segments' workloads independently, so a
        // per-segment choice can only match or improve on replicating one
        // segment's config everywhere (checked on the best uniform pick)
        let sp = spec();
        let mut rng = Pcg32::seeded(62);
        let mut best_uniform = f64::INFINITY;
        let mut best_any = f64::INFINITY;
        for _ in 0..64 {
            let cfg = sample_structured(&mut rng, &sp.budget, sp.n_segments());
            best_any = best_any.min(eval_structured(&sp, &cfg).edp);
            let uni = constrain(&sp.budget, vec![cfg.segments[0]; sp.n_segments()]);
            best_uniform = best_uniform.min(eval_structured(&sp, &uni).edp);
        }
        assert!(best_any.is_finite() && best_uniform.is_finite());
    }

    #[test]
    fn eval_uniform_matches_explicit_replication() {
        let sp = spec();
        let mut rng = Pcg32::seeded(63);
        for _ in 0..16 {
            let hw = TargetSpace::sample(&mut rng);
            let via_obj = eval_uniform(&sp, &hw);
            let cfg = constrain(&sp.budget, vec![hw; sp.n_segments()]);
            let direct = eval_structured(&sp, &cfg).report();
            assert_eq!(via_obj.cycles.to_bits(), direct.cycles.to_bits());
            assert_eq!(via_obj.edp.to_bits(), direct.edp.to_bits());
            assert_eq!(via_obj.hw, direct.hw);
        }
    }
}
