//! Experiment 1 (§IV-B.1, Table III, Fig 16): generate hardware hitting a
//! target runtime, and the five optimization baselines adapted to the same
//! objective `min |T_gen − T*| / T*`.

use super::{coarsen, runtime_of};
use crate::baselines::{bo, gd, BoOptions, GdOptions};
use crate::design_space::{decode_rounded, encode_norm, HwConfig, TargetSpace};
use crate::models::DiffAxE;
use crate::util::rng::Pcg32;
use crate::util::stats::Timer;
use crate::workload::Gemm;
use anyhow::Result;

/// One method's aggregate result over a set of (workload, target) queries.
#[derive(Debug, Clone)]
pub struct MethodResult {
    pub name: &'static str,
    /// mean |T_gen − T*| / T*
    pub error_gen: f64,
    /// mean wall-clock search time per query (seconds)
    pub search_time_s: f64,
    pub queries: usize,
}

/// A runtime-generation query.
#[derive(Debug, Clone, Copy)]
pub struct Query {
    pub g: Gemm,
    pub target_cycles: f64,
}

/// Sample `n_targets` uniform (in log space) runtime targets per workload
/// between its observed min and max (paper: 20 targets/workload).
pub fn make_queries(engine: &DiffAxE, workloads: &[Gemm], n_targets: usize) -> Vec<Query> {
    let mut out = Vec::new();
    for g in workloads {
        let st = engine.stats.stats_for(g);
        let (lo, hi) = st.runtime_range();
        for i in 0..n_targets {
            let f = (i as f64 + 0.5) / n_targets as f64;
            let target = (lo.ln() + f * (hi.ln() - lo.ln())).exp();
            out.push(Query { g: *g, target_cycles: target });
        }
    }
    out
}

fn rel_err(hw: &HwConfig, q: &Query) -> f64 {
    ((runtime_of(hw, &q.g) - q.target_cycles) / q.target_cycles).abs()
}

/// DiffAxE: one diffusion batch per query (n designs), error = mean over
/// generated designs (the paper's protocol: all generated designs count).
pub fn run_diffaxe(
    engine: &DiffAxE,
    queries: &[Query],
    n_designs: usize,
    seed: u32,
) -> Result<MethodResult> {
    let mut errs = Vec::new();
    let timer = Timer::start();
    for (qi, q) in queries.iter().enumerate() {
        let st = engine.stats.stats_for(&q.g);
        let p = st.norm_runtime(q.target_cycles);
        let n = n_designs.min(engine.stats.gen_batch);
        let conds: Vec<(f32, [f32; 3])> = (0..n).map(|_| (p, q.g.norm_vec())).collect();
        let configs = engine.sample_runtime(seed.wrapping_add(qi as u32), &conds)?;
        let mean: f64 = configs.iter().map(|c| rel_err(c, q)).sum::<f64>() / configs.len() as f64;
        errs.push(mean);
    }
    Ok(MethodResult {
        name: "DiffAxE",
        error_gen: mean(&errs),
        search_time_s: timer.elapsed_s() / queries.len() as f64,
        queries: queries.len(),
    })
}

/// GANDSE: one-shot GAN generation (same query protocol).
pub fn run_gandse(engine: &DiffAxE, queries: &[Query], n_designs: usize, seed: u32) -> Result<MethodResult> {
    let mut errs = Vec::new();
    let timer = Timer::start();
    for (qi, q) in queries.iter().enumerate() {
        let st = engine.stats.stats_for(&q.g);
        let p = st.norm_runtime(q.target_cycles);
        let n = n_designs.min(engine.stats.gen_batch);
        let conds: Vec<(f32, [f32; 3])> = (0..n).map(|_| (p, q.g.norm_vec())).collect();
        let configs = engine.gandse_generate(seed.wrapping_add(qi as u32), &conds)?;
        let mean: f64 = configs.iter().map(|c| rel_err(c, q)).sum::<f64>() / configs.len() as f64;
        errs.push(mean);
    }
    Ok(MethodResult {
        name: "GANDSE",
        error_gen: mean(&errs),
        search_time_s: timer.elapsed_s() / queries.len() as f64,
        queries: queries.len(),
    })
}

/// Vanilla BO directly over the 8-d normalized hardware encoding.
pub fn run_vanilla_bo(queries: &[Query], opts: &BoOptions, seed: u64) -> MethodResult {
    let mut errs = Vec::new();
    let timer = Timer::start();
    for (qi, q) in queries.iter().enumerate() {
        let mut rng = Pcg32::new(seed, qi as u64);
        let res = bo::minimize(
            |r: &mut Pcg32| encode_norm(&TargetSpace::sample(r)).iter().map(|&x| x as f64).collect(),
            |x| {
                let v: Vec<f32> = x.iter().map(|&v| v as f32).collect();
                rel_err(&decode_rounded(&v), q)
            },
            opts,
            &mut rng,
        );
        errs.push(res.best_y);
    }
    MethodResult {
        name: "Vanilla BO",
        error_gen: mean(&errs),
        search_time_s: timer.elapsed_s() / queries.len() as f64,
        queries: queries.len(),
    }
}

/// VAESA-style latent BO: search the Phase-1 latent space, decode through
/// the AE, evaluate on the simulator.
pub fn run_latent_bo(
    engine: &DiffAxE,
    queries: &[Query],
    opts: &BoOptions,
    seed: u64,
) -> Result<MethodResult> {
    let mut errs = Vec::new();
    let timer = Timer::start();
    for (qi, q) in queries.iter().enumerate() {
        let mut rng = Pcg32::new(seed, 1000 + qi as u64);
        // candidate generator: latents of random target-space configs
        let pool: Vec<Vec<f32>> = (0..opts.budget * 2)
            .map(|_| encode_norm(&TargetSpace::sample(&mut rng)).to_vec())
            .collect();
        let latents = engine.encode(&pool)?;
        let mut pool_iter = 0usize;
        let mut err = f64::INFINITY;
        {
            let sample = |r: &mut Pcg32| -> Vec<f64> {
                let _ = &r;
                let l = &latents[pool_iter % latents.len()];
                pool_iter += 1;
                l.iter().map(|&x| x as f64).collect()
            };
            let objective = |x: &[f64]| -> f64 {
                let lat: Vec<f32> = x.iter().map(|&v| v as f32).collect();
                match engine.decode_rounded(&[lat]) {
                    Ok(cfgs) => rel_err(&cfgs[0], q),
                    Err(_) => f64::INFINITY,
                }
            };
            let res = bo::minimize(sample, objective, opts, &mut rng);
            err = err.min(res.best_y);
        }
        errs.push(err);
    }
    Ok(MethodResult {
        name: "Latent BO (VAESA)",
        error_gen: mean(&errs),
        search_time_s: timer.elapsed_s() / queries.len() as f64,
        queries: queries.len(),
    })
}

/// Vanilla GD (DOSA-style): descend the exported differentiable surrogate in
/// hardware space, then evaluate the rounded design on the simulator.
pub fn run_vanilla_gd(
    engine: &DiffAxE,
    queries: &[Query],
    opts: &GdOptions,
    seed: u64,
) -> Result<MethodResult> {
    let mut errs = Vec::new();
    let timer = Timer::start();
    for (qi, q) in queries.iter().enumerate() {
        let st = engine.stats.stats_for(&q.g);
        let p = st.norm_runtime(q.target_cycles);
        let mut rng = Pcg32::new(seed, 2000 + qi as u64);
        let res = gd::descend(
            |x: &[f64]| {
                let hw: Vec<f32> = x.iter().map(|&v| v as f32).collect();
                let (losses, grads) = engine
                    .surrogate_grad(&[hw], &q.g, &[p])
                    .expect("surrogate_grad");
                (losses[0] as f64, grads[0].iter().map(|&g| g as f64).collect())
            },
            |r: &mut Pcg32| encode_norm(&TargetSpace::sample(r)).iter().map(|&x| x as f64).collect(),
            opts,
            &mut rng,
        );
        let v: Vec<f32> = res.best_x.iter().map(|&x| x as f32).collect();
        // DOSA searches a coarse space: snap to the training grid
        errs.push(rel_err(&coarsen(&decode_rounded(&v)), q));
    }
    Ok(MethodResult {
        name: "Vanilla GD (DOSA)",
        error_gen: mean(&errs),
        search_time_s: timer.elapsed_s() / queries.len() as f64,
        queries: queries.len(),
    })
}

/// Latent GD (Polaris-style): descend the PP gradient in latent space.
pub fn run_latent_gd(
    engine: &DiffAxE,
    queries: &[Query],
    opts: &GdOptions,
    seed: u64,
) -> Result<MethodResult> {
    let mut errs = Vec::new();
    let timer = Timer::start();
    let d = engine.stats.latent_dim;
    for (qi, q) in queries.iter().enumerate() {
        let st = engine.stats.stats_for(&q.g);
        let p = st.norm_runtime(q.target_cycles);
        let mut rng = Pcg32::new(seed, 3000 + qi as u64);
        // init at encodings of random configs (the latent space has no box
        // bounds, so clamp is off)
        let res = gd::descend(
            |x: &[f64]| {
                let lat: Vec<f32> = x.iter().map(|&v| v as f32).collect();
                let (losses, grads) = engine.pp_grad(&[lat], &q.g, &[p]).expect("pp_grad");
                (losses[0] as f64, grads[0].iter().map(|&g| g as f64).collect())
            },
            |r: &mut Pcg32| {
                let hw = encode_norm(&TargetSpace::sample(r)).to_vec();
                engine.encode(&[hw]).expect("encode")[0]
                    .iter()
                    .map(|&x| x as f64)
                    .collect()
            },
            &GdOptions { clamp: false, ..opts.clone() },
            &mut rng,
        );
        let lat: Vec<f32> = res.best_x.iter().map(|&x| x as f32).collect();
        let hw = engine.decode_rounded(&[lat])?[0];
        errs.push(rel_err(&hw, q));
        let _ = d;
    }
    Ok(MethodResult {
        name: "Latent GD (Polaris)",
        error_gen: mean(&errs),
        search_time_s: timer.elapsed_s() / queries.len() as f64,
        queries: queries.len(),
    })
}

fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len().max(1) as f64
}
