//! Experiment protocol for §IV-B.1 (Table III, Fig 16): runtime-conditioned
//! generation. The per-method free functions are gone — every strategy is an
//! [`Optimizer`] and [`evaluate_method`] drives it over a query set, so the
//! (method × task) matrix collapses to one loop.

use super::api::{Budget, Objective, Optimizer, SearchCtx};
use crate::models::DiffAxE;
use crate::util::rng;
use crate::workload::Gemm;
use anyhow::Result;

/// One method's aggregate result over a set of (workload, target) queries.
#[derive(Debug, Clone)]
pub struct MethodResult {
    pub name: String,
    /// mean `|T_gen − T*| / T*` under the chosen [`ErrorStat`]
    pub error_gen: f64,
    /// mean wall-clock search time per query (seconds)
    pub search_time_s: f64,
    pub queries: usize,
}

/// A runtime-generation query.
#[derive(Debug, Clone, Copy)]
pub struct Query {
    pub g: Gemm,
    pub target_cycles: f64,
}

impl Query {
    pub fn objective(&self) -> Objective {
        Objective::Runtime { g: self.g, target_cycles: self.target_cycles }
    }
}

/// How a method's per-query error is read off its [`SearchOutcome`]:
/// the generative methods report the mean over *all* generated designs
/// (the paper's protocol), the optimization baselines their single best.
///
/// [`SearchOutcome`]: super::api::SearchOutcome
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorStat {
    MeanOfGenerated,
    BestFound,
}

/// Sample `n_targets` uniform (in log space) runtime targets per workload
/// between its observed min and max (paper: 20 targets/workload).
pub fn make_queries(engine: &DiffAxE, workloads: &[Gemm], n_targets: usize) -> Vec<Query> {
    let mut out = Vec::new();
    for g in workloads {
        let st = engine.stats.stats_for(g);
        let (lo, hi) = st.runtime_range();
        for i in 0..n_targets {
            let f = (i as f64 + 0.5) / n_targets as f64;
            let target = (lo.ln() + f * (hi.ln() - lo.ln())).exp();
            out.push(Query { g: *g, target_cycles: target });
        }
    }
    out
}

/// Drive one optimizer over every query and aggregate the Table III
/// metrics. Each query gets an independent seed stream derived from
/// `seed` and its index.
pub fn evaluate_method(
    opt: &mut dyn Optimizer,
    queries: &[Query],
    budget: &Budget,
    stat: ErrorStat,
    seed: u64,
) -> Result<MethodResult> {
    let mut errs = Vec::with_capacity(queries.len());
    let mut time_s = 0.0;
    let ctx = SearchCtx::background();
    for (qi, q) in queries.iter().enumerate() {
        let out = opt.search(&ctx, &q.objective(), budget, rng::derive(seed, qi as u64))?;
        errs.push(match stat {
            ErrorStat::MeanOfGenerated => out.mean_score(),
            ErrorStat::BestFound => out.best_score(),
        });
        time_s += out.search_time_s;
    }
    let n = queries.len().max(1);
    Ok(MethodResult {
        name: opt.name().to_string(),
        error_gen: errs.iter().sum::<f64>() / n as f64,
        search_time_s: time_s / n as f64,
        queries: queries.len(),
    })
}
