//! Experiment 2 (§III-D, §IV-B.2, Table IV): EDP-oriented DSE through
//! power–performance class conditioning, and the SP metric
//! `SP = EDP_random / EDP_method` (higher is better).

use super::{coarsen, edp_of};
use crate::baselines::{bo, gd, random, BoOptions, GdOptions};
use crate::design_space::{decode_rounded, encode_norm, HwConfig, TargetSpace};
use crate::models::{ClassMode, DiffAxE};
use crate::util::rng::Pcg32;
use crate::util::stats::Timer;
use crate::workload::Gemm;
use anyhow::Result;

/// One method's EDP-DSE outcome on one workload.
#[derive(Debug, Clone)]
pub struct EdpOutcome {
    pub best_edp: f64,
    pub best_hw: HwConfig,
    pub search_time_s: f64,
    pub evals: usize,
}

/// DiffAxE: generate `n_per_class` designs for each of the N_power × N_perf
/// classes, evaluate all, keep the minimum EDP (paper: 1000 × 9 designs).
pub fn diffaxe_edp(engine: &DiffAxE, g: &Gemm, n_per_class: usize, seed: u32) -> Result<EdpOutcome> {
    let timer = Timer::start();
    let n_classes = engine.stats.n_power * engine.stats.n_perf;
    let b = engine.stats.gen_batch;
    let mut best: Option<(f64, HwConfig)> = None;
    let mut evals = 0;
    for class in 0..n_classes {
        let mut remaining = n_per_class;
        let mut chunk_idx = 0u32;
        while remaining > 0 {
            let n = remaining.min(b);
            let conds: Vec<(i32, [f32; 3])> =
                (0..n).map(|_| (class as i32, g.norm_vec())).collect();
            let s = seed
                .wrapping_add(class as u32)
                .wrapping_mul(2654435761)
                .wrapping_add(chunk_idx);
            let configs = engine.sample_class(ClassMode::Edp, s, &conds)?;
            for hw in configs {
                let e = edp_of(&hw, g);
                evals += 1;
                if best.as_ref().map(|(b, _)| e < *b).unwrap_or(true) {
                    best = Some((e, hw));
                }
            }
            remaining -= n;
            chunk_idx += 1;
        }
    }
    let (best_edp, best_hw) = best.unwrap();
    Ok(EdpOutcome { best_edp, best_hw, search_time_s: timer.elapsed_s(), evals })
}

/// Random search with the same total evaluation budget.
pub fn random_edp(g: &Gemm, budget: usize, seed: u64) -> EdpOutcome {
    let timer = Timer::start();
    let mut rng = Pcg32::new(seed, 55);
    let (hw, e) = random::search(budget, |hw| edp_of(hw, g), &mut rng);
    EdpOutcome { best_edp: e, best_hw: hw, search_time_s: timer.elapsed_s(), evals: budget }
}

/// Vanilla BO on EDP over the full target space.
pub fn vanilla_bo_edp(g: &Gemm, opts: &BoOptions, seed: u64) -> EdpOutcome {
    let timer = Timer::start();
    let mut rng = Pcg32::new(seed, 56);
    let res = bo::minimize(
        |r: &mut Pcg32| encode_norm(&TargetSpace::sample(r)).iter().map(|&x| x as f64).collect(),
        |x| {
            let v: Vec<f32> = x.iter().map(|&v| v as f32).collect();
            edp_of(&decode_rounded(&v), g)
        },
        opts,
        &mut rng,
    );
    let v: Vec<f32> = res.best_x.iter().map(|&x| x as f32).collect();
    EdpOutcome {
        best_edp: res.best_y,
        best_hw: decode_rounded(&v),
        search_time_s: timer.elapsed_s(),
        evals: res.evals,
    }
}

/// VAESA-style latent BO on EDP.
pub fn latent_bo_edp(engine: &DiffAxE, g: &Gemm, opts: &BoOptions, seed: u64) -> Result<EdpOutcome> {
    let timer = Timer::start();
    let mut rng = Pcg32::new(seed, 57);
    let pool: Vec<Vec<f32>> = (0..opts.budget * 2)
        .map(|_| encode_norm(&TargetSpace::sample(&mut rng)).to_vec())
        .collect();
    let latents = engine.encode(&pool)?;
    let mut pool_iter = 0usize;
    let mut best: Option<(f64, HwConfig)> = None;
    let res = bo::minimize(
        |_r: &mut Pcg32| {
            let l = &latents[pool_iter % latents.len()];
            pool_iter += 1;
            l.iter().map(|&x| x as f64).collect()
        },
        |x| {
            let lat: Vec<f32> = x.iter().map(|&v| v as f32).collect();
            match engine.decode_rounded(&[lat]) {
                Ok(cfgs) => {
                    let e = edp_of(&cfgs[0], g);
                    if best.as_ref().map(|(b, _)| e < *b).unwrap_or(true) {
                        best = Some((e, cfgs[0]));
                    }
                    e
                }
                Err(_) => f64::INFINITY,
            }
        },
        opts,
        &mut rng,
    );
    let (best_edp, best_hw) =
        best.unwrap_or_else(|| (res.best_y, TargetSpace::sample(&mut rng)));
    Ok(EdpOutcome { best_edp, best_hw, search_time_s: timer.elapsed_s(), evals: res.evals })
}

/// DOSA stand-in: finite-difference GD on EDP over the *coarse* grid
/// (Table IV: DOSA searches ~O(10^7) granularity).
pub fn dosa_edp(g: &Gemm, opts: &GdOptions, seed: u64) -> EdpOutcome {
    let timer = Timer::start();
    let mut rng = Pcg32::new(seed, 58);
    // log-EDP objective keeps gradients scaled
    let res = gd::fd_gd(
        |x: &[f64]| {
            let v: Vec<f32> = x.iter().map(|&v| v as f32).collect();
            edp_of(&coarsen(&decode_rounded(&v)), g).ln()
        },
        |r: &mut Pcg32| encode_norm(&TargetSpace::sample(r)).iter().map(|&x| x as f64).collect(),
        0.05,
        opts,
        &mut rng,
    );
    let v: Vec<f32> = res.best_x.iter().map(|&x| x as f32).collect();
    let hw = coarsen(&decode_rounded(&v));
    EdpOutcome {
        best_edp: edp_of(&hw, g),
        best_hw: hw,
        search_time_s: timer.elapsed_s(),
        evals: res.grad_evals,
    }
}

/// Polaris stand-in: finite-difference GD in the latent space, decoded
/// through the AE and coarsened.
pub fn polaris_edp(engine: &DiffAxE, g: &Gemm, opts: &GdOptions, seed: u64) -> Result<EdpOutcome> {
    let timer = Timer::start();
    let mut rng = Pcg32::new(seed, 59);
    // FD over 128-d latents is expensive; descend a random 8-d subspace
    // around an encoded anchor (multi-fidelity flavour of Polaris).
    let anchor = {
        let hw = encode_norm(&TargetSpace::sample(&mut rng)).to_vec();
        engine.encode(&[hw])?[0].clone()
    };
    let d = anchor.len();
    let dirs: Vec<Vec<f32>> = (0..8)
        .map(|_| {
            let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
            v.iter().map(|x| x / n).collect()
        })
        .collect();
    let to_latent = |x: &[f64]| -> Vec<f32> {
        let mut l = anchor.clone();
        for (coef, dir) in x.iter().zip(&dirs) {
            for (li, di) in l.iter_mut().zip(dir) {
                *li += (*coef as f32 - 0.5) * 8.0 * di;
            }
        }
        l
    };
    let mut best: Option<(f64, HwConfig)> = None;
    let res = gd::fd_gd(
        |x: &[f64]| {
            let lat = to_latent(x);
            match engine.decode_rounded(&[lat]) {
                Ok(cfgs) => {
                    let hw = coarsen(&cfgs[0]);
                    let e = edp_of(&hw, g);
                    if best.as_ref().map(|(b, _)| e < *b).unwrap_or(true) {
                        best = Some((e, hw));
                    }
                    e.ln()
                }
                Err(_) => f64::INFINITY,
            }
        },
        |r: &mut Pcg32| (0..8).map(|_| r.f64()).collect(),
        0.05,
        opts,
        &mut rng,
    );
    let (best_edp, best_hw) = best.unwrap_or_else(|| {
        let hw = TargetSpace::sample(&mut rng);
        (edp_of(&hw, g), hw)
    });
    Ok(EdpOutcome {
        best_edp,
        best_hw,
        search_time_s: timer.elapsed_s(),
        evals: res.grad_evals,
    })
}
