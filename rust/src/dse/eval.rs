//! The memoized, pooled evaluation core shared by every optimizer.
//!
//! Candidate scoring is the hot path of every search strategy — the paper's
//! headline numbers (17000× over BO, 145.6×/1312× structured-DSE speedups,
//! O(10^17) LLM co-design sweeps) are all throughput claims about exactly
//! this loop. Two structural facts make it optimizable without touching a
//! single result bit:
//!
//! 1. **Evaluation is pure.** `(HwConfig, Gemm) → (SimResult,
//!    EnergyResult)` has no state, so results can be memoized and the work
//!    partitioned over threads; cached, pooled and scalar paths are
//!    bit-identical by construction.
//! 2. **Rounded design points recur.** Generation and rounding are
//!    many-to-one (paper Fig 2a): decoders snap a continuous latent onto a
//!    discrete grid, coarse searchers (DOSA) revisit grid points across
//!    finite-difference probes and restarts, and the coordinator serves
//!    many clients chasing the same workloads. A memo table converts that
//!    recurrence into lookups.
//!
//! # Cache keying
//!
//! [`EvalCache`] maps `(HwConfig, Gemm)` → `(SimResult,
//! Option<EnergyResult>)`, where the energy half is the 32 nm ASIC
//! evaluation (the [`crate::dse::evaluate`] pair) filled *lazily*:
//! sim-only consumers ([`EvalCache::simulate`] /
//! [`EvalCache::simulate_pairs`] — the LLM probe loop, the structured
//! evaluator) cache `(sim, None)` and skip the energy dot product
//! entirely; the first energy consumer of the same key fills the `Some`
//! in place. `asic::evaluate` is a pure function of `(HwConfig,
//! SimResult)`, so the late fill is bit-identical to the eager one. The
//! key includes the loop order (it is a field of `HwConfig`), so the LLM
//! fast path's per-`(layer, order)` probes are individually cached. FPGA
//! consumers reuse the cached `SimResult` and re-price energy through
//! [`crate::energy::EnergyCoeffs`] — a dot product, cheap enough to never
//! be worth caching per platform.
//!
//! # Batched misses
//!
//! The batch entry points ([`EvalCache::simulate_pairs`],
//! [`EvalCache::evaluate_many`]) probe every key first, then compute all
//! misses as **one SoA batch** through [`crate::sim::batch`] instead of
//! per-key scalar calls — the loop-order dispatch is hoisted once per
//! batch rather than paid per candidate. [`par_map_chunks`] is the pool
//! bridge: it hands each worker a contiguous *slice* of the batch so the
//! worker can make a single batched call over its chunk.
//!
//! The table is **lock-striped**: the key hash picks one of
//! [`EvalCache::DEFAULT_SHARDS`] independently-locked shards, so concurrent
//! pool workers rarely contend. Each shard is capacity-bounded
//! ([`EvalCache::DEFAULT_CAP_PER_SHARD`]) and clears wholesale when full —
//! eviction precision is worthless for a memo of recurring points, and a
//! bounded table keeps a long-lived coordinator's footprint flat (~tens of
//! MB at the defaults). Raise the shard count if profiles show contention
//! (more shards = less contention, slightly worse locality); raise the
//! per-shard cap if hit rates sag on workloads with huge working sets.
//!
//! # Pool lifecycle
//!
//! [`WorkerPool`] replaces the per-call `std::thread::scope` spawning the
//! batched hot path used before: the coordinator serves many *small*
//! batches, and re-spawning OS threads per batch wastes more time than the
//! evaluation itself. The pool spawns `available_parallelism` workers once
//! (lazily, on first parallel batch), keeps them parked on a shared channel,
//! and never tears them down — workers exit only when the process does.
//! [`par_map`] splits a batch into contiguous per-worker chunks, runs the
//! chunks on the pool, and reassembles results in input order; a panicking
//! closure is caught on the worker (which survives for the next job) and
//! re-raised on the caller. Jobs must not call [`par_map`] themselves — a
//! nested call from a worker runs inline rather than deadlocking the pool.
//!
//! # Tuning `PAR_THRESHOLD`
//!
//! Below [`PAR_THRESHOLD`] items, a batch runs inline on the caller: one
//! analytical evaluation costs ~0.5 µs, so at small sizes channel round
//! trips and cache-line handoffs cost more than they save. The default (64)
//! was chosen with `benches/micro_sim.rs`; re-measure there before changing
//! it — the crossover moves with simulator cost, not with core count.

use crate::design_space::HwConfig;
use crate::energy::EnergyResult;
use crate::sim::SimResult;
use crate::util::sync::{rank, TrackedMutex};
use crate::workload::Gemm;
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, OnceLock};

/// Below this batch size threading overhead beats the win; run inline.
pub const PAR_THRESHOLD: usize = 64;

// ---------------------------------------------------------------------------
// persistent worker pool
// ---------------------------------------------------------------------------

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Name prefix of pool worker threads (also the nested-call guard: a
/// [`par_map`] issued from a worker thread runs inline).
const WORKER_NAME: &str = "eval-worker";

/// A long-lived, channel-fed thread pool for evaluation batches (rayon is
/// not in the offline registry). One process-wide instance, spawned lazily
/// by [`WorkerPool::global`]; see the module docs for the lifecycle.
pub struct WorkerPool {
    tx: TrackedMutex<Sender<Job>>,
    workers: usize,
}

static POOL: OnceLock<WorkerPool> = OnceLock::new();

impl WorkerPool {
    /// The shared pool (spawned on first use).
    pub fn global() -> &'static WorkerPool {
        POOL.get_or_init(|| {
            let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            WorkerPool::with_workers(n)
        })
    }

    fn with_workers(n: usize) -> WorkerPool {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(TrackedMutex::new("eval.pool.rx", rank::POOL_RECEIVER, rx));
        for i in 0..n {
            let rx = rx.clone();
            std::thread::Builder::new()
                .name(format!("{WORKER_NAME}-{i}"))
                .spawn(move || loop {
                    // take the next job while holding the queue lock, run it
                    // after releasing; exit when every sender is gone
                    let job = { rx.lock().recv() };
                    match job {
                        Ok(job) => job(),
                        Err(_) => return,
                    }
                })
                .expect("spawn eval-worker thread");
        }
        WorkerPool { tx: TrackedMutex::new("eval.pool.tx", rank::POOL_SENDER, tx), workers: n }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers
    }

    fn submit(&self, job: Job) {
        self.tx.lock().send(job).expect("eval-worker queue closed");
    }
}

/// Order-preserving parallel map over the persistent [`WorkerPool`].
///
/// Bit-identical to `items.iter().map(f).collect()` — the closure must be
/// pure; threads only partition the index range. Runs inline when the batch
/// is below [`PAR_THRESHOLD`], when the machine has a single core, or when
/// called from a pool worker (nested parallelism guard). A panic inside `f`
/// is forwarded to the caller after the batch drains; the workers survive.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Clone + Send + Sync + 'static,
    R: Send + 'static,
    F: Fn(&T) -> R + Send + Sync + 'static,
{
    par_map_chunks(items, move |chunk| chunk.iter().map(|t| f(t)).collect())
}

/// Chunk-at-a-time variant of [`par_map`]: the closure receives each
/// worker's contiguous *slice* of the batch and returns one result per
/// item, letting callers amortize per-call work across the chunk (the
/// batched evaluators make a single SoA simulation call per chunk).
/// Order-preserving and bit-identical to `f(items)` run inline — which is
/// exactly what happens below [`PAR_THRESHOLD`], on single-core machines,
/// or from a pool worker (nested parallelism guard). Panics in `f` are
/// forwarded after the batch drains; a chunk result of the wrong length
/// is a caller bug and panics on reassembly.
pub fn par_map_chunks<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Clone + Send + Sync + 'static,
    R: Send + 'static,
    F: Fn(&[T]) -> Vec<R> + Send + Sync + 'static,
{
    let nested = std::thread::current().name().is_some_and(|n| n.starts_with(WORKER_NAME));
    if nested || items.len() < PAR_THRESHOLD {
        return f(items);
    }
    let pool = WorkerPool::global();
    if pool.workers() <= 1 {
        return f(items);
    }
    // From<&[T]> clones straight into the Arc allocation: one copy, not two
    let shared: Arc<[T]> = Arc::from(items);
    let f = Arc::new(f);
    let chunk = items.len().div_ceil(pool.workers());
    let n_chunks = items.len().div_ceil(chunk);
    let (tx, rx) = channel();
    for ci in 0..n_chunks {
        let lo = ci * chunk;
        let hi = ((ci + 1) * chunk).min(shared.len());
        let shared = shared.clone();
        let f = f.clone();
        let tx = tx.clone();
        pool.submit(Box::new(move || {
            let out = catch_unwind(AssertUnwindSafe(|| f(&shared[lo..hi])));
            let _ = tx.send((ci, out));
        }));
    }
    drop(tx);
    let mut slots: Vec<Option<Vec<R>>> = (0..n_chunks).map(|_| None).collect();
    let mut panicked = None;
    for _ in 0..n_chunks {
        let (ci, res) = rx.recv().expect("eval-worker dropped a chunk result");
        match res {
            Ok(v) => slots[ci] = Some(v),
            Err(payload) => panicked = Some(payload),
        }
    }
    if let Some(payload) = panicked {
        resume_unwind(payload);
    }
    let mut out = Vec::with_capacity(shared.len());
    for s in slots {
        out.extend(s.expect("every chunk reported exactly once"));
    }
    assert_eq!(out.len(), shared.len(), "chunk closure must return one result per item");
    out
}

// ---------------------------------------------------------------------------
// sharded evaluation cache
// ---------------------------------------------------------------------------

/// Point-in-time cache counters (monotonic except `entries`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// entries currently resident across all shards
    pub entries: u64,
    /// shard wholesale-clear events (capacity evictions)
    pub evictions: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

impl std::fmt::Display for CacheStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "hits={} misses={} hit_rate={:.3} entries={} evictions={}",
            self.hits,
            self.misses,
            self.hit_rate(),
            self.entries,
            self.evictions
        )
    }
}

/// Memo key: the configuration (loop order included) and the workload.
type EvalKey = (HwConfig, Gemm);
/// What [`EvalCache::evaluate`] returns: the simulation and its 32 nm
/// ASIC energy evaluation.
type EvalValue = (SimResult, EnergyResult);
/// What a shard stores: the energy half is `None` until an energy
/// consumer first touches the key (sim-only paths never pay for it).
type CachedValue = (SimResult, Option<EnergyResult>);
/// All shards share one rank ([`rank::EVAL_SHARD`]): probes and inserts
/// take exactly one shard at a time, never two — the debug assertions
/// enforce that too (same-rank nesting panics).
type Shard = TrackedMutex<HashMap<EvalKey, CachedValue>>;

/// Lock-striped memo table for the pure evaluation function — see the
/// module docs for keying, sharding and eviction policy.
pub struct EvalCache {
    shards: Vec<Shard>,
    cap_per_shard: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

static CACHE: OnceLock<Arc<EvalCache>> = OnceLock::new();

impl EvalCache {
    /// Default shard count — enough stripes that `available_parallelism`
    /// workers rarely collide on one lock.
    pub const DEFAULT_SHARDS: usize = 16;

    /// Default per-shard entry cap (~16 k entries × 16 shards ≈ 260 k
    /// cached points, tens of MB).
    pub const DEFAULT_CAP_PER_SHARD: usize = 1 << 14;

    /// A cache with explicit geometry (benches and tests).
    pub fn new(shards: usize, cap_per_shard: usize) -> EvalCache {
        EvalCache {
            shards: (0..shards.max(1))
                .map(|_| TrackedMutex::new("eval.cache.shard", rank::EVAL_SHARD, HashMap::new()))
                .collect(),
            cap_per_shard: cap_per_shard.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// The process-wide cache behind [`crate::dse::evaluate_batch`] (and
    /// thus `Session::evaluate_batch` and the coordinator's batcher), the
    /// scalar `Objective::evaluate` scoring path, and the LLM fast path's
    /// per-(layer, order) probes.
    pub fn global() -> &'static EvalCache {
        Self::global_arc_ref().as_ref()
    }

    /// An owning handle to the process-wide cache. The coordinator's
    /// worker fleet hands one clone of this `Arc` to every worker's
    /// `Session`, making the shared-ownership contract explicit: tenants
    /// probing overlapping design regions hit each other's entries, and a
    /// test can substitute an isolated cache via `Session::with_cache`.
    pub fn global_arc() -> Arc<EvalCache> {
        Self::global_arc_ref().clone()
    }

    fn global_arc_ref() -> &'static Arc<EvalCache> {
        CACHE.get_or_init(|| {
            Arc::new(EvalCache::new(Self::DEFAULT_SHARDS, Self::DEFAULT_CAP_PER_SHARD))
        })
    }

    fn shard_of(&self, key: &EvalKey) -> usize {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) % self.shards.len()
    }

    /// Insert (or refresh) one entry, clearing the shard wholesale when it
    /// is at capacity.
    fn insert(&self, key: &EvalKey, v: CachedValue) {
        let mut m = self.shards[self.shard_of(key)].lock();
        if m.len() >= self.cap_per_shard {
            m.clear();
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        m.insert(*key, v);
    }

    /// Simulate + ASIC-evaluate through the memo table. Bit-identical to
    /// [`crate::dse::evaluate`] (the function is pure; the table only
    /// short-circuits recomputation).
    pub fn evaluate(&self, hw: &HwConfig, g: &Gemm) -> EvalValue {
        let key = (*hw, *g);
        let si = self.shard_of(&key);
        let cached = self.shards[si].lock().get(&key).copied();
        match cached {
            Some((s, Some(e))) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                (s, e)
            }
            Some((s, None)) => {
                // sim cached by a sim-only path: fill the energy half in
                // place — asic::evaluate is pure in (hw, sim), so the late
                // fill is bit-identical to the eager one
                self.hits.fetch_add(1, Ordering::Relaxed);
                let e = crate::energy::asic::evaluate(hw, &s);
                self.insert(&key, (s, Some(e)));
                (s, e)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                // compute outside the lock: misses must not serialize on
                // the shard
                let v = crate::dse::evaluate(hw, g);
                self.insert(&key, (v.0, Some(v.1)));
                v
            }
        }
    }

    /// Cached simulation only (the LLM fast path re-prices energy itself
    /// through [`crate::energy::EnergyCoeffs`]). Misses cache `(sim,
    /// None)` — the energy half stays unpaid until an energy consumer
    /// touches the key.
    pub fn simulate(&self, hw: &HwConfig, g: &Gemm) -> SimResult {
        let key = (*hw, *g);
        let si = self.shard_of(&key);
        if let Some(v) = self.shards[si].lock().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return v.0;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let s = crate::sim::simulate(hw, g);
        self.insert(&key, (s, None));
        s
    }

    /// Cached simulation of per-candidate `(configuration, GEMM)` pairs:
    /// probe every key, then compute all misses as one SoA batch through
    /// [`crate::sim::batch::simulate_pairs`]. Bit-identical to calling
    /// [`EvalCache::simulate`] per pair (the batch simulator's scalar
    /// oracle guarantee), in input order; duplicates within the batch are
    /// simulated per occurrence but cache to the same key.
    pub fn simulate_pairs(&self, pairs: &[(HwConfig, Gemm)]) -> Vec<SimResult> {
        let mut out: Vec<Option<SimResult>> = vec![None; pairs.len()];
        let mut miss_idx: Vec<usize> = Vec::new();
        for (i, key) in pairs.iter().enumerate() {
            let si = self.shard_of(key);
            match self.shards[si].lock().get(key) {
                Some(v) => out[i] = Some(v.0),
                None => miss_idx.push(i),
            }
        }
        self.hits.fetch_add((pairs.len() - miss_idx.len()) as u64, Ordering::Relaxed);
        self.misses.fetch_add(miss_idx.len() as u64, Ordering::Relaxed);
        if !miss_idx.is_empty() {
            let miss_pairs: Vec<(HwConfig, Gemm)> = miss_idx.iter().map(|&i| pairs[i]).collect();
            let sims = crate::sim::batch::simulate_pairs(&miss_pairs);
            for (&i, sim) in miss_idx.iter().zip(&sims) {
                self.insert(&pairs[i], (*sim, None));
                out[i] = Some(*sim);
            }
        }
        out.into_iter().map(|o| o.expect("every lane filled")).collect()
    }

    /// Cached simulate + ASIC-evaluate of a configuration batch on one
    /// GEMM: probe every key, compute sim misses as one SoA batch through
    /// [`crate::sim::batch::simulate_batch`], and fill any outstanding
    /// lazy energies. Bit-identical to calling [`EvalCache::evaluate`]
    /// per configuration, in input order.
    pub fn evaluate_many(&self, cfgs: &[HwConfig], g: &Gemm) -> Vec<EvalValue> {
        let mut out: Vec<Option<EvalValue>> = vec![None; cfgs.len()];
        let mut sim_only: Vec<(usize, SimResult)> = Vec::new();
        let mut miss_idx: Vec<usize> = Vec::new();
        for (i, hw) in cfgs.iter().enumerate() {
            let key = (*hw, *g);
            let si = self.shard_of(&key);
            match self.shards[si].lock().get(&key) {
                Some(&(s, Some(e))) => out[i] = Some((s, e)),
                Some(&(s, None)) => sim_only.push((i, s)),
                None => miss_idx.push(i),
            }
        }
        self.hits.fetch_add((cfgs.len() - miss_idx.len()) as u64, Ordering::Relaxed);
        self.misses.fetch_add(miss_idx.len() as u64, Ordering::Relaxed);
        for (i, s) in sim_only {
            let e = crate::energy::asic::evaluate(&cfgs[i], &s);
            self.insert(&(cfgs[i], *g), (s, Some(e)));
            out[i] = Some((s, e));
        }
        if !miss_idx.is_empty() {
            let miss_cfgs: Vec<HwConfig> = miss_idx.iter().map(|&i| cfgs[i]).collect();
            let sims = crate::sim::batch::simulate_batch(&miss_cfgs, g);
            for (&i, sim) in miss_idx.iter().zip(&sims) {
                let e = crate::energy::asic::evaluate(&cfgs[i], sim);
                self.insert(&(cfgs[i], *g), (*sim, Some(e)));
                out[i] = Some((*sim, e));
            }
        }
        out.into_iter().map(|o| o.expect("every lane filled")).collect()
    }

    /// Current counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.shards.iter().map(|s| s.lock().len() as u64).sum(),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }

    /// Drop every entry (counters keep accumulating). Benches use this to
    /// measure cold-path cost.
    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design_space::TargetSpace;
    use crate::util::rng::Pcg32;

    #[test]
    fn cache_returns_bit_identical_results_and_counts_hits() {
        let cache = EvalCache::new(4, 1024);
        let mut rng = Pcg32::seeded(3);
        let g = Gemm::new(128, 768, 768);
        let cfgs: Vec<HwConfig> = (0..32).map(|_| TargetSpace::sample(&mut rng)).collect();
        for hw in &cfgs {
            let (s, e) = cache.evaluate(hw, &g);
            let (s2, e2) = crate::dse::evaluate(hw, &g);
            assert_eq!(s, s2);
            assert_eq!(e, e2);
        }
        let cold = cache.stats();
        assert_eq!(cold.misses, 32);
        assert_eq!(cold.entries, 32);
        for hw in &cfgs {
            let (s, e) = cache.evaluate(hw, &g);
            let (s2, e2) = crate::dse::evaluate(hw, &g);
            assert_eq!(s, s2);
            assert_eq!(e, e2);
        }
        let warm = cache.stats();
        assert_eq!(warm.hits, 32);
        assert_eq!(warm.misses, 32);
        assert!((warm.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn cache_eviction_bounds_entries() {
        let cache = EvalCache::new(2, 8);
        let mut rng = Pcg32::seeded(9);
        let g = Gemm::new(64, 64, 64);
        for _ in 0..200 {
            let hw = TargetSpace::sample(&mut rng);
            cache.evaluate(&hw, &g);
        }
        let s = cache.stats();
        assert!(s.entries <= 2 * 8, "entries {} exceed cap", s.entries);
        assert!(s.evictions > 0, "200 inserts into 16 slots must evict");
    }

    #[test]
    fn par_map_matches_inline_and_preserves_order() {
        let items: Vec<u64> = (0..(PAR_THRESHOLD as u64 * 4)).collect();
        let out = par_map(&items, |&x| x * x + 1);
        let expect: Vec<u64> = items.iter().map(|&x| x * x + 1).collect();
        assert_eq!(out, expect);
        // below the threshold: inline path, same contract
        let small: Vec<u64> = (0..5).collect();
        assert_eq!(par_map(&small, |&x| x + 7), vec![7, 8, 9, 10, 11]);
        assert_eq!(par_map(&[] as &[u64], |&x| x), Vec::<u64>::new());
    }

    #[test]
    fn simulate_pairs_matches_scalar_cold_and_warm() {
        let cache = EvalCache::new(4, 1024);
        let mut rng = Pcg32::seeded(17);
        let shapes = [Gemm::new(1, 4096, 12288), Gemm::new(128, 768, 768), Gemm::new(5, 7, 3)];
        let pairs: Vec<(HwConfig, Gemm)> = (0..30)
            .map(|i| (TargetSpace::sample(&mut rng), shapes[i % shapes.len()]))
            .collect();
        let cold = cache.simulate_pairs(&pairs);
        for ((hw, g), s) in pairs.iter().zip(&cold) {
            assert_eq!(*s, crate::sim::simulate(hw, g));
        }
        assert_eq!(cache.stats().misses, 30);
        // warm pass: all hits, same bits
        let warm = cache.simulate_pairs(&pairs);
        assert_eq!(warm, cold);
        assert_eq!(cache.stats().hits, 30);
    }

    #[test]
    fn evaluate_many_matches_per_key_evaluate() {
        let cache = EvalCache::new(4, 1024);
        let mut rng = Pcg32::seeded(29);
        let g = Gemm::new(96, 512, 320);
        let cfgs: Vec<HwConfig> = (0..24).map(|_| TargetSpace::sample(&mut rng)).collect();
        let many = cache.evaluate_many(&cfgs, &g);
        for (hw, (s, e)) in cfgs.iter().zip(&many) {
            let (s2, e2) = crate::dse::evaluate(hw, &g);
            assert_eq!(*s, s2);
            assert_eq!(*e, e2);
        }
        assert_eq!(cache.stats().misses, 24);
        // warm: full hits including the stored energy half
        let warm = cache.evaluate_many(&cfgs, &g);
        assert_eq!(warm, many);
        assert_eq!(cache.stats().hits, 24);
    }

    #[test]
    fn lazy_energy_fill_is_bit_identical() {
        let cache = EvalCache::new(2, 256);
        let mut rng = Pcg32::seeded(41);
        let g = Gemm::new(64, 256, 64);
        let hw = TargetSpace::sample(&mut rng);
        // sim-only first: caches (sim, None) without paying for energy
        let s = cache.simulate(&hw, &g);
        assert_eq!(cache.stats().misses, 1);
        // energy consumer fills the Some in place — counts as a hit
        let (s2, e) = cache.evaluate(&hw, &g);
        assert_eq!(s2, s);
        assert_eq!((s2, e), crate::dse::evaluate(&hw, &g));
        let stats = cache.stats();
        assert_eq!((stats.hits, stats.misses), (1, 1));
        // evaluate_many sees the filled entry as a plain hit
        let many = cache.evaluate_many(&[hw], &g);
        assert_eq!(many, vec![(s2, e)]);
        assert_eq!(cache.stats().hits, 2);
    }

    #[test]
    fn par_map_chunks_matches_inline() {
        let items: Vec<u64> = (0..(PAR_THRESHOLD as u64 * 3)).collect();
        let out = par_map_chunks(&items, |chunk| chunk.iter().map(|&x| x * 3).collect());
        let expect: Vec<u64> = items.iter().map(|&x| x * 3).collect();
        assert_eq!(out, expect);
        let small: Vec<u64> = (0..7).collect();
        assert_eq!(
            par_map_chunks(&small, |c| c.iter().map(|&x| x + 1).collect()),
            (1..8).collect::<Vec<u64>>()
        );
        assert_eq!(par_map_chunks(&[] as &[u64], |c| c.to_vec()), Vec::<u64>::new());
    }

    #[test]
    fn par_map_panic_propagates_and_pool_survives() {
        let items: Vec<u64> = (0..(PAR_THRESHOLD as u64 * 2)).collect();
        let crashed = std::panic::catch_unwind(|| {
            par_map(&items, |&x| if x == 100 { panic!("boom") } else { x })
        });
        assert!(crashed.is_err(), "worker panic must reach the caller");
        // the pool still serves subsequent batches
        let out = par_map(&items, |&x| x + 1);
        assert_eq!(out.len(), items.len());
        assert_eq!(out[0], 1);
    }
}
