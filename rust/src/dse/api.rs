//! The unified DSE API: one [`Objective`] × [`Budget`] interface served by
//! every search strategy through the [`Optimizer`] trait, plus a
//! [`Session`] that owns the generative-engine handle and a batched
//! evaluation hot path ([`evaluate_batch`]).
//!
//! The paper's four experiment settings (runtime-conditioned generation,
//! EDP-class DSE, perf-opt generation, LLM co-design) and its baseline zoo
//! (BO, GD, random, fixed architectures, GANDSE, AIRCHITECT) all reduce to
//! `optimizer.search(&objective, &budget, seed) -> SearchOutcome`, so a new
//! workload or a new searcher is one impl, not a new family of free
//! functions. The coordinator's wire protocol
//! ([`crate::coordinator::protocol`]) speaks these exact types.
//!
//! # Budget semantics
//!
//! `Budget::evals` is honoured exactly by the generative and random
//! searchers and by BO (it becomes the BO evaluation budget). The GD
//! searchers take their step/restart structure from their [`GdOptions`]
//! but cap it so the implied evaluation count (finite differences spend
//! `1 + 2·dim` evaluations per step) stays within `Budget::evals`, and
//! report their true cost in [`SearchOutcome::evals`]. `Budget::per_class`
//! overrides the per-class (or per-layer) generation count for
//! class-conditioned searches.
//!
//! # Interruption
//!
//! Every search takes a [`SearchCtx`]: a cancellation flag, an optional
//! wall-clock deadline, and an optional [`ProgressSink`] that receives
//! per-batch [`SearchEvent`]s. Strategies poll the ctx between sampler /
//! evaluation batches (never mid-batch) and return a *partial*
//! [`SearchOutcome`] whose [`SearchOutcome::stopped`] records why the
//! search ended ([`StopReason`]). `Budget::wall_clock_s` is enforced
//! through the same mechanism: [`SearchRun`] folds it into the effective
//! deadline, so a budget cap and a ctx deadline behave identically.
//!
//! # Determinism
//!
//! Every optimizer derives its randomness from the caller's `seed: u64`
//! through [`crate::util::rng::split`]; the same `(objective, budget,
//! seed)` triple yields the same `SearchOutcome` (modulo `search_time_s`).

use super::coarsen;
use super::eval::{par_map, par_map_chunks, CacheStats, EvalCache};
use super::structured::{self, StructuredSpec};
use crate::baselines::{bo, gd, BoOptions, FixedArch, GdOptions};
use crate::design_space::{decode_rounded, encode_norm, HwConfig, TargetSpace, NORM_DIM};
use crate::energy::EnergyResult;
use crate::models::{ClassMode, DiffAxE};
use crate::sim::SimResult;
use crate::util::fault::{FaultPlan, FaultSite};
use crate::util::rng::{self, Pcg32};
use crate::workload::{Gemm, LlmModel, Stage};
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

pub use super::llm::Platform;

// ---------------------------------------------------------------------------
// shared vocabulary types
// ---------------------------------------------------------------------------

/// What a search is optimizing: a workload plus a metric.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Objective {
    /// §III-C: hit a target runtime — score is `|cycles − T*| / T*`.
    Runtime { g: Gemm, target_cycles: f64 },
    /// §III-D: minimize EDP (µJ·cycles) on one GEMM.
    MinEdp { g: Gemm },
    /// §III-E: minimize runtime (cycles) on one GEMM.
    MaxPerf { g: Gemm },
    /// §VI: minimize whole-model EDP for an LLM inference stage (per-layer
    /// loop orders chosen optimally for every candidate base config).
    LlmEdp { model: LlmModel, stage: Stage, seq: u32, platform: Platform },
    /// §V: structured DSE — minimize whole-model EDP with an independent
    /// per-segment sub-configuration under a shared accelerator budget
    /// (the O(10^17) heterogeneous setting; see [`crate::dse::structured`]).
    StructuredEdp { spec: StructuredSpec },
    /// §V: structured DSE for performance — minimize whole-model cycles
    /// over the same per-segment space.
    StructuredPerf { spec: StructuredSpec },
}

impl Objective {
    /// The single GEMM this objective evaluates on, if it is GEMM-shaped.
    pub fn gemm(&self) -> Option<Gemm> {
        match self {
            Objective::Runtime { g, .. }
            | Objective::MinEdp { g }
            | Objective::MaxPerf { g } => Some(*g),
            Objective::LlmEdp { .. }
            | Objective::StructuredEdp { .. }
            | Objective::StructuredPerf { .. } => None,
        }
    }

    /// The structured-DSE spec, if this is a structured objective.
    pub fn structured(&self) -> Option<StructuredSpec> {
        match self {
            Objective::StructuredEdp { spec } | Objective::StructuredPerf { spec } => Some(*spec),
            _ => None,
        }
    }

    /// Score of an already-evaluated design (lower is better).
    pub fn score_report(&self, d: &DesignReport) -> f64 {
        match self {
            Objective::Runtime { target_cycles, .. } => {
                ((d.cycles - target_cycles) / target_cycles).abs()
            }
            Objective::MinEdp { .. }
            | Objective::LlmEdp { .. }
            | Objective::StructuredEdp { .. } => d.edp,
            Objective::MaxPerf { .. } | Objective::StructuredPerf { .. } => d.cycles,
        }
    }

    /// Evaluate one configuration under this objective. Memoized through
    /// the shared [`EvalCache`] (bit-identical to uncached evaluation —
    /// the function is pure), so searchers that revisit grid points (DOSA
    /// finite differences, BO re-probes) pay a lookup, not a simulation.
    pub fn evaluate(&self, hw: &HwConfig) -> DesignReport {
        match self {
            Objective::Runtime { g, .. }
            | Objective::MinEdp { g }
            | Objective::MaxPerf { g } => {
                let (s, e) = EvalCache::global().evaluate(hw, g);
                DesignReport::from_sim(*hw, &s, &e)
            }
            Objective::LlmEdp { model, stage, seq, platform } => {
                let ev = super::llm::eval_model(hw, *model, *stage, *seq, *platform);
                DesignReport::from_sim(*hw, &ev.sim, &ev.energy)
            }
            // single-config view of the structured space: `hw` replicated
            // uniformly across segments (the heterogeneous searches go
            // through dse::structured, not through here)
            Objective::StructuredEdp { spec } | Objective::StructuredPerf { spec } => {
                structured::eval_uniform(spec, hw)
            }
        }
    }

    /// Score one configuration (evaluates it; lower is better).
    pub fn score(&self, hw: &HwConfig) -> f64 {
        self.score_report(&self.evaluate(hw))
    }

    /// Evaluate a batch of configurations in parallel, preserving order.
    /// Results are bit-identical to calling [`Objective::evaluate`] per
    /// element (the evaluation is pure; threads only partition the batch).
    pub fn evaluate_all(&self, cfgs: &[HwConfig]) -> Vec<DesignReport> {
        match self {
            Objective::Runtime { g, .. }
            | Objective::MinEdp { g }
            | Objective::MaxPerf { g } => evaluate_batch(cfgs, g)
                .into_iter()
                .zip(cfgs)
                .map(|((s, e), hw)| DesignReport::from_sim(*hw, &s, &e))
                .collect(),
            Objective::LlmEdp { model, stage, seq, platform } => {
                // hoist the workload memo lookup out of the per-candidate
                // loop: one Arc clone here instead of a memo-mutex hit per
                // candidate on every pool worker
                let wl = crate::workload::model_workload(*model, *stage, *seq);
                let platform = *platform;
                par_map(cfgs, move |hw| {
                    let ev = super::llm::eval_workload(hw, &wl, platform);
                    DesignReport::from_sim(*hw, &ev.sim, &ev.energy)
                })
            }
            Objective::StructuredEdp { spec } | Objective::StructuredPerf { spec } => {
                let spec = *spec;
                par_map(cfgs, move |hw| structured::eval_uniform(&spec, hw))
            }
        }
    }

    /// Loss transform for gradient descent: log-compress the wide-dynamic-
    /// range metrics (EDP spans decades); relative runtime error is already
    /// well-scaled.
    pub(crate) fn gd_loss(&self, score: f64) -> f64 {
        match self {
            Objective::Runtime { .. } => score,
            _ => score.max(f64::MIN_POSITIVE).ln(),
        }
    }
}

impl std::fmt::Display for Objective {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Objective::Runtime { g, target_cycles } => {
                write!(f, "runtime {g} -> {target_cycles:.0} cycles")
            }
            Objective::MinEdp { g } => write!(f, "min-EDP {g}"),
            Objective::MaxPerf { g } => write!(f, "max-perf {g}"),
            Objective::LlmEdp { model, stage, seq, platform } => {
                write!(f, "LLM-EDP {} {} seq={seq} {platform:?}", model.name(), stage.name())
            }
            Objective::StructuredEdp { spec } => write!(f, "structured-EDP {spec}"),
            Objective::StructuredPerf { spec } => write!(f, "structured-perf {spec}"),
        }
    }
}

// ---------------------------------------------------------------------------
// interruptible search context
// ---------------------------------------------------------------------------

/// Why a search returned. Anything but [`StopReason::Completed`] means the
/// [`SearchOutcome`] is *partial*: every design evaluated so far is still
/// ranked and reported, the strategy just did not run its full schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// The strategy ran its planned schedule to the end.
    Completed,
    /// The [`SearchCtx`] cancellation flag was raised.
    Cancelled,
    /// The effective deadline (ctx deadline or `Budget::wall_clock_s`)
    /// passed.
    DeadlineExceeded,
    /// `Budget::evals` cut the strategy's configured schedule short.
    BudgetExhausted,
}

impl StopReason {
    /// Stable wire name (see [`crate::coordinator::protocol`]).
    pub fn name(&self) -> &'static str {
        match self {
            StopReason::Completed => "completed",
            StopReason::Cancelled => "cancelled",
            StopReason::DeadlineExceeded => "deadline_exceeded",
            StopReason::BudgetExhausted => "budget_exhausted",
        }
    }

    /// Parse a wire name (inverse of [`StopReason::name`]).
    pub fn from_name(s: &str) -> Option<StopReason> {
        [
            StopReason::Completed,
            StopReason::Cancelled,
            StopReason::DeadlineExceeded,
            StopReason::BudgetExhausted,
        ]
        .into_iter()
        .find(|r| r.name() == s)
    }

    /// True when the outcome is partial (the search was interrupted).
    pub fn is_partial(&self) -> bool {
        !matches!(self, StopReason::Completed)
    }
}

/// One progress heartbeat, emitted between evaluation batches.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SearchEvent {
    /// Objective evaluations finished so far.
    pub evals: usize,
    /// Best (lowest) score seen so far; `f64::INFINITY` before the first
    /// evaluation completes.
    pub best_score: f64,
    /// Seconds since the search started.
    pub elapsed_s: f64,
}

/// Receives [`SearchEvent`]s. Implemented for any
/// `Fn(&SearchEvent) + Send + Sync` closure.
pub trait ProgressSink: Send + Sync {
    fn on_event(&self, ev: &SearchEvent);
}

impl<F: Fn(&SearchEvent) + Send + Sync> ProgressSink for F {
    fn on_event(&self, ev: &SearchEvent) {
        self(ev)
    }
}

/// The interruption context every [`Optimizer::search`] runs under:
/// a shared cancellation flag, an optional wall-clock deadline, and an
/// optional progress sink. [`SearchCtx::background`] is the inert default
/// (never cancels, never expires, drops events) used by batch experiments.
#[derive(Clone, Default)]
pub struct SearchCtx {
    cancel: Option<Arc<AtomicBool>>,
    deadline: Option<Instant>,
    sink: Option<Arc<dyn ProgressSink>>,
}

impl SearchCtx {
    /// A context that never cancels, never expires and drops progress.
    pub fn background() -> SearchCtx {
        SearchCtx::default()
    }

    /// Builder: attach a shared cancellation flag (store `true` to stop
    /// the search at its next poll point).
    pub fn with_cancel_flag(mut self, flag: Arc<AtomicBool>) -> SearchCtx {
        self.cancel = Some(flag);
        self
    }

    /// Builder: set an absolute wall-clock deadline.
    pub fn with_deadline(mut self, deadline: Instant) -> SearchCtx {
        self.deadline = Some(deadline);
        self
    }

    /// Builder: set a deadline `seconds` from now.
    pub fn with_deadline_in(self, seconds: f64) -> SearchCtx {
        self.with_deadline(Instant::now() + Duration::from_secs_f64(seconds.max(0.0)))
    }

    /// Builder: attach a progress sink.
    pub fn with_sink(mut self, sink: Arc<dyn ProgressSink>) -> SearchCtx {
        self.sink = Some(sink);
        self
    }

    /// Builder: attach a progress closure.
    pub fn with_progress(self, f: impl Fn(&SearchEvent) + Send + Sync + 'static) -> SearchCtx {
        self.with_sink(Arc::new(f))
    }

    /// True once the cancellation flag has been raised.
    pub fn cancelled(&self) -> bool {
        self.cancel.as_ref().map(|c| c.load(Ordering::Relaxed)).unwrap_or(false)
    }

    /// The ctx-level deadline, if any (the per-search effective deadline
    /// also folds in `Budget::wall_clock_s` — see [`SearchRun`]).
    pub fn deadline(&self) -> Option<Instant> {
        self.deadline
    }

    /// Deliver one progress event to the sink (no-op without a sink).
    pub fn emit(&self, ev: SearchEvent) {
        if let Some(s) = &self.sink {
            s.on_event(&ev);
        }
    }
}

/// Cap on eager `Vec` preallocation for eval-count-sized buffers: a huge
/// `Budget::evals` plus an early deadline must not reserve gigabytes.
pub(crate) const MAX_PREALLOC: usize = 65_536;

/// Per-search driver over a [`SearchCtx`]: merges the ctx deadline with
/// `Budget::wall_clock_s`, owns the search timer, and records the first
/// stop cause. Strategies call [`SearchRun::should_stop`] between batches
/// and stamp [`SearchRun::stop_reason`] into their outcome.
pub struct SearchRun<'c> {
    ctx: &'c SearchCtx,
    start: Instant,
    deadline: Option<Instant>,
    stopped: StopReason,
}

impl<'c> SearchRun<'c> {
    /// Start the run clock; the effective deadline is the earlier of the
    /// ctx deadline and `now + budget.wall_clock_s`.
    pub fn start(ctx: &'c SearchCtx, budget: &Budget) -> SearchRun<'c> {
        let now = Instant::now();
        let wall = budget
            .wall_clock_s
            .map(|s| now + Duration::from_secs_f64(s.max(0.0)));
        let deadline = match (ctx.deadline, wall) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        SearchRun { ctx, start: now, deadline, stopped: StopReason::Completed }
    }

    /// Poll the ctx: true once the search must wind down. The first
    /// triggering cause is latched (cancellation wins over the deadline).
    pub fn should_stop(&mut self) -> bool {
        if self.stopped == StopReason::Cancelled
            || self.stopped == StopReason::DeadlineExceeded
        {
            return true;
        }
        if self.ctx.cancelled() {
            self.stopped = StopReason::Cancelled;
            return true;
        }
        if self.deadline.map(|d| Instant::now() >= d).unwrap_or(false) {
            self.stopped = StopReason::DeadlineExceeded;
            return true;
        }
        false
    }

    /// Record that `Budget::evals` truncated the strategy's configured
    /// schedule (weakest stop cause: never overrides cancel/deadline).
    pub fn exhausted(&mut self) {
        if self.stopped == StopReason::Completed {
            self.stopped = StopReason::BudgetExhausted;
        }
    }

    /// Why the search ended (so far).
    pub fn stop_reason(&self) -> StopReason {
        self.stopped
    }

    /// True when any interruption cause has latched.
    pub fn interrupted(&self) -> bool {
        self.stopped.is_partial()
    }

    /// Seconds since [`SearchRun::start`].
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Emit one progress heartbeat through the ctx sink.
    pub fn progress(&self, evals: usize, best_score: f64) {
        self.ctx.emit(SearchEvent { evals, best_score, elapsed_s: self.elapsed_s() });
    }

    /// Evaluate candidates in deadline-pollable chunks through
    /// [`Objective::evaluate_all`], emitting a progress event per chunk.
    /// Order-preserving and bit-identical to one monolithic batch; an
    /// interruption returns the prefix evaluated so far.
    pub fn evaluate_chunked(&mut self, obj: &Objective, cfgs: &[HwConfig]) -> Vec<DesignReport> {
        // LLM/structured candidates run a whole-model evaluation each; keep
        // chunks small so the deadline poll granularity stays sub-second
        let chunk = match obj {
            Objective::LlmEdp { .. }
            | Objective::StructuredEdp { .. }
            | Objective::StructuredPerf { .. } => 16,
            _ => 512,
        };
        let mut out = Vec::with_capacity(cfgs.len());
        let mut best = f64::INFINITY;
        for c in cfgs.chunks(chunk) {
            if self.should_stop() {
                break;
            }
            let start = out.len();
            out.extend(obj.evaluate_all(c));
            for d in &out[start..] {
                best = best.min(obj.score_report(d));
            }
            self.progress(out.len(), best);
        }
        out
    }
}

/// How much a search may spend.
#[derive(Debug, Clone, PartialEq)]
pub struct Budget {
    /// Total evaluation budget (designs generated / points evaluated).
    pub evals: usize,
    /// Per-class (EDP classes) or per-layer (LLM) generation count for the
    /// class-conditioned searches; derived from `evals` when `None`.
    pub per_class: Option<usize>,
    /// Wall-clock cap in seconds, enforced uniformly through the
    /// [`SearchCtx`]/[`SearchRun`] deadline (polled between batches).
    pub wall_clock_s: Option<f64>,
}

impl Default for Budget {
    fn default() -> Self {
        Budget { evals: 256, per_class: None, wall_clock_s: None }
    }
}

impl Budget {
    /// A plain evaluation-count budget.
    pub fn evals(n: usize) -> Budget {
        Budget { evals: n, ..Default::default() }
    }

    /// Builder: set the per-class generation count.
    pub fn with_per_class(mut self, n: usize) -> Budget {
        self.per_class = Some(n);
        self
    }

    /// Builder: set the wall-clock cap.
    pub fn with_wall_clock(mut self, s: f64) -> Budget {
        self.wall_clock_s = Some(s);
        self
    }

    /// Per-class count for a search over `n_classes` classes.
    pub fn class_count(&self, n_classes: usize) -> usize {
        self.per_class.unwrap_or_else(|| (self.evals / n_classes.max(1)).max(1))
    }
}

/// One evaluated design. This is also the wire unit the coordinator
/// returns (see [`crate::coordinator::protocol`] for its JSON encoding).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DesignReport {
    pub hw: HwConfig,
    pub cycles: f64,
    pub power_w: f64,
    pub edp: f64,
}

impl DesignReport {
    pub fn from_sim(hw: HwConfig, s: &SimResult, e: &EnergyResult) -> DesignReport {
        DesignReport { hw, cycles: s.cycles as f64, power_w: e.power_w, edp: e.edp }
    }
}

/// The result of one `Optimizer::search` call: every evaluated design
/// ranked best-first, the per-evaluation score trace (evaluation order),
/// and cost accounting.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchOutcome {
    /// Display name of the optimizer that produced this outcome.
    pub optimizer: String,
    /// Evaluated designs, best (lowest score) first.
    pub ranked: Vec<DesignReport>,
    /// Objective score of each evaluation, in evaluation order.
    pub trace: Vec<f64>,
    /// Number of objective evaluations actually spent.
    pub evals: usize,
    /// Wall-clock cost in seconds.
    pub search_time_s: f64,
    /// Per-segment configurations of structured-DSE designs, parallel to
    /// `ranked` (`ranked[i].hw` is then the provisioned envelope and
    /// `segments[i]` its per-segment sub-configurations). Empty for
    /// single-config objectives.
    pub segments: Vec<Vec<HwConfig>>,
    /// Learned layer-segmentation cut points of structured-DSE designs,
    /// parallel to `ranked` (`boundaries[i]` are the interior layer
    /// indices where `segments[i]`'s segments begin). Empty when the
    /// search used the canonical fixed partition (or for single-config
    /// objectives).
    pub boundaries: Vec<Vec<usize>>,
    /// Why the search returned; anything but [`StopReason::Completed`]
    /// marks this outcome as partial (still ranked, still well-formed).
    pub stopped: StopReason,
}

impl SearchOutcome {
    /// Rank `reports` under `objective` and assemble the outcome.
    pub fn from_reports(
        optimizer: &str,
        objective: &Objective,
        reports: Vec<DesignReport>,
        search_time_s: f64,
    ) -> SearchOutcome {
        Self::from_reports_with_segments(optimizer, objective, reports, Vec::new(), search_time_s)
    }

    /// [`SearchOutcome::from_reports`] carrying per-design segment lists
    /// (the structured-DSE constructor): `segments` is parallel to
    /// `reports` (or empty) and is ranked in lockstep with them.
    pub fn from_reports_with_segments(
        optimizer: &str,
        objective: &Objective,
        reports: Vec<DesignReport>,
        segments: Vec<Vec<HwConfig>>,
        search_time_s: f64,
    ) -> SearchOutcome {
        Self::from_reports_with_structure(
            optimizer,
            objective,
            reports,
            segments,
            Vec::new(),
            search_time_s,
        )
    }

    /// [`SearchOutcome::from_reports_with_segments`] additionally carrying
    /// the learned segmentation cut points (the learned-boundary
    /// structured-DSE constructor): `boundaries` is parallel to `reports`
    /// (or empty) and is ranked in lockstep with them.
    pub fn from_reports_with_structure(
        optimizer: &str,
        objective: &Objective,
        reports: Vec<DesignReport>,
        segments: Vec<Vec<HwConfig>>,
        boundaries: Vec<Vec<usize>>,
        search_time_s: f64,
    ) -> SearchOutcome {
        debug_assert!(
            segments.is_empty() || segments.len() == reports.len(),
            "segments must be parallel to reports"
        );
        debug_assert!(
            boundaries.is_empty() || boundaries.len() == reports.len(),
            "boundaries must be parallel to reports"
        );
        let trace: Vec<f64> = reports.iter().map(|d| objective.score_report(d)).collect();
        let mut order: Vec<usize> = (0..reports.len()).collect();
        order.sort_by(|&a, &b| {
            trace[a].partial_cmp(&trace[b]).unwrap_or(std::cmp::Ordering::Equal)
        });
        let ranked: Vec<DesignReport> = order.iter().map(|&i| reports[i]).collect();
        let segments = if segments.is_empty() {
            Vec::new()
        } else {
            order.iter().map(|&i| segments[i].clone()).collect()
        };
        let boundaries = if boundaries.is_empty() {
            Vec::new()
        } else {
            order.iter().map(|&i| boundaries[i].clone()).collect()
        };
        SearchOutcome {
            optimizer: optimizer.to_string(),
            evals: reports.len(),
            ranked,
            trace,
            search_time_s,
            segments,
            boundaries,
            stopped: StopReason::Completed,
        }
    }

    /// An empty (zero-evaluation) outcome — the well-formed answer to a
    /// drained budget or a pre-cancelled search.
    pub fn empty(optimizer: &str, stopped: StopReason) -> SearchOutcome {
        SearchOutcome {
            optimizer: optimizer.to_string(),
            ranked: Vec::new(),
            trace: Vec::new(),
            evals: 0,
            search_time_s: 0.0,
            segments: Vec::new(),
            boundaries: Vec::new(),
            stopped,
        }
    }

    /// Builder: record why the search returned (strategies stamp their
    /// [`SearchRun::stop_reason`] here).
    pub fn with_stopped(mut self, stopped: StopReason) -> SearchOutcome {
        self.stopped = stopped;
        self
    }

    /// Best design found (lowest score), if any evaluation happened.
    pub fn best(&self) -> Option<&DesignReport> {
        self.ranked.first()
    }

    /// Best (minimum) score over the whole search.
    pub fn best_score(&self) -> f64 {
        self.trace.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Mean score over every evaluation — the paper's `error_gen` protocol
    /// for the generative methods (all generated designs count).
    pub fn mean_score(&self) -> f64 {
        if self.trace.is_empty() {
            f64::NAN
        } else {
            self.trace.iter().sum::<f64>() / self.trace.len() as f64
        }
    }

    /// Keep only the top-`k` ranked designs (trace and accounting intact).
    pub fn truncated(mut self, k: usize) -> SearchOutcome {
        self.ranked.truncate(k);
        self.segments.truncate(k);
        self.boundaries.truncate(k);
        self
    }
}

// ---------------------------------------------------------------------------
// batched evaluation hot path
// ---------------------------------------------------------------------------

/// Simulate + ASIC-evaluate a batch of configurations on one workload,
/// memoized through the shared [`EvalCache`] and partitioned over the
/// persistent [`crate::dse::eval::WorkerPool`]. Each worker receives a
/// contiguous chunk and computes its cache misses as one SoA batch
/// through [`crate::sim::batch`] ([`EvalCache::evaluate_many`]).
/// Order-preserving and bit-identical to calling [`super::evaluate`] per
/// element — the hot path is pure, so the cache only short-circuits
/// recomputation, threads only split the index range, and the batch
/// simulator is bit-identical to the scalar one by the scalar-oracle
/// guarantee.
pub fn evaluate_batch(cfgs: &[HwConfig], g: &Gemm) -> Vec<(SimResult, EnergyResult)> {
    let g = *g;
    par_map_chunks(cfgs, move |chunk| EvalCache::global().evaluate_many(chunk, &g))
}

/// A `Budget::evals(0)` search is answered immediately with a well-formed
/// empty outcome (`stopped: BudgetExhausted`) rather than spending a
/// forced minimum evaluation (or dividing by zero in a schedule
/// derivation). Every strategy checks this before starting its run.
pub(crate) fn drained(name: &str, budget: &Budget) -> Option<SearchOutcome> {
    (budget.evals == 0).then(|| SearchOutcome::empty(name, StopReason::BudgetExhausted))
}

// ---------------------------------------------------------------------------
// the Optimizer trait
// ---------------------------------------------------------------------------

/// A search strategy: anything that can spend a [`Budget`] chasing an
/// [`Objective`] from a seed, polling a [`SearchCtx`] between batches.
pub trait Optimizer {
    /// Display name (used in tables and wire responses).
    fn name(&self) -> &'static str;

    /// Run the search. Deterministic in `(objective, budget, seed)` under
    /// an inert ctx; an interrupting ctx yields a partial outcome whose
    /// [`SearchOutcome::stopped`] records the cause.
    fn search(
        &mut self,
        ctx: &SearchCtx,
        obj: &Objective,
        budget: &Budget,
        seed: u64,
    ) -> Result<SearchOutcome>;
}

impl<T: Optimizer + ?Sized> Optimizer for &mut T {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn search(
        &mut self,
        ctx: &SearchCtx,
        obj: &Objective,
        budget: &Budget,
        seed: u64,
    ) -> Result<SearchOutcome> {
        (**self).search(ctx, obj, budget, seed)
    }
}

/// Nameable optimizer selector — the wire protocol's `"optimizer"` field
/// and [`Session::search`]'s strategy key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerKind {
    DiffAxE,
    VanillaBo,
    LatentBo,
    VanillaGd,
    DosaGd,
    Polaris,
    RandomSearch,
    Fixed(FixedArch),
    GanDse,
    AirchitectV1,
    AirchitectV2,
}

impl OptimizerKind {
    pub const ALL: [OptimizerKind; 13] = [
        OptimizerKind::DiffAxE,
        OptimizerKind::VanillaBo,
        OptimizerKind::LatentBo,
        OptimizerKind::VanillaGd,
        OptimizerKind::DosaGd,
        OptimizerKind::Polaris,
        OptimizerKind::RandomSearch,
        OptimizerKind::Fixed(FixedArch::Eyeriss),
        OptimizerKind::Fixed(FixedArch::ShiDianNao),
        OptimizerKind::Fixed(FixedArch::Nvdla),
        OptimizerKind::GanDse,
        OptimizerKind::AirchitectV1,
        OptimizerKind::AirchitectV2,
    ];

    /// Stable wire name.
    pub fn name(&self) -> &'static str {
        match self {
            OptimizerKind::DiffAxE => "diffaxe",
            OptimizerKind::VanillaBo => "vanilla-bo",
            OptimizerKind::LatentBo => "latent-bo",
            OptimizerKind::VanillaGd => "vanilla-gd",
            OptimizerKind::DosaGd => "dosa-gd",
            OptimizerKind::Polaris => "polaris",
            OptimizerKind::RandomSearch => "random",
            OptimizerKind::Fixed(FixedArch::Eyeriss) => "fixed-eyeriss",
            OptimizerKind::Fixed(FixedArch::ShiDianNao) => "fixed-shidiannao",
            OptimizerKind::Fixed(FixedArch::Nvdla) => "fixed-nvdla",
            OptimizerKind::GanDse => "gandse",
            OptimizerKind::AirchitectV1 => "airchitect-v1",
            OptimizerKind::AirchitectV2 => "airchitect-v2",
        }
    }

    /// Parse a wire name (inverse of [`OptimizerKind::name`]).
    pub fn parse(s: &str) -> Option<OptimizerKind> {
        OptimizerKind::ALL.iter().copied().find(|k| k.name() == s)
    }

    /// Whether this strategy needs the compiled generative engine.
    pub fn needs_engine(&self) -> bool {
        matches!(
            self,
            OptimizerKind::DiffAxE
                | OptimizerKind::LatentBo
                | OptimizerKind::Polaris
                | OptimizerKind::GanDse
                | OptimizerKind::AirchitectV1
                | OptimizerKind::AirchitectV2
        )
    }

    /// Whether this strategy can serve the given objective (lets callers
    /// reject an unsupported pairing before any budget is spent).
    pub fn supports(&self, obj: &Objective) -> bool {
        if obj.structured().is_some() {
            // §V structured DSE: the diffusion engine (per-segment
            // conditioning) plus the generic-encoding baselines and the
            // latent-space BO baseline (per-segment latents)
            return matches!(
                self,
                OptimizerKind::DiffAxE
                    | OptimizerKind::VanillaBo
                    | OptimizerKind::LatentBo
                    | OptimizerKind::VanillaGd
                    | OptimizerKind::DosaGd
                    | OptimizerKind::Polaris
                    | OptimizerKind::RandomSearch
                    | OptimizerKind::Fixed(_)
            );
        }
        match self {
            OptimizerKind::GanDse => matches!(obj, Objective::Runtime { .. }),
            OptimizerKind::AirchitectV1 | OptimizerKind::AirchitectV2 => obj.gemm().is_some(),
            _ => true,
        }
    }
}

/// Chunked conditional generation: draw up to `n` configurations in
/// sampler-batch-sized chunks, polling the [`SearchRun`] between sampler
/// calls (cancel / deadline stop generation at a chunk boundary). The
/// closure gets `(chunk_index, take)` and performs one sampler call.
fn sample_chunked(
    n: usize,
    gen_batch: usize,
    run: &mut SearchRun<'_>,
    mut sample: impl FnMut(u64, usize) -> Result<Vec<HwConfig>>,
) -> Result<Vec<HwConfig>> {
    let mut cfgs = Vec::with_capacity(n.min(MAX_PREALLOC));
    let mut chunk = 0u64;
    while cfgs.len() < n && !run.should_stop() {
        let take = (n - cfgs.len()).min(gen_batch);
        cfgs.extend(sample(chunk, take)?);
        chunk += 1;
    }
    Ok(cfgs)
}

// ---------------------------------------------------------------------------
// generative searches (the engine IS an optimizer)
// ---------------------------------------------------------------------------

impl Optimizer for DiffAxE {
    fn name(&self) -> &'static str {
        "DiffAxE"
    }

    fn search(
        &mut self,
        ctx: &SearchCtx,
        obj: &Objective,
        budget: &Budget,
        seed: u64,
    ) -> Result<SearchOutcome> {
        if let Some(out) = drained(self.name(), budget) {
            return Ok(out);
        }
        if let Some(spec) = obj.structured() {
            return structured::search_engine(self, ctx, obj, &spec, budget, seed);
        }
        let mut run = SearchRun::start(ctx, budget);
        let b = self.stats.gen_batch;
        let cfgs = match obj {
            Objective::Runtime { g, target_cycles } => {
                let p = self.stats.stats_for(g).norm_runtime(*target_cycles);
                sample_chunked(budget.evals.max(1), b, &mut run, |chunk, take| {
                    let conds: Vec<(f32, [f32; 3])> = vec![(p, g.norm_vec()); take];
                    self.sample_runtime(rng::derive_u32(seed, chunk), &conds)
                })?
            }
            Objective::MinEdp { g } => {
                let n_classes = self.stats.n_power * self.stats.n_perf;
                let per_class = budget.class_count(n_classes);
                let mut cfgs = Vec::with_capacity((n_classes * per_class).min(MAX_PREALLOC));
                for class in 0..n_classes {
                    if run.should_stop() {
                        break;
                    }
                    cfgs.extend(sample_chunked(per_class, b, &mut run, |chunk, take| {
                        let conds: Vec<(i32, [f32; 3])> =
                            vec![(class as i32, g.norm_vec()); take];
                        let s = rng::derive_u32(seed, ((class as u64) << 24) | chunk);
                        self.sample_class(ClassMode::Edp, s, &conds)
                    })?);
                }
                cfgs
            }
            Objective::MaxPerf { g } => {
                // condition on class 0: the lowest-EDP percentile (§III-E)
                sample_chunked(budget.evals.max(1), b, &mut run, |chunk, take| {
                    let conds: Vec<(i32, [f32; 3])> = vec![(0, g.norm_vec()); take];
                    self.sample_class(ClassMode::PerfOpt, rng::derive_u32(seed, chunk), &conds)
                })?
            }
            Objective::LlmEdp { model, stage, seq, .. } => {
                // candidate base configs from the low-EDP class conditioned
                // on each layer's shape; dedup before the expensive
                // whole-model evaluation
                let gemms = model.layer_gemms(*stage, *seq);
                let per_layer = budget.class_count(gemms.len());
                let mut cfgs = Vec::with_capacity((gemms.len() * per_layer).min(MAX_PREALLOC));
                for (li, g) in gemms.iter().enumerate() {
                    if run.should_stop() {
                        break;
                    }
                    cfgs.extend(sample_chunked(per_layer, b, &mut run, |chunk, take| {
                        let conds: Vec<(i32, [f32; 3])> = vec![(0, g.norm_vec()); take];
                        let s = rng::derive_u32(seed, ((li as u64) << 24) | chunk);
                        self.sample_class(ClassMode::Edp, s, &conds)
                    })?);
                }
                cfgs.sort_by_key(|h| (h.r, h.c, h.ip_b, h.wt_b, h.op_b, h.bw));
                cfgs.dedup();
                cfgs
            }
            Objective::StructuredEdp { .. } | Objective::StructuredPerf { .. } => {
                unreachable!("structured objectives dispatch to dse::structured above")
            }
        };
        if cfgs.is_empty() {
            // interrupted before the first sampler chunk finished: a clean
            // (empty) partial outcome, not an error
            anyhow::ensure!(run.interrupted(), "generation produced no candidates");
            return Ok(SearchOutcome::from_reports("DiffAxE", obj, Vec::new(), run.elapsed_s())
                .with_stopped(run.stop_reason()));
        }
        let reports = run.evaluate_chunked(obj, &cfgs);
        Ok(SearchOutcome::from_reports("DiffAxE", obj, reports, run.elapsed_s())
            .with_stopped(run.stop_reason()))
    }
}

/// GANDSE one-shot GAN generation — runtime-conditioned only.
pub struct GanDse<'e> {
    pub engine: &'e DiffAxE,
}

impl Optimizer for GanDse<'_> {
    fn name(&self) -> &'static str {
        "GANDSE"
    }

    fn search(
        &mut self,
        ctx: &SearchCtx,
        obj: &Objective,
        budget: &Budget,
        seed: u64,
    ) -> Result<SearchOutcome> {
        let Objective::Runtime { g, target_cycles } = obj else {
            bail!("GANDSE is runtime-conditioned only; objective {obj} unsupported");
        };
        if let Some(out) = drained(self.name(), budget) {
            return Ok(out);
        }
        let mut run = SearchRun::start(ctx, budget);
        let b = self.engine.stats.gen_batch;
        let p = self.engine.stats.stats_for(g).norm_runtime(*target_cycles);
        let cfgs = sample_chunked(budget.evals.max(1), b, &mut run, |chunk, take| {
            let conds: Vec<(f32, [f32; 3])> = vec![(p, g.norm_vec()); take];
            self.engine.gandse_generate(rng::derive_u32(seed, chunk), &conds)
        })?;
        let reports = run.evaluate_chunked(obj, &cfgs);
        Ok(SearchOutcome::from_reports("GANDSE", obj, reports, run.elapsed_s())
            .with_stopped(run.stop_reason()))
    }
}

/// AIRCHITECT v1/v2 one-shot recommenders (Fig 17 baselines).
pub struct Airchitect<'e> {
    pub engine: &'e DiffAxE,
    /// v2 = direct regression; v1 = argmax over the fixed grid.
    pub v2: bool,
}

impl Optimizer for Airchitect<'_> {
    fn name(&self) -> &'static str {
        if self.v2 { "AIRCHITECT v2" } else { "AIRCHITECT" }
    }

    fn search(
        &mut self,
        ctx: &SearchCtx,
        obj: &Objective,
        budget: &Budget,
        _seed: u64,
    ) -> Result<SearchOutcome> {
        let g = obj
            .gemm()
            .with_context(|| format!("AIRCHITECT recommends per-GEMM; objective {obj} unsupported"))?;
        if let Some(out) = drained(self.name(), budget) {
            return Ok(out);
        }
        let mut run = SearchRun::start(ctx, budget);
        let reports = if run.should_stop() {
            Vec::new()
        } else {
            let hw = if self.v2 {
                self.engine.airchitect_v2(&g)?
            } else {
                self.engine.airchitect_v1(&g)?
            };
            let d = obj.evaluate(&hw);
            run.progress(1, obj.score_report(&d));
            vec![d]
        };
        Ok(SearchOutcome::from_reports(self.name(), obj, reports, run.elapsed_s())
            .with_stopped(run.stop_reason()))
    }
}

// ---------------------------------------------------------------------------
// optimization baselines
// ---------------------------------------------------------------------------

/// Vanilla BO over the 8-d normalized hardware encoding.
#[derive(Debug, Clone, Default)]
pub struct VanillaBo {
    pub opts: BoOptions,
}

/// Clamp BO options so `bo::minimize`'s invariants hold under any budget.
/// The second return is true when `budget.evals` cut the configured BO
/// schedule short (reported as [`StopReason::BudgetExhausted`]).
pub(crate) fn bo_opts_for(opts: &BoOptions, budget: &Budget) -> (BoOptions, bool) {
    let mut o = opts.clone();
    o.budget = budget.evals.max(2);
    o.n_init = o.n_init.clamp(2, o.budget);
    let clamped = o.budget < opts.budget;
    (o, clamped)
}

/// Cap a GD schedule so its implied evaluation count stays within
/// `budget.evals`. `evals_per_step` is 1 for analytic gradients and
/// `1 + 2·dim` for central finite differences; each restart spends
/// `steps + 1` gradient evaluations. The second return is true when the
/// configured schedule was truncated to fit the budget.
pub(crate) fn gd_opts_for(
    opts: &GdOptions,
    budget: &Budget,
    evals_per_step: usize,
) -> (GdOptions, bool) {
    let mut o = opts.clone();
    let unit = evals_per_step.max(1);
    o.restarts = o.restarts.max(1).min((budget.evals / (2 * unit)).max(1));
    o.steps = o.steps.max(1).min((budget.evals / (o.restarts * unit)).max(2) - 1);
    let clamped = o.restarts < opts.restarts.max(1) || o.steps < opts.steps.max(1);
    (o, clamped)
}

impl Optimizer for VanillaBo {
    fn name(&self) -> &'static str {
        "Vanilla BO"
    }

    fn search(
        &mut self,
        ctx: &SearchCtx,
        obj: &Objective,
        budget: &Budget,
        seed: u64,
    ) -> Result<SearchOutcome> {
        if let Some(out) = drained(self.name(), budget) {
            return Ok(out);
        }
        if let Some(spec) = obj.structured() {
            return structured::search_bo(&self.opts, ctx, obj, &spec, budget, seed);
        }
        let (o, clamped) = bo_opts_for(&self.opts, budget);
        // the objective closure (progress) and the stop closure (polling)
        // both need the run; RefCell arbitrates the disjoint borrows
        let run = std::cell::RefCell::new(SearchRun::start(ctx, budget));
        let mut rng = rng::split(seed, 10);
        let mut reports = Vec::with_capacity(o.budget.min(MAX_PREALLOC));
        let mut best = f64::INFINITY;
        bo::minimize(
            |r: &mut Pcg32| {
                encode_norm(&TargetSpace::sample(r)).iter().map(|&x| x as f64).collect()
            },
            |x| {
                let v: Vec<f32> = x.iter().map(|&v| v as f32).collect();
                let d = obj.evaluate(&decode_rounded(&v));
                let s = obj.score_report(&d);
                reports.push(d);
                best = best.min(s);
                run.borrow().progress(reports.len(), best);
                s
            },
            || run.borrow_mut().should_stop(),
            &o,
            &mut rng,
        );
        let mut run = run.into_inner();
        if clamped {
            run.exhausted();
        }
        Ok(SearchOutcome::from_reports("Vanilla BO", obj, reports, run.elapsed_s())
            .with_stopped(run.stop_reason()))
    }
}

/// VAESA-style latent BO: search the Phase-1 latent space, decode through
/// the AE, evaluate on the simulator.
pub struct LatentBo<'e> {
    pub engine: &'e DiffAxE,
    pub opts: BoOptions,
}

impl Optimizer for LatentBo<'_> {
    fn name(&self) -> &'static str {
        "Latent BO (VAESA)"
    }

    fn search(
        &mut self,
        ctx: &SearchCtx,
        obj: &Objective,
        budget: &Budget,
        seed: u64,
    ) -> Result<SearchOutcome> {
        if let Some(out) = drained(self.name(), budget) {
            return Ok(out);
        }
        if let Some(spec) = obj.structured() {
            // BO over the concatenated per-segment latent encoding
            return structured::search_latent_bo(
                self.engine,
                &self.opts,
                ctx,
                obj,
                &spec,
                budget,
                seed,
            );
        }
        let (o, clamped) = bo_opts_for(&self.opts, budget);
        let run = std::cell::RefCell::new(SearchRun::start(ctx, budget));
        let mut rng = rng::split(seed, 11);
        // candidate generator: latents of random target-space configs
        // (pool capped so a huge eval budget cannot stall the search in
        // this un-pollable encode prelude)
        let pool: Vec<Vec<f32>> = (0..(o.budget * 2).clamp(4, 1024))
            .map(|_| encode_norm(&TargetSpace::sample(&mut rng)).to_vec())
            .collect();
        let latents = self.engine.encode(&pool)?;
        let mut pool_iter = 0usize;
        let mut reports = Vec::with_capacity(o.budget.min(MAX_PREALLOC));
        let mut best = f64::INFINITY;
        let engine = self.engine;
        bo::minimize(
            |_r: &mut Pcg32| {
                let l = &latents[pool_iter % latents.len()];
                pool_iter += 1;
                l.iter().map(|&x| x as f64).collect()
            },
            |x| {
                let lat: Vec<f32> = x.iter().map(|&v| v as f32).collect();
                match engine.decode_rounded(&[lat]) {
                    Ok(cfgs) => {
                        let d = obj.evaluate(&cfgs[0]);
                        let s = obj.score_report(&d);
                        reports.push(d);
                        best = best.min(s);
                        run.borrow().progress(reports.len(), best);
                        s
                    }
                    Err(_) => f64::INFINITY,
                }
            },
            || run.borrow_mut().should_stop(),
            &o,
            &mut rng,
        );
        let mut run = run.into_inner();
        if clamped {
            run.exhausted();
        }
        anyhow::ensure!(
            !reports.is_empty() || run.interrupted(),
            "latent decode failed for every BO iterate"
        );
        Ok(SearchOutcome::from_reports("Latent BO (VAESA)", obj, reports, run.elapsed_s())
            .with_stopped(run.stop_reason()))
    }
}

/// Vanilla GD in hardware space: the exported differentiable surrogate's
/// gradient for runtime objectives (when the engine is available), plain
/// finite differences on the real simulator otherwise.
pub struct VanillaGd<'e> {
    pub engine: Option<&'e DiffAxE>,
    pub opts: GdOptions,
}

impl Optimizer for VanillaGd<'_> {
    fn name(&self) -> &'static str {
        "Vanilla GD"
    }

    fn search(
        &mut self,
        ctx: &SearchCtx,
        obj: &Objective,
        budget: &Budget,
        seed: u64,
    ) -> Result<SearchOutcome> {
        if let Some(out) = drained(self.name(), budget) {
            return Ok(out);
        }
        if let Some(spec) = obj.structured() {
            // fine-grid FD over the concatenated per-segment encoding
            return structured::search_fd(
                "Vanilla GD",
                false,
                &self.opts,
                ctx,
                obj,
                &spec,
                budget,
                seed,
            );
        }
        let run = std::cell::RefCell::new(SearchRun::start(ctx, budget));
        let mut rng = rng::split(seed, 12);
        let mut clamped = false;
        let reports = match (obj, self.engine) {
            (Objective::Runtime { g, target_cycles }, Some(engine)) => {
                let opts;
                (opts, clamped) = gd_opts_for(&self.opts, budget, 1);
                let p = engine.stats.stats_for(g).norm_runtime(*target_cycles);
                let res = gd::descend(
                    |x: &[f64]| {
                        let hw: Vec<f32> = x.iter().map(|&v| v as f32).collect();
                        let (losses, grads) =
                            engine.surrogate_grad(&[hw], g, &[p]).expect("surrogate_grad");
                        (losses[0] as f64, grads[0].iter().map(|&g| g as f64).collect())
                    },
                    |r: &mut Pcg32| {
                        encode_norm(&TargetSpace::sample(r)).iter().map(|&x| x as f64).collect()
                    },
                    || run.borrow_mut().should_stop(),
                    &opts,
                    &mut rng,
                );
                if res.best_x.is_empty() {
                    Vec::new() // stopped before the first gradient step
                } else {
                    let v: Vec<f32> = res.best_x.iter().map(|&x| x as f32).collect();
                    // the surrogate was trained on the coarse grid: snap to it
                    vec![obj.evaluate(&coarsen(&decode_rounded(&v)))]
                }
            }
            _ => {
                let opts;
                (opts, clamped) = gd_opts_for(&self.opts, budget, 1 + 2 * NORM_DIM);
                let mut reports = Vec::new();
                let mut best = f64::INFINITY;
                let res = gd::fd_gd(
                    |x: &[f64]| {
                        let v: Vec<f32> = x.iter().map(|&v| v as f32).collect();
                        let d = obj.evaluate(&decode_rounded(&v));
                        let s = obj.score_report(&d);
                        reports.push(d);
                        best = best.min(s);
                        run.borrow().progress(reports.len(), best);
                        obj.gd_loss(s)
                    },
                    |r: &mut Pcg32| {
                        encode_norm(&TargetSpace::sample(r)).iter().map(|&x| x as f64).collect()
                    },
                    0.05,
                    || run.borrow_mut().should_stop(),
                    &opts,
                    &mut rng,
                );
                if !res.best_x.is_empty() {
                    let v: Vec<f32> = res.best_x.iter().map(|&x| x as f32).collect();
                    reports.push(obj.evaluate(&decode_rounded(&v)));
                }
                reports
            }
        };
        let mut run = run.into_inner();
        if clamped {
            run.exhausted();
        }
        Ok(SearchOutcome::from_reports("Vanilla GD", obj, reports, run.elapsed_s())
            .with_stopped(run.stop_reason()))
    }
}

/// DOSA-style GD: finite differences on the real simulator over the
/// *coarse* training grid (Table IV: DOSA searches ~O(10^7) granularity).
#[derive(Debug, Clone, Default)]
pub struct DosaGd {
    pub opts: GdOptions,
}

impl Optimizer for DosaGd {
    fn name(&self) -> &'static str {
        "DOSA (coarse GD)"
    }

    fn search(
        &mut self,
        ctx: &SearchCtx,
        obj: &Objective,
        budget: &Budget,
        seed: u64,
    ) -> Result<SearchOutcome> {
        if let Some(out) = drained(self.name(), budget) {
            return Ok(out);
        }
        if let Some(spec) = obj.structured() {
            // DOSA stays on the coarse grid, per segment (Table IV note)
            return structured::search_fd(
                "DOSA (coarse GD)",
                true,
                &self.opts,
                ctx,
                obj,
                &spec,
                budget,
                seed,
            );
        }
        let (opts, clamped) = gd_opts_for(&self.opts, budget, 1 + 2 * NORM_DIM);
        let run = std::cell::RefCell::new(SearchRun::start(ctx, budget));
        let mut rng = rng::split(seed, 13);
        let mut reports = Vec::new();
        let mut best = f64::INFINITY;
        let res = gd::fd_gd(
            |x: &[f64]| {
                let v: Vec<f32> = x.iter().map(|&v| v as f32).collect();
                let d = obj.evaluate(&coarsen(&decode_rounded(&v)));
                let s = obj.score_report(&d);
                reports.push(d);
                best = best.min(s);
                run.borrow().progress(reports.len(), best);
                obj.gd_loss(s)
            },
            |r: &mut Pcg32| {
                encode_norm(&TargetSpace::sample(r)).iter().map(|&x| x as f64).collect()
            },
            0.05,
            || run.borrow_mut().should_stop(),
            &opts,
            &mut rng,
        );
        if !res.best_x.is_empty() {
            let v: Vec<f32> = res.best_x.iter().map(|&x| x as f32).collect();
            reports.push(obj.evaluate(&coarsen(&decode_rounded(&v))));
        }
        let mut run = run.into_inner();
        if clamped {
            run.exhausted();
        }
        Ok(SearchOutcome::from_reports("DOSA (coarse GD)", obj, reports, run.elapsed_s())
            .with_stopped(run.stop_reason()))
    }
}

/// Polaris-style latent GD: the exported PP gradient in latent space for
/// runtime objectives; a random 8-d latent subspace descended by finite
/// differences (multi-fidelity flavour) for the EDP-class objectives.
pub struct Polaris<'e> {
    pub engine: &'e DiffAxE,
    pub opts: GdOptions,
}

impl Optimizer for Polaris<'_> {
    fn name(&self) -> &'static str {
        "Polaris (latent GD)"
    }

    fn search(
        &mut self,
        ctx: &SearchCtx,
        obj: &Objective,
        budget: &Budget,
        seed: u64,
    ) -> Result<SearchOutcome> {
        if let Some(out) = drained(self.name(), budget) {
            return Ok(out);
        }
        if let Some(spec) = obj.structured() {
            return structured::search_polaris(
                self.engine,
                &self.opts,
                ctx,
                obj,
                &spec,
                budget,
                seed,
            );
        }
        let run = std::cell::RefCell::new(SearchRun::start(ctx, budget));
        let mut rng = rng::split(seed, 14);
        let mut clamped = false;
        let engine = self.engine;
        let reports = match obj {
            Objective::Runtime { g, target_cycles } => {
                let p = engine.stats.stats_for(g).norm_runtime(*target_cycles);
                let opts;
                (opts, clamped) = gd_opts_for(&self.opts, budget, 1);
                // the latent space has no box bounds: clamp off
                let res = gd::descend(
                    |x: &[f64]| {
                        let lat: Vec<f32> = x.iter().map(|&v| v as f32).collect();
                        let (losses, grads) =
                            engine.pp_grad(&[lat], g, &[p]).expect("pp_grad");
                        (losses[0] as f64, grads[0].iter().map(|&g| g as f64).collect())
                    },
                    |r: &mut Pcg32| {
                        let hw = encode_norm(&TargetSpace::sample(r)).to_vec();
                        engine.encode(&[hw]).expect("encode")[0]
                            .iter()
                            .map(|&x| x as f64)
                            .collect()
                    },
                    || run.borrow_mut().should_stop(),
                    &GdOptions { clamp: false, ..opts },
                    &mut rng,
                );
                if res.best_x.is_empty() {
                    Vec::new()
                } else {
                    let lat: Vec<f32> = res.best_x.iter().map(|&x| x as f32).collect();
                    vec![obj.evaluate(&engine.decode_rounded(&[lat])?[0])]
                }
            }
            _ => {
                // FD over the full latent dim is expensive; descend a random
                // 8-d subspace around an encoded anchor
                let anchor = {
                    let hw = encode_norm(&TargetSpace::sample(&mut rng)).to_vec();
                    engine.encode(&[hw])?[0].clone()
                };
                let d = anchor.len();
                let dirs: Vec<Vec<f32>> = (0..8)
                    .map(|_| {
                        let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                        let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
                        v.iter().map(|x| x / n).collect()
                    })
                    .collect();
                let to_latent = |x: &[f64]| -> Vec<f32> {
                    let mut l = anchor.clone();
                    for (coef, dir) in x.iter().zip(&dirs) {
                        for (li, di) in l.iter_mut().zip(dir) {
                            *li += (*coef as f32 - 0.5) * 8.0 * di;
                        }
                    }
                    l
                };
                let opts;
                (opts, clamped) = gd_opts_for(&self.opts, budget, 1 + 2 * 8);
                let mut reports = Vec::new();
                let mut best = f64::INFINITY;
                gd::fd_gd(
                    |x: &[f64]| match engine.decode_rounded(&[to_latent(x)]) {
                        Ok(cfgs) => {
                            let d = obj.evaluate(&coarsen(&cfgs[0]));
                            let s = obj.score_report(&d);
                            reports.push(d);
                            best = best.min(s);
                            run.borrow().progress(reports.len(), best);
                            obj.gd_loss(s)
                        }
                        Err(_) => f64::INFINITY,
                    },
                    |r: &mut Pcg32| (0..8).map(|_| r.f64()).collect(),
                    0.05,
                    || run.borrow_mut().should_stop(),
                    &opts,
                    &mut rng,
                );
                anyhow::ensure!(
                    !reports.is_empty() || run.borrow().interrupted(),
                    "latent decode failed for every iterate"
                );
                reports
            }
        };
        let mut run = run.into_inner();
        if clamped {
            run.exhausted();
        }
        Ok(SearchOutcome::from_reports("Polaris (latent GD)", obj, reports, run.elapsed_s())
            .with_stopped(run.stop_reason()))
    }
}

/// Uniform random search over the full target design space.
#[derive(Debug, Clone, Copy, Default)]
pub struct RandomSearch;

impl Optimizer for RandomSearch {
    fn name(&self) -> &'static str {
        "Random Search"
    }

    fn search(
        &mut self,
        ctx: &SearchCtx,
        obj: &Objective,
        budget: &Budget,
        seed: u64,
    ) -> Result<SearchOutcome> {
        if let Some(out) = drained(self.name(), budget) {
            return Ok(out);
        }
        if let Some(spec) = obj.structured() {
            return structured::search_random(ctx, obj, &spec, budget, seed);
        }
        let mut run = SearchRun::start(ctx, budget);
        let mut rng = rng::split(seed, 15);
        let n = budget.evals.max(1);
        let mut reports = Vec::with_capacity(n.min(MAX_PREALLOC));
        let mut best = f64::INFINITY;
        while reports.len() < n && !run.should_stop() {
            let take = (n - reports.len()).min(1024);
            let cfgs: Vec<HwConfig> = (0..take).map(|_| TargetSpace::sample(&mut rng)).collect();
            let start = reports.len();
            reports.extend(obj.evaluate_all(&cfgs));
            for d in &reports[start..] {
                best = best.min(obj.score_report(d));
            }
            run.progress(reports.len(), best);
        }
        Ok(SearchOutcome::from_reports("Random Search", obj, reports, run.elapsed_s())
            .with_stopped(run.stop_reason()))
    }
}

impl Optimizer for FixedArch {
    fn name(&self) -> &'static str {
        FixedArch::name(self)
    }

    fn search(
        &mut self,
        ctx: &SearchCtx,
        obj: &Objective,
        budget: &Budget,
        _seed: u64,
    ) -> Result<SearchOutcome> {
        if let Some(out) = drained(FixedArch::name(self), budget) {
            return Ok(out);
        }
        if let Some(spec) = obj.structured() {
            // the fixed silicon replicated uniformly across segments
            return structured::search_fixed(*self, ctx, obj, &spec, budget);
        }
        let mut run = SearchRun::start(ctx, budget);
        // one candidate: the fixed silicon (LLM objectives still grant it
        // per-layer loop-order choice — charitable, see FixedArch::config)
        let reports = if run.should_stop() {
            Vec::new()
        } else {
            let d = obj.evaluate(&self.config());
            run.progress(1, obj.score_report(&d));
            vec![d]
        };
        Ok(SearchOutcome::from_reports(FixedArch::name(self), obj, reports, run.elapsed_s())
            .with_stopped(run.stop_reason()))
    }
}

// ---------------------------------------------------------------------------
// Session: engine ownership + strategy dispatch
// ---------------------------------------------------------------------------

/// A DSE session: owns the (optional) generative engine and the shared
/// baseline options, dispatches [`Session::search`] calls to any
/// [`OptimizerKind`], and exposes the batched evaluation hot path —
/// memoized through the shared [`EvalCache`] and partitioned over the
/// persistent worker pool (see [`crate::dse::eval`]).
///
/// The engine holds PJRT executables (raw C pointers, deliberately
/// `!Send`), so a `Session` lives on one thread — the coordinator service
/// wraps one in its dedicated engine thread.
pub struct Session {
    engine: Option<DiffAxE>,
    pub bo_opts: BoOptions,
    pub gd_opts: GdOptions,
    /// deterministic fault injection ([`crate::util::fault`]); `None`
    /// (the default everywhere) means every [`Session::fault_check`] is a
    /// single pointer test
    pub fault_plan: Option<Arc<FaultPlan>>,
    /// the evaluation memo table this session's batched hot path runs
    /// through. Defaults to the process-wide shared instance
    /// ([`EvalCache::global_arc`]) — every coordinator worker's session
    /// holds a clone of the *same* cache, so tenants probing overlapping
    /// design regions hit each other's work. Tests can isolate with
    /// [`Session::with_cache`].
    cache: Arc<EvalCache>,
}

impl Session {
    /// A session around a loaded engine.
    pub fn new(engine: DiffAxE) -> Session {
        Session {
            engine: Some(engine),
            bo_opts: BoOptions::default(),
            gd_opts: GdOptions::default(),
            fault_plan: None,
            cache: EvalCache::global_arc(),
        }
    }

    /// Load the AOT artifacts in `dir` and wrap them in a session.
    pub fn load(dir: &Path) -> Result<Session> {
        Ok(Session::new(DiffAxE::load(dir)?))
    }

    /// A session around the hermetic mock engine ([`DiffAxE::mock`]):
    /// every engine-backed strategy works, deterministically, without
    /// artifacts. CI runs the engine-kind suites through this.
    pub fn mock() -> Session {
        Session::new(DiffAxE::mock())
    }

    /// A session without the generative engine: only the simulator-backed
    /// strategies (random, vanilla BO/GD, DOSA GD, fixed archs) work.
    pub fn simulator_only() -> Session {
        Session {
            engine: None,
            bo_opts: BoOptions::default(),
            gd_opts: GdOptions::default(),
            fault_plan: None,
            cache: EvalCache::global_arc(),
        }
    }

    /// Route this session's batched evaluation path through `cache`
    /// instead of the shared global instance (isolation for tests and
    /// benches; the coordinator fleet passes one shared handle to every
    /// worker).
    pub fn with_cache(mut self, cache: Arc<EvalCache>) -> Session {
        self.cache = cache;
        self
    }

    /// The evaluation cache handle this session evaluates through.
    pub fn cache(&self) -> &Arc<EvalCache> {
        &self.cache
    }

    /// Consult the session's fault plan at `site` (no-op without a plan).
    /// `Err` means an injected error fired; panic/delay actions take
    /// effect inside the call.
    pub fn fault_check(&self, site: FaultSite) -> Result<()> {
        match &self.fault_plan {
            Some(fp) => fp.check(site).map_err(anyhow::Error::msg),
            None => Ok(()),
        }
    }

    pub fn engine(&self) -> Option<&DiffAxE> {
        self.engine.as_ref()
    }

    pub fn has_engine(&self) -> bool {
        self.engine.is_some()
    }

    fn engine_required(&self, kind: OptimizerKind) -> Result<&DiffAxE> {
        self.engine
            .as_ref()
            .with_context(|| format!("optimizer {:?} requires the generative engine", kind.name()))
    }

    /// Evaluate a batch of configurations on one workload through the
    /// shared memo table and the persistent worker pool (see
    /// [`evaluate_batch`]).
    pub fn evaluate_batch(&self, cfgs: &[HwConfig], g: &Gemm) -> Vec<(SimResult, EnergyResult)> {
        let g = *g;
        let cache = self.cache.clone();
        par_map_chunks(cfgs, move |chunk| cache.evaluate_many(chunk, &g))
    }

    /// Counters of the evaluation cache this session's batched and
    /// LLM hot paths run through (exported by the coordinator's metrics).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Run one search with the named strategy under the inert background
    /// ctx (convenience for batch experiments and benches).
    pub fn search(
        &mut self,
        kind: OptimizerKind,
        obj: &Objective,
        budget: &Budget,
        seed: u64,
    ) -> Result<SearchOutcome> {
        self.search_ctx(kind, &SearchCtx::background(), obj, budget, seed)
    }

    /// Run one search with the named strategy under an interruption ctx:
    /// the coordinator's job path (cancellation, deadlines, progress
    /// streaming) enters here.
    pub fn search_ctx(
        &mut self,
        kind: OptimizerKind,
        ctx: &SearchCtx,
        obj: &Objective,
        budget: &Budget,
        seed: u64,
    ) -> Result<SearchOutcome> {
        // fault site: search entry on the engine worker (chaos tests
        // inject panics/errors here to exercise job-level isolation)
        self.fault_check(FaultSite::EngineSample)?;
        match kind {
            OptimizerKind::DiffAxE => self
                .engine
                .as_mut()
                .context("optimizer \"diffaxe\" requires the generative engine")?
                .search(ctx, obj, budget, seed),
            OptimizerKind::VanillaBo => {
                VanillaBo { opts: self.bo_opts.clone() }.search(ctx, obj, budget, seed)
            }
            OptimizerKind::LatentBo => {
                LatentBo { engine: self.engine_required(kind)?, opts: self.bo_opts.clone() }
                    .search(ctx, obj, budget, seed)
            }
            OptimizerKind::VanillaGd => {
                VanillaGd { engine: self.engine.as_ref(), opts: self.gd_opts.clone() }
                    .search(ctx, obj, budget, seed)
            }
            OptimizerKind::DosaGd => {
                DosaGd { opts: self.gd_opts.clone() }.search(ctx, obj, budget, seed)
            }
            OptimizerKind::Polaris => {
                Polaris { engine: self.engine_required(kind)?, opts: self.gd_opts.clone() }
                    .search(ctx, obj, budget, seed)
            }
            OptimizerKind::RandomSearch => RandomSearch.search(ctx, obj, budget, seed),
            OptimizerKind::Fixed(mut arch) => arch.search(ctx, obj, budget, seed),
            OptimizerKind::GanDse => {
                GanDse { engine: self.engine_required(kind)? }.search(ctx, obj, budget, seed)
            }
            OptimizerKind::AirchitectV1 => {
                Airchitect { engine: self.engine_required(kind)?, v2: false }
                    .search(ctx, obj, budget, seed)
            }
            OptimizerKind::AirchitectV2 => {
                Airchitect { engine: self.engine_required(kind)?, v2: true }
                    .search(ctx, obj, budget, seed)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design_space::LoopOrder;

    fn small_gd() -> GdOptions {
        GdOptions { steps: 4, restarts: 2, ..Default::default() }
    }

    fn small_bo() -> BoOptions {
        BoOptions { n_init: 4, budget: 10, pool: 16, ..Default::default() }
    }

    #[test]
    fn batch_is_bit_identical_to_scalar() {
        let mut rng = Pcg32::seeded(7);
        let cfgs: Vec<HwConfig> = (0..200).map(|_| TargetSpace::sample(&mut rng)).collect();
        let g = Gemm::new(128, 768, 768);
        let batch = evaluate_batch(&cfgs, &g);
        assert_eq!(batch.len(), cfgs.len());
        for (hw, (s, e)) in cfgs.iter().zip(&batch) {
            let (s2, e2) = crate::dse::evaluate(hw, &g);
            assert_eq!(*s, s2);
            assert_eq!(*e, e2);
        }
    }

    #[test]
    fn session_cached_batch_is_bit_identical_to_scalar() {
        let s = Session::simulator_only();
        let mut rng = Pcg32::seeded(11);
        let mut cfgs: Vec<HwConfig> = (0..150).map(|_| TargetSpace::sample(&mut rng)).collect();
        let dups = cfgs[..50].to_vec();
        cfgs.extend(dups); // recurring rounded points: the cache's bread and butter
        let g = Gemm::new(64, 512, 256);
        for _ in 0..2 {
            let batch = s.evaluate_batch(&cfgs, &g);
            for (hw, (sr, er)) in cfgs.iter().zip(&batch) {
                let (s2, e2) = crate::dse::evaluate(hw, &g);
                assert_eq!(*sr, s2);
                assert_eq!(*er, e2);
            }
        }
    }

    #[test]
    fn evaluate_all_preserves_order() {
        let mut rng = Pcg32::seeded(9);
        let cfgs: Vec<HwConfig> = (0..130).map(|_| TargetSpace::sample(&mut rng)).collect();
        let obj = Objective::MaxPerf { g: Gemm::new(64, 256, 512) };
        let reports = obj.evaluate_all(&cfgs);
        for (hw, d) in cfgs.iter().zip(&reports) {
            assert_eq!(*hw, d.hw);
            assert_eq!(d.cycles, obj.evaluate(hw).cycles);
        }
    }

    fn bg() -> SearchCtx {
        SearchCtx::background()
    }

    fn engine_free_outcomes(obj: &Objective, budget: &Budget, seed: u64) -> Vec<SearchOutcome> {
        vec![
            RandomSearch.search(&bg(), obj, budget, seed).unwrap(),
            VanillaBo { opts: small_bo() }.search(&bg(), obj, budget, seed).unwrap(),
            VanillaGd { engine: None, opts: small_gd() }.search(&bg(), obj, budget, seed).unwrap(),
            DosaGd { opts: small_gd() }.search(&bg(), obj, budget, seed).unwrap(),
            FixedArch::Eyeriss.search(&bg(), obj, budget, seed).unwrap(),
        ]
    }

    #[test]
    fn same_seed_same_outcome_for_every_engine_free_optimizer() {
        for obj in [
            Objective::MinEdp { g: Gemm::new(64, 256, 512) },
            Objective::Runtime { g: Gemm::new(128, 768, 768), target_cycles: 1e6 },
            Objective::MaxPerf { g: Gemm::new(32, 128, 256) },
        ] {
            let budget = Budget::evals(16);
            let a = engine_free_outcomes(&obj, &budget, 42);
            let b = engine_free_outcomes(&obj, &budget, 42);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.optimizer, y.optimizer);
                assert_eq!(x.ranked, y.ranked, "{} not deterministic", x.optimizer);
                assert_eq!(x.trace, y.trace, "{} trace not deterministic", x.optimizer);
                assert_eq!(x.evals, y.evals);
                assert_eq!(x.stopped, y.stopped);
            }
        }
    }

    #[test]
    fn ranked_is_sorted_and_consistent_with_trace() {
        let obj = Objective::MinEdp { g: Gemm::new(128, 512, 512) };
        let out = RandomSearch.search(&bg(), &obj, &Budget::evals(64), 3).unwrap();
        assert_eq!(out.evals, 64);
        assert_eq!(out.trace.len(), 64);
        assert_eq!(out.ranked.len(), 64);
        for w in out.ranked.windows(2) {
            assert!(obj.score_report(&w[0]) <= obj.score_report(&w[1]));
        }
        assert_eq!(obj.score_report(out.best().unwrap()), out.best_score());
    }

    #[test]
    fn budget_is_honoured_by_count_driven_searchers() {
        let obj = Objective::MaxPerf { g: Gemm::new(64, 256, 512) };
        let out = RandomSearch.search(&bg(), &obj, &Budget::evals(33), 1).unwrap();
        assert_eq!(out.evals, 33);
        assert_eq!(out.stopped, StopReason::Completed);
        let out =
            VanillaBo { opts: small_bo() }.search(&bg(), &obj, &Budget::evals(12), 1).unwrap();
        assert_eq!(out.evals, 12);
    }

    #[test]
    fn gd_respects_eval_budget_cap() {
        let obj = Objective::MinEdp { g: Gemm::new(64, 256, 512) };
        let out = DosaGd { opts: GdOptions::default() }
            .search(&bg(), &obj, &Budget::evals(40), 5)
            .unwrap();
        // one final evaluation of the best iterate may exceed the cap
        assert!(out.evals <= 41, "evals {} exceed budget", out.evals);
        // the default 80x4 schedule was truncated to fit 40 evaluations
        assert_eq!(out.stopped, StopReason::BudgetExhausted);
    }

    #[test]
    fn fixed_arch_reports_its_own_config() {
        let obj = Objective::MinEdp { g: Gemm::new(128, 768, 2304) };
        let out = FixedArch::Nvdla.search(&bg(), &obj, &Budget::default(), 0).unwrap();
        assert_eq!(out.evals, 1);
        assert_eq!(out.best().unwrap().hw, FixedArch::Nvdla.config());
        assert_eq!(out.stopped, StopReason::Completed);
    }

    #[test]
    fn stop_reason_names_roundtrip() {
        for r in [
            StopReason::Completed,
            StopReason::Cancelled,
            StopReason::DeadlineExceeded,
            StopReason::BudgetExhausted,
        ] {
            assert_eq!(StopReason::from_name(r.name()), Some(r), "{r:?}");
        }
        assert_eq!(StopReason::from_name("nope"), None);
        assert!(!StopReason::Completed.is_partial());
        assert!(StopReason::Cancelled.is_partial());
    }

    #[test]
    fn pre_cancelled_ctx_returns_empty_partial_outcome() {
        let flag = Arc::new(AtomicBool::new(true));
        let ctx = SearchCtx::background().with_cancel_flag(flag);
        let obj = Objective::MinEdp { g: Gemm::new(64, 256, 512) };
        let out = RandomSearch.search(&ctx, &obj, &Budget::evals(10_000), 1).unwrap();
        assert_eq!(out.stopped, StopReason::Cancelled);
        assert!(out.ranked.is_empty());
        assert_eq!(out.evals, 0);
    }

    #[test]
    fn cancel_flag_stops_mid_search_with_partial_results() {
        let flag = Arc::new(AtomicBool::new(false));
        let seen = Arc::new(std::sync::Mutex::new(Vec::<SearchEvent>::new()));
        let ctx = {
            let flag = flag.clone();
            let seen = seen.clone();
            SearchCtx::background().with_cancel_flag(flag.clone()).with_progress(
                move |ev: &SearchEvent| {
                    seen.lock().unwrap().push(*ev);
                    // cancel as soon as the first batch lands
                    flag.store(true, Ordering::Relaxed);
                },
            )
        };
        let obj = Objective::MinEdp { g: Gemm::new(64, 256, 512) };
        let out = RandomSearch.search(&ctx, &obj, &Budget::evals(1_000_000), 2).unwrap();
        assert_eq!(out.stopped, StopReason::Cancelled);
        assert!(!out.ranked.is_empty(), "partial ranked designs expected");
        assert!(out.evals < 1_000_000);
        let evs = seen.lock().unwrap();
        assert!(!evs.is_empty());
        assert!(evs[0].evals >= 1 && evs[0].best_score.is_finite());
    }

    #[test]
    fn budget_wall_clock_routes_through_ctx_deadline() {
        let obj = Objective::MinEdp { g: Gemm::new(64, 256, 512) };
        let out = RandomSearch
            .search(&bg(), &obj, &Budget::evals(100_000_000).with_wall_clock(0.02), 3)
            .unwrap();
        assert_eq!(out.stopped, StopReason::DeadlineExceeded);
        assert!(out.evals < 100_000_000);
    }

    #[test]
    fn search_run_merges_earliest_deadline() {
        // ctx deadline earlier than the budget wall clock wins
        let ctx = SearchCtx::background().with_deadline_in(0.0);
        let mut run = SearchRun::start(&ctx, &Budget::evals(4).with_wall_clock(60.0));
        assert!(run.should_stop());
        assert_eq!(run.stop_reason(), StopReason::DeadlineExceeded);
        // and exhausted() never overrides a latched deadline
        run.exhausted();
        assert_eq!(run.stop_reason(), StopReason::DeadlineExceeded);
    }

    #[test]
    fn optimizer_kind_names_roundtrip() {
        for k in OptimizerKind::ALL {
            assert_eq!(OptimizerKind::parse(k.name()), Some(k), "{k:?}");
        }
        assert_eq!(OptimizerKind::parse("nope"), None);
    }

    #[test]
    fn supports_rejects_known_mismatches() {
        let g = Gemm::new(4, 4, 4);
        let runtime = Objective::Runtime { g, target_cycles: 1.0 };
        let edp = Objective::MinEdp { g };
        let llm = Objective::LlmEdp {
            model: LlmModel::BertBase,
            stage: Stage::Prefill,
            seq: 8,
            platform: Platform::Asic32nm,
        };
        assert!(OptimizerKind::GanDse.supports(&runtime));
        assert!(!OptimizerKind::GanDse.supports(&edp));
        assert!(OptimizerKind::AirchitectV1.supports(&edp));
        assert!(!OptimizerKind::AirchitectV2.supports(&llm));
        for k in OptimizerKind::ALL {
            assert!(k.supports(&runtime) || k != OptimizerKind::DiffAxE);
        }
        assert!(OptimizerKind::RandomSearch.supports(&llm));
    }

    #[test]
    fn budget_class_count_derivation() {
        assert_eq!(Budget::evals(90).class_count(9), 10);
        assert_eq!(Budget::evals(4).class_count(9), 1);
        assert_eq!(Budget::evals(90).with_per_class(7).class_count(9), 7);
    }

    #[test]
    fn session_without_engine_rejects_generative_kinds() {
        let mut s = Session::simulator_only();
        let obj = Objective::MinEdp { g: Gemm::new(64, 64, 64) };
        assert!(s.search(OptimizerKind::DiffAxE, &obj, &Budget::evals(4), 1).is_err());
        assert!(s.search(OptimizerKind::LatentBo, &obj, &Budget::evals(4), 1).is_err());
        // simulator-backed kinds work
        let out = s.search(OptimizerKind::RandomSearch, &obj, &Budget::evals(4), 1).unwrap();
        assert_eq!(out.evals, 4);
        let out = s
            .search(OptimizerKind::Fixed(FixedArch::Eyeriss), &obj, &Budget::evals(1), 1)
            .unwrap();
        assert_eq!(out.best().unwrap().hw, FixedArch::Eyeriss.config());
    }

    #[test]
    fn objective_scoring_matches_metrics() {
        let hw = HwConfig::new_kb(32, 32, 128.0, 128.0, 32.0, 16, LoopOrder::Mnk);
        let g = Gemm::new(128, 768, 768);
        let (s, e) = crate::dse::evaluate(&hw, &g);
        let d = Objective::MinEdp { g }.evaluate(&hw);
        assert_eq!(d.edp, e.edp);
        assert_eq!(d.cycles, s.cycles as f64);
        let rt = Objective::Runtime { g, target_cycles: 2.0 * s.cycles as f64 };
        assert!((rt.score(&hw) - 0.5).abs() < 1e-12);
    }
}
