//! §VI: LLM inference co-design — Figs 22/23/24, Tables VII/VIII.
//!
//! A DNN is a *sequence* of GEMMs (Fig 20): the array/buffer/bandwidth
//! parameters are shared across layers while each layer gets its own loop
//! order. DiffAxE generates base-configuration candidates by conditioning
//! the class sampler on each layer's workload; the coordinator then picks
//! the per-layer loop orders exactly (given the shared base configuration
//! the additive cost model makes per-layer choices independent, so 2·l
//! simulations suffice) and keeps the candidate with the lowest whole-model
//! EDP. The paper does this with an attention-based sequence PP; evaluating
//! sequences natively in the simulator is the rust-coordinator adaptation
//! of the same search (see DESIGN.md §3).

use crate::baselines::{gd, FixedArch, GdOptions};
use crate::design_space::{decode_rounded, encode_norm, HwConfig, LoopOrder, TargetSpace};
use crate::energy::{asic, fpga, EnergyResult};
use crate::models::{ClassMode, DiffAxE};
use crate::sim::{simulate_seq, SeqConfig, SimResult};
use crate::util::rng::Pcg32;
use crate::util::stats::Timer;
use crate::workload::{Gemm, LlmModel, Stage};
use anyhow::Result;

/// Evaluation platform.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Platform {
    Asic32nm,
    FpgaVu13p,
}

/// Whole-model evaluation of a sequence configuration.
#[derive(Debug, Clone)]
pub struct SeqEval {
    pub cfg: SeqConfig,
    pub sim: SimResult,
    pub energy: EnergyResult,
}

/// Evaluate a base config on an LLM (one transformer block scaled by the
/// block count), choosing each layer's loop order optimally.
pub fn eval_llm(
    base: &HwConfig,
    model: LlmModel,
    stage: Stage,
    seq: u32,
    platform: Platform,
) -> SeqEval {
    let gemms = model.layer_gemms(stage, seq);
    // per-layer best order: independent given the shared base config
    let orders: Vec<LoopOrder> = gemms
        .iter()
        .map(|g| {
            LoopOrder::OS_ORDERS
                .iter()
                .copied()
                .min_by(|&a, &b| {
                    let ea = layer_edp(base, g, a, platform);
                    let eb = layer_edp(base, g, b, platform);
                    ea.partial_cmp(&eb).unwrap()
                })
                .unwrap()
        })
        .collect();
    let cfg = SeqConfig { base: *base, orders };
    let mut sim = simulate_seq(&cfg, &gemms);
    // scale one block to the whole model (linear in blocks)
    let blocks = model.n_blocks() as u64;
    sim = scale_sim(&sim, blocks);
    let energy = match platform {
        Platform::Asic32nm => asic::evaluate(base, &sim),
        Platform::FpgaVu13p => fpga::evaluate(base, &sim),
    };
    SeqEval { cfg, sim, energy }
}

fn layer_edp(base: &HwConfig, g: &Gemm, order: LoopOrder, platform: Platform) -> f64 {
    let hw = HwConfig { loop_order: order, ..*base };
    let s = crate::sim::simulate(&hw, g);
    match platform {
        Platform::Asic32nm => asic::evaluate(&hw, &s).edp,
        Platform::FpgaVu13p => fpga::evaluate(&hw, &s).edp,
    }
}

fn scale_sim(s: &SimResult, blocks: u64) -> SimResult {
    let mut out = *s;
    out.cycles *= blocks;
    out.compute_cycles *= blocks;
    out.mem_cycles *= blocks;
    out.dram.a_reads *= blocks;
    out.dram.b_reads *= blocks;
    out.dram.out_writes *= blocks;
    out.dram.out_reads *= blocks;
    out.sram.ip_reads *= blocks;
    out.sram.wt_reads *= blocks;
    out.sram.op_writes *= blocks;
    out.sram.op_reads *= blocks;
    out.sram.fills *= blocks;
    out.macs_useful *= blocks;
    out.pe_cycles *= blocks;
    out
}

/// DiffAxE LLM co-design: candidate base configs from the low-EDP class
/// sampler conditioned on each layer's shape; best whole-model EDP wins.
pub fn diffaxe_llm(
    engine: &DiffAxE,
    model: LlmModel,
    stage: Stage,
    seq: u32,
    n_per_layer: usize,
    platform: Platform,
    seed: u32,
) -> Result<(SeqEval, f64)> {
    let timer = Timer::start();
    let gemms = model.layer_gemms(stage, seq);
    let b = engine.stats.gen_batch;
    let mut candidates: Vec<HwConfig> = Vec::new();
    for (li, g) in gemms.iter().enumerate() {
        let mut remaining = n_per_layer;
        let mut chunk = 0u32;
        while remaining > 0 {
            let take = remaining.min(b);
            let conds: Vec<(i32, [f32; 3])> = (0..take).map(|_| (0, g.norm_vec())).collect();
            let s = seed.wrapping_add((li as u32) << 8).wrapping_add(chunk);
            candidates.extend(engine.sample_class(ClassMode::Edp, s, &conds)?);
            remaining -= take;
            chunk += 1;
        }
    }
    candidates.sort_by_key(|h| (h.r, h.c, h.ip_b, h.wt_b, h.op_b, h.bw));
    candidates.dedup();
    let best = candidates
        .iter()
        .map(|hw| eval_llm(hw, model, stage, seq, platform))
        .min_by(|a, b| a.energy.edp.partial_cmp(&b.energy.edp).unwrap())
        .expect("non-empty candidate set");
    Ok((best, timer.elapsed_s()))
}

/// DOSA stand-in for §VI: finite-difference GD on whole-model EDP over the
/// coarse grid (see DESIGN.md §3).
pub fn dosa_llm(
    model: LlmModel,
    stage: Stage,
    seq: u32,
    platform: Platform,
    seed: u64,
) -> (SeqEval, f64) {
    let timer = Timer::start();
    let mut rng = Pcg32::new(seed, 66);
    let opts = GdOptions { steps: 30, restarts: 3, ..Default::default() };
    let res = gd::fd_gd(
        |x: &[f64]| {
            let v: Vec<f32> = x.iter().map(|&v| v as f32).collect();
            let hw = super::coarsen(&decode_rounded(&v));
            eval_llm(&hw, model, stage, seq, platform).energy.edp.ln()
        },
        |r: &mut Pcg32| encode_norm(&TargetSpace::sample(r)).iter().map(|&x| x as f64).collect(),
        0.05,
        &opts,
        &mut rng,
    );
    let v: Vec<f32> = res.best_x.iter().map(|&x| x as f32).collect();
    let hw = super::coarsen(&decode_rounded(&v));
    (eval_llm(&hw, model, stage, seq, platform), timer.elapsed_s())
}

/// Fixed-architecture evaluation (charitably granting per-layer loop-order
/// choice — see [`FixedArch::config`]).
pub fn fixed_llm(arch: FixedArch, model: LlmModel, stage: Stage, seq: u32, platform: Platform) -> SeqEval {
    eval_llm(&arch.config(), model, stage, seq, platform)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_llm_scales_with_blocks() {
        let hw = HwConfig::new_kb(32, 32, 128.0, 128.0, 32.0, 16, LoopOrder::Mnk);
        let e = eval_llm(&hw, LlmModel::BertBase, Stage::Prefill, 128, Platform::Asic32nm);
        let gemms = LlmModel::BertBase.layer_gemms(Stage::Prefill, 128);
        let one_block = simulate_seq(&e.cfg, &gemms);
        assert_eq!(e.sim.cycles, one_block.cycles * 12);
    }

    #[test]
    fn per_layer_orders_not_worse_than_uniform() {
        let hw = HwConfig::new_kb(64, 64, 256.0, 64.0, 32.0, 16, LoopOrder::Mnk);
        let opt = eval_llm(&hw, LlmModel::BertBase, Stage::Prefill, 128, Platform::Asic32nm);
        for uniform in LoopOrder::OS_ORDERS {
            let gemms = LlmModel::BertBase.layer_gemms(Stage::Prefill, 128);
            let cfg = SeqConfig::uniform(HwConfig { loop_order: uniform, ..hw }, gemms.len());
            let sim = scale_sim(&simulate_seq(&cfg, &gemms), 12);
            let e = asic::evaluate(&hw, &sim);
            // per-layer EDP-optimal ordering beats (or ties) any uniform order
            // on runtime-energy product within rounding
            assert!(opt.energy.edp <= e.edp * 1.001,
                    "{uniform:?}: {} vs {}", opt.energy.edp, e.edp);
        }
    }

    #[test]
    fn bigger_arrays_help_prefill_more_than_decode() {
        // paper Fig 22 narrative: flexibility in PE sizing matters most in
        // prefill; decode is latency/memory bound
        let small = HwConfig::new_kb(16, 16, 128.0, 128.0, 32.0, 16, LoopOrder::Mnk);
        let big = HwConfig::new_kb(128, 128, 128.0, 128.0, 32.0, 16, LoopOrder::Mnk);
        let pf_gain = eval_llm(&small, LlmModel::BertBase, Stage::Prefill, 128, Platform::Asic32nm)
            .sim
            .cycles as f64
            / eval_llm(&big, LlmModel::BertBase, Stage::Prefill, 128, Platform::Asic32nm).sim.cycles
                as f64;
        let dec_gain = eval_llm(&small, LlmModel::BertBase, Stage::Decode, 128, Platform::Asic32nm)
            .sim
            .cycles as f64
            / eval_llm(&big, LlmModel::BertBase, Stage::Decode, 128, Platform::Asic32nm).sim.cycles
                as f64;
        assert!(pf_gain > dec_gain, "prefill gain {pf_gain} vs decode {dec_gain}");
    }

    #[test]
    fn fixed_archs_evaluate_on_both_platforms() {
        for arch in FixedArch::ALL {
            for platform in [Platform::Asic32nm, Platform::FpgaVu13p] {
                let e = fixed_llm(arch, LlmModel::BertBase, Stage::Prefill, 128, platform);
                assert!(e.energy.edp > 0.0);
                assert!(e.energy.power_w > 0.0);
            }
        }
    }
}
