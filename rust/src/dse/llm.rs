//! §VI: LLM inference co-design — Figs 22/23/24, Tables VII/VIII.
//!
//! A DNN is a *sequence* of GEMMs (Fig 20): the array/buffer/bandwidth
//! parameters are shared across layers while each layer gets its own loop
//! order. This module holds the whole-model evaluator [`eval_model`] that
//! `Objective::LlmEdp` scores candidates with: given a shared base
//! configuration the additive cost model makes per-layer loop-order choices
//! independent, so 2·l simulations pick them exactly, and one block scales
//! linearly to the whole model. The paper does this with an attention-based
//! sequence PP; evaluating sequences natively in the simulator is the
//! rust-coordinator adaptation of the same search (see DESIGN.md §3).
//!
//! The searches themselves (DiffAxE per-layer conditioning, the DOSA-style
//! coarse GD, fixed architectures) are [`crate::dse::api::Optimizer`] impls
//! driven with `Objective::LlmEdp`.

use crate::design_space::{HwConfig, LoopOrder};
use crate::energy::{asic, fpga, EnergyResult};
use crate::sim::{simulate_seq, SeqConfig, SimResult};
use crate::workload::{Gemm, LlmModel, Stage};

/// Evaluation platform.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Platform {
    Asic32nm,
    FpgaVu13p,
}

impl Platform {
    /// Stable wire name.
    pub fn name(&self) -> &'static str {
        match self {
            Platform::Asic32nm => "asic-32nm",
            Platform::FpgaVu13p => "fpga-vu13p",
        }
    }

    /// Parse a wire name (inverse of [`Platform::name`]; `"asic"` and
    /// `"fpga"` shorthands accepted).
    pub fn from_name(s: &str) -> Option<Platform> {
        match s {
            "asic-32nm" | "asic" => Some(Platform::Asic32nm),
            "fpga-vu13p" | "fpga" => Some(Platform::FpgaVu13p),
            _ => None,
        }
    }
}

/// Whole-model evaluation of a sequence configuration.
#[derive(Debug, Clone)]
pub struct SeqEval {
    pub cfg: SeqConfig,
    pub sim: SimResult,
    pub energy: EnergyResult,
}

/// Evaluate a base config on an LLM (one transformer block scaled by the
/// block count), choosing each layer's loop order optimally.
pub fn eval_model(
    base: &HwConfig,
    model: LlmModel,
    stage: Stage,
    seq: u32,
    platform: Platform,
) -> SeqEval {
    let gemms = model.layer_gemms(stage, seq);
    // per-layer best order: independent given the shared base config
    let orders: Vec<LoopOrder> = gemms
        .iter()
        .map(|g| {
            LoopOrder::OS_ORDERS
                .iter()
                .copied()
                .min_by(|&a, &b| {
                    let ea = edp_for_order(base, g, a, platform);
                    let eb = edp_for_order(base, g, b, platform);
                    ea.partial_cmp(&eb).unwrap()
                })
                .unwrap()
        })
        .collect();
    let cfg = SeqConfig { base: *base, orders };
    let mut sim = simulate_seq(&cfg, &gemms);
    // scale one block to the whole model (linear in blocks)
    let blocks = model.n_blocks() as u64;
    sim = scale_sim(&sim, blocks);
    let energy = match platform {
        Platform::Asic32nm => asic::evaluate(base, &sim),
        Platform::FpgaVu13p => fpga::evaluate(base, &sim),
    };
    SeqEval { cfg, sim, energy }
}

fn edp_for_order(base: &HwConfig, g: &Gemm, order: LoopOrder, platform: Platform) -> f64 {
    let hw = HwConfig { loop_order: order, ..*base };
    let s = crate::sim::simulate(&hw, g);
    match platform {
        Platform::Asic32nm => asic::evaluate(&hw, &s).edp,
        Platform::FpgaVu13p => fpga::evaluate(&hw, &s).edp,
    }
}

fn scale_sim(s: &SimResult, blocks: u64) -> SimResult {
    let mut out = *s;
    out.cycles *= blocks;
    out.compute_cycles *= blocks;
    out.mem_cycles *= blocks;
    out.dram.a_reads *= blocks;
    out.dram.b_reads *= blocks;
    out.dram.out_writes *= blocks;
    out.dram.out_reads *= blocks;
    out.sram.ip_reads *= blocks;
    out.sram.wt_reads *= blocks;
    out.sram.op_writes *= blocks;
    out.sram.op_reads *= blocks;
    out.sram.fills *= blocks;
    out.macs_useful *= blocks;
    out.pe_cycles *= blocks;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::FixedArch;

    #[test]
    fn eval_model_scales_with_blocks() {
        let hw = HwConfig::new_kb(32, 32, 128.0, 128.0, 32.0, 16, LoopOrder::Mnk);
        let e = eval_model(&hw, LlmModel::BertBase, Stage::Prefill, 128, Platform::Asic32nm);
        let gemms = LlmModel::BertBase.layer_gemms(Stage::Prefill, 128);
        let one_block = simulate_seq(&e.cfg, &gemms);
        assert_eq!(e.sim.cycles, one_block.cycles * 12);
    }

    #[test]
    fn per_layer_orders_not_worse_than_uniform() {
        let hw = HwConfig::new_kb(64, 64, 256.0, 64.0, 32.0, 16, LoopOrder::Mnk);
        let opt = eval_model(&hw, LlmModel::BertBase, Stage::Prefill, 128, Platform::Asic32nm);
        for uniform in LoopOrder::OS_ORDERS {
            let gemms = LlmModel::BertBase.layer_gemms(Stage::Prefill, 128);
            let cfg = SeqConfig::uniform(HwConfig { loop_order: uniform, ..hw }, gemms.len());
            let sim = scale_sim(&simulate_seq(&cfg, &gemms), 12);
            let e = asic::evaluate(&hw, &sim);
            // per-layer EDP-optimal ordering beats (or ties) any uniform order
            // on runtime-energy product within rounding
            assert!(opt.energy.edp <= e.edp * 1.001,
                    "{uniform:?}: {} vs {}", opt.energy.edp, e.edp);
        }
    }

    #[test]
    fn bigger_arrays_help_prefill_more_than_decode() {
        // paper Fig 22 narrative: flexibility in PE sizing matters most in
        // prefill; decode is latency/memory bound
        let small = HwConfig::new_kb(16, 16, 128.0, 128.0, 32.0, 16, LoopOrder::Mnk);
        let big = HwConfig::new_kb(128, 128, 128.0, 128.0, 32.0, 16, LoopOrder::Mnk);
        let pf_gain = eval_model(&small, LlmModel::BertBase, Stage::Prefill, 128, Platform::Asic32nm)
            .sim
            .cycles as f64
            / eval_model(&big, LlmModel::BertBase, Stage::Prefill, 128, Platform::Asic32nm).sim.cycles
                as f64;
        let dec_gain = eval_model(&small, LlmModel::BertBase, Stage::Decode, 128, Platform::Asic32nm)
            .sim
            .cycles as f64
            / eval_model(&big, LlmModel::BertBase, Stage::Decode, 128, Platform::Asic32nm).sim.cycles
                as f64;
        assert!(pf_gain > dec_gain, "prefill gain {pf_gain} vs decode {dec_gain}");
    }

    #[test]
    fn fixed_archs_evaluate_on_both_platforms() {
        for arch in FixedArch::ALL {
            for platform in [Platform::Asic32nm, Platform::FpgaVu13p] {
                let e = eval_model(&arch.config(), LlmModel::BertBase, Stage::Prefill, 128, platform);
                assert!(e.energy.edp > 0.0);
                assert!(e.energy.power_w > 0.0);
            }
        }
    }

    #[test]
    fn platform_names_roundtrip() {
        for p in [Platform::Asic32nm, Platform::FpgaVu13p] {
            assert_eq!(Platform::from_name(p.name()), Some(p));
        }
        assert_eq!(Platform::from_name("asic"), Some(Platform::Asic32nm));
        assert_eq!(Platform::from_name("tpu"), None);
    }
}
