//! §VI: LLM inference co-design — Figs 22/23/24, Tables VII/VIII.
//!
//! A DNN is a *sequence* of GEMMs (Fig 20): the array/buffer/bandwidth
//! parameters are shared across layers while each layer gets its own loop
//! order. This module holds the whole-model evaluator [`eval_model`] that
//! `Objective::LlmEdp` scores candidates with: given a shared base
//! configuration the additive cost model makes per-layer loop-order choices
//! independent, so one simulation per `(distinct layer shape, loop order)`
//! pair picks them exactly, and one block scales linearly to the whole
//! model. The paper does this with an attention-based sequence PP;
//! evaluating sequences natively in the simulator is the rust-coordinator
//! adaptation of the same search (see DESIGN.md §3).
//!
//! # The fast path
//!
//! [`eval_model`] is the per-candidate hot loop of every LLM search, so it
//! leans on three structural facts (see [`crate::dse::eval`] for the shared
//! machinery):
//!
//! * the workload is fixed across candidates — [`ModelWorkload`] memoizes
//!   the layer list (and dedups identical GEMM shapes) once per
//!   `(model, stage, seq)` instead of re-allocating it per candidate;
//! * energy coefficients depend only on the base parameters, never on the
//!   loop order — one [`EnergyCoeffs`] prices every order probe, so order
//!   selection is a dot product over [`SimResult`] counters instead of a
//!   full energy evaluation per probe;
//! * per-layer winners are summed directly ([`SimResult::add`]) — the
//!   winning simulations are already in hand, so nothing is re-simulated.
//!
//! Layer simulations go through the global [`EvalCache`], which converts
//! the many-to-one recurrence of rounded design points (Fig 2a) into
//! lookups across candidates and requests. [`eval_model_reference`] retains
//! the pre-memoization implementation; `tests/eval_core.rs` proves the two
//! bit-identical over every `LlmModel` × `Stage` × `Platform`.
//!
//! The searches themselves (DiffAxE per-layer conditioning, the DOSA-style
//! coarse GD, fixed architectures) are [`crate::dse::api::Optimizer`] impls
//! driven with `Objective::LlmEdp`.

use super::eval::EvalCache;
use crate::design_space::{HwConfig, LoopOrder};
use crate::energy::{asic, fpga, EnergyCoeffs, EnergyResult};
use crate::sim::{simulate_seq, SeqConfig, SimResult};
use crate::workload::{model_workload, Gemm, LlmModel, ModelWorkload, Stage};
use std::cmp::Ordering;

/// Evaluation platform.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Platform {
    Asic32nm,
    FpgaVu13p,
}

impl Platform {
    /// Stable wire name.
    pub fn name(&self) -> &'static str {
        match self {
            Platform::Asic32nm => "asic-32nm",
            Platform::FpgaVu13p => "fpga-vu13p",
        }
    }

    /// Parse a wire name (inverse of [`Platform::name`]; `"asic"` and
    /// `"fpga"` shorthands accepted).
    pub fn from_name(s: &str) -> Option<Platform> {
        match s {
            "asic-32nm" | "asic" => Some(Platform::Asic32nm),
            "fpga-vu13p" | "fpga" => Some(Platform::FpgaVu13p),
            _ => None,
        }
    }

    /// Loop-order-independent energy coefficients of `hw` on this platform.
    pub fn coeffs(&self, hw: &HwConfig) -> EnergyCoeffs {
        match self {
            Platform::Asic32nm => asic::coeffs(hw),
            Platform::FpgaVu13p => fpga::coeffs(hw),
        }
    }

    /// Full energy evaluation of a simulated run on this platform.
    pub fn evaluate(&self, hw: &HwConfig, sim: &SimResult) -> EnergyResult {
        match self {
            Platform::Asic32nm => asic::evaluate(hw, sim),
            Platform::FpgaVu13p => fpga::evaluate(hw, sim),
        }
    }
}

/// Whole-model evaluation of a sequence configuration.
#[derive(Debug, Clone)]
pub struct SeqEval {
    pub cfg: SeqConfig,
    pub sim: SimResult,
    pub energy: EnergyResult,
}

/// Evaluate a base config on an LLM (one transformer block scaled by the
/// block count), choosing each layer's loop order optimally. Fast path —
/// see the module docs; bit-identical to [`eval_model_reference`].
pub fn eval_model(
    base: &HwConfig,
    model: LlmModel,
    stage: Stage,
    seq: u32,
    platform: Platform,
) -> SeqEval {
    eval_workload(base, &model_workload(model, stage, seq), platform)
}

/// [`eval_model`] over an already-shared [`ModelWorkload`] (the objective
/// hot loop holds one and skips the memo lookup entirely).
///
/// An **empty** workload (zero GEMMs) evaluates to the zero cost point —
/// a well-formed [`SeqEval`] with zero cycles/energy — instead of
/// panicking; searches over such degenerate objectives return empty
/// outcomes (see `Budget`/`SearchOutcome` edge-case handling in
/// [`crate::dse::api`]).
pub fn eval_workload(base: &HwConfig, wl: &ModelWorkload, platform: Platform) -> SeqEval {
    if wl.gemms.is_empty() {
        return SeqEval {
            cfg: SeqConfig { base: *base, orders: Vec::new() },
            sim: SimResult::zero(),
            energy: EnergyResult {
                e_dyn_uj: 0.0,
                e_static_uj: 0.0,
                power_w: 0.0,
                edp: 0.0,
                runtime_s: 0.0,
            },
        };
    }
    let cache = EvalCache::global();
    let coeffs = platform.coeffs(base);
    // one cached simulation per (distinct shape, order), all probes batched
    // into a single SoA call (misses simulate as one grouped batch); order
    // selection by coefficient dot product. First-minimal tie-break and
    // NaN-safe comparison (total_cmp: a NaN EDP loses to any number) match
    // the reference `min_by` exactly.
    let n_orders = LoopOrder::OS_ORDERS.len();
    let probes: Vec<(HwConfig, Gemm)> = wl
        .unique
        .iter()
        .flat_map(|g| {
            LoopOrder::OS_ORDERS
                .iter()
                .map(move |&order| (HwConfig { loop_order: order, ..*base }, *g))
        })
        .collect();
    let sims = cache.simulate_pairs(&probes);
    let best: Vec<(LoopOrder, SimResult)> = sims
        .chunks_exact(n_orders)
        .map(|shape_sims| {
            let mut best_order = LoopOrder::OS_ORDERS[0];
            let mut best_sim = shape_sims[0];
            let mut best_edp = coeffs.edp(&best_sim);
            for (order, sim) in LoopOrder::OS_ORDERS.iter().zip(shape_sims).skip(1) {
                let edp = coeffs.edp(sim);
                if edp.total_cmp(&best_edp) == Ordering::Less {
                    best_order = *order;
                    best_sim = *sim;
                    best_edp = edp;
                }
            }
            (best_order, best_sim)
        })
        .collect();
    let orders: Vec<LoopOrder> = wl.layer_to_unique.iter().map(|&u| best[u].0).collect();
    // sum the winning per-layer simulations directly (u64 counters: exact)
    let mut acc: Option<SimResult> = None;
    for &u in &wl.layer_to_unique {
        acc = Some(match acc {
            None => best[u].1,
            Some(a) => a.add(&best[u].1),
        });
    }
    // scale one block to the whole model (linear in blocks)
    let sim = acc.expect("non-empty GEMM sequence").scale(wl.blocks);
    let energy = coeffs.evaluate(&sim);
    SeqEval { cfg: SeqConfig { base: *base, orders }, sim, energy }
}

/// The pre-memoization implementation, retained as the equivalence oracle:
/// one full `simulate` + platform `evaluate` per (layer, order) probe, a
/// `simulate_seq` re-simulation of the chosen orders, and a fresh
/// `layer_gemms` allocation per call. `tests/eval_core.rs` and
/// `benches/micro_sim.rs` hold [`eval_model`] to bit-identity and to a
/// throughput multiple against this path.
pub fn eval_model_reference(
    base: &HwConfig,
    model: LlmModel,
    stage: Stage,
    seq: u32,
    platform: Platform,
) -> SeqEval {
    let gemms = model.layer_gemms(stage, seq);
    // per-layer best order: independent given the shared base config
    let orders: Vec<LoopOrder> = gemms
        .iter()
        .map(|g| {
            LoopOrder::OS_ORDERS
                .iter()
                .copied()
                .min_by(|&a, &b| {
                    let ea = edp_for_order(base, g, a, platform);
                    let eb = edp_for_order(base, g, b, platform);
                    ea.total_cmp(&eb)
                })
                .expect("OS_ORDERS is non-empty")
        })
        .collect();
    let cfg = SeqConfig { base: *base, orders };
    let sim = simulate_seq(&cfg, &gemms).scale(model.n_blocks() as u64);
    let energy = platform.evaluate(base, &sim);
    SeqEval { cfg, sim, energy }
}

fn edp_for_order(base: &HwConfig, g: &Gemm, order: LoopOrder, platform: Platform) -> f64 {
    let hw = HwConfig { loop_order: order, ..*base };
    let s = crate::sim::simulate(&hw, g);
    platform.evaluate(&hw, &s).edp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::FixedArch;

    #[test]
    fn eval_model_scales_with_blocks() {
        let hw = HwConfig::new_kb(32, 32, 128.0, 128.0, 32.0, 16, LoopOrder::Mnk);
        let e = eval_model(&hw, LlmModel::BertBase, Stage::Prefill, 128, Platform::Asic32nm);
        let gemms = LlmModel::BertBase.layer_gemms(Stage::Prefill, 128);
        let one_block = simulate_seq(&e.cfg, &gemms);
        assert_eq!(e.sim.cycles, one_block.cycles * 12);
    }

    #[test]
    fn fast_path_matches_reference_spot_check() {
        // the exhaustive model × stage × platform sweep lives in
        // tests/eval_core.rs; this guards the module in isolation
        let hw = HwConfig::new_kb(48, 24, 256.0, 32.0, 16.0, 8, LoopOrder::Nmk);
        let a = eval_model(&hw, LlmModel::Opt350m, Stage::Decode, 96, Platform::FpgaVu13p);
        let b = eval_model_reference(&hw, LlmModel::Opt350m, Stage::Decode, 96, Platform::FpgaVu13p);
        assert_eq!(a.cfg, b.cfg);
        assert_eq!(a.sim, b.sim);
        assert_eq!(a.energy.edp.to_bits(), b.energy.edp.to_bits());
        assert_eq!(a.energy.power_w.to_bits(), b.energy.power_w.to_bits());
    }

    #[test]
    fn per_layer_orders_not_worse_than_uniform() {
        let hw = HwConfig::new_kb(64, 64, 256.0, 64.0, 32.0, 16, LoopOrder::Mnk);
        let opt = eval_model(&hw, LlmModel::BertBase, Stage::Prefill, 128, Platform::Asic32nm);
        for uniform in LoopOrder::OS_ORDERS {
            let gemms = LlmModel::BertBase.layer_gemms(Stage::Prefill, 128);
            let cfg = SeqConfig::uniform(HwConfig { loop_order: uniform, ..hw }, gemms.len());
            let sim = simulate_seq(&cfg, &gemms).scale(12);
            let e = asic::evaluate(&hw, &sim);
            // per-layer EDP-optimal ordering beats (or ties) any uniform order
            // on runtime-energy product within rounding
            assert!(opt.energy.edp <= e.edp * 1.001,
                    "{uniform:?}: {} vs {}", opt.energy.edp, e.edp);
        }
    }

    #[test]
    fn bigger_arrays_help_prefill_more_than_decode() {
        // paper Fig 22 narrative: flexibility in PE sizing matters most in
        // prefill; decode is latency/memory bound
        let small = HwConfig::new_kb(16, 16, 128.0, 128.0, 32.0, 16, LoopOrder::Mnk);
        let big = HwConfig::new_kb(128, 128, 128.0, 128.0, 32.0, 16, LoopOrder::Mnk);
        let pf_gain = eval_model(&small, LlmModel::BertBase, Stage::Prefill, 128, Platform::Asic32nm)
            .sim
            .cycles as f64
            / eval_model(&big, LlmModel::BertBase, Stage::Prefill, 128, Platform::Asic32nm).sim.cycles
                as f64;
        let dec_gain = eval_model(&small, LlmModel::BertBase, Stage::Decode, 128, Platform::Asic32nm)
            .sim
            .cycles as f64
            / eval_model(&big, LlmModel::BertBase, Stage::Decode, 128, Platform::Asic32nm).sim.cycles
                as f64;
        assert!(pf_gain > dec_gain, "prefill gain {pf_gain} vs decode {dec_gain}");
    }

    #[test]
    fn fixed_archs_evaluate_on_both_platforms() {
        for arch in FixedArch::ALL {
            for platform in [Platform::Asic32nm, Platform::FpgaVu13p] {
                let e = eval_model(&arch.config(), LlmModel::BertBase, Stage::Prefill, 128, platform);
                assert!(e.energy.edp > 0.0);
                assert!(e.energy.power_w > 0.0);
            }
        }
    }

    #[test]
    fn platform_names_roundtrip() {
        for p in [Platform::Asic32nm, Platform::FpgaVu13p] {
            assert_eq!(Platform::from_name(p.name()), Some(p));
        }
        assert_eq!(Platform::from_name("asic"), Some(Platform::Asic32nm));
        assert_eq!(Platform::from_name("tpu"), None);
    }
}
