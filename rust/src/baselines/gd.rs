//! Gradient-descent baselines.
//!
//! * [`descend`] — generic GD with momentum over a boxed [0,1]^d encoding,
//!   driven by a gradient closure. Vanilla GD (DOSA-style [8]) plugs in the
//!   exported surrogate gradient in hardware space; latent GD
//!   (Polaris-style [19]) plugs in the exported PP gradient in latent space.
//! * [`fd_gd`] — finite-difference GD directly on a black-box objective,
//!   used by the LLM experiment's DOSA stand-in where the objective is the
//!   real simulator's EDP on a coarse grid.

use crate::util::rng::Pcg32;

/// Options for [`descend`].
#[derive(Debug, Clone)]
pub struct GdOptions {
    pub steps: usize,
    pub lr: f64,
    pub momentum: f64,
    /// clamp iterates into [0,1]^d (all our encodings are normalized)
    pub clamp: bool,
    pub restarts: usize,
}

impl Default for GdOptions {
    fn default() -> Self {
        GdOptions { steps: 80, lr: 0.08, momentum: 0.7, clamp: true, restarts: 4 }
    }
}

/// Result of a GD run.
#[derive(Debug, Clone)]
pub struct GdResult {
    pub best_x: Vec<f64>,
    pub best_loss: f64,
    pub grad_evals: usize,
}

/// Minimize via momentum GD from random restarts.
///
/// `grad(x) -> (loss, gradient)`; `init(rng) -> x0`. `should_stop()` is
/// polled before every gradient evaluation — once true, the best-so-far
/// is returned immediately (`best_x` is empty if nothing was evaluated).
/// Pass `|| false` for an uninterruptible run.
pub fn descend<G, I, P>(
    mut grad: G,
    mut init: I,
    mut should_stop: P,
    opts: &GdOptions,
    rng: &mut Pcg32,
) -> GdResult
where
    G: FnMut(&[f64]) -> (f64, Vec<f64>),
    I: FnMut(&mut Pcg32) -> Vec<f64>,
    P: FnMut() -> bool,
{
    let mut best_x = Vec::new();
    let mut best_loss = f64::INFINITY;
    let mut grad_evals = 0;
    'restarts: for _ in 0..opts.restarts.max(1) {
        let mut x = init(rng);
        let mut vel = vec![0.0; x.len()];
        for _ in 0..opts.steps {
            if should_stop() {
                break 'restarts;
            }
            let (loss, g) = grad(&x);
            grad_evals += 1;
            if loss < best_loss {
                best_loss = loss;
                best_x = x.clone();
            }
            for i in 0..x.len() {
                vel[i] = opts.momentum * vel[i] - opts.lr * g[i];
                x[i] += vel[i];
                if opts.clamp {
                    x[i] = x[i].clamp(0.0, 1.0);
                }
            }
        }
        if should_stop() {
            break;
        }
        let (loss, _) = grad(&x);
        grad_evals += 1;
        if loss < best_loss {
            best_loss = loss;
            best_x = x;
        }
    }
    GdResult { best_x, best_loss, grad_evals }
}

/// Finite-difference GD on a black-box objective (central differences).
/// `should_stop()` is polled between gradient evaluations (each spends
/// `1 + 2·dim` objective calls).
pub fn fd_gd<F, I, P>(
    mut f: F,
    mut init: I,
    h: f64,
    should_stop: P,
    opts: &GdOptions,
    rng: &mut Pcg32,
) -> GdResult
where
    F: FnMut(&[f64]) -> f64,
    I: FnMut(&mut Pcg32) -> Vec<f64>,
    P: FnMut() -> bool,
{
    let mut evals = 0usize;
    let mut grad = |x: &[f64]| -> (f64, Vec<f64>) {
        let base = f(x);
        let mut g = vec![0.0; x.len()];
        let mut xp = x.to_vec();
        for i in 0..x.len() {
            let orig = xp[i];
            xp[i] = (orig + h).min(1.0);
            let up = f(&xp);
            xp[i] = (orig - h).max(0.0);
            let dn = f(&xp);
            xp[i] = orig;
            g[i] = (up - dn) / (2.0 * h);
        }
        evals += 1 + 2 * x.len();
        (base, g)
    };
    let mut res = descend(&mut grad, &mut init, should_stop, opts, rng);
    res.grad_evals = evals;
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_on_quadratic() {
        let target = [0.3, 0.8, 0.5];
        let grad = |x: &[f64]| {
            let loss: f64 = x.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum();
            let g: Vec<f64> = x.iter().zip(&target).map(|(a, b)| 2.0 * (a - b)).collect();
            (loss, g)
        };
        let mut rng = Pcg32::seeded(2);
        let res = descend(
            grad,
            |r: &mut Pcg32| (0..3).map(|_| r.f64()).collect(),
            || false,
            &GdOptions::default(),
            &mut rng,
        );
        assert!(res.best_loss < 1e-3, "loss {}", res.best_loss);
        for (a, b) in res.best_x.iter().zip(&target) {
            assert!((a - b).abs() < 0.05);
        }
    }

    #[test]
    fn clamps_to_unit_box() {
        // gradient pushes out of the box; iterates must stay in [0,1]
        let grad = |x: &[f64]| (x[0], vec![-10.0]);
        let mut rng = Pcg32::seeded(3);
        let res = descend(
            grad,
            |_: &mut Pcg32| vec![0.5],
            || false,
            &GdOptions { steps: 20, restarts: 1, ..Default::default() },
            &mut rng,
        );
        assert!((0.0..=1.0).contains(&res.best_x[0]));
    }

    #[test]
    fn fd_matches_analytic_on_smooth_fn() {
        let f = |x: &[f64]| (x[0] - 0.6).powi(2) + (x[1] - 0.2).powi(2);
        let mut rng = Pcg32::seeded(4);
        let res = fd_gd(
            f,
            |r: &mut Pcg32| vec![r.f64(), r.f64()],
            1e-4,
            || false,
            &GdOptions::default(),
            &mut rng,
        );
        assert!(res.best_loss < 1e-3);
        assert!(res.grad_evals > 0);
    }

    #[test]
    fn stop_hook_interrupts_descent() {
        let calls = std::cell::Cell::new(0usize);
        let mut rng = Pcg32::seeded(9);
        let res = descend(
            |x: &[f64]| {
                calls.set(calls.get() + 1);
                (x[0] * x[0], vec![2.0 * x[0]])
            },
            |_: &mut Pcg32| vec![0.9],
            || calls.get() >= 3,
            &GdOptions { steps: 100, restarts: 10, ..Default::default() },
            &mut rng,
        );
        assert_eq!(res.grad_evals, 3);
        assert!(!res.best_x.is_empty());
        // immediate stop: nothing evaluated, empty best
        let res = descend(
            |x: &[f64]| (x[0], vec![1.0]),
            |_: &mut Pcg32| vec![0.5],
            || true,
            &GdOptions::default(),
            &mut rng,
        );
        assert_eq!(res.grad_evals, 0);
        assert!(res.best_x.is_empty());
    }

    #[test]
    fn restarts_help_on_multimodal() {
        // two basins; global min at 0.85
        let f = |x: &[f64]| {
            let a = (x[0] - 0.15).powi(2) + 0.3;
            let b = (x[0] - 0.85).powi(2);
            a.min(b)
        };
        let mut rng = Pcg32::seeded(5);
        let res = fd_gd(
            f,
            |r: &mut Pcg32| vec![r.f64()],
            1e-4,
            || false,
            &GdOptions { restarts: 8, ..Default::default() },
            &mut rng,
        );
        assert!((res.best_x[0] - 0.85).abs() < 0.05, "stuck at {:?}", res.best_x);
    }
}
