//! Random search over the full target design space — the SP-normalization
//! baseline of Table IV.

use crate::design_space::{HwConfig, TargetSpace};
use crate::util::rng::Pcg32;

/// Draw `n` uniform samples and keep the best under `objective` (lower is
/// better). Returns (best config, best value).
pub fn search<F>(n: usize, mut objective: F, rng: &mut Pcg32) -> (HwConfig, f64)
where
    F: FnMut(&HwConfig) -> f64,
{
    assert!(n > 0);
    let mut best = TargetSpace::sample(rng);
    let mut best_y = objective(&best);
    for _ in 1..n {
        let c = TargetSpace::sample(rng);
        let y = objective(&c);
        if y < best_y {
            best_y = y;
            best = c;
        }
    }
    (best, best_y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::asic;
    use crate::sim::simulate;
    use crate::workload::Gemm;

    #[test]
    fn more_samples_never_worse() {
        let g = Gemm::new(128, 512, 512);
        let obj = |hw: &HwConfig| asic::evaluate(hw, &simulate(hw, &g)).edp;
        let mut r1 = Pcg32::seeded(11);
        let (_, few) = search(10, obj, &mut r1);
        let mut r2 = Pcg32::seeded(11);
        let (_, many) = search(200, obj, &mut r2);
        assert!(many <= few, "{many} vs {few}");
    }

    #[test]
    fn returns_valid_config() {
        let mut rng = Pcg32::seeded(12);
        let (hw, y) = search(50, |hw| hw.macs() as f64, &mut rng);
        assert!(hw.in_target_space());
        assert!(y >= 16.0); // min 4x4
    }
}
