//! Fixed accelerator architectures (paper Table VI) used as LLM-inference
//! baselines in §VI.

use crate::design_space::{HwConfig, LoopOrder};

/// Named fixed architecture.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FixedArch {
    Eyeriss,
    ShiDianNao,
    Nvdla,
}

impl FixedArch {
    pub const ALL: [FixedArch; 3] = [FixedArch::Eyeriss, FixedArch::ShiDianNao, FixedArch::Nvdla];

    pub fn name(&self) -> &'static str {
        match self {
            FixedArch::Eyeriss => "Eyeriss",
            FixedArch::ShiDianNao => "ShiDianNao",
            FixedArch::Nvdla => "NVDLA",
        }
    }

    /// Table VI parameters. Loop order is chosen per layer at evaluation
    /// time (these chips have fixed dataflows, but granting them the better
    /// of the two OS orders is strictly charitable to the baselines).
    pub fn config(&self) -> HwConfig {
        match self {
            FixedArch::Eyeriss => HwConfig::new_kb(12, 14, 108.0, 108.0, 8.0, 16, LoopOrder::Mnk),
            FixedArch::ShiDianNao => HwConfig::new_kb(16, 16, 32.0, 32.0, 8.0, 8, LoopOrder::Mnk),
            FixedArch::Nvdla => HwConfig::new_kb(32, 32, 64.0, 512.0, 32.0, 16, LoopOrder::Mnk),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_parameters() {
        let e = FixedArch::Eyeriss.config();
        assert_eq!((e.r, e.c, e.bw), (12, 14, 16));
        assert_eq!(e.wt_kb(), 108.0);
        let n = FixedArch::Nvdla.config();
        assert_eq!(n.macs(), 1024);
        assert_eq!(n.wt_kb(), 512.0);
        let s = FixedArch::ShiDianNao.config();
        assert_eq!((s.r, s.c, s.bw), (16, 16, 8));
    }
}
