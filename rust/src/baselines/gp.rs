//! Gaussian-process regression substrate for the Bayesian-optimization
//! baselines (vanilla BO and VAESA-style latent BO).
//!
//! RBF kernel, exact Cholesky inference, expected-improvement acquisition.
//! Problem sizes are a few hundred points, so O(n³) fits are fine.

use crate::util::linalg::{cholesky, solve_lower, solve_upper_t, Mat};

/// Exact GP with an RBF kernel `σ²·exp(-‖a−b‖²/2ℓ²)` + noise.
#[derive(Debug, Clone)]
pub struct Gp {
    x: Vec<Vec<f64>>,
    chol: Mat,
    alpha: Vec<f64>,
    pub lengthscale: f64,
    pub signal: f64,
    pub noise: f64,
}

fn rbf(a: &[f64], b: &[f64], ls: f64, signal: f64) -> f64 {
    let d2: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    signal * (-d2 / (2.0 * ls * ls)).exp()
}

impl Gp {
    /// Fit to observations. Targets should be roughly standardized by the
    /// caller. Returns `None` only if the kernel matrix is numerically
    /// singular even after jitter (shouldn't happen with noise > 0).
    pub fn fit(x: Vec<Vec<f64>>, y: &[f64], lengthscale: f64, signal: f64, noise: f64) -> Option<Gp> {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        let n = x.len();
        let mut k = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = rbf(&x[i], &x[j], lengthscale, signal);
                k[(i, j)] = v;
                k[(j, i)] = v;
            }
            k[(i, i)] += noise;
        }
        let chol = cholesky(&k).or_else(|| {
            for i in 0..n {
                k[(i, i)] += 1e-6 * signal;
            }
            cholesky(&k)
        })?;
        let alpha = solve_upper_t(&chol, &solve_lower(&chol, y));
        Some(Gp { x, chol, alpha, lengthscale, signal, noise })
    }

    /// Posterior mean and variance at a query point.
    pub fn predict(&self, q: &[f64]) -> (f64, f64) {
        let kq: Vec<f64> =
            self.x.iter().map(|xi| rbf(xi, q, self.lengthscale, self.signal)).collect();
        let mean: f64 = kq.iter().zip(&self.alpha).map(|(a, b)| a * b).sum();
        let v = solve_lower(&self.chol, &kq);
        let var = (self.signal - v.iter().map(|x| x * x).sum::<f64>()).max(1e-12);
        (mean, var)
    }

    /// Expected improvement for *minimization* below `best`.
    pub fn expected_improvement(&self, q: &[f64], best: f64) -> f64 {
        let (mu, var) = self.predict(q);
        let sigma = var.sqrt();
        if sigma < 1e-12 {
            return (best - mu).max(0.0);
        }
        let z = (best - mu) / sigma;
        (best - mu) * normal_cdf(z) + sigma * normal_pdf(z)
    }
}

fn normal_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Φ(z) via the Abramowitz–Stegun erf approximation (|err| < 1.5e-7).
pub fn normal_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn interpolates_training_points() {
        let x: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64 / 8.0]).collect();
        let y: Vec<f64> = x.iter().map(|v| (v[0] * 6.0).sin()).collect();
        let gp = Gp::fit(x.clone(), &y, 0.3, 1.0, 1e-6).unwrap();
        for (xi, yi) in x.iter().zip(&y) {
            let (mu, var) = gp.predict(xi);
            assert!((mu - yi).abs() < 1e-2, "{mu} vs {yi}");
            assert!(var < 0.05);
        }
    }

    #[test]
    fn uncertainty_grows_away_from_data() {
        let x = vec![vec![0.0], vec![0.1]];
        let y = vec![0.0, 0.1];
        let gp = Gp::fit(x, &y, 0.2, 1.0, 1e-4).unwrap();
        let (_, var_near) = gp.predict(&[0.05]);
        let (_, var_far) = gp.predict(&[3.0]);
        assert!(var_far > 10.0 * var_near, "{var_far} vs {var_near}");
    }

    #[test]
    fn ei_prefers_promising_regions() {
        // objective = x²; data away from minimum
        let x: Vec<Vec<f64>> = vec![vec![-1.0], vec![-0.5], vec![0.5], vec![1.0]];
        let y: Vec<f64> = x.iter().map(|v| v[0] * v[0]).collect();
        let gp = Gp::fit(x, &y, 0.5, 1.0, 1e-6).unwrap();
        let best = 0.25;
        let ei_center = gp.expected_improvement(&[0.0], best);
        let ei_edge = gp.expected_improvement(&[1.5], best);
        assert!(ei_center > ei_edge, "{ei_center} vs {ei_edge}");
    }

    #[test]
    fn erf_matches_known_values() {
        assert!((erf(0.0)).abs() < 1e-7);
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427007929).abs() < 1e-6);
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-9);
        assert!((normal_cdf(1.96) - 0.975).abs() < 1e-3);
    }

    #[test]
    fn fit_handles_duplicate_points() {
        let mut rng = Pcg32::seeded(3);
        let mut x: Vec<Vec<f64>> = (0..20).map(|_| vec![rng.f64(), rng.f64()]).collect();
        x.push(x[0].clone()); // exact duplicate
        let y: Vec<f64> = x.iter().map(|v| v[0] + v[1]).collect();
        assert!(Gp::fit(x, &y, 0.5, 1.0, 1e-4).is_some());
    }
}
