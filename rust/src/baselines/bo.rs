//! Generic Bayesian-optimization loop over an arbitrary design encoding.
//!
//! Vanilla BO runs it on the 8-d normalized hardware vector; the
//! VAESA-style latent BO runs it on the Phase-1 latent space (the encoding /
//! decoding is supplied by the caller through the objective closure + the
//! candidate sampler).

use super::gp::Gp;
use crate::util::rng::Pcg32;

/// Result of a BO run.
#[derive(Debug, Clone)]
pub struct BoResult {
    pub best_x: Vec<f64>,
    pub best_y: f64,
    pub evals: usize,
    /// best-so-far after each evaluation (for convergence plots)
    pub history: Vec<f64>,
}

/// Options for [`minimize`].
#[derive(Debug, Clone)]
pub struct BoOptions {
    pub n_init: usize,
    pub budget: usize,
    pub pool: usize,
    pub lengthscale: f64,
    pub noise: f64,
}

impl Default for BoOptions {
    fn default() -> Self {
        BoOptions { n_init: 12, budget: 60, pool: 256, lengthscale: 0.4, noise: 1e-4 }
    }
}

/// Minimize `objective` over points produced by `sample_candidate`.
///
/// * `sample_candidate(rng)` draws a random point in the search encoding;
/// * `objective(x)` evaluates it (lower is better);
/// * `should_stop()` is polled before every evaluation and before every
///   (cubic-cost) GP refit — once true, the best-so-far is returned
///   immediately. Pass `|| false` for an uninterruptible run.
pub fn minimize<S, F, P>(
    mut sample_candidate: S,
    mut objective: F,
    mut should_stop: P,
    opts: &BoOptions,
    rng: &mut Pcg32,
) -> BoResult
where
    S: FnMut(&mut Pcg32) -> Vec<f64>,
    F: FnMut(&[f64]) -> f64,
    P: FnMut() -> bool,
{
    assert!(opts.n_init >= 2 && opts.budget >= opts.n_init);
    // a huge budget with an early stop must not reserve gigabytes up front
    let cap = opts.budget.min(65_536);
    let mut xs: Vec<Vec<f64>> = Vec::with_capacity(cap);
    let mut ys: Vec<f64> = Vec::with_capacity(cap);
    let mut history = Vec::with_capacity(cap);

    for _ in 0..opts.n_init {
        if should_stop() {
            break;
        }
        let x = sample_candidate(rng);
        let y = objective(&x);
        xs.push(x);
        ys.push(y);
        history.push(ys.iter().cloned().fold(f64::INFINITY, f64::min));
    }

    while xs.len() < opts.budget && !should_stop() {
        // standardize targets for GP conditioning
        let mean = ys.iter().sum::<f64>() / ys.len() as f64;
        let std = (ys.iter().map(|y| (y - mean) * (y - mean)).sum::<f64>() / ys.len() as f64)
            .sqrt()
            .max(1e-9);
        let ys_std: Vec<f64> = ys.iter().map(|y| (y - mean) / std).collect();
        let best_std = ys_std.iter().cloned().fold(f64::INFINITY, f64::min);

        let next = match Gp::fit(xs.clone(), &ys_std, opts.lengthscale, 1.0, opts.noise) {
            Some(gp) => {
                let mut best_cand = sample_candidate(rng);
                let mut best_ei = gp.expected_improvement(&best_cand, best_std);
                for _ in 1..opts.pool {
                    let c = sample_candidate(rng);
                    let ei = gp.expected_improvement(&c, best_std);
                    if ei > best_ei {
                        best_ei = ei;
                        best_cand = c;
                    }
                }
                best_cand
            }
            None => sample_candidate(rng), // singular kernel: fall back to random
        };
        let y = objective(&next);
        xs.push(next);
        ys.push(y);
        history.push(ys.iter().cloned().fold(f64::INFINITY, f64::min));
    }

    // stopped before the first evaluation: an empty (but well-formed) result
    let Some((bi, by)) = ys
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, y)| (i, *y))
    else {
        return BoResult { best_x: Vec::new(), best_y: f64::INFINITY, evals: 0, history };
    };
    BoResult { best_x: xs[bi].clone(), best_y: by, evals: ys.len(), history }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn beats_random_on_smooth_objective() {
        // minimize ‖x − 0.7·1‖² over [0,1]^4
        let target = [0.7; 4];
        let obj = |x: &[f64]| -> f64 {
            x.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum()
        };
        let opts = BoOptions { n_init: 8, budget: 40, pool: 128, ..Default::default() };

        let mut bo_best = Vec::new();
        let mut rnd_best = Vec::new();
        for seed in 0..5 {
            let mut rng = Pcg32::seeded(seed);
            let res = minimize(
                |r: &mut Pcg32| (0..4).map(|_| r.f64()).collect(),
                obj,
                || false,
                &opts,
                &mut rng,
            );
            bo_best.push(res.best_y);
            let mut rng2 = Pcg32::seeded(seed + 100);
            let best_rand = (0..opts.budget)
                .map(|_| {
                    let x: Vec<f64> = (0..4).map(|_| rng2.f64()).collect();
                    obj(&x)
                })
                .fold(f64::INFINITY, f64::min);
            rnd_best.push(best_rand);
        }
        let bo_avg: f64 = bo_best.iter().sum::<f64>() / 5.0;
        let rnd_avg: f64 = rnd_best.iter().sum::<f64>() / 5.0;
        assert!(bo_avg < rnd_avg, "BO {bo_avg} should beat random {rnd_avg}");
    }

    #[test]
    fn history_is_monotone_nonincreasing() {
        let mut rng = Pcg32::seeded(1);
        let res = minimize(
            |r: &mut Pcg32| vec![r.f64()],
            |x| (x[0] - 0.3).abs(),
            || false,
            &BoOptions { n_init: 4, budget: 20, pool: 32, ..Default::default() },
            &mut rng,
        );
        assert_eq!(res.history.len(), 20);
        for w in res.history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
        assert_eq!(res.evals, 20);
    }

    #[test]
    fn stop_hook_returns_best_so_far() {
        let mut rng = Pcg32::seeded(7);
        let evals = std::cell::Cell::new(0usize);
        let res = minimize(
            |r: &mut Pcg32| vec![r.f64()],
            |x| {
                evals.set(evals.get() + 1);
                (x[0] - 0.5).abs()
            },
            || evals.get() >= 6, // stop mid-run, after the init phase
            &BoOptions { n_init: 4, budget: 50, pool: 16, ..Default::default() },
            &mut rng,
        );
        assert!(res.evals >= 6 && res.evals < 50, "evals {}", res.evals);
        assert!(res.best_y.is_finite());
        assert!(!res.best_x.is_empty());
    }

    #[test]
    fn immediate_stop_yields_empty_result() {
        let mut rng = Pcg32::seeded(8);
        let res = minimize(
            |r: &mut Pcg32| vec![r.f64()],
            |_| 0.0,
            || true,
            &BoOptions { n_init: 2, budget: 4, pool: 4, ..Default::default() },
            &mut rng,
        );
        assert_eq!(res.evals, 0);
        assert!(res.best_x.is_empty());
        assert_eq!(res.best_y, f64::INFINITY);
    }
}
