//! Every optimization baseline the paper compares against (Tables III/IV,
//! Figs 16/17/22): random search, GP-based Bayesian optimization (vanilla +
//! VAESA-style latent), gradient descent (vanilla/DOSA-style + Polaris-style
//! latent, plus finite-difference GD on the real simulator), and the fixed
//! accelerator architectures of Table VI. The learned baselines (GANDSE,
//! AIRCHITECT v1/v2, the differentiable surrogate) live in the AOT
//! artifacts and are driven through [`crate::models::DiffAxE`].

pub mod bo;
pub mod fixed;
pub mod gd;
pub mod gp;
pub mod random;

pub use bo::{BoOptions, BoResult};
pub use fixed::FixedArch;
pub use gd::{GdOptions, GdResult};
pub use gp::Gp;
