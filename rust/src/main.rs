//! `diffaxe` — leader binary: dataset generation, DSE experiments and the
//! generation service. Run with no arguments for usage.

use anyhow::Result;
use diffaxe::cli::Args;

const USAGE: &str = "\
diffaxe <subcommand> [options]

subcommands:
  gen-dataset   enumerate the training design space, simulate labels and
                write artifacts/dataset/ (--workloads N --configs N --seed S
                --out DIR; DIFFAXE_SCALE=paper|quick overrides defaults)
  sim           simulate one configuration on one GEMM
                (--r --c --ip-kb --wt-kb --op-kb --bw --order --m --k --n)
  search        run one DSE search through the unified Optimizer API
                (--objective runtime|min-edp|max-perf --m --k --n
                [--target-cycles T] --optimizer NAME --evals N [--per-class N]
                [--seed S] [--top N] [--artifacts DIR]; engine-backed
                optimizers need the AOT artifacts, the rest run standalone)
";

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("gen-dataset") => cmd_gen_dataset(&args),
        Some("sim") => cmd_sim(&args),
        Some("search") => cmd_search(&args),
        _ => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
}

fn cmd_search(args: &Args) -> Result<()> {
    use diffaxe::dse::{Budget, Objective, OptimizerKind, Session};
    use diffaxe::models::DiffAxE;
    use diffaxe::workload::Gemm;
    let g = Gemm::new(
        args.get_u64("m", 128)? as u32,
        args.get_u64("k", 768)? as u32,
        args.get_u64("n", 2304)? as u32,
    );
    let objective = match args.get_str("objective", "min-edp") {
        "runtime" => Objective::Runtime {
            g,
            target_cycles: args.get_f64("target-cycles", 1e6)?,
        },
        "min-edp" => Objective::MinEdp { g },
        "max-perf" => Objective::MaxPerf { g },
        other => anyhow::bail!("unknown objective {other:?} (runtime|min-edp|max-perf)"),
    };
    let name = args.get_str("optimizer", "random");
    let kind = OptimizerKind::parse(name)
        .ok_or_else(|| anyhow::anyhow!("unknown optimizer {name:?}"))?;
    let mut budget = Budget::evals(args.get_usize("evals", 256)?);
    if let Some(pc) = args.get("per-class") {
        budget = budget.with_per_class(pc.parse()?);
    }
    let dir = std::path::PathBuf::from(args.get_str("artifacts", "artifacts"));
    let mut session = if kind.needs_engine() {
        anyhow::ensure!(
            DiffAxE::artifacts_present(&dir),
            "optimizer {name:?} needs the AOT artifacts — run `make artifacts`"
        );
        Session::load(&dir)?
    } else if DiffAxE::artifacts_present(&dir) {
        Session::load(&dir)?
    } else {
        Session::simulator_only()
    };
    let out = session.search(kind, &objective, &budget, args.get_u64("seed", 1)?)?;
    println!(
        "{}: {} evaluations in {:.2}s on {objective}",
        out.optimizer, out.evals, out.search_time_s
    );
    for (i, d) in out.ranked.iter().take(args.get_usize("top", 5)?).enumerate() {
        println!(
            "#{:<2} {}  cycles={:.3e} power={:.2}W edp={:.3e} score={:.4}",
            i + 1,
            d.hw,
            d.cycles,
            d.power_w,
            d.edp,
            objective.score_report(d)
        );
    }
    Ok(())
}

fn cmd_gen_dataset(args: &Args) -> Result<()> {
    use diffaxe::dataset::{Dataset, GenConfig};
    let mut cfg = GenConfig::from_env();
    cfg.n_workloads = args.get_usize("workloads", cfg.n_workloads)?;
    cfg.n_configs_per_workload = args.get_usize("configs", cfg.n_configs_per_workload)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    let out = std::path::PathBuf::from(args.get_str("out", "artifacts/dataset"));
    let t = diffaxe::util::stats::Timer::start();
    let ds = Dataset::generate(&cfg);
    ds.save(&out)?;
    println!(
        "gen-dataset: {} workloads x {} configs = {} rows -> {} ({:.1}s)",
        cfg.n_workloads,
        cfg.n_configs_per_workload,
        ds.n_rows(),
        out.display(),
        t.elapsed_s()
    );
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    use diffaxe::design_space::{HwConfig, LoopOrder};
    use diffaxe::energy::{asic, fpga};
    use diffaxe::sim::simulate;
    use diffaxe::workload::Gemm;
    let order = LoopOrder::from_name(args.get_str("order", "mnk"))
        .ok_or_else(|| anyhow::anyhow!("unknown loop order"))?;
    let hw = HwConfig::new_kb(
        args.get_u64("r", 32)? as u32,
        args.get_u64("c", 32)? as u32,
        args.get_f64("ip-kb", 128.0)?,
        args.get_f64("wt-kb", 128.0)?,
        args.get_f64("op-kb", 32.0)?,
        args.get_u64("bw", 16)? as u32,
        order,
    );
    let g = Gemm::new(
        args.get_u64("m", 128)? as u32,
        args.get_u64("k", 768)? as u32,
        args.get_u64("n", 768)? as u32,
    );
    let sim = simulate(&hw, &g);
    let e = asic::evaluate(&hw, &sim);
    let f = fpga::evaluate(&hw, &sim);
    println!("hw: {hw}\nworkload: {g}");
    println!(
        "cycles={} (compute={} mem={}) util={:.3} dram_bytes={}",
        sim.cycles,
        sim.compute_cycles,
        sim.mem_cycles,
        sim.utilization(),
        sim.dram.total()
    );
    println!("asic: power={:.3}W energy={:.1}uJ edp={:.3e}", e.power_w, e.total_uj(), e.edp);
    println!("fpga: power={:.3}W edp={:.3e} resources={:?}", f.power_w, f.edp, fpga::resources(&hw));
    Ok(())
}
