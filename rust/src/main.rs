//! `diffaxe` — leader binary: dataset generation, DSE experiments and the
//! generation service. Run with no arguments for usage.

use anyhow::Result;
use diffaxe::cli::Args;

const USAGE: &str = "\
diffaxe <subcommand> [options]

subcommands:
  gen-dataset   enumerate the training design space, simulate labels and
                write artifacts/dataset/ (--workloads N --configs N --seed S
                --out DIR; DIFFAXE_SCALE=paper|quick overrides defaults)
  sim           simulate one configuration on one GEMM
                (--r --c --ip-kb --wt-kb --op-kb --bw --order --m --k --n)
  search        run one DSE search through the unified Optimizer API
                (--objective runtime|min-edp|max-perf --m --k --n
                [--target-cycles T] --optimizer NAME --evals N [--per-class N]
                [--wall-clock S] [--seed S] [--top N] [--artifacts DIR];
                engine-backed optimizers need the AOT artifacts, the rest
                run standalone)
  structured    run a structured DSE search: per-layer-segment heterogeneous
                sub-configs over a shared accelerator budget (O(10^17) space)
                (--model bert-base|opt-350m|llama-2-7b --stage prefill|decode
                --seq N --platform asic|fpga --segments S --objective edp|perf
                [--pe N] [--buf-kb K] [--bw B] --optimizer NAME --evals N
                [--seed S] [--top N] [--artifacts DIR] [--mock]; without
                artifacts the engine kinds run on the hermetic mock engine)
  serve         start the DSE service + TCP front end
                (--artifacts DIR --addr 127.0.0.1:7979 --seed S
                [--workers N] [--max-queued N] [--max-attempts N]
                [--drain-s S] [--fault-plan SPEC]; N engine workers share
                one eval cache behind work-stealing dispatch, default =
                available cores capped; SPEC injects deterministic faults
                for chaos testing, e.g. \"engine-sample:panic@3\" — see
                src/util/fault.rs)
  submit        submit a search job to a running server, print its job id
                (search options plus --addr; add --watch to stream it)
  watch         stream a job's progress events until its terminal outcome
                (--addr --job ID)
  cancel        cancel a job; a started search keeps its partial outcome
                (--addr --job ID)
  jobs          list the server's retained jobs (--addr)
  bench-history accumulate per-commit throughput points from bench snapshot
                JSONs into a committed history stream, gate CI on
                regressions and render the trajectory page
                (--history benchmarks/history.json
                [--eval-core BENCH_eval_core.json]
                [--structured BENCH_structured.json]
                [--fleet BENCH_fleet.json]
                [--check] [--append] [--html FILE] [--tolerance 0.15]
                [--commit SHA] [--message MSG] [--timestamp TS])
  lint          check the source tree against the repo's concurrency and
                determinism invariants (docs/INVARIANTS.md); exits non-zero
                on violations ([--root DIR] [--json])
";

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("gen-dataset") => cmd_gen_dataset(&args),
        Some("sim") => cmd_sim(&args),
        Some("search") => cmd_search(&args),
        Some("structured") => cmd_structured(&args),
        Some("serve") => cmd_serve(&args),
        Some("submit") => cmd_submit(&args),
        Some("watch") => cmd_watch(&args),
        Some("cancel") => cmd_cancel(&args),
        Some("jobs") => cmd_jobs(&args),
        Some("bench-history") => cmd_bench_history(&args),
        Some("lint") => cmd_lint(&args),
        _ => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
}

/// Build the (objective, budget, optimizer) triple shared by the local
/// `search` runner and the remote `submit` client.
fn parse_search_request(args: &Args) -> Result<diffaxe::coordinator::SearchRequest> {
    use diffaxe::coordinator::SearchRequest;
    use diffaxe::dse::{Budget, Objective, OptimizerKind};
    use diffaxe::workload::Gemm;
    let g = Gemm::new(
        args.get_u64("m", 128)? as u32,
        args.get_u64("k", 768)? as u32,
        args.get_u64("n", 2304)? as u32,
    );
    let objective = match args.get_str("objective", "min-edp") {
        "runtime" => {
            Objective::Runtime { g, target_cycles: args.get_f64("target-cycles", 1e6)? }
        }
        "min-edp" => Objective::MinEdp { g },
        "max-perf" => Objective::MaxPerf { g },
        other => anyhow::bail!("unknown objective {other:?} (runtime|min-edp|max-perf)"),
    };
    let name = args.get_str("optimizer", "random");
    let optimizer = OptimizerKind::parse(name)
        .ok_or_else(|| anyhow::anyhow!("unknown optimizer {name:?}"))?;
    let mut budget = Budget::evals(args.get_usize("evals", 256)?);
    if let Some(pc) = args.get("per-class") {
        budget = budget.with_per_class(pc.parse()?);
    }
    if let Some(w) = args.get("wall-clock") {
        budget = budget.with_wall_clock(w.parse()?);
    }
    let mut sr = SearchRequest::new(objective, budget, optimizer);
    if let Some(k) = args.get("top-k") {
        sr.top_k = Some(k.parse()?);
    }
    Ok(sr)
}

fn client(args: &Args) -> Result<diffaxe::coordinator::server::Client> {
    diffaxe::coordinator::server::Client::connect_str(args.get_str("addr", "127.0.0.1:7979"))
}

fn print_job(info: &diffaxe::coordinator::JobInfo) {
    println!(
        "{:<10} {:<10} {:<16} {:<28} evals={:<8} best={} t={:.2}s",
        info.id,
        info.state.name(),
        info.optimizer,
        info.objective,
        info.evals,
        info.best_score.map(|b| format!("{b:.4e}")).unwrap_or_else(|| "-".into()),
        info.elapsed_s
    );
}

fn cmd_serve(args: &Args) -> Result<()> {
    use diffaxe::coordinator::{server, Service, ServiceConfig};
    use diffaxe::models::DiffAxE;
    let dir = std::path::PathBuf::from(args.get_str("artifacts", "artifacts"));
    anyhow::ensure!(
        DiffAxE::artifacts_present(&dir),
        "artifacts/ missing — run `make artifacts` first"
    );
    let mut cfg = ServiceConfig::new(dir);
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.workers = args.get_usize("workers", cfg.workers)?;
    anyhow::ensure!(cfg.workers >= 1, "--workers must be at least 1");
    cfg.max_queued = args.get_usize("max-queued", cfg.max_queued)?;
    cfg.max_attempts = args.get_u64("max-attempts", cfg.max_attempts as u64)? as u32;
    cfg.drain_deadline =
        std::time::Duration::from_secs_f64(args.get_f64("drain-s", cfg.drain_deadline.as_secs_f64())?);
    if let Some(spec) = args.get("fault-plan") {
        let plan = diffaxe::util::fault::FaultPlan::parse(spec, cfg.seed)
            .map_err(|e| anyhow::anyhow!("bad --fault-plan: {e}"))?;
        cfg.fault_plan = Some(std::sync::Arc::new(plan));
    }
    let svc = Service::start(cfg)?;
    server::serve(svc.handle(), args.get_str("addr", "127.0.0.1:7979"))
}

fn cmd_submit(args: &Args) -> Result<()> {
    let sr = parse_search_request(args)?;
    let mut c = client(args)?;
    let job_id = c.submit(&sr)?;
    println!("{job_id}");
    if args.flag("watch") {
        watch_and_print(&mut c, &job_id)?;
    }
    Ok(())
}

fn cmd_watch(args: &Args) -> Result<()> {
    let job_id = args
        .get("job")
        .map(str::to_string)
        .or_else(|| args.positional().first().cloned())
        .ok_or_else(|| anyhow::anyhow!("watch needs --job ID"))?;
    let mut c = client(args)?;
    watch_and_print(&mut c, &job_id)
}

fn watch_and_print(c: &mut diffaxe::coordinator::server::Client, job_id: &str) -> Result<()> {
    use diffaxe::coordinator::Response;
    let terminal = c.watch(job_id, |ev| {
        let best = if ev.best_score.is_finite() {
            format!("{:.4e}", ev.best_score)
        } else {
            "-".into()
        };
        println!("event: evals={} best={} t={:.2}s", ev.evals, best, ev.elapsed_s);
    })?;
    match terminal {
        Response::JobOutcome { job_id, outcome } => {
            println!(
                "{job_id} {}: {} evals in {:.2}s ({})",
                outcome.optimizer,
                outcome.evals,
                outcome.search_time_s,
                outcome.stopped.name()
            );
            if let Some(d) = outcome.best() {
                println!(
                    "best: {} cycles={:.3e} power={:.2}W edp={:.3e}",
                    d.hw, d.cycles, d.power_w, d.edp
                );
            }
        }
        other => println!("terminal: {other:?}"),
    }
    Ok(())
}

fn cmd_cancel(args: &Args) -> Result<()> {
    let job_id = args
        .get("job")
        .map(str::to_string)
        .or_else(|| args.positional().first().cloned())
        .ok_or_else(|| anyhow::anyhow!("cancel needs --job ID"))?;
    let info = client(args)?.cancel(&job_id)?;
    print_job(&info);
    Ok(())
}

fn cmd_jobs(args: &Args) -> Result<()> {
    for info in client(args)?.jobs()? {
        print_job(&info);
    }
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    use diffaxe::dse::{Session, StopReason};
    use diffaxe::models::DiffAxE;
    let sr = parse_search_request(args)?;
    let (kind, objective, budget) = (sr.optimizer, sr.objective, sr.budget);
    let dir = std::path::PathBuf::from(args.get_str("artifacts", "artifacts"));
    let mut session = if kind.needs_engine() {
        anyhow::ensure!(
            DiffAxE::artifacts_present(&dir),
            "optimizer {:?} needs the AOT artifacts — run `make artifacts`",
            kind.name()
        );
        Session::load(&dir)?
    } else if DiffAxE::artifacts_present(&dir) {
        Session::load(&dir)?
    } else {
        Session::simulator_only()
    };
    let out = session.search(kind, &objective, &budget, args.get_u64("seed", 1)?)?;
    println!(
        "{}: {} evaluations in {:.2}s on {objective}{}",
        out.optimizer,
        out.evals,
        out.search_time_s,
        if out.stopped == StopReason::Completed {
            String::new()
        } else {
            format!(" [{}]", out.stopped.name())
        }
    );
    for (i, d) in out.ranked.iter().take(args.get_usize("top", 5)?).enumerate() {
        println!(
            "#{:<2} {}  cycles={:.3e} power={:.2}W edp={:.3e} score={:.4}",
            i + 1,
            d.hw,
            d.cycles,
            d.power_w,
            d.edp,
            objective.score_report(d)
        );
    }
    Ok(())
}

fn cmd_structured(args: &Args) -> Result<()> {
    use diffaxe::design_space::SharedBudget;
    use diffaxe::dse::llm::Platform;
    use diffaxe::dse::{Budget, Objective, OptimizerKind, Session, StopReason, StructuredSpec};
    use diffaxe::models::DiffAxE;
    use diffaxe::workload::{llm::DEFAULT_SEQ, LlmModel, Stage};
    let model_name = args.get_str("model", "bert-base");
    let model = LlmModel::from_name(model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model_name:?}"))?;
    let stage_name = args.get_str("stage", "prefill");
    let stage = Stage::from_name(stage_name)
        .ok_or_else(|| anyhow::anyhow!("unknown stage {stage_name:?}"))?;
    let platform_name = args.get_str("platform", "asic");
    let platform = Platform::from_name(platform_name)
        .ok_or_else(|| anyhow::anyhow!("unknown platform {platform_name:?}"))?;
    let u32_arg = |name: &str, default: u32| -> Result<u32> {
        u32::try_from(args.get_u64(name, default as u64)?)
            .map_err(|_| anyhow::anyhow!("--{name} out of range"))
    };
    let defaults = SharedBudget::default();
    let budget = SharedBudget {
        pe: u32_arg("pe", defaults.pe)?,
        buf_b: match args.get("buf-kb") {
            Some(kb) => (kb.parse::<f64>()? * 1024.0).round() as u64,
            None => defaults.buf_b,
        },
        bw: u32_arg("bw", defaults.bw)?,
    };
    let spec = StructuredSpec {
        model,
        stage,
        seq: u32_arg("seq", DEFAULT_SEQ)?,
        platform,
        segments: u32_arg("segments", 3)?,
        budget,
    };
    spec.validate().map_err(|e| anyhow::anyhow!("invalid spec: {e}"))?;
    let objective = match args.get_str("objective", "edp") {
        "edp" => Objective::StructuredEdp { spec },
        "perf" => Objective::StructuredPerf { spec },
        other => anyhow::bail!("unknown structured objective {other:?} (edp|perf)"),
    };
    let name = args.get_str("optimizer", "random");
    let kind = OptimizerKind::parse(name)
        .ok_or_else(|| anyhow::anyhow!("unknown optimizer {name:?}"))?;
    anyhow::ensure!(
        kind.supports(&objective),
        "optimizer {:?} does not serve structured objectives",
        kind.name()
    );
    let dir = std::path::PathBuf::from(args.get_str("artifacts", "artifacts"));
    let mut session = if !args.flag("mock") && DiffAxE::artifacts_present(&dir) {
        Session::load(&dir)?
    } else {
        Session::mock()
    };
    if kind.needs_engine() && session.engine().is_some_and(|e| e.is_mock()) {
        eprintln!("note: running on the hermetic mock engine (no artifacts)");
    }
    let out = session.search(
        kind,
        &objective,
        &Budget::evals(args.get_usize("evals", 256)?),
        args.get_u64("seed", 1)?,
    )?;
    println!(
        "{}: {} evaluations in {:.2}s on {objective} (space ~{:.2e} points){}",
        out.optimizer,
        out.evals,
        out.search_time_s,
        spec.cardinality(),
        if out.stopped == StopReason::Completed {
            String::new()
        } else {
            format!(" [{}]", out.stopped.name())
        }
    );
    for (i, d) in out.ranked.iter().take(args.get_usize("top", 3)?).enumerate() {
        println!(
            "#{:<2} envelope {}  cycles={:.3e} power={:.2}W edp={:.3e}",
            i + 1,
            d.hw,
            d.cycles,
            d.power_w,
            d.edp
        );
        if let Some(cuts) = out.boundaries.get(i) {
            if !cuts.is_empty() {
                println!("    learned cuts: {cuts:?}");
            }
        }
        if let Some(segs) = out.segments.get(i) {
            for (si, s) in segs.iter().enumerate() {
                println!("    segment {si}: {s}");
            }
        }
    }
    Ok(())
}

fn cmd_gen_dataset(args: &Args) -> Result<()> {
    use diffaxe::dataset::{Dataset, GenConfig};
    let mut cfg = GenConfig::from_env();
    cfg.n_workloads = args.get_usize("workloads", cfg.n_workloads)?;
    cfg.n_configs_per_workload = args.get_usize("configs", cfg.n_configs_per_workload)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    let out = std::path::PathBuf::from(args.get_str("out", "artifacts/dataset"));
    let t = diffaxe::util::stats::Timer::start();
    let ds = Dataset::generate(&cfg);
    ds.save(&out)?;
    println!(
        "gen-dataset: {} workloads x {} configs = {} rows -> {} ({:.1}s)",
        cfg.n_workloads,
        cfg.n_configs_per_workload,
        ds.n_rows(),
        out.display(),
        t.elapsed_s()
    );
    Ok(())
}

/// Accumulate bench-snapshot throughput points into the committed history
/// stream and/or gate on regressions against its last entry — the CI
/// enforcement of "`candidates/sec` only goes up" (ROADMAP item 3).
fn cmd_bench_history(args: &Args) -> Result<()> {
    use diffaxe::util::bench_history as hist;
    use diffaxe::util::json::Json;
    use std::path::Path;

    let history_path = args.get_str("history", "benchmarks/history.json").to_string();
    let tolerance = args.get_f64("tolerance", 0.15)?;
    let do_check = args.flag("check");
    let do_append = args.flag("append");
    let html_out = args.get("html").map(str::to_string);
    anyhow::ensure!(
        do_check || do_append || html_out.is_some(),
        "nothing to do: pass --check, --append and/or --html FILE"
    );

    // collect the current run's points from whichever snapshots exist
    // (--html alone renders the committed history and needs none)
    let mut points = Vec::new();
    for (source, flag, default) in [
        ("eval_core", "eval-core", "BENCH_eval_core.json"),
        ("structured", "structured", "BENCH_structured.json"),
        ("fleet", "fleet", "BENCH_fleet.json"),
    ] {
        let p = args.get_str(flag, default);
        match std::fs::read_to_string(p) {
            Ok(text) => {
                let snap = Json::parse(&text)
                    .map_err(|e| anyhow::anyhow!("parse bench snapshot {p}: {e:?}"))?;
                points.extend(hist::points_from_snapshot(source, &snap));
            }
            Err(_) => eprintln!("bench-history: snapshot {p} missing, skipping"),
        }
    }
    anyhow::ensure!(
        !points.is_empty() || (!do_check && !do_append),
        "no bench snapshots found — nothing to record"
    );

    let mut entries = hist::load(Path::new(&history_path)).map_err(|e| anyhow::anyhow!(e))?;
    if do_check {
        match entries.last() {
            None => println!("bench-history: empty history, nothing to gate against"),
            Some(last) => {
                let bad = hist::regressions(last, &points, tolerance);
                if bad.is_empty() {
                    println!(
                        "bench-history: {} throughput metrics within {:.0}% of the last entry",
                        points
                            .iter()
                            .filter(|p| p.unit == "candidates/sec")
                            .count(),
                        tolerance * 100.0
                    );
                } else {
                    for line in &bad {
                        eprintln!("bench-history REGRESSION: {line}");
                    }
                    anyhow::bail!("{} throughput regression(s) past tolerance", bad.len());
                }
            }
        }
    }
    if do_append {
        let now_s = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let commit = hist::CommitInfo {
            id: args.get_str("commit", "unknown").to_string(),
            message: args.get_str("message", "").to_string(),
            timestamp: args.get_str("timestamp", &now_s.to_string()).to_string(),
        };
        entries.push(hist::make_entry(&commit, now_s, &points));
        hist::store(Path::new(&history_path), &entries, now_s).map_err(|e| anyhow::anyhow!(e))?;
        println!(
            "bench-history: appended entry {} ({} points) -> {history_path} ({} total)",
            commit.id,
            points.len(),
            entries.len()
        );
    }
    if let Some(html_path) = html_out {
        // renders whatever `entries` holds now — after --append that
        // includes this run's point, so the page and the stored history
        // stay in lockstep
        std::fs::write(&html_path, hist::render_html(&entries))
            .map_err(|e| anyhow::anyhow!("write {html_path}: {e}"))?;
        println!("bench-history: rendered {} entries -> {html_path}", entries.len());
    }
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    use diffaxe::design_space::{HwConfig, LoopOrder};
    use diffaxe::energy::{asic, fpga};
    use diffaxe::sim::simulate;
    use diffaxe::workload::Gemm;
    let order = LoopOrder::from_name(args.get_str("order", "mnk"))
        .ok_or_else(|| anyhow::anyhow!("unknown loop order"))?;
    let hw = HwConfig::new_kb(
        args.get_u64("r", 32)? as u32,
        args.get_u64("c", 32)? as u32,
        args.get_f64("ip-kb", 128.0)?,
        args.get_f64("wt-kb", 128.0)?,
        args.get_f64("op-kb", 32.0)?,
        args.get_u64("bw", 16)? as u32,
        order,
    );
    let g = Gemm::new(
        args.get_u64("m", 128)? as u32,
        args.get_u64("k", 768)? as u32,
        args.get_u64("n", 768)? as u32,
    );
    let sim = simulate(&hw, &g);
    let e = asic::evaluate(&hw, &sim);
    let f = fpga::evaluate(&hw, &sim);
    println!("hw: {hw}\nworkload: {g}");
    println!(
        "cycles={} (compute={} mem={}) util={:.3} dram_bytes={}",
        sim.cycles,
        sim.compute_cycles,
        sim.mem_cycles,
        sim.utilization(),
        sim.dram.total()
    );
    println!("asic: power={:.3}W energy={:.1}uJ edp={:.3e}", e.power_w, e.total_uj(), e.edp);
    println!("fpga: power={:.3}W edp={:.3e} resources={:?}", f.power_w, f.edp, fpga::resources(&hw));
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    use diffaxe::util::lint;
    let root = std::path::PathBuf::from(args.get_str("root", "."));
    let diags = lint::lint_tree(&root)?;
    if args.flag("json") {
        println!("{}", lint::to_json(&diags));
    } else {
        for d in &diags {
            println!("{d}");
        }
    }
    if diags.is_empty() {
        if !args.flag("json") {
            eprintln!("diffaxe lint: clean ({} rules)", lint::RULES.len());
        }
        Ok(())
    } else {
        eprintln!("diffaxe lint: {} violation(s) — see docs/INVARIANTS.md", diags.len());
        std::process::exit(1);
    }
}
