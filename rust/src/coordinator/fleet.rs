//! The engine-worker fleet: N supervised worker slots behind one
//! `JobRegistry`, with least-loaded dispatch, back-end work stealing, a
//! fleet-wide admission budget, and one process-shared `EvalCache` handle
//! handed to every worker's `Session`.
//!
//! # Dispatch / steal ordering
//!
//! Admission routes each job to the *least-loaded live* slot (shortest
//! deque among slots that have not exhausted their restart budget). An
//! idle worker whose own deque is empty steals from the *back* of the
//! longest sibling deque — the opposite end from the victim's own
//! `pop_front` — so FIFO order is preserved for the victim and the two
//! workers never contend for the same message. Every deque draws from
//! one [`QueueBudget`], so `ServiceConfig::max_queued` bounds the total
//! queued work no matter how it is spread.
//!
//! # Failure containment
//!
//! Each slot keeps the PR-8 supervisor machinery (panic isolation,
//! bounded-backoff restart, in-flight retry) — see
//! `coordinator/supervisor.rs`. A slot that exhausts its restart budget
//! is marked dead and skipped by dispatch; the fleet rejects admissions
//! only when *every* slot is dead. A single worker crash therefore
//! degrades capacity, not availability.

use super::metrics::Metrics;
use super::protocol::{ErrorCode, Response};
use super::service::JobEntry;
use super::supervisor::{Msg, QueueBudget, Shared};
use crate::dse::eval::EvalCache;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Duration;

pub(crate) struct Fleet {
    slots: Vec<Arc<Shared>>,
    /// the one evaluation memo table every worker's `Session` runs
    /// through — tenants probing overlapping design regions hit each
    /// other's entries regardless of which worker serves them
    cache: Arc<EvalCache>,
    /// monotonically increasing engine spawn index, unique fleet-wide
    next_worker_idx: AtomicU32,
}

impl Fleet {
    /// Build `workers` slots sharing one admission budget of `max_queued`
    /// and one evaluation cache. Each slot's own deque is additionally
    /// capped at `max_queued`, so the single-slot fleet behaves exactly
    /// like the pre-fleet single queue.
    pub(crate) fn new(
        workers: usize,
        max_queued: usize,
        drain_deadline: Duration,
        cache: Arc<EvalCache>,
    ) -> Arc<Fleet> {
        let budget = QueueBudget::new(max_queued);
        let slots = (0..workers.max(1))
            .map(|_| Arc::new(Shared::with_budget(max_queued, drain_deadline, budget.clone())))
            .collect();
        Arc::new(Fleet { slots, cache, next_worker_idx: AtomicU32::new(0) })
    }

    pub(crate) fn size(&self) -> usize {
        self.slots.len()
    }

    pub(crate) fn slot(&self, i: usize) -> &Arc<Shared> {
        &self.slots[i]
    }

    /// A clone of the process-shared evaluation cache handle for a
    /// worker's `Session`.
    pub(crate) fn cache(&self) -> Arc<EvalCache> {
        self.cache.clone()
    }

    pub(crate) fn alloc_worker_idx(&self) -> u32 {
        self.next_worker_idx.fetch_add(1, Ordering::SeqCst)
    }

    /// Least-loaded dispatch: admit onto the shortest live slot's deque.
    /// Only when every slot has exhausted its restart budget does the
    /// fleet reject outright. Depth reads and the chosen slot's admission
    /// are not atomic with each other — a race can land two jobs on the
    /// same slot, which stealing then rebalances.
    pub(crate) fn admit(
        &self,
        metrics: &Metrics,
        submit: impl FnOnce() -> Arc<JobEntry>,
        reply: Option<Sender<Response>>,
    ) -> Result<Arc<JobEntry>, Response> {
        let mut best: Option<(usize, usize)> = None;
        for (i, s) in self.slots.iter().enumerate() {
            if s.is_dead() {
                continue;
            }
            let len = s.queue_len();
            let better = match best {
                None => true,
                Some((_, shortest)) => len < shortest,
            };
            if better {
                best = Some((i, len));
            }
        }
        match best {
            Some((i, _)) => self.slots[i].admit(metrics, submit, reply),
            None => Err(Response::error(
                ErrorCode::Internal,
                "engine worker unavailable (restart budget exhausted)",
            )),
        }
    }

    /// Work stealing: an idle `thief` slot takes from the *back* of the
    /// longest sibling deque. Returns `None` when no sibling has queued
    /// work (or the fleet is a single slot).
    pub(crate) fn steal(&self, thief: usize, metrics: &Metrics) -> Option<Msg> {
        let mut best: Option<(usize, usize)> = None;
        for (i, s) in self.slots.iter().enumerate() {
            if i == thief || s.is_dead() {
                continue;
            }
            let len = s.queue_len();
            let better = match best {
                None => len > 0,
                Some((_, longest)) => len > longest,
            };
            if better {
                best = Some((i, len));
            }
        }
        let (victim, _) = best?;
        let msg = self.slots[victim].steal_back();
        if msg.is_some() {
            metrics.steal();
        }
        msg
    }

    /// Close admissions on every slot and wake every worker.
    pub(crate) fn begin_stop(&self) {
        for s in &self.slots {
            s.begin_stop();
        }
    }

    pub(crate) fn set_drain_deadline(&self, d: Duration) {
        for s in &self.slots {
            s.set_drain_deadline(d);
        }
    }
}
