//! Newline-delimited-JSON TCP front end over the service.
//!
//! One line in = one [`Request`], one line out = one [`Response`]. A thread
//! per connection (DSE request rates are low; the engine thread is the
//! shared resource and does the batching).

use super::protocol::{ErrorCode, Request, Response};
use super::service::Handle;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

/// Serve forever on `addr` (e.g. "127.0.0.1:7979").
pub fn serve(handle: Handle, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!("diffaxe: serving on {addr}");
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                eprintln!("accept error: {e}");
                continue;
            }
        };
        let h = handle.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(h, stream) {
                eprintln!("connection error: {e:#}");
            }
        });
    }
    Ok(())
}

/// Bind an ephemeral port and return (listener thread spawner, addr) — used
/// by tests and the quickstart example.
pub fn serve_ephemeral(handle: Handle) -> Result<std::net::SocketAddr> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    std::thread::spawn(move || {
        for stream in listener.incoming().flatten() {
            let h = handle.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(h, stream);
            });
        }
    });
    Ok(addr)
}

fn handle_conn(handle: Handle, stream: TcpStream) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        // every decode failure — bad JSON, bad request, unsupported
        // version — answers with a structured error on the same
        // connection; the stream is never dropped mid-session
        let response = match Json::parse(&line) {
            Ok(j) => match Request::from_json(&j) {
                Ok(req) => handle.request(req),
                Err(e) => Response::error(e.code, e.message),
            },
            Err(e) => Response::error(ErrorCode::BadRequest, format!("bad json: {e}")),
        };
        writeln!(writer, "{}", response.to_json())?;
        writer.flush()?;
    }
    Ok(())
}

/// Minimal blocking client (examples + integration tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn request(&mut self, req: &Request) -> Result<Response> {
        self.send_line(&req.to_json().to_string())
    }

    /// Send one raw wire line (legacy-alias and compatibility testing).
    pub fn send_line(&mut self, line: &str) -> Result<Response> {
        writeln!(self.writer, "{line}")?;
        self.writer.flush()?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        let j = Json::parse(&reply).context("parsing response")?;
        Response::from_json(&j)
    }
}
