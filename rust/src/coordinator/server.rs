//! Newline-delimited-JSON TCP front end over the service.
//!
//! One line in = one [`Request`]; most requests answer one line. The v3
//! `watch` request instead **streams**: progress `event` lines as the job
//! advances, then one terminal `outcome` line — after which the same
//! connection keeps serving requests. Event delivery is backpressured by
//! the job's single coalescing slot (drop-to-latest): a watcher stalled in
//! a TCP write never queues unbounded events, it just skips intermediate
//! heartbeats.
//!
//! A thread per connection (DSE request rates are low; the engine thread
//! is the shared resource and does the batching), capped by a counting
//! semaphore so a connection flood cannot spawn unboundedly — excess
//! connections wait in the accept loop until a slot frees.

use super::protocol::{ErrorCode, JobInfo, Request, Response};
use super::service::Handle;
use crate::dse::api::SearchEvent;
use crate::util::json::Json;
use crate::util::sync::{rank, TrackedMutex};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Condvar};

/// Maximum concurrently-served connections.
pub const MAX_CONNECTIONS: usize = 256;

/// Minimal counting semaphore (std has none): `acquire` blocks while no
/// permit is free; the returned guard releases on drop.
struct Semaphore {
    permits: TrackedMutex<usize>,
    cv: Condvar,
}

impl Semaphore {
    fn new(n: usize) -> Arc<Semaphore> {
        Arc::new(Semaphore {
            permits: TrackedMutex::new("server.semaphore", rank::CONN_SEMAPHORE, n),
            cv: Condvar::new(),
        })
    }

    fn acquire(self: &Arc<Semaphore>) -> Permit {
        let mut p = self.permits.lock();
        while *p == 0 {
            p = p.wait(&self.cv);
        }
        *p -= 1;
        Permit(self.clone())
    }
}

struct Permit(Arc<Semaphore>);

impl Drop for Permit {
    fn drop(&mut self) {
        *self.0.permits.lock() += 1;
        self.0.cv.notify_one();
    }
}

/// The shared accept loop: one handler thread per connection, capped at
/// [`MAX_CONNECTIONS`] by the semaphore ([`serve`] and [`serve_ephemeral`]
/// differ only in who owns the listener thread).
fn accept_loop(listener: TcpListener, handle: Handle) {
    let sem = Semaphore::new(MAX_CONNECTIONS);
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                eprintln!("accept error: {e}");
                continue;
            }
        };
        // blocks the accept loop when saturated: the flood waits in the
        // kernel backlog instead of becoming threads
        let permit = sem.acquire();
        let h = handle.clone();
        std::thread::spawn(move || {
            let _permit = permit;
            if let Err(e) = handle_conn(h, stream) {
                eprintln!("connection error: {e:#}");
            }
        });
    }
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7979").
pub fn serve(handle: Handle, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!("diffaxe: serving on {addr}");
    accept_loop(listener, handle);
    Ok(())
}

/// Bind an ephemeral port, serve on a background thread, return the addr —
/// used by tests and the quickstart example.
pub fn serve_ephemeral(handle: Handle) -> Result<std::net::SocketAddr> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    std::thread::spawn(move || accept_loop(listener, handle));
    Ok(addr)
}

fn handle_conn(handle: Handle, stream: TcpStream) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        // every decode failure — bad JSON, bad request, unsupported
        // version — answers with a structured error on the same
        // connection; the stream is never dropped mid-session
        match Json::parse(&line).map_err(|e| (ErrorCode::BadRequest, format!("bad json: {e}")))
            .and_then(|j| Request::from_json(&j).map_err(|e| (e.code, e.message)))
        {
            Ok(Request::Watch { job_id }) => stream_job(&handle, &mut writer, &job_id)?,
            Ok(req) => write_line(&mut writer, &handle.request(req))?,
            Err((code, message)) => write_line(&mut writer, &Response::error(code, message))?,
        }
    }
    Ok(())
}

fn write_line(writer: &mut TcpStream, resp: &Response) -> Result<()> {
    writeln!(writer, "{}", resp.to_json())?;
    writer.flush()?;
    Ok(())
}

/// Stream one job over the connection: `event` lines as the coalescing
/// slot refreshes, then the terminal `outcome` (or stored error) line.
/// Guarantees at least one `event` line before a successful terminal, so
/// a watcher always observes progress shape even on instant jobs.
fn stream_job(handle: &Handle, writer: &mut TcpStream, job_id: &str) -> Result<()> {
    let Some(entry) = handle.registry().get(job_id) else {
        let err = Response::error(ErrorCode::BadRequest, format!("unknown job {job_id:?}"));
        return write_line(writer, &err);
    };
    let mut seq = 0u64;
    let mut events_sent = 0usize;
    loop {
        let (new_seq, ev, terminal) = entry.next_event(seq);
        seq = new_seq;
        if let Some(event) = ev {
            write_line(writer, &Response::Event { job_id: job_id.to_string(), event })?;
            events_sent += 1;
        }
        if let Some((_state, result)) = terminal {
            match result {
                Response::Outcome(outcome) => {
                    if events_sent == 0 {
                        // instant job: synthesize the one guaranteed event
                        let best = outcome.best_score();
                        write_line(
                            writer,
                            &Response::Event {
                                job_id: job_id.to_string(),
                                event: SearchEvent {
                                    evals: outcome.evals,
                                    best_score: best,
                                    elapsed_s: outcome.search_time_s,
                                },
                            },
                        )?;
                    }
                    write_line(
                        writer,
                        &Response::JobOutcome { job_id: job_id.to_string(), outcome },
                    )?;
                }
                other => write_line(writer, &other)?,
            }
            return Ok(());
        }
    }
}

/// Minimal blocking client (examples + integration tests + CLI).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Connect to a `host:port` string (CLI convenience).
    pub fn connect_str(addr: &str) -> Result<Client> {
        use std::net::ToSocketAddrs;
        let resolved = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {addr}"))?
            .next()
            .with_context(|| format!("no address for {addr}"))?;
        Client::connect(&resolved)
    }

    pub fn request(&mut self, req: &Request) -> Result<Response> {
        self.send_line(&req.to_json().to_string())
    }

    /// Send one raw wire line (legacy-alias and compatibility testing).
    pub fn send_line(&mut self, line: &str) -> Result<Response> {
        writeln!(self.writer, "{line}")?;
        self.writer.flush()?;
        self.read_response()
    }

    fn read_response(&mut self) -> Result<Response> {
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        let j = Json::parse(&reply).context("parsing response")?;
        Response::from_json(&j)
    }

    /// v3: submit a search job, returning its id.
    pub fn submit(&mut self, sr: &super::protocol::SearchRequest) -> Result<String> {
        match self.request(&Request::Submit(sr.clone()))? {
            Response::Submitted { job_id, .. } => Ok(job_id),
            Response::Error { code, message, .. } => bail!("submit failed: {}: {message}", code.name()),
            other => bail!("unexpected submit response {other:?}"),
        }
    }

    /// v3: one job's status.
    pub fn status(&mut self, job_id: &str) -> Result<JobInfo> {
        match self.request(&Request::Status { job_id: job_id.to_string() })? {
            Response::Job(info) => Ok(info),
            Response::Error { code, message, .. } => bail!("status failed: {}: {message}", code.name()),
            other => bail!("unexpected status response {other:?}"),
        }
    }

    /// v3: cancel a job (the post-cancel status comes back).
    pub fn cancel(&mut self, job_id: &str) -> Result<JobInfo> {
        match self.request(&Request::Cancel { job_id: job_id.to_string() })? {
            Response::Job(info) => Ok(info),
            Response::Error { code, message, .. } => bail!("cancel failed: {}: {message}", code.name()),
            other => bail!("unexpected cancel response {other:?}"),
        }
    }

    /// v3: every retained job.
    pub fn jobs(&mut self) -> Result<Vec<JobInfo>> {
        match self.request(&Request::Jobs)? {
            Response::Jobs(infos) => Ok(infos),
            other => bail!("unexpected jobs response {other:?}"),
        }
    }

    /// v3: stream a job — `on_event` sees every delivered heartbeat; the
    /// terminal line ([`Response::JobOutcome`] or an error) is returned.
    pub fn watch(
        &mut self,
        job_id: &str,
        mut on_event: impl FnMut(&SearchEvent),
    ) -> Result<Response> {
        writeln!(self.writer, "{}", Request::Watch { job_id: job_id.to_string() }.to_json())?;
        self.writer.flush()?;
        loop {
            match self.read_response()? {
                Response::Event { event, .. } => on_event(&event),
                terminal => return Ok(terminal),
            }
        }
    }
}
