//! Newline-delimited-JSON TCP front end over the service.
//!
//! One line in = one [`Request`]; most requests answer one line. The v3
//! `watch` request instead **streams**: progress `event` lines as the job
//! advances, then one terminal `outcome` line — after which the same
//! connection keeps serving requests. Event delivery is backpressured by
//! the job's single coalescing slot (drop-to-latest): a watcher stalled in
//! a TCP write never queues unbounded events, it just skips intermediate
//! heartbeats.
//!
//! # Threading
//!
//! Request/response traffic is thread-per-connection (DSE request rates
//! are low; the engine fleet is the shared resource and does the
//! batching), capped by a counting semaphore so a connection flood cannot
//! spawn unboundedly — excess connections wait in the accept loop until a
//! slot frees.
//!
//! `watch` streaming does **not** hold a thread per watcher: the
//! connection (socket, connection permit, and any request bytes its
//! reader had already buffered) is handed to a single poll-based
//! [`Reactor`] event thread. The reactor polls every watched job's
//! coalescing slot on a short cadence and writes event lines through
//! nonblocking sockets — a stalled watcher leaves bytes queued in its own
//! subscription, never blocks the event thread, and never blocks other
//! watchers. When a job's terminal line flushes, the connection resumes
//! normal request service on a fresh handler thread (carried-over bytes
//! are replayed first, so pipelined requests survive the round trip).

use super::protocol::{ErrorCode, JobInfo, Request, Response};
use super::service::{Handle, JobEntry};
use crate::dse::api::SearchEvent;
use crate::util::json::Json;
use crate::util::sync::{rank, TrackedMutex};
use anyhow::{bail, Context, Result};
use std::io::{self, BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Condvar};
use std::time::Duration;

/// Maximum concurrently-served connections.
pub const MAX_CONNECTIONS: usize = 256;

/// How often the reactor's event thread polls watched jobs and retries
/// stalled writes. Progress events are coalesced drop-to-latest, so a
/// short fixed cadence loses nothing.
const POLL_INTERVAL: Duration = Duration::from_millis(5);

/// Minimal counting semaphore (std has none): `acquire` blocks while no
/// permit is free; the returned guard releases on drop.
struct Semaphore {
    permits: TrackedMutex<usize>,
    cv: Condvar,
}

impl Semaphore {
    fn new(n: usize) -> Arc<Semaphore> {
        Arc::new(Semaphore {
            permits: TrackedMutex::new("server.semaphore", rank::CONN_SEMAPHORE, n),
            cv: Condvar::new(),
        })
    }

    fn acquire(self: &Arc<Semaphore>) -> Permit {
        let mut p = self.permits.lock();
        while *p == 0 {
            p = p.wait(&self.cv);
        }
        *p -= 1;
        Permit(self.clone())
    }
}

struct Permit(Arc<Semaphore>);

impl Drop for Permit {
    fn drop(&mut self) {
        *self.0.permits.lock() += 1;
        self.0.cv.notify_one();
    }
}

/// Line source for one connection: drains carried-over bytes (request
/// data a previous handler had buffered past a `watch` line) before
/// touching the socket, and can surrender everything it has buffered when
/// the connection is handed to the reactor.
struct ConnReader {
    carry: Vec<u8>,
    reader: BufReader<TcpStream>,
}

impl ConnReader {
    fn read_line(&mut self, line: &mut String) -> io::Result<usize> {
        if !self.carry.is_empty() {
            if let Some(pos) = self.carry.iter().position(|&b| b == b'\n') {
                let rest = self.carry.split_off(pos + 1);
                let taken = std::mem::replace(&mut self.carry, rest);
                line.push_str(&String::from_utf8_lossy(&taken));
                return Ok(taken.len());
            }
            // partial carried line: splice the socket's continuation on
            let head = String::from_utf8_lossy(&self.carry).into_owned();
            self.carry.clear();
            line.push_str(&head);
            let n = self.reader.read_line(line)?;
            return Ok(head.len() + n);
        }
        self.reader.read_line(line)
    }

    /// Everything already buffered (carry + the `BufReader`'s unread
    /// bytes) — rides along to the reactor so no pipelined request bytes
    /// are lost across the handoff.
    fn take_buffered(&mut self) -> Vec<u8> {
        let mut out = std::mem::take(&mut self.carry);
        let buffered = self.reader.buffer().len();
        out.extend_from_slice(self.reader.buffer());
        self.reader.consume(buffered);
        out
    }
}

/// One watched connection owned by the reactor: the job being followed,
/// the nonblocking socket, bytes not yet accepted by the kernel, and the
/// state needed to resume request service afterwards.
struct WatchSub {
    entry: Arc<JobEntry>,
    job_id: String,
    stream: TcpStream,
    /// request bytes buffered before the handoff, replayed on resume
    carry: Vec<u8>,
    handle: Handle,
    permit: Permit,
    seq: u64,
    events_sent: usize,
    /// serialized lines the socket has not accepted yet
    out: Vec<u8>,
    /// terminal line has been queued; flush then resume
    done: bool,
}

enum Pump {
    /// still watching (or still flushing)
    Active,
    /// terminal line fully flushed — resume request service
    Finished,
    /// write error — drop the connection
    Dead,
}

/// The poll-based watch reactor: one event thread pumps every watch
/// subscription — poll the job's coalescing slot, serialize fresh lines,
/// nonblocking-write as much as the socket accepts.
struct Reactor {
    subs: TrackedMutex<Vec<WatchSub>>,
    cv: Condvar,
}

impl Reactor {
    fn spawn() -> Arc<Reactor> {
        let reactor = Arc::new(Reactor {
            subs: TrackedMutex::new("server.watch_subs", rank::WATCH_SUBS, Vec::new()),
            cv: Condvar::new(),
        });
        let r = reactor.clone();
        std::thread::Builder::new()
            .name("diffaxe-watch-reactor".into())
            .spawn(move || r.run())
            .expect("spawning watch reactor");
        reactor
    }

    fn subscribe(&self, sub: WatchSub) {
        self.subs.lock().push(sub);
        self.cv.notify_one();
    }

    fn run(self: Arc<Reactor>) {
        loop {
            {
                let mut subs = self.subs.lock();
                while subs.is_empty() {
                    subs = subs.wait(&self.cv);
                }
                let mut i = 0;
                while i < subs.len() {
                    match Self::pump(&mut subs[i]) {
                        Pump::Active => i += 1,
                        Pump::Finished => resume(self.clone(), subs.remove(i)),
                        Pump::Dead => drop(subs.remove(i)),
                    }
                }
            }
            std::thread::sleep(POLL_INTERVAL);
        }
    }

    /// One poll round for one subscription. Holds the subscription lock
    /// (rank `WATCH_SUBS`) while taking the job core inside `poll_event`
    /// — ranks increase, see `docs/INVARIANTS.md`.
    fn pump(sub: &mut WatchSub) -> Pump {
        if !sub.done {
            let (seq, ev, terminal) = sub.entry.poll_event(sub.seq);
            sub.seq = seq;
            if let Some(event) = ev {
                queue_line(sub, &Response::Event { job_id: sub.job_id.clone(), event });
                sub.events_sent += 1;
            }
            if let Some((_state, result)) = terminal {
                match result {
                    Response::Outcome(outcome) => {
                        if sub.events_sent == 0 {
                            // instant job: synthesize the one guaranteed event
                            let best = outcome.best_score();
                            queue_line(
                                sub,
                                &Response::Event {
                                    job_id: sub.job_id.clone(),
                                    event: SearchEvent {
                                        evals: outcome.evals,
                                        best_score: best,
                                        elapsed_s: outcome.search_time_s,
                                    },
                                },
                            );
                        }
                        let job_id = sub.job_id.clone();
                        queue_line(sub, &Response::JobOutcome { job_id, outcome });
                    }
                    other => queue_line(sub, &other),
                }
                sub.done = true;
            }
        }
        match flush_out(sub) {
            Err(_) => Pump::Dead,
            Ok(()) if sub.done && sub.out.is_empty() => Pump::Finished,
            Ok(()) => Pump::Active,
        }
    }
}

fn queue_line(sub: &mut WatchSub, resp: &Response) {
    sub.out.extend_from_slice(resp.to_json().to_string().as_bytes());
    sub.out.push(b'\n');
}

/// Push queued bytes through the nonblocking socket; `WouldBlock` leaves
/// the remainder for the next poll round.
fn flush_out(sub: &mut WatchSub) -> io::Result<()> {
    while !sub.out.is_empty() {
        match sub.stream.write(&sub.out) {
            Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
            Ok(n) => {
                sub.out.drain(..n);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(()),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// The watched job is terminal and flushed: put the socket back in
/// blocking mode and resume request service on a fresh handler thread,
/// replaying any carried-over request bytes first. The connection permit
/// transfers with the subscription, so the connection cap holds across
/// the reactor round trip.
fn resume(reactor: Arc<Reactor>, sub: WatchSub) {
    std::thread::spawn(move || {
        let WatchSub { stream, carry, handle, permit, .. } = sub;
        if stream.set_nonblocking(false).is_err() {
            return;
        }
        let clone = match stream.try_clone() {
            Ok(s) => s,
            Err(_) => return,
        };
        let reader = ConnReader { carry, reader: BufReader::new(clone) };
        if let Err(e) = serve_conn(&reactor, handle, reader, stream, permit) {
            eprintln!("connection error: {e:#}");
        }
    });
}

/// The shared accept loop: one handler thread per connection, capped at
/// [`MAX_CONNECTIONS`] by the semaphore ([`serve`] and [`serve_ephemeral`]
/// differ only in who owns the listener thread). Watch streaming is
/// offloaded to this listener's single [`Reactor`] thread.
fn accept_loop(listener: TcpListener, handle: Handle) {
    let sem = Semaphore::new(MAX_CONNECTIONS);
    let reactor = Reactor::spawn();
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                eprintln!("accept error: {e}");
                continue;
            }
        };
        // blocks the accept loop when saturated: the flood waits in the
        // kernel backlog instead of becoming threads
        let permit = sem.acquire();
        let h = handle.clone();
        let r = reactor.clone();
        std::thread::spawn(move || {
            let reader = match stream.try_clone() {
                Ok(s) => ConnReader { carry: Vec::new(), reader: BufReader::new(s) },
                Err(e) => {
                    eprintln!("connection error: {e:#}");
                    return;
                }
            };
            if let Err(e) = serve_conn(&r, h, reader, stream, permit) {
                eprintln!("connection error: {e:#}");
            }
        });
    }
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7979").
pub fn serve(handle: Handle, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!("diffaxe: serving on {addr}");
    accept_loop(listener, handle);
    Ok(())
}

/// Bind an ephemeral port, serve on a background thread, return the addr —
/// used by tests and the quickstart example.
pub fn serve_ephemeral(handle: Handle) -> Result<std::net::SocketAddr> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    std::thread::spawn(move || accept_loop(listener, handle));
    Ok(addr)
}

/// Request/response loop for one connection. A `watch` on a live job ends
/// this thread's ownership: the socket (plus permit and buffered bytes)
/// transfers to the reactor, which resumes a fresh handler when the
/// stream completes.
fn serve_conn(
    reactor: &Arc<Reactor>,
    handle: Handle,
    mut reader: ConnReader,
    mut writer: TcpStream,
    permit: Permit,
) -> Result<()> {
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        if line.trim().is_empty() {
            continue;
        }
        // every decode failure — bad JSON, bad request, unsupported
        // version — answers with a structured error on the same
        // connection; the stream is never dropped mid-session
        match Json::parse(&line).map_err(|e| (ErrorCode::BadRequest, format!("bad json: {e}")))
            .and_then(|j| Request::from_json(&j).map_err(|e| (e.code, e.message)))
        {
            Ok(Request::Watch { job_id }) => match handle.registry().get(&job_id) {
                None => {
                    let err =
                        Response::error(ErrorCode::BadRequest, format!("unknown job {job_id:?}"));
                    write_line(&mut writer, &err)?;
                }
                Some(entry) => {
                    let carry = reader.take_buffered();
                    writer.set_nonblocking(true)?;
                    reactor.subscribe(WatchSub {
                        entry,
                        job_id,
                        stream: writer,
                        carry,
                        handle,
                        permit,
                        seq: 0,
                        events_sent: 0,
                        out: Vec::new(),
                        done: false,
                    });
                    return Ok(());
                }
            },
            Ok(req) => write_line(&mut writer, &handle.request(req))?,
            Err((code, message)) => write_line(&mut writer, &Response::error(code, message))?,
        }
    }
}

fn write_line(writer: &mut TcpStream, resp: &Response) -> Result<()> {
    writeln!(writer, "{}", resp.to_json())?;
    writer.flush()?;
    Ok(())
}

/// Minimal blocking client (examples + integration tests + CLI).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Connect to a `host:port` string (CLI convenience).
    pub fn connect_str(addr: &str) -> Result<Client> {
        use std::net::ToSocketAddrs;
        let resolved = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {addr}"))?
            .next()
            .with_context(|| format!("no address for {addr}"))?;
        Client::connect(&resolved)
    }

    pub fn request(&mut self, req: &Request) -> Result<Response> {
        self.send_line(&req.to_json().to_string())
    }

    /// Send one raw wire line (legacy-alias and compatibility testing).
    pub fn send_line(&mut self, line: &str) -> Result<Response> {
        writeln!(self.writer, "{line}")?;
        self.writer.flush()?;
        self.read_response()
    }

    fn read_response(&mut self) -> Result<Response> {
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        let j = Json::parse(&reply).context("parsing response")?;
        Response::from_json(&j)
    }

    /// v3: submit a search job, returning its id.
    pub fn submit(&mut self, sr: &super::protocol::SearchRequest) -> Result<String> {
        match self.request(&Request::Submit(sr.clone()))? {
            Response::Submitted { job_id, .. } => Ok(job_id),
            Response::Error { code, message, .. } => bail!("submit failed: {}: {message}", code.name()),
            other => bail!("unexpected submit response {other:?}"),
        }
    }

    /// v3: one job's status.
    pub fn status(&mut self, job_id: &str) -> Result<JobInfo> {
        match self.request(&Request::Status { job_id: job_id.to_string() })? {
            Response::Job(info) => Ok(info),
            Response::Error { code, message, .. } => bail!("status failed: {}: {message}", code.name()),
            other => bail!("unexpected status response {other:?}"),
        }
    }

    /// v3: cancel a job (the post-cancel status comes back).
    pub fn cancel(&mut self, job_id: &str) -> Result<JobInfo> {
        match self.request(&Request::Cancel { job_id: job_id.to_string() })? {
            Response::Job(info) => Ok(info),
            Response::Error { code, message, .. } => bail!("cancel failed: {}: {message}", code.name()),
            other => bail!("unexpected cancel response {other:?}"),
        }
    }

    /// v3: every retained job.
    pub fn jobs(&mut self) -> Result<Vec<JobInfo>> {
        match self.request(&Request::Jobs)? {
            Response::Jobs(infos) => Ok(infos),
            other => bail!("unexpected jobs response {other:?}"),
        }
    }

    /// v3: stream a job — `on_event` sees every delivered heartbeat; the
    /// terminal line ([`Response::JobOutcome`] or an error) is returned.
    pub fn watch(
        &mut self,
        job_id: &str,
        mut on_event: impl FnMut(&SearchEvent),
    ) -> Result<Response> {
        writeln!(self.writer, "{}", Request::Watch { job_id: job_id.to_string() }.to_json())?;
        self.writer.flush()?;
        loop {
            match self.read_response()? {
                Response::Event { event, .. } => on_event(&event),
                terminal => return Ok(terminal),
            }
        }
    }
}
