//! The DSE service: a dedicated engine thread owning a [`Session`] (the
//! PJRT executables hold raw C pointers and are deliberately never shared),
//! fed by a cloneable handle over an mpsc channel, with every search
//! tracked as a *job* in the [`JobRegistry`].
//!
//! # Jobs
//!
//! Every search — synchronous or not — enters the registry as a job:
//! `submit` answers a `job_id` immediately and the search runs when the
//! engine thread reaches it; the classic synchronous `search`/`batch`
//! requests are submit-plus-wait over the same path, so their wire
//! behaviour is unchanged. Jobs move `queued → running → done |
//! cancelled | failed`; cancellation raises a flag the search polls
//! between evaluation batches (see [`crate::dse::api::SearchCtx`]), so a
//! cancelled job still retains its *partial* outcome. Progress events are
//! published into a single coalescing slot per job (drop-to-latest): a
//! slow watcher never queues unbounded events, it just skips intermediate
//! heartbeats. Terminal jobs are retained for `status` queries up to
//! [`MAX_RETAINED_JOBS`], then garbage-collected oldest-first.
//!
//! # Batching
//!
//! Runtime-generation searches with the `diffaxe` optimizer are
//! **dynamically batched**: the engine thread drains the queue up to the
//! sampler's fixed batch width (slots can mix workloads — the sampler
//! conditions per batch element) before issuing one diffusion call, then
//! splits, batch-evaluates, and replies per request. This is the
//! vLLM-router-style continuous batching adapted to design generation: the
//! expensive fixed-batch executable always runs as full as the queue
//! allows. Every other `(objective, optimizer)` pair — and whole `Batch`
//! requests — run directly on the session between sampler flushes.
//!
//! Candidate evaluation goes through the session's memoized, pooled hot
//! path ([`crate::dse::eval`]): recurring rounded design points across
//! requests are served from the sharded eval cache, whose hit/miss counters
//! are mirrored into [`Metrics`] after every evaluation burst.

use super::metrics::Metrics;
use super::protocol::{ErrorCode, JobInfo, JobState, Request, Response, SearchRequest};
use crate::dse::api::{
    DesignReport, Objective, OptimizerKind, SearchCtx, SearchEvent, SearchOutcome, Session,
    StopReason,
};
use crate::design_space::HwConfig;
use crate::util::rng;
use crate::util::sync::{rank, TrackedMutex};
use crate::workload::Gemm;
use anyhow::Result;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar};
use std::time::{Duration, Instant};

/// Default cap on ranked designs carried in one response (requests can
/// override with `top_k`).
pub const DEFAULT_TOP_K: usize = 64;

/// Terminal jobs retained for `status`/`jobs` queries before GC.
pub const MAX_RETAINED_JOBS: usize = 256;

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub artifacts_dir: std::path::PathBuf,
    /// how long the batcher waits to fill a sampler batch
    pub batch_window: Duration,
    /// root seed; per-sampler-call and per-search seeds derive from it via
    /// [`rng::derive`]
    pub seed: u64,
    /// serve the hermetic mock engine instead of compiling artifacts
    /// ([`crate::models::DiffAxE::mock`]) — CI and artifact-free hosts
    pub use_mock_engine: bool,
}

impl ServiceConfig {
    pub fn new(artifacts_dir: impl Into<std::path::PathBuf>) -> Self {
        ServiceConfig {
            artifacts_dir: artifacts_dir.into(),
            batch_window: Duration::from_millis(4),
            seed: 1,
            use_mock_engine: false,
        }
    }

    /// A config serving the artifact-free mock engine (engine-kind wire
    /// paths run hermetically; results are deterministic in `seed`).
    pub fn mock() -> Self {
        ServiceConfig { use_mock_engine: true, ..ServiceConfig::new("") }
    }
}

// ---------------------------------------------------------------------------
// job registry
// ---------------------------------------------------------------------------

/// Mutable core of one job, guarded by its entry's mutex; the condvar
/// wakes watchers (new event) and waiters (terminal result).
struct JobCore {
    state: JobState,
    /// bumps on every observable change (event published, state change,
    /// terminal result) — watchers resume from the last seq they saw
    seq: u64,
    /// the coalescing progress slot: (seq at publish, event). A newer
    /// event *replaces* the buffered one (drop-to-latest backpressure).
    latest: Option<(u64, SearchEvent)>,
    /// terminal response (outcome or error); `Some` ⇔ state is terminal
    result: Option<Response>,
    /// wall-clock from submission to the terminal transition
    elapsed_s: Option<f64>,
}

/// One tracked search job.
pub struct JobEntry {
    num: u64,
    pub id: String,
    pub request: SearchRequest,
    cancel: Arc<AtomicBool>,
    submitted: Instant,
    core: TrackedMutex<JobCore>,
    cv: Condvar,
}

impl JobEntry {
    /// The shared cancellation flag the running search polls.
    pub fn cancel_flag(&self) -> Arc<AtomicBool> {
        self.cancel.clone()
    }

    /// Current lifecycle state.
    pub fn state(&self) -> JobState {
        self.core.lock().state
    }

    /// Point-in-time description (the `status` wire unit).
    pub fn info(&self) -> JobInfo {
        let core = self.core.lock();
        let (evals, best_score) = match (&core.result, &core.latest) {
            (Some(Response::Outcome(o)), _) => {
                let best = o.best_score();
                (o.evals, if best.is_finite() { Some(best) } else { None })
            }
            (_, Some((_, ev))) => {
                (ev.evals, if ev.best_score.is_finite() { Some(ev.best_score) } else { None })
            }
            _ => (0, None),
        };
        JobInfo {
            id: self.id.clone(),
            state: core.state,
            optimizer: self.request.optimizer.name().to_string(),
            objective: self.request.objective.to_string(),
            evals,
            best_score,
            elapsed_s: core
                .elapsed_s
                .unwrap_or_else(|| self.submitted.elapsed().as_secs_f64()),
        }
    }

    /// The terminal response if the job already finished (internal error
    /// placeholder otherwise — callers only use this on terminal jobs).
    pub fn result_now(&self) -> Response {
        self.core
            .lock()
            .result
            .clone()
            .unwrap_or_else(|| Response::error(ErrorCode::Internal, "job not finished"))
    }

    /// Block until something newer than `last_seq` is observable. Returns
    /// `(new_seq, fresh_event, terminal)` where `fresh_event` is the
    /// coalesced latest event iff it was published after `last_seq`, and
    /// `terminal` carries the final state + response once the job ends.
    pub fn next_event(
        &self,
        last_seq: u64,
    ) -> (u64, Option<SearchEvent>, Option<(JobState, Response)>) {
        let mut core = self.core.lock();
        while core.seq <= last_seq && core.result.is_none() {
            core = core.wait(&self.cv);
        }
        let ev = core.latest.as_ref().filter(|(s, _)| *s > last_seq).map(|(_, e)| *e);
        let terminal = core.result.clone().map(|r| (core.state, r));
        (core.seq, ev, terminal)
    }
}

struct RegistryInner {
    next_id: u64,
    jobs: BTreeMap<u64, Arc<JobEntry>>,
    /// terminal job numbers in completion order (GC queue)
    terminal: VecDeque<u64>,
}

/// Tracks every search job the service has accepted: id allocation,
/// lifecycle transitions (mirrored into [`Metrics`] gauges), progress
/// publication, and bounded retention of finished jobs.
///
/// Lock order: `inner` may take an entry's `core`; an entry's `core` is
/// never held while taking `inner`. The ranks ([`rank::REGISTRY`] <
/// [`rank::JOB_CORE`]) make debug builds assert exactly that — see the
/// lock-rank table in `docs/INVARIANTS.md`.
pub struct JobRegistry {
    inner: TrackedMutex<RegistryInner>,
    metrics: Arc<Metrics>,
}

impl JobRegistry {
    pub fn new(metrics: Arc<Metrics>) -> JobRegistry {
        JobRegistry {
            inner: TrackedMutex::new(
                "registry.inner",
                rank::REGISTRY,
                RegistryInner { next_id: 0, jobs: BTreeMap::new(), terminal: VecDeque::new() },
            ),
            metrics,
        }
    }

    /// Accept a search as a new queued job.
    pub fn submit(&self, request: SearchRequest) -> Arc<JobEntry> {
        let entry = {
            let mut inner = self.inner.lock();
            inner.next_id += 1;
            let num = inner.next_id;
            let entry = Arc::new(JobEntry {
                num,
                id: format!("job-{num}"),
                request,
                cancel: Arc::new(AtomicBool::new(false)),
                submitted: Instant::now(),
                core: TrackedMutex::new(
                    "job.core",
                    rank::JOB_CORE,
                    JobCore {
                        state: JobState::Queued,
                        seq: 0,
                        latest: None,
                        result: None,
                        elapsed_s: None,
                    },
                ),
                cv: Condvar::new(),
            });
            inner.jobs.insert(num, entry.clone());
            Self::gc(&mut inner);
            entry
        };
        self.metrics.job_submitted();
        entry
    }

    /// Look a job up by its wire id.
    pub fn get(&self, id: &str) -> Option<Arc<JobEntry>> {
        self.inner.lock().jobs.values().find(|e| e.id == id).cloned()
    }

    /// Every retained job, oldest first.
    pub fn list(&self) -> Vec<JobInfo> {
        self.inner.lock().jobs.values().map(|e| e.info()).collect()
    }

    /// Transition a queued job to running. False if the job was cancelled
    /// (or otherwise finished) before the engine reached it.
    pub fn start(&self, entry: &JobEntry) -> bool {
        {
            let mut core = entry.core.lock();
            if core.state != JobState::Queued || core.result.is_some() {
                return false;
            }
            core.state = JobState::Running;
            core.seq += 1;
            entry.cv.notify_all();
        }
        self.metrics.job_started();
        true
    }

    /// Publish a progress event into the job's coalescing slot
    /// (drop-to-latest: a buffered event is *replaced*, never queued).
    pub fn publish(&self, entry: &JobEntry, ev: SearchEvent) {
        let was_empty = {
            let mut core = entry.core.lock();
            if core.result.is_some() {
                return;
            }
            let was_empty = core.latest.is_none();
            core.seq += 1;
            core.latest = Some((core.seq, ev));
            entry.cv.notify_all();
            was_empty
        };
        if was_empty {
            self.metrics.event_buffered();
        }
    }

    /// Record a job's terminal state + response. Idempotent: the first
    /// finalization wins (a cancel racing a completion keeps the earlier
    /// result).
    pub fn finalize(&self, entry: &Arc<JobEntry>, state: JobState, result: Response) {
        debug_assert!(state.terminal());
        let (was_running, had_event);
        {
            let mut core = entry.core.lock();
            if core.result.is_some() {
                return;
            }
            was_running = core.state == JobState::Running;
            had_event = core.latest.is_some();
            core.state = state;
            core.result = Some(result);
            core.elapsed_s = Some(entry.submitted.elapsed().as_secs_f64());
            core.seq += 1;
            entry.cv.notify_all();
        }
        self.metrics.job_finished(state, was_running, had_event);
        let mut inner = self.inner.lock();
        inner.terminal.push_back(entry.num);
        Self::gc(&mut inner);
    }

    /// Raise a job's cancellation flag. A still-queued job becomes
    /// terminal immediately (it never ran, so its outcome is empty); a
    /// running job stops at its next batch boundary and retains the
    /// partial outcome. Returns the post-cancel [`JobInfo`].
    pub fn cancel(&self, id: &str) -> Option<JobInfo> {
        let entry = self.get(id)?;
        entry.cancel.store(true, Ordering::SeqCst);
        let became_terminal = {
            let mut core = entry.core.lock();
            if core.state == JobState::Queued && core.result.is_none() {
                let outcome = SearchOutcome {
                    search_time_s: entry.submitted.elapsed().as_secs_f64(),
                    ..SearchOutcome::empty(
                        entry.request.optimizer.name(),
                        StopReason::Cancelled,
                    )
                };
                core.state = JobState::Cancelled;
                core.result = Some(Response::Outcome(outcome));
                core.elapsed_s = Some(entry.submitted.elapsed().as_secs_f64());
                core.seq += 1;
                entry.cv.notify_all();
                true
            } else {
                false
            }
        };
        if became_terminal {
            self.metrics.job_finished(JobState::Cancelled, false, false);
            let mut inner = self.inner.lock();
            inner.terminal.push_back(entry.num);
            Self::gc(&mut inner);
        }
        Some(entry.info())
    }

    fn gc(inner: &mut RegistryInner) {
        while inner.terminal.len() > MAX_RETAINED_JOBS {
            if let Some(num) = inner.terminal.pop_front() {
                inner.jobs.remove(&num);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// handle + service
// ---------------------------------------------------------------------------

/// One unit of engine-thread work: run a registered job, optionally
/// delivering the terminal response to a synchronous waiter.
enum Msg {
    Run { entry: Arc<JobEntry>, reply: Option<Sender<Response>> },
}

/// Cloneable handle to the service. Registry queries (`status`, `cancel`,
/// `jobs`, `metrics`) answer directly — they never queue behind a running
/// search on the engine thread.
#[derive(Clone)]
pub struct Handle {
    tx: Sender<Msg>,
    metrics: Arc<Metrics>,
    registry: Arc<JobRegistry>,
}

impl Handle {
    /// Submit a request and block for the response. Synchronous `search`
    /// and `batch` are submit-plus-wait over the job registry.
    pub fn request(&self, request: Request) -> Response {
        let start = Instant::now();
        match request {
            Request::Metrics => {
                let r = Response::MetricsText(self.metrics.snapshot().to_string());
                self.metrics.record_request(start.elapsed().as_secs_f64() * 1e6, 0);
                r
            }
            Request::Jobs => Response::Jobs(self.registry.list()),
            // a watch reaching the blocking path degrades to a status
            // probe; the streaming server intercepts it before this point
            Request::Status { job_id } | Request::Watch { job_id } => {
                match self.registry.get(&job_id) {
                    Some(e) => Response::Job(e.info()),
                    None => unknown_job(&job_id),
                }
            }
            Request::Cancel { job_id } => match self.registry.cancel(&job_id) {
                Some(info) => Response::Job(info),
                None => unknown_job(&job_id),
            },
            Request::Submit(sr) => {
                if let Err(msg) = validate(&sr) {
                    return Response::error(ErrorCode::BadRequest, msg);
                }
                let entry = self.enqueue(sr, None);
                Response::Submitted { job_id: entry.id.clone(), state: entry.state() }
            }
            Request::Search(sr) => {
                if let Err(msg) = validate(&sr) {
                    return Response::error(ErrorCode::BadRequest, msg);
                }
                let (tx, rx) = channel();
                self.enqueue(sr, Some(tx));
                rx.recv()
                    .unwrap_or_else(|_| Response::error(ErrorCode::Internal, "service stopped"))
            }
            Request::Batch(items) => {
                // validate the whole batch before running any item, so a bad
                // pairing cannot discard minutes of completed sibling searches
                for (i, sr) in items.iter().enumerate() {
                    if let Err(msg) = validate(sr) {
                        return Response::error(
                            ErrorCode::BadRequest,
                            format!("batch item {i}: {msg}"),
                        );
                    }
                }
                let rxs: Vec<Receiver<Response>> = items
                    .iter()
                    .map(|sr| {
                        let (tx, rx) = channel();
                        self.enqueue(sr.clone(), Some(tx));
                        rx
                    })
                    .collect();
                let mut outs = Vec::with_capacity(items.len());
                let mut first_err: Option<Response> = None;
                for (i, (sr, rx)) in items.iter().zip(rxs).enumerate() {
                    let resp = rx.recv().unwrap_or_else(|_| {
                        Response::error(ErrorCode::Internal, "service stopped")
                    });
                    match resp {
                        Response::Outcome(o) => outs.push(o),
                        Response::Error { code, message } if first_err.is_none() => {
                            // all-or-nothing by protocol contract (see the
                            // `batch` docs in protocol.rs)
                            first_err = Some(Response::error(
                                code,
                                format!("batch item {i} ({}): {message}", sr.optimizer.name()),
                            ));
                        }
                        _ => {}
                    }
                }
                first_err.unwrap_or(Response::Batch(outs))
            }
        }
    }

    /// Submit without waiting; the receiver yields the response.
    pub fn submit(&self, request: Request) -> Receiver<Response> {
        match request {
            Request::Search(sr) => {
                let (tx, rx) = channel();
                if let Err(msg) = validate(&sr) {
                    let _ = tx.send(Response::error(ErrorCode::BadRequest, msg));
                } else {
                    self.enqueue(sr, Some(tx));
                }
                rx
            }
            other => {
                let (tx, rx) = channel();
                let _ = tx.send(self.request(other));
                rx
            }
        }
    }

    /// Register a job and hand it to the engine thread.
    fn enqueue(&self, sr: SearchRequest, reply: Option<Sender<Response>>) -> Arc<JobEntry> {
        let entry = self.registry.submit(sr);
        if self.tx.send(Msg::Run { entry: entry.clone(), reply }).is_err() {
            self.registry.finalize(
                &entry,
                JobState::Failed,
                Response::error(ErrorCode::Internal, "service stopped"),
            );
        }
        entry
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    pub fn registry(&self) -> Arc<JobRegistry> {
        self.registry.clone()
    }
}

fn unknown_job(job_id: &str) -> Response {
    Response::error(ErrorCode::BadRequest, format!("unknown job {job_id:?}"))
}

/// Running service (engine thread + handle).
pub struct Service {
    pub handle: Handle,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Service {
    /// Start the engine thread. Blocks until the artifacts are compiled (or
    /// fail to), so a returned `Service` is ready to serve.
    pub fn start(cfg: ServiceConfig) -> Result<Service> {
        let (tx, rx) = channel::<Msg>();
        let metrics = Arc::new(Metrics::new());
        let registry = Arc::new(JobRegistry::new(metrics.clone()));
        let stop = Arc::new(AtomicBool::new(false));
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let thread = {
            let metrics = metrics.clone();
            let registry = registry.clone();
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("diffaxe-engine".into())
                .spawn(move || {
                    // the session must be constructed on this thread: PJRT
                    // handles are !Send (the mock backend rides the same
                    // engine type, so it follows the same rule)
                    let session = if cfg.use_mock_engine {
                        Ok(Session::mock())
                    } else {
                        Session::load(&cfg.artifacts_dir)
                    };
                    let session = match session {
                        Ok(s) => {
                            let _ = ready_tx.send(Ok(()));
                            s
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    };
                    engine_loop(session, cfg, rx, registry, metrics, stop);
                })?
        };
        ready_rx.recv()??;
        Ok(Service { handle: Handle { tx, metrics, registry }, stop, thread: Some(thread) })
    }

    pub fn handle(&self) -> Handle {
        self.handle.clone()
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the engine thread's recv by dropping our sender clone…
        let (tx, _) = channel();
        let old = std::mem::replace(&mut self.handle.tx, tx);
        drop(old);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

// ---------------------------------------------------------------------------
// engine loop
// ---------------------------------------------------------------------------

/// A runtime-generation search waiting in the batcher. `acc` collects
/// designs across sampler calls when the request spans batches.
struct PendingGen {
    g: Gemm,
    p_norm: f32,
    n: usize,
    top_k: usize,
    objective: Objective,
    acc: Vec<DesignReport>,
    /// running best score over `acc` (heartbeats stay O(1) per burst)
    best: f64,
    entry: Arc<JobEntry>,
    /// when the request joined `pending` — the batch-window clock. Queue
    /// wait behind non-batchable jobs must not count against the window,
    /// or a request that sat queued "expires" on arrival and flushes a
    /// batch of one (`entry.submitted` keeps measuring end-to-end
    /// latency).
    joined: Instant,
    reply: Option<Sender<Response>>,
}

/// Whether a search joins the continuous diffusion batcher (wall-clock-
/// capped requests run the direct path, which enforces the deadline).
fn batchable(sr: &SearchRequest) -> bool {
    sr.optimizer == OptimizerKind::DiffAxE
        && matches!(sr.objective, Objective::Runtime { .. })
        && sr.budget.wall_clock_s.is_none()
}

fn engine_loop(
    mut session: Session,
    cfg: ServiceConfig,
    rx: Receiver<Msg>,
    registry: Arc<JobRegistry>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) {
    let gen_batch = session.engine().expect("service session has an engine").stats.gen_batch;
    let mut stream = 0u64;
    let mut pending: Vec<PendingGen> = Vec::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        // wait for work (or flush deadline if a batch is forming)
        let msg = if pending.is_empty() {
            match rx.recv_timeout(Duration::from_millis(200)) {
                Ok(m) => Some(m),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => return,
            }
        } else {
            match rx.recv_timeout(cfg.batch_window) {
                Ok(m) => Some(m),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => {
                    flush_gen_batch(&session, &registry, &mut pending, cfg.seed, &mut stream, &metrics);
                    return;
                }
            }
        };

        if let Some(Msg::Run { entry, reply }) = msg {
            if batchable(&entry.request) {
                // runtime-conditioned diffusion joins the continuous batcher
                if registry.start(&entry) {
                    let Objective::Runtime { g, target_cycles } = entry.request.objective else {
                        unreachable!("batchable() matched Runtime")
                    };
                    let engine = session.engine().expect("engine");
                    let p = PendingGen {
                        g,
                        p_norm: engine.stats.stats_for(&g).norm_runtime(target_cycles),
                        n: entry.request.budget.evals,
                        top_k: entry.request.top_k.unwrap_or(DEFAULT_TOP_K),
                        objective: entry.request.objective,
                        acc: Vec::new(),
                        best: f64::INFINITY,
                        entry: entry.clone(),
                        joined: Instant::now(),
                        reply,
                    };
                    if p.n == 0 {
                        // `Budget::evals(0)` answers immediately with the
                        // empty budget-exhausted outcome — the same
                        // contract every direct-path strategy honors
                        // (`dse::api::drained`) — instead of a forced
                        // minimum generation
                        finish_pending(&registry, &metrics, p, StopReason::BudgetExhausted);
                    } else {
                        pending.push(p);
                    }
                } else if let Some(reply) = reply {
                    // cancelled while queued: deliver the stored result
                    let _ = reply.send(entry.result_now());
                }
            } else {
                // non-batchable jobs flush the batch first (ordering)
                flush_gen_batch(&session, &registry, &mut pending, cfg.seed, &mut stream, &metrics);
                if registry.start(&entry) {
                    run_job(&mut session, &registry, &entry, reply, cfg.seed, &mut stream, &metrics);
                } else if let Some(reply) = reply {
                    let _ = reply.send(entry.result_now());
                }
            }
        }

        // flush when full or when the window expired with waiters (the
        // window clock starts when a request joins `pending`, not at
        // submission — queue wait behind non-batchable jobs must not
        // expire the window)
        let slots: usize = pending.iter().map(|p| p.n.saturating_sub(p.acc.len())).sum();
        let window_expired = pending
            .iter()
            .map(|p| p.joined.elapsed())
            .max()
            .map(|d| d >= cfg.batch_window)
            .unwrap_or(false);
        if slots >= gen_batch || (window_expired && !pending.is_empty()) {
            flush_gen_batch(&session, &registry, &mut pending, cfg.seed, &mut stream, &metrics);
        }
    }
}

/// Execute one non-batchable job directly on the session, under a ctx
/// carrying the job's cancellation flag and a progress sink into the
/// registry's coalescing event slot.
fn run_job(
    session: &mut Session,
    registry: &Arc<JobRegistry>,
    entry: &Arc<JobEntry>,
    reply: Option<Sender<Response>>,
    seed: u64,
    stream: &mut u64,
    metrics: &Arc<Metrics>,
) {
    *stream += 1;
    let sr = &entry.request;
    let ctx = {
        let registry = registry.clone();
        let sink_entry = entry.clone();
        SearchCtx::background()
            .with_cancel_flag(entry.cancel_flag())
            .with_progress(move |ev: &SearchEvent| registry.publish(&sink_entry, *ev))
    };
    let resp = match session.search_ctx(
        sr.optimizer,
        &ctx,
        &sr.objective,
        &sr.budget,
        rng::derive(seed, *stream),
    ) {
        Ok(out) => {
            metrics.record_evaluations(out.evals);
            let cs = session.cache_stats();
            metrics.record_cache(cs.hits, cs.misses);
            Response::Outcome(out.truncated(sr.top_k.unwrap_or(DEFAULT_TOP_K)))
        }
        Err(e) => {
            metrics.record_error();
            Response::error(ErrorCode::Internal, format!("{e:#}"))
        }
    };
    let state = match &resp {
        Response::Outcome(o) if o.stopped == StopReason::Cancelled => JobState::Cancelled,
        Response::Outcome(_) => JobState::Done,
        _ => JobState::Failed,
    };
    let designs = match &resp {
        Response::Outcome(o) => o.ranked.len(),
        _ => 0,
    };
    metrics.record_request(entry.submitted.elapsed().as_secs_f64() * 1e6, designs);
    registry.finalize(entry, state, resp.clone());
    if let Some(reply) = reply {
        let _ = reply.send(resp);
    }
}

/// Retire one batcher request with whatever it accumulated.
fn finish_pending(
    registry: &Arc<JobRegistry>,
    metrics: &Arc<Metrics>,
    p: PendingGen,
    stopped: StopReason,
) {
    let latency_s = p.entry.submitted.elapsed().as_secs_f64();
    metrics.record_request(latency_s * 1e6, p.acc.len());
    let outcome = SearchOutcome::from_reports("DiffAxE", &p.objective, p.acc, latency_s)
        .with_stopped(stopped)
        .truncated(p.top_k);
    let state =
        if stopped == StopReason::Cancelled { JobState::Cancelled } else { JobState::Done };
    let resp = Response::Outcome(outcome);
    registry.finalize(&p.entry, state, resp.clone());
    if let Some(reply) = p.reply {
        let _ = reply.send(resp);
    }
}

/// Pack pending generation requests into sampler batches, batch-evaluate
/// the designs, publish per-request progress, and retire each request with
/// a ranked outcome — early (partial) if its cancellation flag is up.
fn flush_gen_batch(
    session: &Session,
    registry: &Arc<JobRegistry>,
    pending: &mut Vec<PendingGen>,
    seed: u64,
    stream: &mut u64,
    metrics: &Arc<Metrics>,
) {
    let Some(engine) = session.engine() else { return };
    while !pending.is_empty() {
        // cancelled batcher jobs retire immediately with their partial acc
        for idx in (0..pending.len()).rev() {
            if pending[idx].entry.cancel.load(Ordering::SeqCst) {
                let p = pending.remove(idx);
                finish_pending(registry, metrics, p, StopReason::Cancelled);
            }
        }
        if pending.is_empty() {
            return;
        }
        let b = engine.stats.gen_batch;
        // take whole requests while they fit; split oversized ones
        let mut slots: Vec<(f32, [f32; 3])> = Vec::with_capacity(b);
        let mut owners: Vec<usize> = Vec::with_capacity(b); // slot -> pending idx
        for (i, p) in pending.iter().enumerate() {
            let take = p.n.saturating_sub(p.acc.len()).min(b - slots.len());
            for _ in 0..take {
                slots.push((p.p_norm, p.g.norm_vec()));
                owners.push(i);
            }
            if slots.len() == b {
                break;
            }
        }
        *stream += 1;
        let t = Instant::now();
        let result = engine.sample_runtime(rng::derive_u32(seed, *stream), &slots);
        metrics.record_sampler_call(t.elapsed().as_secs_f64() * 1e6, slots.len(), b);
        match result {
            Ok(configs) => {
                // group the new designs per owning request so each group
                // runs through the vectorized evaluation hot path
                let mut per_owner: Vec<Vec<HwConfig>> = vec![Vec::new(); pending.len()];
                for (slot, hw) in configs.into_iter().enumerate() {
                    per_owner[owners[slot]].push(hw);
                }
                let mut evaluated = 0;
                for (idx, cfgs) in per_owner.iter().enumerate() {
                    if cfgs.is_empty() {
                        continue;
                    }
                    let g = pending[idx].g;
                    // memoized + pooled hot path: recurring rounded designs
                    // across requests become cache hits
                    for (hw, (s, e)) in cfgs.iter().zip(session.evaluate_batch(cfgs, &g)) {
                        let d = DesignReport::from_sim(*hw, &s, &e);
                        let score = pending[idx].objective.score_report(&d);
                        pending[idx].best = pending[idx].best.min(score);
                        pending[idx].acc.push(d);
                    }
                    evaluated += cfgs.len();
                    // heartbeat into the job's coalescing event slot
                    let p = &pending[idx];
                    registry.publish(
                        &p.entry,
                        SearchEvent {
                            evals: p.acc.len(),
                            best_score: p.best,
                            elapsed_s: p.entry.submitted.elapsed().as_secs_f64(),
                        },
                    );
                }
                metrics.record_evaluations(evaluated);
                let cs = session.cache_stats();
                metrics.record_cache(cs.hits, cs.misses);
                // retire fully-served requests (from the end, keep indices valid)
                for idx in (0..pending.len()).rev() {
                    if pending[idx].acc.len() >= pending[idx].n {
                        let p = pending.remove(idx);
                        finish_pending(registry, metrics, p, StopReason::Completed);
                    }
                }
            }
            Err(e) => {
                metrics.record_error();
                for p in pending.drain(..) {
                    let resp = Response::error(
                        ErrorCode::Internal,
                        format!("sampler failed: {e:#}"),
                    );
                    registry.finalize(&p.entry, JobState::Failed, resp.clone());
                    if let Some(reply) = p.reply {
                        let _ = reply.send(resp);
                    }
                }
            }
        }
    }
}

/// Reject detectably-invalid (objective, optimizer) pairings up front —
/// a client error, reported before any budget is spent.
fn validate(sr: &SearchRequest) -> Result<(), String> {
    if sr.optimizer.supports(&sr.objective) {
        Ok(())
    } else {
        Err(format!("optimizer {:?} does not serve this objective", sr.optimizer.name()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::api::Budget;

    fn request() -> SearchRequest {
        SearchRequest::new(
            Objective::MinEdp { g: Gemm::new(8, 8, 8) },
            Budget::evals(4),
            OptimizerKind::RandomSearch,
        )
    }

    fn done_outcome(evals: usize) -> Response {
        Response::Outcome(SearchOutcome {
            evals,
            ..SearchOutcome::empty("random", StopReason::Completed)
        })
    }

    #[test]
    fn registry_lifecycle_and_gauges() {
        let metrics = Arc::new(Metrics::new());
        let reg = JobRegistry::new(metrics.clone());
        let e = reg.submit(request());
        assert_eq!(e.id, "job-1");
        assert_eq!(e.state(), JobState::Queued);
        assert_eq!(metrics.snapshot().jobs_queued, 1);

        assert!(reg.start(&e));
        assert!(!reg.start(&e), "double start must be rejected");
        assert_eq!(e.state(), JobState::Running);
        reg.publish(&e, SearchEvent { evals: 2, best_score: 1.0, elapsed_s: 0.0 });
        let s = metrics.snapshot();
        assert_eq!((s.jobs_active, s.event_queue_depth), (1, 1));

        reg.finalize(&e, JobState::Done, done_outcome(4));
        // idempotent: a late cancel cannot overwrite the result
        reg.finalize(&e, JobState::Cancelled, done_outcome(0));
        assert_eq!(e.state(), JobState::Done);
        let info = reg.get("job-1").unwrap().info();
        assert_eq!(info.state, JobState::Done);
        assert_eq!(info.evals, 4);
        let s = metrics.snapshot();
        assert_eq!((s.jobs_active, s.event_queue_depth), (0, 0));
        assert_eq!((s.jobs_completed, s.jobs_cancelled), (1, 0));
    }

    #[test]
    fn queued_cancel_is_immediately_terminal() {
        let metrics = Arc::new(Metrics::new());
        let reg = JobRegistry::new(metrics.clone());
        let e = reg.submit(request());
        let info = reg.cancel(&e.id).unwrap();
        assert_eq!(info.state, JobState::Cancelled);
        assert_eq!(info.evals, 0);
        // the engine later refuses to start it
        assert!(!reg.start(&e));
        match e.result_now() {
            Response::Outcome(o) => {
                assert_eq!(o.stopped, StopReason::Cancelled);
                assert!(o.ranked.is_empty());
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(metrics.snapshot().jobs_cancelled, 1);
        assert!(reg.cancel("job-99").is_none());
    }

    #[test]
    fn watcher_sees_coalesced_events_then_terminal() {
        let metrics = Arc::new(Metrics::new());
        let reg = JobRegistry::new(metrics);
        let e = reg.submit(request());
        reg.start(&e);
        // two events land before the watcher polls: drop-to-latest keeps
        // only the newer one
        reg.publish(&e, SearchEvent { evals: 1, best_score: 5.0, elapsed_s: 0.1 });
        reg.publish(&e, SearchEvent { evals: 2, best_score: 3.0, elapsed_s: 0.2 });
        let (seq, ev, terminal) = e.next_event(0);
        assert_eq!(ev.unwrap().evals, 2);
        assert!(terminal.is_none());
        reg.finalize(&e, JobState::Done, done_outcome(2));
        let (_seq, ev, terminal) = e.next_event(seq);
        assert!(ev.is_none(), "stale event must not repeat");
        let (state, resp) = terminal.unwrap();
        assert_eq!(state, JobState::Done);
        assert!(matches!(resp, Response::Outcome(_)));
    }

    #[test]
    fn gc_bounds_terminal_retention() {
        let metrics = Arc::new(Metrics::new());
        let reg = JobRegistry::new(metrics);
        for _ in 0..(MAX_RETAINED_JOBS + 10) {
            let e = reg.submit(request());
            reg.start(&e);
            reg.finalize(&e, JobState::Done, done_outcome(1));
        }
        let jobs = reg.list();
        assert!(jobs.len() <= MAX_RETAINED_JOBS + 1, "retained {}", jobs.len());
        // the oldest jobs were collected, the newest survive
        assert!(reg.get("job-1").is_none());
        assert!(reg.get(&format!("job-{}", MAX_RETAINED_JOBS + 10)).is_some());
    }
}
